//! Offline stand-in for the PJRT `xla` bindings.
//!
//! The dvfo runtime (`rust/src/runtime`) loads AOT HLO-text artifacts and
//! executes them through PJRT. The real bindings need a compiled XLA
//! toolchain which is not available in the offline build environment, so
//! this in-tree stub provides the same API surface:
//!
//! * `Literal` construction/reshape/readback work for real (they are pure
//!   host-side data plumbing, and the runtime unit tests exercise them).
//! * Everything that would touch a PJRT device (`PjRtClient::cpu`,
//!   `compile`, `execute`) returns a descriptive error, so the engine
//!   fails loudly at load time instead of pretending to run artifacts.
//!
//! Swapping in the real bindings is a one-line change in
//! `rust/Cargo.toml` (replace the path dependency); no runtime source
//! changes are needed.

use std::fmt;

/// Error type matching the `?`/`with_context` usage in the runtime.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: PJRT is unavailable in this offline build (xla stub crate); \
             link the real xla bindings to execute artifacts"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// A host-side literal: flat f32 data plus dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    /// Reshape without copying semantics (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape to {dims:?} incompatible with {} elements",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Read the literal back as a flat vector (f32 only in the stub).
    pub fn to_vec<T: Clone + 'static>(&self) -> Result<Vec<T>, Error> {
        let any: &dyn std::any::Any = &self.data;
        any.downcast_ref::<Vec<T>>()
            .cloned()
            .ok_or_else(|| Error::unavailable("Literal::to_vec (non-f32 element type)"))
    }

    /// Unpack a tuple literal — only produced by device execution, which
    /// the stub cannot perform.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module handle (text is validated to exist, not parsed).
pub struct HloModuleProto {
    _path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        match std::fs::metadata(path) {
            Ok(_) => Ok(HloModuleProto {
                _path: path.to_string(),
            }),
            Err(e) => Err(Error(format!("reading HLO text {path}: {e}"))),
        }
    }
}

/// Computation handle built from an HLO proto.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client — creation fails in the stub so callers error at load
/// time rather than at first execution.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_works_host_side() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn device_paths_error_loudly() {
        assert!(PjRtClient::cpu().is_err());
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("PJRT is unavailable"));
    }
}
