//! detlint — a determinism-contract analyzer for the dvfo workspace.
//!
//! The engine's golden/parity/sweep gates only stay byte-identical if no
//! decision path ever consults an unordered container, ambient
//! wall-clock, or NaN-unsafe float comparator. `detlint` enforces that
//! contract lexically, with zero dependencies (the offline crate policy
//! rules out `syn`), so it runs as a plain workspace binary:
//!
//! ```text
//! cargo run --release -p detlint -- rust/src
//! ```
//!
//! Rules:
//!
//! - **R1** — float orderings must be total: no `.partial_cmp(..)`
//!   chased by `.unwrap()`, no `.sort_by(..)` over `partial_cmp`; use
//!   `total_cmp`. Applies everywhere, *including* `#[cfg(test)]` code —
//!   a NaN panic inside a gate test is still a flake.
//! - **R2** — no `HashMap`/`HashSet` under `coordinator/`, `telemetry/`,
//!   `dqn/`, or `util/` (iteration order feeds decisions and telemetry);
//!   use `BTreeMap`/`BTreeSet` or dense `Vec` indexing. Also applies in
//!   tests: a test that iterates a `HashMap` asserts on lucky ordering.
//! - **R3** — no `Instant::now` / `SystemTime` / `thread_rng` /
//!   `rand::random` in simulation code; thread virtual time and seeded
//!   PRNGs through the engine instead. Harness entry points
//!   (`bench_harness.rs`, `main.rs`, `cli.rs`) are exempt by file name,
//!   and the walker skips `benches/` and `examples/` trees.
//! - **R4** — float `.sum()` / `.fold(..)` reductions in `coordinator/`
//!   and `dqn/` need an inline waiver pinning the accumulation order
//!   (float addition is non-associative; a reordered reduction silently
//!   shifts every downstream decision).
//! - **R5** — `BinaryHeap` (unstable ordering among equal keys) only
//!   inside `coordinator/sched.rs`, which wraps it with a deterministic
//!   sequence-number tie-break.
//!
//! Waivers are plain `//` line comments (doc comments do not count) that
//! *must* carry a reason:
//!
//! ```text
//! // detlint: allow(R4, summed in fixed index order; replay-gated)
//! // detlint: allow-file(R3, times a real PJRT pipeline, not sim time)
//! ```
//!
//! An inline waiver covers its own line; a standalone waiver comment
//! covers the next code line; `allow-file` covers the whole file. A
//! waiver that suppresses nothing, or a comment starting with `detlint:`
//! that does not parse, is itself a finding — waivers cannot rot
//! silently.
//!
//! The analysis is lexical: a comment/string-aware masking pass, brace
//! matching for `#[cfg(test)]` regions, then per-line pattern rules with
//! short (3-line) windows for multi-line chains. That keeps the linter
//! dependency-free at the cost of heuristics; the fixture suite under
//! `tests/fixtures/` pins both the hits and the deliberate non-hits.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The five determinism rules. See the crate docs for definitions.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Rule {
    R1,
    R2,
    R3,
    R4,
    R5,
}

impl Rule {
    pub fn parse(s: &str) -> Option<Rule> {
        match s {
            "R1" => Some(Rule::R1),
            "R2" => Some(Rule::R2),
            "R3" => Some(Rule::R3),
            "R4" => Some(Rule::R4),
            "R5" => Some(Rule::R5),
            _ => None,
        }
    }

    pub fn id(self) -> &'static str {
        match self {
            Rule::R1 => "R1",
            Rule::R2 => "R2",
            Rule::R3 => "R3",
            Rule::R4 => "R4",
            Rule::R5 => "R5",
        }
    }

    pub fn summary(self) -> &'static str {
        match self {
            Rule::R1 => {
                "NaN-unsafe float ordering: use total_cmp instead of \
                 partial_cmp().unwrap() / sort_by over partial_cmp"
            }
            Rule::R2 => {
                "HashMap/HashSet iteration order is nondeterministic in this \
                 module tree: use BTreeMap/BTreeSet or Vec indexing"
            }
            Rule::R3 => {
                "wall-clock / ambient randomness in simulation code: thread \
                 virtual time and seeded PRNGs through the engine"
            }
            Rule::R4 => {
                "float reduction on a decision path: waive with the \
                 accumulation-order rationale or restructure"
            }
            Rule::R5 => {
                "BinaryHeap has unstable tie ordering: only \
                 coordinator/sched.rs wraps it deterministically"
            }
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FindingKind {
    Violation(Rule),
    MalformedWaiver,
    UnusedWaiver(Rule),
}

#[derive(Clone, Debug)]
pub struct Finding {
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub kind: FindingKind,
    pub message: String,
    pub excerpt: String,
}

impl Finding {
    pub fn render(&self) -> String {
        let tag = match &self.kind {
            FindingKind::Violation(r) => r.id(),
            FindingKind::MalformedWaiver | FindingKind::UnusedWaiver(_) => "waiver",
        };
        format!("{}:{}: [{}] {}\n    {}", self.path, self.line, tag, self.message, self.excerpt)
    }
}

/// Result of analyzing a single file.
#[derive(Debug, Default)]
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub waivers_used: usize,
}

/// Result of scanning a tree.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files: usize,
    pub waivers_used: usize,
}

/// Directories never scanned: build output, fixture corpora, and the
/// test/bench/example trees (those run wall-clock harness code by
/// design; the contract covers the library and binary sources).
const SKIP_DIRS: [&str; 6] = ["target", "fixtures", ".git", "tests", "benches", "examples"];

const R3_TOKENS: [&str; 4] = ["Instant::now", "SystemTime", "thread_rng", "rand::random"];

/// Harness entry points where wall-clock use is the whole point.
const R3_EXEMPT_FILES: [&str; 3] = ["bench_harness.rs", "main.rs", "cli.rs"];

/// Integer type ascriptions that mark a `.sum()` / `.fold(..)` on the
/// same line as a non-float reduction.
const INT_HINTS: [&str; 10] = [
    ": usize", ": u8", ": u16", ": u32", ": u64", ": i8", ": i16", ": i32", ": i64", "-> usize",
];

/// Scan a file or directory tree rooted at `root`. Files are visited in
/// sorted order so output is stable; directories named in [`SKIP_DIRS`]
/// are pruned at every depth.
pub fn scan_path(root: &Path) -> io::Result<Report> {
    let mut paths = Vec::new();
    collect(root, &mut paths)?;
    paths.sort();
    let mut report = Report::default();
    for p in &paths {
        let mut rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        if rel.is_empty() {
            rel = p
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default();
        }
        let src = fs::read_to_string(p)?;
        let file = analyze_source(&p.display().to_string(), &rel, &src);
        report.findings.extend(file.findings);
        report.waivers_used += file.waivers_used;
        report.files += 1;
    }
    Ok(report)
}

fn collect(path: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if path.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let mut entries = Vec::new();
    for entry in fs::read_dir(path)? {
        entries.push(entry?.path());
    }
    entries.sort();
    for entry in entries {
        if entry.is_dir() {
            let name = entry.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if SKIP_DIRS.contains(&name) {
                continue;
            }
            collect(&entry, out)?;
        } else if entry.extension().is_some_and(|e| e == "rs") {
            out.push(entry);
        }
    }
    Ok(())
}

/// Analyze one file's source. `path` is for display; `rel` is the
/// `/`-separated path relative to the scan root and drives rule scoping
/// (e.g. `coordinator/engine.rs`).
pub fn analyze_source(path: &str, rel: &str, src: &str) -> FileReport {
    let masked = mask(src);
    let original: Vec<&str> = src.lines().collect();
    let regions = test_regions(&masked.lines);

    struct Waiver {
        rule: Rule,
        file_wide: bool,
        line: usize,
        anchor: usize,
        used: bool,
    }

    let mut waivers: Vec<Waiver> = Vec::new();
    let mut findings: Vec<Finding> = Vec::new();
    for (cline, text) in &masked.comments {
        match parse_waiver(text) {
            None => {}
            Some(Err(msg)) => findings.push(Finding {
                path: path.to_string(),
                line: cline + 1,
                kind: FindingKind::MalformedWaiver,
                message: msg,
                excerpt: excerpt(&original, *cline),
            }),
            Some(Ok((rule, file_wide))) => waivers.push(Waiver {
                rule,
                file_wide,
                line: *cline,
                anchor: anchor_line(&masked.lines, *cline),
                used: false,
            }),
        }
    }

    for (line, rule) in detect(rel, &masked.lines, &regions) {
        let mut waived = false;
        for w in waivers.iter_mut() {
            if w.rule == rule && (w.file_wide || w.anchor == line) {
                w.used = true;
                waived = true;
                break;
            }
        }
        if !waived {
            findings.push(Finding {
                path: path.to_string(),
                line: line + 1,
                kind: FindingKind::Violation(rule),
                message: rule.summary().to_string(),
                excerpt: excerpt(&original, line),
            });
        }
    }

    let waivers_used = waivers.iter().filter(|w| w.used).count();
    for w in &waivers {
        if !w.used {
            findings.push(Finding {
                path: path.to_string(),
                line: w.line + 1,
                kind: FindingKind::UnusedWaiver(w.rule),
                message: format!("waiver for {} suppresses nothing; delete it", w.rule.id()),
                excerpt: excerpt(&original, w.line),
            });
        }
    }

    findings.sort_by_key(|f| f.line);
    FileReport { findings, waivers_used }
}

/// Masked view of a source file: literal and comment contents replaced
/// by spaces (line structure preserved), plus the raw text of every
/// comment keyed by its starting line (for waiver parsing).
struct Masked {
    lines: Vec<String>,
    comments: Vec<(usize, String)>,
}

/// Lexical masking pass. Handles line comments, nested block comments,
/// string/char/byte literals, raw strings (`r"…"`, `r#"…"#`, `br#"…"#`),
/// raw identifiers (`r#match`), and the char-vs-lifetime ambiguity.
/// Output lines are normalized to ASCII (non-ASCII code points become
/// `?`) so byte offsets equal char offsets in every later pass.
fn mask(src: &str) -> Masked {
    let chars: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut line = 0usize;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            let start = line;
            let mut text = String::new();
            out.push_str("  ");
            i += 2;
            while i < chars.len() && chars[i] != '\n' {
                text.push(chars[i]);
                out.push(' ');
                i += 1;
            }
            comments.push((start, text));
            continue;
        }
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let start = line;
            let mut text = String::new();
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < chars.len() && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    text.push_str("/*");
                    out.push_str("  ");
                    i += 2;
                    continue;
                }
                if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                    text.push_str("*/");
                    continue;
                }
                let ch = chars[i];
                text.push(ch);
                if ch == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            comments.push((start, text));
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < chars.len() && (chars[j].is_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            let word: String = chars[i..j].iter().collect();
            if word == "r" || word == "br" {
                let mut h = 0usize;
                while chars.get(j + h) == Some(&'#') {
                    h += 1;
                }
                if chars.get(j + h) == Some(&'"') {
                    out.push_str(&word);
                    for _ in 0..h {
                        out.push('#');
                    }
                    out.push('"');
                    i = j + h + 1;
                    while i < chars.len() {
                        if chars[i] == '"' {
                            let mut k = 0usize;
                            while k < h && chars.get(i + 1 + k) == Some(&'#') {
                                k += 1;
                            }
                            if k == h {
                                out.push('"');
                                for _ in 0..h {
                                    out.push('#');
                                }
                                i += 1 + h;
                                break;
                            }
                        }
                        if chars[i] == '\n' {
                            out.push('\n');
                            line += 1;
                        } else {
                            out.push(' ');
                        }
                        i += 1;
                    }
                    continue;
                }
            }
            if word == "b" && chars.get(j) == Some(&'"') {
                out.push('b');
                i = j;
                continue;
            }
            if word == "b" && chars.get(j) == Some(&'\'') {
                out.push_str("b'");
                i = j + 1;
                mask_until_quote(&chars, &mut i, &mut out, &mut line, '\'');
                continue;
            }
            out.push_str(&word);
            i = j;
            continue;
        }
        if c == '"' {
            out.push('"');
            i += 1;
            mask_until_quote(&chars, &mut i, &mut out, &mut line, '"');
            continue;
        }
        if c == '\'' {
            let is_char = match chars.get(i + 1) {
                Some('\\') => true,
                Some(_) => chars.get(i + 2) == Some(&'\''),
                None => false,
            };
            if is_char {
                out.push('\'');
                i += 1;
                mask_until_quote(&chars, &mut i, &mut out, &mut line, '\'');
                continue;
            }
            out.push('\'');
            i += 1;
            continue;
        }
        out.push(c);
        if c == '\n' {
            line += 1;
        }
        i += 1;
    }
    let lines = out
        .lines()
        .map(|l| l.chars().map(|c| if c.is_ascii() { c } else { '?' }).collect())
        .collect();
    Masked { lines, comments }
}

/// Mask literal contents (escape-aware) up to and including the closing
/// `quote`; newlines inside multi-line strings are preserved.
fn mask_until_quote(chars: &[char], i: &mut usize, out: &mut String, line: &mut usize, quote: char) {
    while *i < chars.len() && chars[*i] != quote {
        if chars[*i] == '\\' {
            out.push(' ');
            *i += 1;
            if *i < chars.len() {
                if chars[*i] == '\n' {
                    out.push('\n');
                    *line += 1;
                } else {
                    out.push(' ');
                }
                *i += 1;
            }
            continue;
        }
        if chars[*i] == '\n' {
            out.push('\n');
            *line += 1;
        } else {
            out.push(' ');
        }
        *i += 1;
    }
    if *i < chars.len() {
        out.push(quote);
        *i += 1;
    }
}

/// Line ranges (inclusive, 0-based) covered by `#[cfg(test)]` items,
/// found by brace-matching from the attribute in the masked text.
fn test_regions(lines: &[String]) -> Vec<(usize, usize)> {
    let mut regions: Vec<(usize, usize)> = Vec::new();
    for (n, l) in lines.iter().enumerate() {
        let Some(col) = l.find("#[cfg(test)]") else {
            continue;
        };
        if regions.iter().any(|&(a, b)| n >= a && n <= b) {
            continue;
        }
        let mut row = n;
        let mut pos = col + "#[cfg(test)]".len();
        let mut open: Option<(usize, usize)> = None;
        'findopen: while row < lines.len() {
            let bytes = lines[row].as_bytes();
            while pos < bytes.len() {
                if bytes[pos] == b'{' {
                    open = Some((row, pos));
                    break 'findopen;
                }
                if bytes[pos] == b';' {
                    regions.push((n, row));
                    break 'findopen;
                }
                pos += 1;
            }
            row += 1;
            pos = 0;
        }
        let Some((mut row, mut pos)) = open else {
            continue;
        };
        let mut depth = 0i64;
        'matching: while row < lines.len() {
            let bytes = lines[row].as_bytes();
            while pos < bytes.len() {
                if bytes[pos] == b'{' {
                    depth += 1;
                } else if bytes[pos] == b'}' {
                    depth -= 1;
                    if depth == 0 {
                        regions.push((n, row));
                        break 'matching;
                    }
                }
                pos += 1;
            }
            row += 1;
            pos = 0;
        }
    }
    regions
}

/// Parse a comment's text as a waiver. Returns `None` for ordinary
/// comments, `Some(Ok(..))` for a valid waiver, and `Some(Err(..))` for
/// a comment that announces itself as a waiver (`detlint:` prefix) but
/// does not parse — those become [`FindingKind::MalformedWaiver`].
fn parse_waiver(text: &str) -> Option<Result<(Rule, bool), String>> {
    let t = text.trim();
    if !t.starts_with("detlint:") {
        return None;
    }
    let rest = t["detlint:".len()..].trim_start();
    let (file_wide, body) = if let Some(b) = rest.strip_prefix("allow-file(") {
        (true, b)
    } else if let Some(b) = rest.strip_prefix("allow(") {
        (false, b)
    } else {
        return Some(Err(String::from(
            "expected `allow(<rule>, <reason>)` or `allow-file(<rule>, <reason>)` after `detlint:`",
        )));
    };
    let Some(close) = body.rfind(')') else {
        return Some(Err(String::from("unclosed waiver: missing `)`")));
    };
    let inner = &body[..close];
    let Some((rule_s, reason)) = inner.split_once(',') else {
        return Some(Err(String::from(
            "waiver must carry a reason: `allow(<rule>, <reason>)`",
        )));
    };
    let Some(rule) = Rule::parse(rule_s.trim()) else {
        return Some(Err(format!("unknown rule `{}` (expected R1..R5)", rule_s.trim())));
    };
    if reason.trim().is_empty() {
        return Some(Err(String::from("waiver reason must be non-empty")));
    }
    Some(Ok((rule, file_wide)))
}

/// The line a waiver covers: its own line when code shares it, else the
/// next non-blank line in the masked text (waiver stacks work because
/// intermediate waiver comments mask to blank lines).
fn anchor_line(lines: &[String], comment_line: usize) -> usize {
    if lines.get(comment_line).is_some_and(|l| !l.trim().is_empty()) {
        return comment_line;
    }
    let mut n = comment_line + 1;
    while n < lines.len() {
        if !lines[n].trim().is_empty() {
            return n;
        }
        n += 1;
    }
    comment_line
}

fn in_scope(rel: &str, segments: &[&str]) -> bool {
    rel.split('/').any(|s| segments.contains(&s))
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Token match with identifier boundaries on both sides, so `HashMap`
/// does not fire on `MyHashMapLike`.
fn word_hit(line: &str, token: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0usize;
    while let Some(p) = line[from..].find(token) {
        let start = from + p;
        let end = start + token.len();
        let pre = start == 0 || !is_ident_byte(bytes[start - 1]);
        let post = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if pre && post {
            return true;
        }
        from = start + 1;
    }
    false
}

/// Run all five rules over the masked lines. Returns deduplicated
/// (0-based line, rule) pairs in line order.
fn detect(rel: &str, lines: &[String], regions: &[(usize, usize)]) -> Vec<(usize, Rule)> {
    let file_name = rel.rsplit('/').next().unwrap_or(rel);
    let r2_scope = in_scope(rel, &["coordinator", "telemetry", "dqn", "util"]);
    let r4_scope = in_scope(rel, &["coordinator", "dqn"]);
    let r3_exempt = R3_EXEMPT_FILES.contains(&file_name);
    let r5_exempt = rel.ends_with("coordinator/sched.rs");
    let mut hits: Vec<(usize, Rule)> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let in_test = regions.iter().any(|&(a, b)| i >= a && i <= b);
        let fwd = lines[i..lines.len().min(i + 4)].join("\n");
        let back = lines[i.saturating_sub(3)..=i].join("\n");

        if !line.contains("fn partial_cmp") {
            if let Some(p) = line.find(".partial_cmp(") {
                if fwd[p..].contains(".unwrap()") {
                    hits.push((i, Rule::R1));
                }
            }
            let sorts = line.contains(".sort_by(");
            if sorts && fwd.contains("partial_cmp") && !fwd.contains("total_cmp") {
                hits.push((i, Rule::R1));
            }
        }

        if r2_scope && (word_hit(line, "HashMap") || word_hit(line, "HashSet")) {
            hits.push((i, Rule::R2));
        }

        if !in_test && !r3_exempt && R3_TOKENS.iter().any(|t| word_hit(line, t)) {
            hits.push((i, Rule::R3));
        }

        if r4_scope && !in_test {
            let int_hint = INT_HINTS.iter().any(|h| line.contains(h));
            let float_near = back.contains("f64") || back.contains("f32") || back.contains("0.0");
            if line.contains(".sum::<f64>()") || line.contains(".sum::<f32>()") {
                hits.push((i, Rule::R4));
            } else if line.contains(".sum()") && !int_hint && float_near {
                hits.push((i, Rule::R4));
            } else if line.contains(".fold(") && !int_hint && float_near {
                hits.push((i, Rule::R4));
            }
        }

        if !in_test && !r5_exempt && word_hit(line, "BinaryHeap") {
            hits.push((i, Rule::R5));
        }
    }
    hits.sort();
    hits.dedup();
    hits
}

fn excerpt(original: &[&str], line: usize) -> String {
    let l = original.get(line).map_or("", |l| l.trim());
    if l.len() <= 120 {
        return l.to_string();
    }
    let mut end = 120;
    while !l.is_char_boundary(end) {
        end -= 1;
    }
    format!("{}...", &l[..end])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn violations(rel: &str, src: &str) -> Vec<(usize, Rule)> {
        analyze_source("mem", rel, src)
            .findings
            .into_iter()
            .filter_map(|f| match f.kind {
                FindingKind::Violation(r) => Some((f.line, r)),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn string_and_comment_contents_are_masked() {
        let src = "pub fn f() -> &'static str {\n    \
                   // says Instant::now and BinaryHeap\n    \
                   \"Instant::now HashMap .sum::<f64>()\"\n}\n";
        assert!(violations("coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn nested_block_comments_are_masked() {
        let src = "/* outer /* BinaryHeap */ still Instant::now */\npub fn f() {}\n";
        assert!(violations("coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn raw_strings_and_raw_idents_are_handled() {
        let src = "pub fn f() -> &'static str {\n    let r#match = 1u32;\n    \
                   let _ = r#match;\n    r##\"HashSet \"# SystemTime\"##\n}\n";
        assert!(violations("util/x.rs", src).is_empty());
    }

    #[test]
    fn char_literal_with_quote_does_not_open_string() {
        let src = "pub fn f(s: &str) -> usize {\n    \
                   s.split('\"').count() + s.find('\\'').unwrap_or(0)\n}\n\
                   pub fn g() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
        let v = violations("coordinator/x.rs", src);
        assert_eq!(v, vec![(5, Rule::R3)]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "pub fn f<'a>(s: &'a str) -> &'a str {\n    s\n}\n";
        assert!(violations("coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn r1_fires_once_per_line_even_with_both_triggers() {
        let src = "pub fn f(xs: &mut [f64]) {\n    \
                   xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        assert_eq!(violations("x.rs", src), vec![(2, Rule::R1)]);
    }

    #[test]
    fn r1_applies_inside_test_regions() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(xs: &mut [f64]) {\n        \
                   xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n    }\n}\n";
        assert_eq!(violations("x.rs", src), vec![(4, Rule::R1)]);
    }

    #[test]
    fn r1_skips_total_cmp_and_definitions() {
        let src = "pub fn f(xs: &mut [f64]) {\n    xs.sort_by(|a, b| a.total_cmp(b));\n}\n\
                   fn partial_cmp(a: f64, b: f64) -> bool {\n    a < b\n}\n";
        assert!(violations("x.rs", src).is_empty());
    }

    #[test]
    fn r1_sees_unwrap_on_following_lines() {
        let src = "pub fn f(a: f64, b: f64) -> std::cmp::Ordering {\n    \
                   a.partial_cmp(&b)\n        .unwrap()\n}\n";
        assert_eq!(violations("x.rs", src), vec![(2, Rule::R1)]);
    }

    #[test]
    fn r2_is_scoped_and_word_bounded() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(violations("coordinator/x.rs", src), vec![(1, Rule::R2)]);
        assert!(violations("perfmodel/x.rs", src).is_empty());
        let named = "pub struct MyHashMapLike;\n";
        assert!(violations("coordinator/x.rs", named).is_empty());
    }

    #[test]
    fn r3_exempts_harness_files_and_test_regions() {
        let src = "pub fn t() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
        assert_eq!(violations("coordinator/x.rs", src), vec![(2, Rule::R3)]);
        assert!(violations("bench_harness.rs", src).is_empty());
        assert!(violations("main.rs", src).is_empty());
        assert!(violations("cli.rs", src).is_empty());
        let in_test = "#[cfg(test)]\nmod tests {\n    fn t() -> u64 {\n        \
                       let _ = std::time::Instant::now();\n        0\n    }\n}\n";
        assert!(violations("coordinator/x.rs", in_test).is_empty());
    }

    #[test]
    fn r4_triggers_and_integer_exemptions() {
        let a = "pub fn f(xs: &[f64]) -> f64 {\n    xs.iter().sum::<f64>()\n}\n";
        assert_eq!(violations("dqn/x.rs", a), vec![(2, Rule::R4)]);
        let b = "pub fn f(xs: &[f64]) -> f64 {\n    let s: f64 = xs.iter().sum();\n    s\n}\n";
        assert_eq!(violations("dqn/x.rs", b), vec![(2, Rule::R4)]);
        let c = "pub fn f(xs: &[f64]) -> f64 {\n    \
                 xs.iter().fold(0.0, |acc, x| acc + x)\n}\n";
        assert_eq!(violations("dqn/x.rs", c), vec![(2, Rule::R4)]);
        let int = "pub fn f(xs: &[u64]) -> usize {\n    let n: usize = xs.len();\n    \
                   let s: usize = xs.iter().map(|&x| x as usize).sum();\n    n + s\n}\n";
        assert!(violations("dqn/x.rs", int).is_empty());
        assert!(violations("perfmodel/x.rs", a).is_empty());
    }

    #[test]
    fn r5_allows_only_sched() {
        let src = "use std::collections::BinaryHeap;\n";
        assert_eq!(violations("coordinator/engine.rs", src), vec![(1, Rule::R5)]);
        assert!(violations("coordinator/sched.rs", src).is_empty());
    }

    #[test]
    fn inline_and_standalone_waivers_anchor_correctly() {
        let inline = "pub fn f(xs: &[f64]) -> f64 {\n    \
                      xs.iter().sum::<f64>() // detlint: allow(R4, fixed order)\n}\n";
        let rep = analyze_source("mem", "dqn/x.rs", inline);
        assert!(rep.findings.is_empty());
        assert_eq!(rep.waivers_used, 1);
        let standalone = "pub fn f(xs: &[f64]) -> f64 {\n    \
                          // detlint: allow(R4, fixed order)\n    xs.iter().sum::<f64>()\n}\n";
        let rep = analyze_source("mem", "dqn/x.rs", standalone);
        assert!(rep.findings.is_empty());
        assert_eq!(rep.waivers_used, 1);
    }

    #[test]
    fn waiver_stacks_cover_the_next_code_line() {
        let src = "pub fn f(xs: &mut [f64]) -> f64 {\n    \
                   // detlint: allow(R1, fixture)\n    // detlint: allow(R4, fixture)\n    \
                   let s: f64 = xs.iter().sum();\n    \
                   xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n    s\n}\n";
        let rep = analyze_source("mem", "coordinator/x.rs", src);
        // the R4 waiver lands on the sum line; the R1 waiver also anchors
        // there, misses, and is reported unused while the sort still fires
        assert_eq!(rep.waivers_used, 1);
        let kinds: Vec<_> = rep.findings.iter().map(|f| f.kind.clone()).collect();
        assert!(kinds.contains(&FindingKind::UnusedWaiver(Rule::R1)));
        assert!(kinds.contains(&FindingKind::Violation(Rule::R1)));
    }

    #[test]
    fn doc_comments_never_parse_as_waivers() {
        let src = "/// detlint: allow(R2, this is documentation, not a waiver)\n\
                   pub fn f() {}\n";
        let rep = analyze_source("mem", "coordinator/x.rs", src);
        assert!(rep.findings.is_empty());
        assert_eq!(rep.waivers_used, 0);
    }

    #[test]
    fn cfg_test_mod_declaration_without_braces() {
        let src = "#[cfg(test)]\nmod tests;\npub fn t() -> std::time::Instant {\n    \
                   std::time::Instant::now()\n}\n";
        assert_eq!(violations("coordinator/x.rs", src), vec![(4, Rule::R3)]);
    }
}
