//! CLI for the determinism-contract analyzer.
//!
//! ```text
//! detlint [ROOT ...]
//! ```
//!
//! Scans each root (default `rust/src`, i.e. run from the workspace
//! top), prints every finding, and exits nonzero if any finding
//! survives — violations, malformed waivers, and unused waivers all
//! count. Exit code 2 means a root could not be read at all.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "-h" || a == "--help") {
        println!("usage: detlint [ROOT ...]   (default root: rust/src)");
        println!("exit 0: every scanned file honors the determinism contract");
        println!("exit 1: findings (printed one per line, `path:line: [rule] message`)");
        println!("exit 2: a root could not be scanned");
        return ExitCode::SUCCESS;
    }
    let roots = if args.is_empty() {
        vec![String::from("rust/src")]
    } else {
        args
    };
    let mut findings = Vec::new();
    let mut files = 0usize;
    let mut waived = 0usize;
    for root in &roots {
        match detlint::scan_path(Path::new(root)) {
            Ok(report) => {
                files += report.files;
                waived += report.waivers_used;
                findings.extend(report.findings);
            }
            Err(err) => {
                eprintln!("detlint: cannot scan {root}: {err}");
                return ExitCode::from(2);
            }
        }
    }
    for f in &findings {
        println!("{}", f.render());
    }
    eprintln!(
        "detlint: {files} file(s) scanned, {} finding(s), {waived} waiver(s) honored",
        findings.len()
    );
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
