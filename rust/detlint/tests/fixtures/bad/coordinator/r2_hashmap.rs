use std::collections::HashMap;

pub fn build() -> usize {
    0
}
