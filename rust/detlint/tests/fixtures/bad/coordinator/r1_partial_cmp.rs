pub fn pick(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[0]
}
