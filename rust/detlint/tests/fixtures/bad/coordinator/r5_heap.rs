use std::collections::BinaryHeap;

pub fn fresh() -> usize {
    0
}
