pub fn all(xs: &mut [f64]) -> f64 {
    // detlint: allow(R1, fixture exercises waiver suppression)
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // detlint: allow(R4, fixture exercises waiver suppression)
    let s: f64 = xs.iter().sum();
    s
}

// detlint: allow(R2, fixture exercises waiver suppression)
pub type Map = std::collections::HashMap<u64, u64>;

// detlint: allow(R5, fixture exercises waiver suppression)
pub type Heap = std::collections::BinaryHeap<u64>;

pub fn stamp() -> std::time::Instant {
    // detlint: allow(R3, fixture exercises waiver suppression)
    std::time::Instant::now()
}
