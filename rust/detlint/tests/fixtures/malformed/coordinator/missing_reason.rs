// detlint: allow(R2)
pub type Map = std::collections::HashMap<u64, u64>;

// detlint: allow(R9, not a real rule)
pub fn unknown() -> usize {
    0
}
