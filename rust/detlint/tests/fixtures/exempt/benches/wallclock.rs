pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}
