use std::collections::BTreeMap;

pub fn ranked(scores: &BTreeMap<u64, f64>) -> Option<u64> {
    scores
        .iter()
        .max_by(|a, b| a.1.total_cmp(b.1).then_with(|| a.0.cmp(b.0)))
        .map(|(id, _)| *id)
}
