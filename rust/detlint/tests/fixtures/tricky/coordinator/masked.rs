//! Patterns inside literals, comments, and test regions must not fire.

/// Docs may mention HashMap, BinaryHeap, and Instant::now freely.
pub fn doc_only() -> &'static str {
    "HashMap BinaryHeap Instant::now .sum::<f64>() thread_rng"
}

/* block comment: BinaryHeap, .partial_cmp(x).unwrap()
   /* nested: HashMap */
   still inside the outer comment: rand::random */
pub fn lifetimes<'a>(s: &'a str) -> char {
    s.chars().next().unwrap_or('x')
}

pub fn raw() -> &'static str {
    r#"SystemTime and HashSet live in a raw string"#
}

pub fn split_quote(s: &str) -> usize {
    s.split('"').count()
}

#[cfg(test)]
mod tests {
    #[test]
    fn float_sum_in_tests_is_fine() {
        let xs = [1.0f64, 2.0];
        let s: f64 = xs.iter().sum();
        assert!(s > 0.0);
        let _ = std::time::Instant::now();
    }
}
