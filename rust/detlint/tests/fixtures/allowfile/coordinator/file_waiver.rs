// detlint: allow-file(R3, fixture times real wall-clock work end to end)

pub fn t0() -> std::time::Instant {
    std::time::Instant::now()
}

pub fn elapsed(t: std::time::Instant) -> f64 {
    let d = std::time::Instant::now() - t;
    d.as_secs_f64()
}
