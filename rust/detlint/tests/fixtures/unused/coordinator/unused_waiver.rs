// detlint: allow(R5, nothing below actually uses a heap)
pub fn quiet() -> usize {
    0
}
