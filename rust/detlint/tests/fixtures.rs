//! Fixture-based self-tests for the detlint rule engine, plus the
//! real-tree gates: the production sources must scan clean, and every
//! waiver in them must be load-bearing (deleting it produces findings).

use detlint::{analyze_source, scan_path, FindingKind, Rule};
use std::fs;
use std::path::{Path, PathBuf};

fn fixture_root(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn kinds(report: &detlint::Report) -> Vec<FindingKind> {
    report.findings.iter().map(|f| f.kind.clone()).collect()
}

#[test]
fn each_bad_fixture_fires_its_rule_exactly_once() {
    let report = scan_path(&fixture_root("bad")).expect("scan bad fixtures");
    assert_eq!(report.files, 5, "expected one fixture file per rule");
    assert_eq!(report.findings.len(), 5, "one finding per fixture: {:?}", report.findings);
    for rule in [Rule::R1, Rule::R2, Rule::R3, Rule::R4, Rule::R5] {
        let n = report
            .findings
            .iter()
            .filter(|f| f.kind == FindingKind::Violation(rule))
            .count();
        assert_eq!(n, 1, "{} must fire exactly once across bad fixtures", rule.id());
    }
    assert_eq!(report.waivers_used, 0);
}

#[test]
fn waived_fixture_scans_clean_with_all_waivers_honored() {
    let report = scan_path(&fixture_root("waived")).expect("scan waived fixture");
    assert!(report.findings.is_empty(), "unexpected findings: {:?}", report.findings);
    assert_eq!(report.waivers_used, 5, "all five waivers must be honored");
}

#[test]
fn deleting_any_single_waiver_unsuppresses_exactly_its_rule() {
    let path = fixture_root("waived").join("coordinator/all_waived.rs");
    let src = fs::read_to_string(&path).expect("read waived fixture");
    let lines: Vec<&str> = src.lines().collect();
    let mut checked = 0;
    for (i, line) in lines.iter().enumerate() {
        let trimmed = line.trim_start();
        if !trimmed.starts_with("// detlint: allow(") {
            continue;
        }
        let rule_s = &trimmed["// detlint: allow(".len()..][..2];
        let rule = Rule::parse(rule_s).expect("fixture waiver names a real rule");
        let mut stripped: Vec<&str> = lines.clone();
        stripped.remove(i);
        let report = analyze_source("mem", "coordinator/all_waived.rs", &stripped.join("\n"));
        assert_eq!(
            report.findings.len(),
            1,
            "stripping the {} waiver must unsuppress exactly one finding, got {:?}",
            rule.id(),
            report.findings
        );
        assert_eq!(report.findings[0].kind, FindingKind::Violation(rule));
        checked += 1;
    }
    assert_eq!(checked, 5, "expected to strip-test five waivers");
}

#[test]
fn malformed_waivers_are_findings_and_do_not_suppress() {
    let report = scan_path(&fixture_root("malformed")).expect("scan malformed fixture");
    let ks = kinds(&report);
    let malformed = ks.iter().filter(|k| **k == FindingKind::MalformedWaiver).count();
    assert_eq!(malformed, 2, "missing-reason and unknown-rule must both report: {ks:?}");
    assert!(
        ks.contains(&FindingKind::Violation(Rule::R2)),
        "a malformed waiver must not suppress the violation under it: {ks:?}"
    );
    assert_eq!(report.findings.len(), 3);
    assert_eq!(report.waivers_used, 0);
}

#[test]
fn unused_waiver_is_a_finding() {
    let report = scan_path(&fixture_root("unused")).expect("scan unused fixture");
    assert_eq!(kinds(&report), vec![FindingKind::UnusedWaiver(Rule::R5)]);
}

#[test]
fn masked_patterns_and_test_regions_stay_silent() {
    let report = scan_path(&fixture_root("tricky")).expect("scan tricky fixture");
    assert!(report.findings.is_empty(), "unexpected findings: {:?}", report.findings);
}

#[test]
fn allow_file_waiver_covers_every_hit_in_the_file() {
    let report = scan_path(&fixture_root("allowfile")).expect("scan allowfile fixture");
    assert!(report.findings.is_empty(), "unexpected findings: {:?}", report.findings);
    assert_eq!(report.waivers_used, 1);

    let path = fixture_root("allowfile").join("coordinator/file_waiver.rs");
    let src = fs::read_to_string(&path).expect("read allowfile fixture");
    let stripped: Vec<&str> = src.lines().filter(|l| !l.contains("detlint:")).collect();
    let report = analyze_source("mem", "coordinator/file_waiver.rs", &stripped.join("\n"));
    let r3 = report
        .findings
        .iter()
        .filter(|f| f.kind == FindingKind::Violation(Rule::R3))
        .count();
    assert_eq!(r3, 2, "without the file waiver both wall-clock reads must fire");
}

#[test]
fn clean_fixture_scans_clean() {
    let report = scan_path(&fixture_root("clean")).expect("scan clean fixture");
    assert!(report.findings.is_empty(), "unexpected findings: {:?}", report.findings);
    assert_eq!(report.files, 1);
}

#[test]
fn bench_and_example_trees_are_skipped() {
    let report = scan_path(&fixture_root("exempt")).expect("scan exempt fixture");
    assert_eq!(report.files, 0, "benches/ must be pruned by the walker");
    assert!(report.findings.is_empty());
}

/// The three production roots CI scans. Relative to this crate's
/// manifest dir so the test is cwd-independent.
const REAL_ROOTS: [&str; 3] = ["../src", "../xla-stub/src", "src"];

#[test]
fn real_tree_is_clean() {
    let base = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut waived = 0;
    for root in REAL_ROOTS {
        let report = scan_path(&base.join(root)).expect("scan production root");
        assert!(report.files > 0, "{root} scanned no files");
        assert!(
            report.findings.is_empty(),
            "{root} must scan clean, got:\n{}",
            report
                .findings
                .iter()
                .map(|f| f.render())
                .collect::<Vec<_>>()
                .join("\n")
        );
        waived += report.waivers_used;
    }
    assert!(waived >= 6, "expected the documented production waivers, saw {waived}");
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .expect("read production dir")
        .map(|e| e.expect("dir entry").path())
        .collect();
    entries.sort();
    for entry in entries {
        if entry.is_dir() {
            walk(&entry, out);
        } else if entry.extension().is_some_and(|e| e == "rs") {
            out.push(entry);
        }
    }
}

#[test]
fn every_real_waiver_is_load_bearing() {
    let base = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut stripped_total = 0;
    // detlint's own sources are excluded: its unit tests embed
    // waiver-shaped text inside string literals, which a line-level
    // strip would mangle mid-literal. They still pass through the
    // full-scan gate above.
    for root in ["../src", "../xla-stub/src"] {
        let root = base.join(root);
        let mut files = Vec::new();
        walk(&root, &mut files);
        for file in files {
            let src = fs::read_to_string(&file).expect("read production file");
            if !src.contains("// detlint: allow") {
                continue;
            }
            let rel = file
                .strip_prefix(&root)
                .expect("walked file under root")
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            let lines: Vec<&str> = src.lines().collect();
            for (i, line) in lines.iter().enumerate() {
                if !line.trim_start().starts_with("// detlint: allow") {
                    continue;
                }
                let mut stripped: Vec<&str> = lines.clone();
                stripped.remove(i);
                let report = analyze_source("mem", &rel, &stripped.join("\n"));
                assert!(
                    !report.findings.is_empty(),
                    "waiver at {}:{} suppresses nothing; delete it",
                    file.display(),
                    i + 1
                );
                stripped_total += 1;
            }
        }
    }
    assert!(stripped_total >= 6, "expected to strip-test the production waivers");
}
