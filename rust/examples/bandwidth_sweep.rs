//! Bandwidth robustness (the Fig. 11 scenario as an API example): sweep
//! the edge-cloud link from 0.5 to 8 Mbps — including a fluctuating
//! Markov-modulated WiFi link — and watch DVFO re-balance the offload
//! proportion ξ while the baselines degrade.
//!
//! Run: `cargo run --release --example bandwidth_sweep`

use dvfo::configx::Config;
use dvfo::coordinator::Coordinator;
use dvfo::telemetry::Table;
use dvfo::workload::{Arrivals, TaskGen};

fn run(policy: &str, bandwidth: &str) -> anyhow::Result<(f64, f64, f64)> {
    let mut cfg = Config::default();
    cfg.policy = policy.into();
    cfg.model = "efficientnet-b0".into();
    cfg.bandwidth = bandwidth.into();
    cfg.train_episodes = 45;
    cfg.requests = 80;
    let mut coord = Coordinator::from_config(&cfg)?;
    let mut gen = TaskGen::new(&cfg.model, coord.env.dataset, Arrivals::Sequential, 3)?;
    if matches!(policy, "dvfo" | "drldo") {
        coord.train(&mut gen, cfg.train_episodes, 24);
    }
    let s = coord.serve(&gen.take(cfg.requests));
    Ok((s.tti_ms.mean(), s.eti_mj.mean(), s.xi.mean()))
}

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(vec![
        "bandwidth", "policy", "tti ms", "eti mJ", "mean xi",
    ]);
    let mut specs: Vec<String> = [0.5, 2.0, 5.0, 8.0]
        .iter()
        .map(|b| format!("static:{b}"))
        .collect();
    specs.push("markov:2,8".to_string()); // fluctuating WiFi
    for bw in &specs {
        for policy in ["dvfo", "drldo", "cloud_only", "edge_only"] {
            let (tti, eti, xi) = run(policy, bw)?;
            t.row(vec![
                bw.clone(),
                policy.to_string(),
                format!("{tti:.1}"),
                format!("{eti:.0}"),
                format!("{xi:.2}"),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "expected shape: cloud_only degrades sharply at low bandwidth; \
         edge_only is flat; DVFO adapts ξ toward 0 on the slow link and \
         offloads on the fast one."
    );
    Ok(())
}
