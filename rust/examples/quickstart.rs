//! Quickstart: train DVFO's DQN offline, then serve a small stream and
//! print the latency/energy/accuracy summary — the simulator-only path
//! (no artifacts needed).
//!
//! Run: `cargo run --release --example quickstart`

use dvfo::configx::Config;
use dvfo::coordinator::Coordinator;
use dvfo::workload::{Arrivals, TaskGen};

fn main() -> anyhow::Result<()> {
    // 1. configure: Xavier NX edge, RTX-3080 cloud, EfficientNet-B0,
    //    CIFAR-100, 5 Mbps WiFi, balanced η (energy vs latency)
    let mut cfg = Config::default();
    cfg.policy = "dvfo".into();
    cfg.model = "efficientnet-b0".into();
    cfg.bandwidth = "static:5".into();
    cfg.eta = 0.5;
    cfg.train_episodes = 30;
    cfg.requests = 100;

    // 2. build the coordinator and train the DQN offline (paper Alg. 1)
    let mut coord = Coordinator::from_config(&cfg)?;
    let mut gen = TaskGen::new(&cfg.model, coord.env.dataset, Arrivals::Sequential, 1)?;
    println!("training {} episodes offline...", cfg.train_episodes);
    let curve = coord.train(&mut gen, cfg.train_episodes, 24);
    println!(
        "reward: first {:+.3} -> last {:+.3}",
        curve.first().unwrap(),
        curve.last().unwrap()
    );

    // 3. deploy: greedy policy over a fresh task stream
    let tasks = gen.take(cfg.requests);
    let s = coord.serve(&tasks);
    println!("\nserved {} requests:", s.count());
    println!("  latency  mean {:.1} ms  p99 {:.1} ms", s.tti_ms.mean(), s.tti_ms.p99());
    println!("  energy   mean {:.0} mJ", s.eti_mj.mean());
    println!("  accuracy mean {:.2} %", s.accuracy_pct.mean());
    println!("  offload  mean xi {:.2}, payload {:.1} KB", s.xi.mean(), s.payload_kb.mean());

    // 4. compare against the static edge-only baseline
    let mut cfg_e = cfg.clone();
    cfg_e.policy = "edge_only".into();
    let mut coord_e = Coordinator::from_config(&cfg_e)?;
    let mut gen_e = TaskGen::new(&cfg_e.model, coord_e.env.dataset, Arrivals::Sequential, 1)?;
    let se = coord_e.serve(&gen_e.take(cfg.requests));
    println!("\nvs edge-only:");
    println!(
        "  latency {:.1} ms -> {:.1} ms ({:+.1}%)",
        se.tti_ms.mean(),
        s.tti_ms.mean(),
        100.0 * (s.tti_ms.mean() / se.tti_ms.mean() - 1.0)
    );
    println!(
        "  energy  {:.0} mJ -> {:.0} mJ ({:+.1}%)",
        se.eti_mj.mean(),
        s.eti_mj.mean(),
        100.0 * (s.eti_mj.mean() / se.eti_mj.mean() - 1.0)
    );
    Ok(())
}
