//! END-TO-END DRIVER (the repo's headline validation): load the real AOT
//! artifacts (trained small CNN + Pallas SCAM + int8 offload + weighted
//! fusion), serve batched requests through the edge+cloud worker pair via
//! PJRT, and report latency / throughput / accuracy at several offload
//! proportions ξ — proving all three layers compose with Python nowhere
//! on the request path. Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example serve_realmodel`

use dvfo::coordinator::pipeline::{Pipeline, PipelineRequest};
use dvfo::telemetry::Table;
use std::path::Path;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts not built — run `make artifacts` first"
    );
    let pipeline = Pipeline::load(dir)?;
    pipeline.warmup()?; // one-time PJRT executable initialization
    let manifest = pipeline.engine().manifest.clone();
    let (imgs, labels) = manifest.load_testset(dir)?;
    let img_len: usize = manifest.img_shape.iter().product();
    let n = manifest.testset_count;
    println!(
        "loaded {} artifacts; test set n={n}; python-measured accuracies: {:?}",
        pipeline.engine().names().len(),
        manifest.accuracy
    );

    let mut t = Table::new(vec![
        "xi", "accuracy %", "throughput req/s", "mean ms", "p99-ish max ms", "payload B",
    ]);
    for xi in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let reqs: Vec<PipelineRequest> = (0..n)
            .map(|i| PipelineRequest {
                id: i as u64,
                image: imgs[i * img_len..(i + 1) * img_len].to_vec(),
                label: Some(labels[i]),
                xi,
                lambda: 0.5,
            })
            .collect();
        let t0 = Instant::now();
        let rs = pipeline.serve(reqs)?;
        let wall = t0.elapsed().as_secs_f64();
        let correct = rs.iter().filter(|r| r.correct == Some(true)).count();
        let mean_ms = 1e3 * rs.iter().map(|r| r.t_total_s).sum::<f64>() / n as f64;
        let max_ms = 1e3
            * rs.iter()
                .map(|r| r.t_total_s)
                .fold(f64::NEG_INFINITY, f64::max);
        let payload = rs.iter().map(|r| r.payload_bytes).sum::<usize>() / n;
        t.row(vec![
            format!("{xi:.2}"),
            format!("{:.2}", 100.0 * correct as f64 / n as f64),
            format!("{:.1}", n as f64 / wall),
            format!("{mean_ms:.3}"),
            format!("{max_ms:.3}"),
            payload.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "note: accuracy at every ξ should stay within ~1-2 pts of the \
         edge-only row — the paper's <1% collaborative-loss claim, \
         measured on real numerics."
    );
    Ok(())
}
