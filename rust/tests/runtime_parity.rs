//! Integration tests over the real AOT artifacts: the rust runtime must
//! reproduce the numbers the python build pipeline promised (manifest
//! probe), the rust DQN forward must agree with the PJRT `dqn_q`
//! artifact on identical weights, and the two-worker pipeline must hit
//! the advertised accuracy. Skipped politely when `make artifacts` has
//! not run.

use dvfo::coordinator::pipeline::{Pipeline, PipelineRequest};
use dvfo::dqn::{InferScratch, Mlp, Tensor2};
use dvfo::runtime::Engine;
use dvfo::scam::ImportanceDist;
use dvfo::util::Pcg32;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn load_testset(engine: &Engine, dir: &Path) -> (Vec<f32>, Vec<u32>, usize) {
    let (imgs, labels) = engine.manifest.load_testset(dir).unwrap();
    let img_len: usize = engine.manifest.img_shape.iter().product();
    (imgs, labels, img_len)
}

#[test]
fn engine_loads_all_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    for name in [
        "extractor",
        "local_head",
        "offload_prep",
        "remote_head",
        "fusion",
        "collaborative",
        "dqn_q",
    ] {
        assert!(engine.has(name), "missing artifact {name}");
    }
}

#[test]
fn collaborative_artifact_matches_python_probe() {
    // the manifest records the fused logits python computed for test
    // image 0 with the top-8 mask and λ=0.5; the rust-side execution of
    // the AOT artifact must reproduce them (build↔serve parity).
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load_filtered(&dir, Some(&["collaborative"])).unwrap();
    let (imgs, _, img_len) = load_testset(&engine, &dir);
    let m = &engine.manifest;

    let imp = ImportanceDist::from_weights(&m.mean_importance);
    let ranked = imp.ranked();
    let mut mask = vec![0.0f32; m.feat_channels];
    for &c in ranked.iter().take(m.probe.mask_topk) {
        mask[c] = 1.0;
    }
    let lam = [m.probe.lambda as f32];
    let out = engine
        .execute_f32("collaborative", &[&imgs[..img_len], &mask, &lam])
        .unwrap()
        .remove(0);
    assert_eq!(out.len(), m.probe.expected_logits.len());
    for (got, want) in out.iter().zip(m.probe.expected_logits.iter()) {
        assert!(
            (*got as f64 - want).abs() < 1e-3 * (1.0 + want.abs()),
            "logit mismatch: got {got}, want {want}"
        );
    }
}

#[test]
fn rust_dqn_forward_matches_pjrt_artifact() {
    // same weights → the in-process rust MLP and the AOT dqn_q artifact
    // must produce (near-)identical Q-values. This is the guarantee that
    // lets the coordinator train in rust and deploy through PJRT.
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load_filtered(&dir, Some(&["dqn_q"])).unwrap();
    let d = &engine.manifest.dqn;

    let mut dims = vec![d.state_dim];
    dims.extend(&d.hidden);
    dims.push(d.action_dim);
    let mut rng = Pcg32::seeded(42);
    let mlp = Mlp::new(&dims, &mut rng);

    let mut scratch = InferScratch::default();
    for trial in 0..5u64 {
        let mut srng = Pcg32::seeded(100 + trial);
        let state: Vec<f32> = (0..d.state_dim).map(|_| srng.next_f32()).collect();
        let rust_q = mlp.infer(&state, &mut scratch);

        let args = mlp.flat_args();
        let mut inputs: Vec<&[f32]> = vec![&state];
        for a in &args {
            inputs.push(a);
        }
        let pjrt_q = engine.execute_f32("dqn_q", &inputs).unwrap().remove(0);
        assert_eq!(pjrt_q.len(), d.action_dim);
        for (r, p) in rust_q.iter().zip(pjrt_q.iter()) {
            assert!((r - p).abs() < 1e-4, "rust {r} vs pjrt {p}");
        }
    }
}

#[test]
fn batched_mlp_forward_matches_infer() {
    // sanity for the parity test above: batch path == scratch path
    let mut rng = Pcg32::seeded(5);
    let mlp = Mlp::new(&[8, 128, 64, 32, 41], &mut rng);
    let state: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
    let t = Tensor2::from_vec(1, 8, state.clone());
    let batch = mlp.forward(&t).output;
    let mut scratch = InferScratch::default();
    let single = mlp.infer(&state, &mut scratch);
    for (a, b) in batch.data.iter().zip(single.iter()) {
        assert!((a - b).abs() < 1e-6);
    }
}

#[test]
fn pipeline_end_to_end_accuracy_matches_manifest() {
    let Some(dir) = artifacts_dir() else { return };
    let pipeline = Pipeline::load(&dir).unwrap();
    let engine = pipeline.engine();
    let (imgs, labels, img_len) = load_testset(engine, &dir);
    let n = 64.min(labels.len());

    let requests: Vec<PipelineRequest> = (0..n)
        .map(|i| PipelineRequest {
            id: i as u64,
            image: imgs[i * img_len..(i + 1) * img_len].to_vec(),
            label: Some(labels[i]),
            xi: 0.5,
            lambda: 0.5,
        })
        .collect();
    let responses = pipeline.serve(requests).unwrap();
    assert_eq!(responses.len(), n);

    let correct = responses.iter().filter(|r| r.correct == Some(true)).count();
    let acc = correct as f64 / n as f64;
    let promised = engine.manifest.accuracy["collab_k8"];
    assert!(
        acc > promised - 0.12,
        "pipeline accuracy {acc} far below python-measured {promised}"
    );

    // phase timings and payloads are sane
    for r in &responses {
        assert!(r.t_total_s > 0.0 && r.t_total_s < 5.0);
        assert!(r.payload_bytes > 0, "xi=0.5 must offload something");
        assert_eq!(r.local_channels, 8);
        let imp_sum: f64 = r.importance.iter().sum();
        assert!((imp_sum - 1.0).abs() < 1e-3, "importance sums to {imp_sum}");
    }
}

#[test]
fn pipeline_edge_only_needs_no_cloud() {
    let Some(dir) = artifacts_dir() else { return };
    let pipeline = Pipeline::load(&dir).unwrap();
    let engine = pipeline.engine();
    let (imgs, labels, img_len) = load_testset(engine, &dir);
    let requests: Vec<PipelineRequest> = (0..16)
        .map(|i| PipelineRequest {
            id: i as u64,
            image: imgs[i * img_len..(i + 1) * img_len].to_vec(),
            label: Some(labels[i]),
            xi: 0.0,
            lambda: 0.5,
        })
        .collect();
    let responses = pipeline.serve(requests).unwrap();
    let correct = responses.iter().filter(|r| r.correct == Some(true)).count();
    assert!(responses.iter().all(|r| r.payload_bytes == 0));
    // edge-only accuracy should track the python-measured edge_only
    let acc = correct as f64 / responses.len() as f64;
    let promised = engine.manifest.accuracy["edge_only"];
    assert!(acc > promised - 0.15, "edge acc {acc} vs promised {promised}");
}

#[test]
fn quantized_offload_changes_little() {
    // int8 round trip: remote logits from quantized features must stay
    // close to logits from raw features (the <1% accuracy-loss mechanism)
    let Some(dir) = artifacts_dir() else { return };
    let engine =
        Engine::load_filtered(&dir, Some(&["extractor", "offload_prep", "remote_head"]))
            .unwrap();
    let (imgs, _, img_len) = load_testset(&engine, &dir);
    let m = &engine.manifest;
    let outs = engine
        .execute_f32("extractor", &[&imgs[..img_len]])
        .unwrap();
    let features = &outs[0];
    let inv_mask = vec![1.0f32; m.feat_channels];

    let dq = engine
        .execute_f32("offload_prep", &[features, &inv_mask])
        .unwrap()
        .remove(0);
    let logits_q = engine
        .execute_f32("remote_head", &[&dq, &inv_mask])
        .unwrap()
        .remove(0);
    let logits_raw = engine
        .execute_f32("remote_head", &[features, &inv_mask])
        .unwrap()
        .remove(0);
    let max_abs = logits_raw
        .iter()
        .fold(0f32, |a, &x| a.max(x.abs()))
        .max(1e-6);
    for (q, r) in logits_q.iter().zip(logits_raw.iter()) {
        assert!(
            (q - r).abs() / max_abs < 0.05,
            "int8 perturbation too large: {q} vs {r}"
        );
    }
}
