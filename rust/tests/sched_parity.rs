//! Heap-vs-calendar scheduler parity gate.
//!
//! The calendar queue (`rust/src/coordinator/sched.rs`) is only allowed
//! to exist because it pops in the **identical** `(f64::total_cmp(time),
//! seq)` total order as the binary heap for any push sequence — that
//! contract is what lets every golden/parity/determinism gate run
//! unchanged under either backend. This test drives both backends with
//! the same randomized push/pop/pop_before interleavings — clustered
//! short-horizon timers, exact ties, far-future outliers, negative
//! times, and NaN/±inf injection — and asserts the popped `(time-bits,
//! seq, payload)` sequences are bit-identical, plus a deterministic
//! burst→drain case that forces both the bucket-grow and bucket-shrink
//! resize paths.

use dvfo::coordinator::sched::SchedKind;
use dvfo::coordinator::Sched;
use dvfo::proptest_mini::{check, vec_of};
use dvfo::util::Pcg32;

/// Derive an adversarial push time from one raw `(selector, unit)`
/// pair: the categories the calendar queue has to get right.
fn time_from(sel: usize, u: f64) -> f64 {
    match sel % 8 {
        // clustered short-horizon timers (the batching-window workload)
        0 | 1 | 2 => u * 0.01,
        // quantized times -> exact ties, resolved by seq alone
        3 => (u * 4.0).floor() * 0.25,
        // spread across many bucket-years
        4 => u * 1e4,
        // far-future outliers that must ride the overflow list
        5 => 1e9 + u * 1e12,
        // negative times (day arithmetic must floor, not truncate)
        6 => -u,
        // non-finite injection: total_cmp slots them deterministically
        _ => {
            if u < 0.25 {
                f64::NAN
            } else if u < 0.5 {
                f64::INFINITY
            } else if u < 0.75 {
                f64::NEG_INFINITY
            } else {
                0.0
            }
        }
    }
}

/// Exact-equality check of one popped observation: `(time, seq,
/// payload)` with the time compared by raw bits (NaN included).
fn compare(
    h: Option<(f64, u64, usize)>,
    c: Option<(f64, u64, usize)>,
    what: &str,
) -> Result<(), String> {
    match (h, c) {
        (None, None) => Ok(()),
        (Some(h), Some(c)) => {
            if h.0.to_bits() != c.0.to_bits() || h.1 != c.1 || h.2 != c.2 {
                Err(format!("{what}: heap {h:?} vs calendar {c:?}"))
            } else {
                Ok(())
            }
        }
        (h, c) => Err(format!("{what}: heap {h:?} vs calendar {c:?}")),
    }
}

/// Replay one op sequence against both backends; every observable —
/// pop/pop_before results (time bits, seq, payload), peek_time bits,
/// and len — must agree exactly.
fn replay(ops: &[(usize, f64)]) -> Result<(), String> {
    let mut heap: Sched<usize> = Sched::new(SchedKind::Heap);
    let mut cal: Sched<usize> = Sched::new(SchedKind::Calendar);
    let mut pushes = 0usize;
    for &(sel, u) in ops {
        match sel % 10 {
            0 | 1 | 2 => {
                let h = heap.pop().map(|e| (e.time, e.seq, e.ev));
                let c = cal.pop().map(|e| (e.time, e.seq, e.ev));
                compare(h, c, "pop")?;
            }
            3 => {
                // the epoch-boundary op: pops only strictly-before t
                let t = time_from(sel.wrapping_add(1), u);
                let h = heap.pop_before(t).map(|e| (e.time, e.seq, e.ev));
                let c = cal.pop_before(t).map(|e| (e.time, e.seq, e.ev));
                compare(h, c, "pop_before")?;
            }
            _ => {
                let t = time_from(sel, u);
                heap.push(t, pushes);
                cal.push(t, pushes);
                pushes += 1;
            }
        }
        let (ph, pc) = (heap.peek_time(), cal.peek_time());
        if ph.map(f64::to_bits) != pc.map(f64::to_bits) {
            return Err(format!("peek_time: heap {ph:?} vs calendar {pc:?}"));
        }
        if heap.len() != cal.len() {
            return Err(format!("len: heap {} vs calendar {}", heap.len(), cal.len()));
        }
    }
    // full drain: the tail must agree too
    loop {
        let h = heap.pop().map(|e| (e.time, e.seq, e.ev));
        let c = cal.pop().map(|e| (e.time, e.seq, e.ev));
        let done = h.is_none();
        compare(h, c, "drain")?;
        if done {
            break;
        }
    }
    Ok(())
}

#[test]
fn randomized_interleavings_pop_bit_identically() {
    let op = |r: &mut Pcg32| (r.below(1000) as usize, r.range_f64(0.0, 1.0));
    check("sched parity", 0xCA1E17DA, 300, vec_of(op, 0, 240), |ops| {
        replay(ops)
    });
}

#[test]
fn burst_then_drain_forces_grow_and_shrink_with_parity() {
    // arrival burst of clustered timers (plus a sprinkle of far-future
    // outliers) blows past the grow threshold; the drain then crosses
    // the shrink threshold. Pop order must track the heap throughout.
    let mut heap: Sched<usize> = Sched::new(SchedKind::Heap);
    let mut cal: Sched<usize> = Sched::new(SchedKind::Calendar);
    let n0 = cal.bucket_count().unwrap();
    let mut rng = Pcg32::seeded(77);
    for i in 0..4096 {
        let t = if i % 97 == 0 {
            1e9 + i as f64
        } else {
            rng.range_f64(0.0, 0.5)
        };
        heap.push(t, i);
        cal.push(t, i);
    }
    let grown = cal.bucket_count().unwrap();
    assert!(grown > n0, "burst must grow the day array: {grown} vs {n0}");
    let mut min_after_growth = grown;
    for _ in 0..4096 {
        let h = heap.pop().expect("heap drained early");
        let c = cal.pop().expect("calendar drained early");
        assert_eq!(h.time.to_bits(), c.time.to_bits());
        assert_eq!(h.seq, c.seq);
        assert_eq!(h.ev, c.ev);
        min_after_growth = min_after_growth.min(cal.bucket_count().unwrap());
    }
    assert!(cal.pop().is_none());
    assert!(
        min_after_growth < grown,
        "drain must shrink the day array: stayed at {grown}"
    );
}
