//! Property-based integration tests over coordinator invariants
//! (routing, batching, state), per the repo test plan: proptest_mini
//! drives randomized configurations through the full simulated stack.

use dvfo::configx::Config;
use dvfo::coordinator::{Coordinator, Decision};
use dvfo::offload::Compression;
use dvfo::proptest_mini as pt;
use dvfo::util::Pcg32;
use dvfo::workload::{Arrivals, TaskGen};

fn rand_cfg(rng: &mut Pcg32) -> Config {
    let mut cfg = Config::default();
    let devices = ["jetson-nano", "jetson-tx2", "xavier-nx"];
    let models = [
        "resnet-18",
        "mobilenet-v2",
        "efficientnet-b0",
        "vit-b16",
        "deepspeech",
    ];
    let policies = ["dvfo", "drldo", "appealnet", "cloud_only", "edge_only"];
    cfg.device = devices[rng.below(3) as usize].into();
    cfg.model = models[rng.below(5) as usize].into();
    cfg.dataset = if rng.chance(0.5) { "cifar100" } else { "imagenet" }.into();
    cfg.policy = policies[rng.below(5) as usize].into();
    cfg.eta = rng.next_f64();
    cfg.lambda = rng.next_f64();
    cfg.bandwidth = format!("static:{:.1}", 0.5 + 8.0 * rng.next_f64());
    cfg.seed = rng.next_u64();
    cfg
}

#[test]
fn every_report_is_physically_consistent() {
    // For random (device, model, dataset, policy, η, λ, bandwidth):
    //   * all latency phases ≥ 0 and sum to the total (± decision+DVFS)
    //   * energy split sums; cost = η·ETI + (1-η)·Pmax·TTI
    //   * ξ ∈ [0,1]; payload > 0 iff ξ > 0; accuracy ∈ (0, 100]
    pt::check(
        "task report physics",
        0xD1F0,
        40,
        |r: &mut Pcg32| rand_cfg(r),
        |cfg| {
            let mut coord = Coordinator::from_config(cfg).map_err(|e| e.to_string())?;
            let mut gen = TaskGen::new(
                &cfg.model,
                coord.env.dataset,
                Arrivals::Sequential,
                cfg.seed,
            )
            .map_err(|e| e.to_string())?;
            for t in gen.take(5) {
                let r = coord.step(&t, false);
                let phases =
                    r.tti_local_s + r.tti_comp_s + r.tti_off_s + r.tti_cloud_s + r.tti_decision_s;
                if !(r.tti_total_s >= phases - 1e-9
                    && r.tti_total_s <= phases + 1e-3)
                {
                    return Err(format!("phase sum {phases} vs total {}", r.tti_total_s));
                }
                if (r.eti_total_j - r.eti_compute_j - r.eti_offload_j).abs() > 1e-9 {
                    return Err("energy split mismatch".into());
                }
                let spec = coord.env.edge.spec();
                let want_cost = coord.env.eta * r.eti_total_j
                    + (1.0 - coord.env.eta) * spec.max_power_w * r.tti_total_s;
                if (r.cost - want_cost).abs() > 1e-9 {
                    return Err(format!("cost {} vs eq4 {}", r.cost, want_cost));
                }
                if !(0.0..=1.0).contains(&r.xi) {
                    return Err(format!("xi {}", r.xi));
                }
                if (r.xi > 0.0) != (r.payload_bytes > 0.0) {
                    return Err("payload iff offload violated".into());
                }
                if !(r.accuracy_pct > 0.0 && r.accuracy_pct <= 100.0) {
                    return Err(format!("accuracy {}", r.accuracy_pct));
                }
                for p in 0..3 {
                    for u in 0..3 {
                        if r.phase_freqs[p][u] <= 0.0 {
                            return Err("non-positive phase frequency".into());
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn frequencies_always_within_device_ladder() {
    pt::check(
        "freq bounds",
        0xF4E0,
        30,
        |r: &mut Pcg32| rand_cfg(r),
        |cfg| {
            let mut coord = Coordinator::from_config(cfg).map_err(|e| e.to_string())?;
            let mut gen = TaskGen::new(
                &cfg.model,
                coord.env.dataset,
                Arrivals::Sequential,
                cfg.seed ^ 1,
            )
            .map_err(|e| e.to_string())?;
            for t in gen.take(4) {
                let r = coord.step(&t, false);
                let spec = coord.env.edge.spec();
                let ladders = [&spec.cpu, &spec.gpu, &spec.mem];
                for (f, l) in r.freqs.iter().zip(ladders.iter()) {
                    if *f < l.min_mhz - 1e-6 || *f > l.max_mhz + 1e-6 {
                        return Err(format!("freq {f} outside [{}, {}]", l.min_mhz, l.max_mhz));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn serve_is_deterministic_for_fixed_seed_policy() {
    // fixed policies must be bit-deterministic across runs
    let run = || {
        let mut cfg = Config::default();
        cfg.policy = "edge_only".into();
        cfg.seed = 99;
        let mut coord = Coordinator::from_config(&cfg).unwrap();
        let mut gen =
            TaskGen::new(&cfg.model, coord.env.dataset, Arrivals::Sequential, 99).unwrap();
        let tasks = gen.take(20);
        let s = coord.serve(&tasks);
        (s.tti_ms.mean(), s.eti_mj.mean(), s.cost.mean())
    };
    assert_eq!(run(), run());
}

#[test]
fn learning_policies_never_emit_out_of_range_actions() {
    pt::check(
        "action ranges",
        0xACE5,
        20,
        |r: &mut Pcg32| {
            let mut c = rand_cfg(r);
            c.policy = if r.chance(0.5) { "dvfo" } else { "drldo" }.into();
            c
        },
        |cfg| {
            let mut coord = Coordinator::from_config(cfg).map_err(|e| e.to_string())?;
            let mut gen = TaskGen::new(
                &cfg.model,
                coord.env.dataset,
                Arrivals::Sequential,
                cfg.seed ^ 2,
            )
            .map_err(|e| e.to_string())?;
            // includes the exploring (training) path
            coord.train(&mut gen, 2, 8);
            for t in gen.take(4) {
                let r = coord.step(&t, false);
                if !(0.0..=1.0).contains(&r.xi) {
                    return Err(format!("xi {}", r.xi));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn env_clone_isolated_from_original() {
    // the Oracle policy depends on clones not mutating the live env
    let cfg = Config::default();
    let mut coord = Coordinator::from_config(&cfg).unwrap();
    let mut gen =
        TaskGen::new(&cfg.model, coord.env.dataset, Arrivals::Sequential, 5).unwrap();
    let task = gen.next_task();
    let before = coord.env.link.mbps();
    let mut clone = coord.env.clone();
    for _ in 0..10 {
        clone.execute(&task, &Decision::edge_only_max(clone.levels()), 0.0);
    }
    assert_eq!(coord.env.link.mbps(), before);
    assert_eq!(coord.env.edge.transitions(), 0);
}

#[test]
fn drldo_never_compresses_dvfo_always_does_when_offloading() {
    let mut rng = Pcg32::seeded(0xC0);
    for _ in 0..10 {
        let mut cfg = rand_cfg(&mut rng);
        cfg.policy = "drldo".into();
        let mut coord = Coordinator::from_config(&cfg).unwrap();
        let mut gen =
            TaskGen::new(&cfg.model, coord.env.dataset, Arrivals::Sequential, cfg.seed).unwrap();
        let task = gen.next_task();
        let obs = coord.observe(&task);
        let d = coord.policy.decide(&obs);
        assert_eq!(d.compression, Compression::None);
        assert!(!d.importance_guided);

        cfg.policy = "dvfo".into();
        let mut coord2 = Coordinator::from_config(&cfg).unwrap();
        let d = coord2.policy.decide(&obs);
        assert_eq!(d.compression, Compression::Int8);
        assert!(d.importance_guided);
    }
}
