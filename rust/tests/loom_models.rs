//! Loom model checks for the two concurrency protocols no replay gate
//! can cover (everything else in the engine is deterministic
//! single-threaded DES, gated bit-for-bit by the golden/parity tests):
//!
//! 1. the shard epoch exchange (`coordinator/shard.rs` →
//!    `util::sync::EpochExchange`): publish → barrier → index-ordered
//!    read → adopt. The model proves no publication is lost, no read
//!    ever observes a neighboring epoch's value (barrier-separated
//!    visibility), and reads happen in ascending index order.
//! 2. the background-learner handshake (`dqn/learner.rs` →
//!    `util::sync::BoundedQueue` + snapshot helpers): bounded push /
//!    `Publish` marker / double-buffered snapshot / finish-drain. The
//!    model proves every adopted snapshot is a function of *exactly*
//!    the transitions pushed before its marker, and that close-then-
//!    drain loses nothing.
//!
//! Run with:
//!
//! ```sh
//! RUSTFLAGS="--cfg loom" cargo test -p dvfo --release --test loom_models
//! ```
//!
//! Under `--cfg loom`, `util::sync` compiles against `loom::sync`
//! primitives, so these models execute the *same* `Mutex`/`Condvar`
//! protocol code the production paths run. On a normal build this file
//! compiles to an empty test binary.

#![cfg(loom)]

use dvfo::util::sync::{adopt_snapshot, take_publish_buf, BoundedQueue, EpochExchange};
use loom::sync::Arc;
use loom::thread;

/// Shard epoch exchange: two participants, two epochs. Participant `k`
/// publishes `epoch * 10 + k`; every read must return both
/// participants' values *for the current epoch* in index order —
/// anything else is a lost transition (stale epoch-0 init value), a
/// torn epoch (mixing epoch `e` and `e±1`), or an ordering leak.
#[test]
fn epoch_exchange_no_lost_or_torn_publications() {
    loom::model(|| {
        let ex = Arc::new(EpochExchange::new(2, 0u64));
        let handles: Vec<_> = (0..2usize)
            .map(|k| {
                let ex = Arc::clone(&ex);
                thread::spawn(move || {
                    for epoch in 1..=2u64 {
                        let mut seen = Vec::new();
                        ex.exchange_with(k, epoch * 10 + k as u64, |i, &v| seen.push((i, v)));
                        assert_eq!(
                            seen,
                            vec![(0, epoch * 10), (1, epoch * 10 + 1)],
                            "participant {k} epoch {epoch}: torn or lost publication"
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

enum Msg {
    Step,
    Publish,
}

/// Background-learner handshake: the actor pushes `S S P S P` through a
/// capacity-1 queue (so every backpressure path is exercised) while the
/// worker applies steps and answers `Publish` markers through the
/// double-buffered snapshot cycle. Weights are modeled as "number of
/// steps applied", buffers as boxes, mirroring `BgLearner`'s worker
/// loop and `push()`/`finish()` exactly.
#[test]
fn learner_handshake_prefix_snapshots_and_lossless_drain() {
    loom::model(|| {
        let msgs = Arc::new(BoundedQueue::new(1));
        let snaps = Arc::new(BoundedQueue::new(1));
        let rets = Arc::new(BoundedQueue::new(2));

        let (wm, ws, wr) = (Arc::clone(&msgs), Arc::clone(&snaps), Arc::clone(&rets));
        let worker = thread::spawn(move || {
            let mut applied = 0u64;
            let mut spare = Some(Box::new(0u64));
            while let Some(msg) = wm.pop() {
                match msg {
                    Msg::Step => applied += 1,
                    Msg::Publish => {
                        let Some(mut buf) = take_publish_buf(&mut spare, &wr) else {
                            break;
                        };
                        *buf = applied;
                        if ws.push(buf).is_err() {
                            break;
                        }
                    }
                }
            }
            applied
        });

        let mut net = Box::new(u64::MAX);
        msgs.push(Msg::Step).unwrap();
        msgs.push(Msg::Step).unwrap();
        msgs.push(Msg::Publish).unwrap();
        assert!(adopt_snapshot(&mut net, &snaps, &rets));
        assert_eq!(*net, 2, "snapshot must be exactly f(S1, S2)");
        msgs.push(Msg::Step).unwrap();
        // second publish exercises the returns path: the worker's spare
        // is gone, so it must reuse the buffer the actor handed back
        msgs.push(Msg::Publish).unwrap();
        assert!(adopt_snapshot(&mut net, &snaps, &rets));
        assert_eq!(*net, 3, "second snapshot must be exactly f(S1, S2, S3)");

        msgs.close();
        snaps.close();
        rets.close();
        assert_eq!(worker.join().unwrap(), 3, "drain must lose no transition");
    });
}
