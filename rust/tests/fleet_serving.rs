//! Integration tests for the multi-edge fleet dispatcher
//! (`rust/src/coordinator/fleet.rs` over the unified kernel in
//! `rust/src/coordinator/engine.rs`):
//!
//! * the fleet parity gate — a 1-device fleet with round-robin routing,
//!   no SLOs, and admission disabled must reproduce `serve_multistream`
//!   reports task-for-task (both paths now share the kernel; the gate
//!   pins the N=1 delegation)
//! * admission control under overload strictly reduces p99 latency and
//!   SLO violations versus no admission
//! * heterogeneous routing and SLO accounting sanity
//! * cloud-side cross-device batching: occupancy, the size cap, the
//!   amortized-dispatch ledger, and window-0 inertness
//! * cross-device rebalancing: round-robin + re-route-before-shed +
//!   migration strictly beats round-robin alone on a skewed fleet,
//!   migration strictly shrinks latency on an imbalanced herd, migrated
//!   tasks keep their original arrival time (no clock reset on
//!   requeue), and a property check that no migration schedule ever
//!   loses or duplicates a task
//! * chaos: the skewed-fleet fault gate (re-route + migration strictly
//!   beats round-robin alone under an identical dropout schedule), a
//!   property check that no fault schedule breaks task conservation
//!   (`offered == completed + shed + failed`), and run-to-run bit
//!   determinism of a faulted run at 1 and 3 shards

use dvfo::configx::Config;
use dvfo::coordinator::des::{serve_multistream, DesOpts};
use dvfo::coordinator::fleet::{
    serve_fleet, serve_fleet_sharded, Admission, Fleet, FleetOpts, Router,
};
use dvfo::coordinator::{Coordinator, FaultSchedule, RetryPolicy};
use dvfo::perfmodel::CLOUD_DISPATCH_OVERHEAD_S;
use dvfo::workload::{Arrivals, SloClass, TaskGen};

fn cfg(policy: &str, seed: u64) -> Config {
    let mut c = Config::default();
    c.policy = policy.into();
    c.seed = seed;
    c
}

fn gens(
    c: &Config,
    dataset: dvfo::perfmodel::Dataset,
    n: usize,
    arrivals: Arrivals,
    base: u64,
) -> Vec<TaskGen> {
    (0..n)
        .map(|s| TaskGen::new(&c.model, dataset, arrivals.clone(), base + s as u64).unwrap())
        .collect()
}

#[test]
fn one_device_fleet_matches_serve_multistream_exactly() {
    // The parity gate: a 1-device fleet with round-robin routing, no
    // SLOs, and admission disabled must reproduce the single-edge
    // discrete-event core report-for-report, for every policy kind and
    // for both batched and unbatched uplinks.
    for policy in ["edge_only", "cloud_only", "appealnet", "dvfo"] {
        for batch_window_s in [0.0, 0.02] {
            let opts = DesOpts {
                batch_window_s,
                ..DesOpts::default()
            };

            let c1 = cfg(policy, 42);
            let mut des = Coordinator::from_config(&c1).unwrap();
            let mut g1 = gens(&c1, des.env.dataset, 3, Arrivals::Poisson { rate: 30.0 }, 7);
            let a = serve_multistream(&mut des, &mut g1, 8, &opts);

            let c2 = cfg(policy, 42);
            let mut fleet = Fleet::from_config(&c2).unwrap();
            assert_eq!(fleet.len(), 1);
            assert_eq!(fleet.names, vec![c2.device.clone()]);
            let arr = Arrivals::Poisson { rate: 30.0 };
            let mut g2 = gens(&c2, fleet.devices[0].env.dataset, 3, arr, 7);
            let fopts = FleetOpts {
                des: opts.clone(),
                router: Router::RoundRobin,
                admission: Admission::Off,
                ..FleetOpts::default()
            };
            let b = serve_fleet(&mut fleet, &mut g2, 8, &fopts);

            assert_eq!(a.count(), b.serve.count(), "{policy}");
            assert_eq!(b.offered, b.completed, "{policy}: nothing shed");
            assert_eq!(b.shed, 0, "{policy}");
            assert_eq!(b.downgraded, 0, "{policy}");
            assert_eq!(b.slo_violations, 0, "{policy}");
            for (x, y) in a.reports.iter().zip(b.serve.reports.iter()) {
                assert_eq!(x.tti_total_s, y.tti_total_s, "{policy}: tti");
                assert_eq!(x.eti_total_j, y.eti_total_j, "{policy}: eti");
                assert_eq!(x.cost, y.cost, "{policy}: cost");
                assert_eq!(x.xi, y.xi, "{policy}: xi");
                assert_eq!(x.accuracy_pct, y.accuracy_pct, "{policy}: accuracy");
                assert_eq!(x.payload_bytes, y.payload_bytes, "{policy}: payload");
                assert_eq!(x.freqs, y.freqs, "{policy}: freqs");
                assert_eq!(x.queue_wait_s, y.queue_wait_s, "{policy}: queue wait");
                assert_eq!(x.e2e_s, y.e2e_s, "{policy}: e2e");
                assert_eq!(x.batch_size, y.batch_size, "{policy}: batch size");
                assert_eq!(x.stream, y.stream, "{policy}: stream tag");
            }
            assert_eq!(a.e2e_ms.mean(), b.serve.e2e_ms.mean(), "{policy}");
            assert_eq!(a.cost.mean(), b.serve.cost.mean(), "{policy}");
        }
    }
}

/// Overload helper: one small device, offered load far beyond its
/// capacity, every task carrying a 200 ms deadline.
fn overloaded_run(admission: Admission) -> dvfo::coordinator::FleetSummary {
    let mut c = cfg("edge_only", 11);
    c.fleet = "jetson-nano".into();
    let mut fleet = Fleet::from_config(&c).unwrap();
    let slo = SloClass::parse("200").unwrap();
    let mut g: Vec<TaskGen> = (0..16)
        .map(|s| {
            TaskGen::new(
                &c.model,
                fleet.devices[0].env.dataset,
                Arrivals::Poisson { rate: 10.0 },
                3000 + s as u64,
            )
            .unwrap()
            .with_slo(slo)
        })
        .collect();
    let opts = FleetOpts {
        admission,
        ..FleetOpts::default()
    };
    serve_fleet(&mut fleet, &mut g, 6, &opts)
}

#[test]
fn admission_shed_cuts_p99_latency_and_violations_under_overload() {
    let off = overloaded_run(Admission::Off);
    let shed = overloaded_run(Admission::Shed);

    // the no-admission run is genuinely overloaded
    assert_eq!(off.offered, 96);
    assert_eq!(off.completed, 96);
    assert!(
        off.slo_violations > off.completed / 2,
        "overload must blow most deadlines: {} of {}",
        off.slo_violations,
        off.completed
    );

    // shedding actually happened, and what remained met more deadlines
    assert!(shed.shed > 0, "admission must shed under overload");
    assert_eq!(shed.completed + shed.shed, shed.offered);
    assert!(
        shed.serve.e2e_ms.p99() < off.serve.e2e_ms.p99(),
        "shed p99 {} must be strictly below no-admission p99 {}",
        shed.serve.e2e_ms.p99(),
        off.serve.e2e_ms.p99()
    );
    assert!(
        shed.slo_violations < off.slo_violations,
        "shed violations {} must be strictly below no-admission {}",
        shed.slo_violations,
        off.slo_violations
    );
    // goodput rate among completed tasks improves too
    let off_rate = off.goodput as f64 / off.completed as f64;
    let shed_rate = shed.goodput as f64 / shed.completed as f64;
    assert!(
        shed_rate > off_rate,
        "goodput rate {shed_rate} vs {off_rate}"
    );
}

#[test]
fn heterogeneous_fleet_shrinks_tail_latency_vs_single_overloaded_device() {
    // Same offered load on a lone jetson-nano (massively overloaded) vs
    // a 3-device fleet that adds tx2 + xavier capacity: every device
    // must contribute and the tail must collapse.
    let run = |fleet_spec: &str, router: Router| {
        let mut c = cfg("edge_only", 13);
        c.fleet = fleet_spec.into();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(
            &c,
            fleet.devices[0].env.dataset,
            8,
            Arrivals::Poisson { rate: 6.0 },
            5000,
        );
        let opts = FleetOpts {
            router,
            ..FleetOpts::default()
        };
        serve_fleet(&mut fleet, &mut g, 5, &opts)
    };
    let single = run("jetson-nano", Router::RoundRobin);
    let fleet = run("jetson-nano,jetson-tx2,xavier-nx", Router::ShortestQueue);
    assert_eq!(single.completed, 40);
    assert_eq!(fleet.completed, 40);
    assert!(fleet.per_device.iter().all(|d| d.served > 0));
    assert!(
        fleet.serve.e2e_ms.p95() < single.serve.e2e_ms.p95(),
        "fleet p95 {} vs single-device p95 {}",
        fleet.serve.e2e_ms.p95(),
        single.serve.e2e_ms.p95()
    );
}

#[test]
fn cloud_pool_is_shared_across_the_fleet() {
    // cloud_only traffic from every device lands in ONE bounded pool.
    // Batching dumps several offloads onto the pool at the same instant,
    // so a 1-slot pool serializes them and mean end-to-end latency must
    // come out strictly above the 8-slot run (the simulation is
    // deterministic, so any pool wait at all separates the two).
    let run = |slots: usize| {
        let mut c = cfg("cloud_only", 17);
        c.fleet = "xavier-nx,jetson-tx2".into();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&c, fleet.devices[0].env.dataset, 8, Arrivals::Sequential, 6000);
        let opts = FleetOpts {
            des: DesOpts {
                batch_window_s: 0.05,
                cloud_slots: slots,
                ..DesOpts::default()
            },
            ..FleetOpts::default()
        };
        serve_fleet(&mut fleet, &mut g, 4, &opts)
    };
    let tight = run(1);
    let wide = run(8);
    assert_eq!(tight.completed, 32);
    assert_eq!(wide.completed, 32);
    // batching actually grouped offloads
    assert!(tight.serve.batch_size.values().iter().any(|&b| b > 1.0));
    assert!(
        tight.serve.e2e_ms.mean() > wide.serve.e2e_ms.mean(),
        "1-slot pool mean {} must exceed 8-slot mean {}",
        tight.serve.e2e_ms.mean(),
        wide.serve.e2e_ms.mean()
    );
}

#[test]
fn cloud_batching_amortizes_dispatch_under_pool_contention() {
    // cloud_only herds from 2 devices into a 1-slot shared pool: with a
    // cloud batch window, invocations collapse, occupancy rises above 1
    // but never beyond the cap, and the amortized dispatch time follows
    // the ledger exactly: (jobs − invocations) × per-invocation overhead.
    let run = |cloud_batch_window_s: f64| {
        let mut c = cfg("cloud_only", 23);
        c.fleet = "xavier-nx,jetson-tx2".into();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&c, fleet.devices[0].env.dataset, 8, Arrivals::Sequential, 8000);
        let opts = FleetOpts {
            des: DesOpts {
                // wide uplink window: the t=0 herd ships as multi-member
                // uplink batches whose members co-arrive at the cloud
                // stage, so the cloud window deterministically merges
                batch_window_s: 10.0,
                cloud_batch_window_s,
                cloud_max_batch: 6,
                cloud_slots: 1,
                ..DesOpts::default()
            },
            ..FleetOpts::default()
        };
        serve_fleet(&mut fleet, &mut g, 4, &opts)
    };
    let solo = run(0.0);
    assert_eq!(solo.completed, 32);
    assert_eq!(solo.cloud_invocations, 32, "window 0: one invocation per job");
    assert!((solo.cloud_occupancy.mean() - 1.0).abs() < 1e-12);
    assert_eq!(solo.cloud_dispatch_saved_s, 0.0);

    let batched = run(0.05);
    assert_eq!(batched.completed, 32, "batching must not lose tasks");
    assert!(
        batched.cloud_invocations < 32,
        "window must merge invocations: {}",
        batched.cloud_invocations
    );
    assert!(batched.cloud_occupancy.mean() > 1.0);
    assert!(
        batched.cloud_occupancy.values().iter().all(|&o| o <= 6.0),
        "cap respected: {:?}",
        batched.cloud_occupancy.values()
    );
    let expected_saved =
        (32 - batched.cloud_invocations) as f64 * CLOUD_DISPATCH_OVERHEAD_S;
    assert!(
        (batched.cloud_dispatch_saved_s - expected_saved).abs() < 1e-12,
        "saved {} vs ledger {expected_saved}",
        batched.cloud_dispatch_saved_s
    );
}

/// Skewed-fleet helper: one fast xavier-nx and two slow jetson-nanos
/// behind a round-robin router, every task carrying a 250 ms deadline,
/// offered load far beyond the nanos' capacity (the multi-user
/// contention regime: a hot device sheds while a sibling has headroom).
fn skewed_run(reroute: bool, rebalance_window_s: f64) -> dvfo::coordinator::FleetSummary {
    let mut c = cfg("edge_only", 47);
    c.fleet = "xavier-nx,jetson-nano,jetson-nano".into();
    let mut fleet = Fleet::from_config(&c).unwrap();
    let slo = SloClass::parse("250").unwrap();
    let mut g: Vec<TaskGen> = (0..12)
        .map(|s| {
            TaskGen::new(
                &c.model,
                fleet.devices[0].env.dataset,
                Arrivals::Poisson { rate: 10.0 },
                12_000 + s as u64,
            )
            .unwrap()
            .with_slo(slo)
        })
        .collect();
    let opts = FleetOpts {
        admission: Admission::Shed,
        reroute,
        rebalance_window_s,
        migrate_threshold_s: 0.05,
        migrate_penalty_s: 0.002,
        ..FleetOpts::default()
    };
    serve_fleet(&mut fleet, &mut g, 10, &opts)
}

#[test]
fn rebalancing_beats_round_robin_alone_on_a_skewed_fleet() {
    // THE acceptance gate: at the same offered load, round-robin +
    // re-route-before-shed + migration must yield strictly higher
    // goodput and strictly fewer sheds than round-robin alone.
    let base = skewed_run(false, 0.0);
    let reb = skewed_run(true, 0.01);
    assert_eq!(base.offered, reb.offered, "same offered load");
    assert!(
        base.shed > 0,
        "baseline must actually shed under the skew: {} shed",
        base.shed
    );
    assert!(
        reb.goodput > base.goodput,
        "rebalanced goodput {} must strictly beat round-robin {}",
        reb.goodput,
        base.goodput
    );
    assert!(
        reb.shed < base.shed,
        "rebalanced sheds {} must be strictly below round-robin {}",
        reb.shed,
        base.shed
    );
    assert!(reb.rerouted > 0, "the skew must trigger re-routing");
    // conservation under rebalancing
    assert_eq!(reb.offered, reb.completed + reb.shed);
    let rerouted_in: usize = reb.per_device.iter().map(|d| d.rerouted_in).sum();
    assert_eq!(rerouted_in, reb.rerouted);
}

#[test]
fn migration_shrinks_latency_on_an_imbalanced_herd() {
    // A t=0 herd split round-robin between one fast and one slow device
    // (no SLOs, no admission): work stealing must move queued tasks off
    // the slow device and strictly cut mean end-to-end latency.
    let run = |rebalance_window_s: f64| {
        let mut c = cfg("edge_only", 53);
        c.fleet = "xavier-nx,jetson-nano".into();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&c, fleet.devices[0].env.dataset, 8, Arrivals::Sequential, 13_000);
        let opts = FleetOpts {
            rebalance_window_s,
            migrate_threshold_s: 0.03,
            migrate_penalty_s: 0.001,
            ..FleetOpts::default()
        };
        serve_fleet(&mut fleet, &mut g, 4, &opts)
    };
    let still = run(0.0);
    let moved = run(0.01);
    assert_eq!(still.completed, 32);
    assert_eq!(moved.completed, 32, "migration must not lose tasks");
    assert_eq!(still.migrated, 0);
    assert!(moved.migrated > 0, "the imbalance must trigger migration");
    // migrated tasks end up served by the fast device
    assert!(
        moved.per_device[0].served > still.per_device[0].served,
        "xavier served {} vs {} without migration",
        moved.per_device[0].served,
        still.per_device[0].served
    );
    assert_eq!(
        moved.per_device[1].migrated_out,
        moved.per_device[0].migrated_in
    );
    assert_eq!(
        moved.per_device.iter().map(|d| d.migrated_in).sum::<usize>(),
        moved.migrated
    );
    assert!(
        moved.serve.e2e_ms.mean() < still.serve.e2e_ms.mean(),
        "migrated mean e2e {} must be strictly below static {}",
        moved.serve.e2e_ms.mean(),
        still.serve.e2e_ms.mean()
    );
    // the reports carry the migration flag; `migrated` counts MOVES, so
    // a task that bounced twice is one flagged report but two moves
    let flagged = moved.serve.reports.iter().filter(|r| r.migrated).count();
    assert!(flagged > 0 && flagged <= moved.migrated, "{flagged} flagged");
}

#[test]
fn migrated_tasks_keep_their_original_arrival_time() {
    // Violation-accounting audit: a migrated task's queue wait and e2e
    // are measured from its ORIGINAL arrival (no clock reset on
    // requeue). With a huge migration penalty every migrated task must
    // show the penalty inside its queue wait and blow its deadline —
    // if the clock reset on requeue, its wait would look tiny and the
    // violation would vanish.
    let penalty_s = 5.0;
    let mut c = cfg("edge_only", 59);
    c.fleet = "xavier-nx,jetson-nano".into();
    let mut fleet = Fleet::from_config(&c).unwrap();
    let slo = SloClass::parse("400").unwrap();
    let mut g: Vec<TaskGen> = (0..8)
        .map(|s| {
            TaskGen::new(
                &c.model,
                fleet.devices[0].env.dataset,
                Arrivals::Sequential,
                14_000 + s as u64,
            )
            .unwrap()
            .with_slo(slo)
        })
        .collect();
    let opts = FleetOpts {
        rebalance_window_s: 0.01,
        migrate_threshold_s: 0.03,
        migrate_penalty_s: penalty_s,
        ..FleetOpts::default()
    };
    let s = serve_fleet(&mut fleet, &mut g, 4, &opts);
    assert_eq!(s.completed, 32, "migration must not lose tasks");
    let migrated: Vec<_> = s.serve.reports.iter().filter(|r| r.migrated).collect();
    assert!(!migrated.is_empty(), "the herd must trigger migration");
    for r in &migrated {
        assert!(
            r.queue_wait_s >= penalty_s,
            "migrated task wait {} must include the {}s transit (measured \
             from the original arrival)",
            r.queue_wait_s,
            penalty_s
        );
        assert!(r.e2e_s >= r.queue_wait_s, "e2e includes the wait");
    }
    assert!(
        s.slo_violations >= migrated.len(),
        "every migrated task blows the 400ms deadline: {} violations vs {}",
        s.slo_violations,
        migrated.len()
    );
}

#[test]
fn no_migration_schedule_loses_or_duplicates_tasks() {
    // Property: across random fleets, loads, SLOs, and rebalancing
    // schedules (tick period / threshold / penalty / re-routing), the
    // dispatcher conserves tasks exactly — offered = completed + shed,
    // one report per completed task, and the per-device migration
    // ledger balances.
    use dvfo::proptest_mini::{check, usize_in, Gen};
    let fleets = [
        "xavier-nx,jetson-nano",
        "xavier-nx,jetson-nano*2",
        "jetson-tx2*2,jetson-nano",
    ];
    let windows = [0.0, 0.002, 0.02];
    let thresholds = [f64::INFINITY, 0.05, 0.0];
    let penalties = [0.0, 0.001, 0.1];
    let slos = ["none", "200", "80,1"];
    check(
        "rebalancing task conservation",
        0xBA1A,
        10,
        |r: &mut dvfo::util::Pcg32| {
            (
                usize_in(0, 2).sample(r),
                usize_in(1, 6).sample(r),
                usize_in(1, 5).sample(r),
                usize_in(0, 2).sample(r),
                usize_in(0, 2).sample(r),
                usize_in(0, 2).sample(r),
                usize_in(0, 2).sample(r),
                usize_in(0, 1).sample(r),
                r.next_u64(),
            )
        },
        |&(fi, streams, per_stream, wi, ti, pi, si, rr, seed)| {
            let mut c = cfg("edge_only", seed);
            c.fleet = fleets[fi].into();
            let mut fleet = Fleet::from_config(&c).map_err(|e| e.to_string())?;
            let slo = SloClass::parse(slos[si]).map_err(|e| e.to_string())?;
            let mut g: Vec<TaskGen> = (0..streams)
                .map(|s| {
                    TaskGen::new(
                        &c.model,
                        fleet.devices[0].env.dataset,
                        Arrivals::Poisson { rate: 25.0 },
                        seed ^ (s as u64) << 3,
                    )
                    .map(|g| g.with_slo(slo))
                    .map_err(|e| e.to_string())
                })
                .collect::<Result<_, _>>()?;
            let opts = FleetOpts {
                admission: Admission::Shed,
                reroute: rr == 1,
                rebalance_window_s: windows[wi],
                migrate_threshold_s: thresholds[ti],
                migrate_penalty_s: penalties[pi],
                ..FleetOpts::default()
            };
            let s = serve_fleet(&mut fleet, &mut g, per_stream, &opts);
            if s.offered != streams * per_stream {
                return Err(format!("offered {} != {}", s.offered, streams * per_stream));
            }
            if s.offered != s.completed + s.shed {
                return Err(format!(
                    "conservation: offered {} vs completed {} + shed {}",
                    s.offered, s.completed, s.shed
                ));
            }
            if s.serve.reports.len() != s.completed {
                return Err(format!(
                    "duplicate/missing reports: {} vs {} completed",
                    s.serve.reports.len(),
                    s.completed
                ));
            }
            let served: usize = s.per_device.iter().map(|d| d.served).sum();
            if served != s.completed {
                return Err(format!("per-device served {served} != {}", s.completed));
            }
            let mig_in: usize = s.per_device.iter().map(|d| d.migrated_in).sum();
            let mig_out: usize = s.per_device.iter().map(|d| d.migrated_out).sum();
            if mig_in != s.migrated || mig_out != s.migrated {
                return Err(format!(
                    "migration ledger: {mig_in} in / {mig_out} out vs {} migrated",
                    s.migrated
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn cloud_window_zero_is_invariant_to_the_cloud_batch_cap() {
    // at --cloud-batch-window 0 the cap must be inert: runs with wildly
    // different caps produce bit-identical summaries
    let run = |cloud_max_batch: usize| {
        let mut c = cfg("cloud_only", 29);
        c.fleet = "xavier-nx,jetson-nano".into();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let arr = Arrivals::Poisson { rate: 25.0 };
        let mut g = gens(&c, fleet.devices[0].env.dataset, 4, arr, 9000);
        let opts = FleetOpts {
            des: DesOpts {
                batch_window_s: 0.01,
                cloud_batch_window_s: 0.0,
                cloud_max_batch,
                ..DesOpts::default()
            },
            ..FleetOpts::default()
        };
        serve_fleet(&mut fleet, &mut g, 5, &opts)
    };
    let a = run(1);
    let b = run(64);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.serve.e2e_ms.mean().to_bits(), b.serve.e2e_ms.mean().to_bits());
    assert_eq!(a.serve.cost.mean().to_bits(), b.serve.cost.mean().to_bits());
    assert_eq!(a.cloud_invocations, b.cloud_invocations);
}

/// Chaos-gate helper: a skewed fleet under cloud-only offloading with a
/// long mid-run dropout of device 1. The offered load saturates the
/// jetson-nano devices, so at the onset device 1 is guaranteed (by work
/// conservation, not timing luck) to hold queued and in-pipeline work
/// for the dropout to bite; with a 2-retry budget and 5–10 ms backoffs
/// the whole retry horizon fits inside the 2 s outage. Round-robin
/// alone can only re-offer killed work to the same dark radio until the
/// budget runs out and must shed the drained queue; re-route ships both
/// through the surviving siblings instead.
fn chaos_gate_run(reroute: bool) -> dvfo::coordinator::FleetSummary {
    let mut c = cfg("cloud_only", 61);
    c.fleet = "xavier-nx,jetson-nano*2".into();
    let mut fleet = Fleet::from_config(&c).unwrap();
    let slo = SloClass::parse("1000").unwrap();
    let mut g: Vec<TaskGen> = (0..9)
        .map(|s| {
            TaskGen::new(
                &c.model,
                fleet.devices[0].env.dataset,
                Arrivals::Poisson { rate: 25.0 },
                4400 + s as u64,
            )
            .unwrap()
            .with_slo(slo)
        })
        .collect();
    let opts = FleetOpts {
        admission: Admission::Shed,
        reroute,
        rebalance_window_s: if reroute { 0.01 } else { 0.0 },
        migrate_threshold_s: 0.05,
        migrate_penalty_s: 0.002,
        chaos: FaultSchedule::parse("down:1@150+2000").unwrap(),
        retry: RetryPolicy {
            max_retries: 2,
            backoff_base_s: 0.005,
        },
        ..FleetOpts::default()
    };
    serve_fleet(&mut fleet, &mut g, 8, &opts)
}

#[test]
fn reroute_and_migration_strictly_beat_rr_under_the_same_dropout() {
    let rr = chaos_gate_run(false);
    let rm = chaos_gate_run(true);
    for (tag, s) in [("rr", &rr), ("rr+reroute+migrate", &rm)] {
        assert_eq!(
            s.offered,
            s.completed + s.shed + s.failed,
            "{tag}: conservation (offered {} vs {} + {} + {})",
            s.offered,
            s.completed,
            s.shed,
            s.failed
        );
        assert_eq!(s.faults_injected, 1, "{tag}: one dropout window");
        assert_eq!(s.per_device[1].faults, 1, "{tag}: fault lands on device 1");
    }
    // the dropout must actually hurt the rr-alone run: retries fire and
    // some work exhausts its budget into terminal failures
    assert!(rr.retries > 0, "rr run must retry fault-killed work");
    assert!(
        rr.failed > 0,
        "the 2 s dropout must outlast the rr retry horizon (failed={})",
        rr.failed
    );
    // the acceptance gate: under the SAME schedule, re-route + migration
    // fails strictly fewer tasks AND completes strictly more in-deadline
    assert!(
        rm.failed < rr.failed,
        "re-route must fail strictly fewer: {} vs rr {}",
        rm.failed,
        rr.failed
    );
    assert!(
        rm.goodput > rr.goodput,
        "re-route goodput {} must strictly beat rr {}",
        rm.goodput,
        rr.goodput
    );
    // the win comes from real re-routing, not accounting slack
    assert!(rm.rerouted > 0, "the gate win must come from re-routes");
}

#[test]
fn no_fault_schedule_breaks_task_conservation() {
    // Property: across random fleets, loads, re-route settings, and
    // random fault schedules (dropouts, bandwidth collapses, cloud
    // outages at random onsets/durations), every offered task reaches
    // exactly one terminal state: offered == completed + shed + failed,
    // one report per completed task, and the per-device failure ledger
    // sums to the fleet total.
    use dvfo::proptest_mini::{check, usize_in, Gen};
    let fleets = [
        "xavier-nx,jetson-nano",
        "xavier-nx,jetson-nano*2",
        "jetson-tx2*2,jetson-nano",
    ];
    let fleet_sizes = [2usize, 3, 3];
    check(
        "chaos task conservation",
        0xC4A05,
        10,
        |r: &mut dvfo::util::Pcg32| {
            let fi = usize_in(0, 2).sample(r);
            let n_faults = usize_in(0, 3).sample(r);
            let mut spec = String::new();
            for k in 0..n_faults {
                if k > 0 {
                    spec.push_str("; ");
                }
                let dev = usize_in(0, fleet_sizes[fi] - 1).sample(r);
                let at = 50 + 37 * usize_in(0, 12).sample(r);
                let dur = 50 + 61 * usize_in(0, 10).sample(r);
                match usize_in(0, 2).sample(r) {
                    0 => spec.push_str(&format!("down:{dev}@{at}+{dur}")),
                    1 => spec.push_str(&format!("bw:{dev}@{at}+{dur}*0.25")),
                    _ => spec.push_str(&format!("cloud@{at}+{dur}")),
                }
            }
            (
                fi,
                usize_in(1, 6).sample(r),
                usize_in(1, 5).sample(r),
                usize_in(0, 1).sample(r),
                spec,
                r.next_u64(),
            )
        },
        |&(fi, streams, per_stream, rr, ref spec, seed)| {
            let mut c = cfg("cloud_only", seed);
            c.fleet = fleets[fi].into();
            let mut fleet = Fleet::from_config(&c).map_err(|e| e.to_string())?;
            let slo = SloClass::parse("200").map_err(|e| e.to_string())?;
            let mut g: Vec<TaskGen> = (0..streams)
                .map(|s| {
                    TaskGen::new(
                        &c.model,
                        fleet.devices[0].env.dataset,
                        Arrivals::Poisson { rate: 25.0 },
                        seed ^ (s as u64) << 5,
                    )
                    .map(|g| g.with_slo(slo))
                    .map_err(|e| e.to_string())
                })
                .collect::<Result<_, _>>()?;
            let opts = FleetOpts {
                admission: Admission::Shed,
                reroute: rr == 1,
                chaos: FaultSchedule::parse(spec).map_err(|e| e.to_string())?,
                retry: RetryPolicy {
                    max_retries: 2,
                    backoff_base_s: 0.004,
                },
                ..FleetOpts::default()
            };
            let s = serve_fleet(&mut fleet, &mut g, per_stream, &opts);
            if s.offered != streams * per_stream {
                return Err(format!("offered {} != {}", s.offered, streams * per_stream));
            }
            if s.offered != s.completed + s.shed + s.failed {
                return Err(format!(
                    "conservation: offered {} vs completed {} + shed {} + failed {}",
                    s.offered, s.completed, s.shed, s.failed
                ));
            }
            if s.serve.reports.len() != s.completed {
                return Err(format!(
                    "duplicate/missing reports: {} vs {} completed",
                    s.serve.reports.len(),
                    s.completed
                ));
            }
            let served: usize = s.per_device.iter().map(|d| d.served).sum();
            if served != s.completed {
                return Err(format!("per-device served {served} != {}", s.completed));
            }
            let dev_failed: usize = s.per_device.iter().map(|d| d.failed).sum();
            if dev_failed != s.failed {
                return Err(format!(
                    "per-device failure ledger {dev_failed} != {} failed",
                    s.failed
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn faulted_runs_are_bit_deterministic_at_one_and_three_shards() {
    // Run-to-run determinism with a fixed composite fault schedule
    // (dropout + cloud outage + bandwidth collapse): at 1 shard and at
    // 3 shards, repeating the run reproduces every chaos counter and a
    // bit-identical latency mean — retries, drains, and partitioned
    // fault replay introduce no nondeterminism, threaded or not.
    let run = |shards: usize| {
        let mut c = cfg("cloud_only", 87);
        c.fleet = "xavier-nx,jetson-tx2,jetson-nano".into();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let slo = SloClass::parse("300").unwrap();
        let mut g: Vec<TaskGen> = (0..6)
            .map(|s| {
                TaskGen::new(
                    &c.model,
                    fleet.devices[0].env.dataset,
                    Arrivals::Poisson { rate: 20.0 },
                    5200 + s as u64,
                )
                .unwrap()
                .with_slo(slo)
            })
            .collect();
        let opts = FleetOpts {
            admission: Admission::Shed,
            reroute: true,
            chaos: FaultSchedule::parse("down:1@100+400; cloud@200+80; bw:2@150+300*0.5")
                .unwrap(),
            retry: RetryPolicy {
                max_retries: 3,
                backoff_base_s: 0.005,
            },
            ..FleetOpts::default()
        };
        serve_fleet_sharded(&mut fleet, &mut g, 5, &opts, shards)
    };
    for shards in [1usize, 3] {
        let a = run(shards);
        let b = run(shards);
        assert_eq!(a.offered, b.offered, "{shards} shards: offered");
        assert_eq!(a.completed, b.completed, "{shards} shards: completed");
        assert_eq!(a.shed, b.shed, "{shards} shards: shed");
        assert_eq!(a.failed, b.failed, "{shards} shards: failed");
        assert_eq!(a.retries, b.retries, "{shards} shards: retries");
        assert_eq!(
            a.faults_injected, b.faults_injected,
            "{shards} shards: faults"
        );
        assert_eq!(
            a.drained_on_dropout, b.drained_on_dropout,
            "{shards} shards: drains"
        );
        assert_eq!(a.rerouted, b.rerouted, "{shards} shards: rerouted");
        assert_eq!(
            a.offered,
            a.completed + a.shed + a.failed,
            "{shards} shards: conservation"
        );
        assert_eq!(
            a.serve.e2e_ms.mean().to_bits(),
            b.serve.e2e_ms.mean().to_bits(),
            "{shards} shards: bit-identical latency mean"
        );
        assert!(a.faults_injected >= 3, "{shards} shards: schedule armed");
    }
}
