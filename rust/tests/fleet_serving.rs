//! Integration tests for the multi-edge fleet dispatcher
//! (`rust/src/coordinator/fleet.rs` over the unified kernel in
//! `rust/src/coordinator/engine.rs`):
//!
//! * the fleet parity gate — a 1-device fleet with round-robin routing,
//!   no SLOs, and admission disabled must reproduce `serve_multistream`
//!   reports task-for-task (both paths now share the kernel; the gate
//!   pins the N=1 delegation)
//! * admission control under overload strictly reduces p99 latency and
//!   SLO violations versus no admission
//! * heterogeneous routing and SLO accounting sanity
//! * cloud-side cross-device batching: occupancy, the size cap, the
//!   amortized-dispatch ledger, and window-0 inertness

use dvfo::configx::Config;
use dvfo::coordinator::des::{serve_multistream, DesOpts};
use dvfo::coordinator::fleet::{serve_fleet, Admission, Fleet, FleetOpts, Router};
use dvfo::coordinator::Coordinator;
use dvfo::perfmodel::CLOUD_DISPATCH_OVERHEAD_S;
use dvfo::workload::{Arrivals, SloClass, TaskGen};

fn cfg(policy: &str, seed: u64) -> Config {
    let mut c = Config::default();
    c.policy = policy.into();
    c.seed = seed;
    c
}

fn gens(
    c: &Config,
    dataset: dvfo::perfmodel::Dataset,
    n: usize,
    arrivals: Arrivals,
    base: u64,
) -> Vec<TaskGen> {
    (0..n)
        .map(|s| TaskGen::new(&c.model, dataset, arrivals, base + s as u64).unwrap())
        .collect()
}

#[test]
fn one_device_fleet_matches_serve_multistream_exactly() {
    // The parity gate: a 1-device fleet with round-robin routing, no
    // SLOs, and admission disabled must reproduce the single-edge
    // discrete-event core report-for-report, for every policy kind and
    // for both batched and unbatched uplinks.
    for policy in ["edge_only", "cloud_only", "appealnet", "dvfo"] {
        for batch_window_s in [0.0, 0.02] {
            let opts = DesOpts {
                batch_window_s,
                ..DesOpts::default()
            };

            let c1 = cfg(policy, 42);
            let mut des = Coordinator::from_config(&c1).unwrap();
            let mut g1 = gens(&c1, des.env.dataset, 3, Arrivals::Poisson { rate: 30.0 }, 7);
            let a = serve_multistream(&mut des, &mut g1, 8, &opts);

            let c2 = cfg(policy, 42);
            let mut fleet = Fleet::from_config(&c2).unwrap();
            assert_eq!(fleet.len(), 1);
            assert_eq!(fleet.names, vec![c2.device.clone()]);
            let arr = Arrivals::Poisson { rate: 30.0 };
            let mut g2 = gens(&c2, fleet.devices[0].env.dataset, 3, arr, 7);
            let fopts = FleetOpts {
                des: opts.clone(),
                router: Router::RoundRobin,
                admission: Admission::Off,
            };
            let b = serve_fleet(&mut fleet, &mut g2, 8, &fopts);

            assert_eq!(a.count(), b.serve.count(), "{policy}");
            assert_eq!(b.offered, b.completed, "{policy}: nothing shed");
            assert_eq!(b.shed, 0, "{policy}");
            assert_eq!(b.downgraded, 0, "{policy}");
            assert_eq!(b.slo_violations, 0, "{policy}");
            for (x, y) in a.reports.iter().zip(b.serve.reports.iter()) {
                assert_eq!(x.tti_total_s, y.tti_total_s, "{policy}: tti");
                assert_eq!(x.eti_total_j, y.eti_total_j, "{policy}: eti");
                assert_eq!(x.cost, y.cost, "{policy}: cost");
                assert_eq!(x.xi, y.xi, "{policy}: xi");
                assert_eq!(x.accuracy_pct, y.accuracy_pct, "{policy}: accuracy");
                assert_eq!(x.payload_bytes, y.payload_bytes, "{policy}: payload");
                assert_eq!(x.freqs, y.freqs, "{policy}: freqs");
                assert_eq!(x.queue_wait_s, y.queue_wait_s, "{policy}: queue wait");
                assert_eq!(x.e2e_s, y.e2e_s, "{policy}: e2e");
                assert_eq!(x.batch_size, y.batch_size, "{policy}: batch size");
                assert_eq!(x.stream, y.stream, "{policy}: stream tag");
            }
            assert_eq!(a.e2e_ms.mean(), b.serve.e2e_ms.mean(), "{policy}");
            assert_eq!(a.cost.mean(), b.serve.cost.mean(), "{policy}");
        }
    }
}

/// Overload helper: one small device, offered load far beyond its
/// capacity, every task carrying a 200 ms deadline.
fn overloaded_run(admission: Admission) -> dvfo::coordinator::FleetSummary {
    let mut c = cfg("edge_only", 11);
    c.fleet = "jetson-nano".into();
    let mut fleet = Fleet::from_config(&c).unwrap();
    let slo = SloClass::parse("200").unwrap();
    let mut g: Vec<TaskGen> = (0..16)
        .map(|s| {
            TaskGen::new(
                &c.model,
                fleet.devices[0].env.dataset,
                Arrivals::Poisson { rate: 10.0 },
                3000 + s as u64,
            )
            .unwrap()
            .with_slo(slo)
        })
        .collect();
    let opts = FleetOpts {
        admission,
        ..FleetOpts::default()
    };
    serve_fleet(&mut fleet, &mut g, 6, &opts)
}

#[test]
fn admission_shed_cuts_p99_latency_and_violations_under_overload() {
    let off = overloaded_run(Admission::Off);
    let shed = overloaded_run(Admission::Shed);

    // the no-admission run is genuinely overloaded
    assert_eq!(off.offered, 96);
    assert_eq!(off.completed, 96);
    assert!(
        off.slo_violations > off.completed / 2,
        "overload must blow most deadlines: {} of {}",
        off.slo_violations,
        off.completed
    );

    // shedding actually happened, and what remained met more deadlines
    assert!(shed.shed > 0, "admission must shed under overload");
    assert_eq!(shed.completed + shed.shed, shed.offered);
    assert!(
        shed.serve.e2e_ms.p99() < off.serve.e2e_ms.p99(),
        "shed p99 {} must be strictly below no-admission p99 {}",
        shed.serve.e2e_ms.p99(),
        off.serve.e2e_ms.p99()
    );
    assert!(
        shed.slo_violations < off.slo_violations,
        "shed violations {} must be strictly below no-admission {}",
        shed.slo_violations,
        off.slo_violations
    );
    // goodput rate among completed tasks improves too
    let off_rate = off.goodput as f64 / off.completed as f64;
    let shed_rate = shed.goodput as f64 / shed.completed as f64;
    assert!(
        shed_rate > off_rate,
        "goodput rate {shed_rate} vs {off_rate}"
    );
}

#[test]
fn heterogeneous_fleet_shrinks_tail_latency_vs_single_overloaded_device() {
    // Same offered load on a lone jetson-nano (massively overloaded) vs
    // a 3-device fleet that adds tx2 + xavier capacity: every device
    // must contribute and the tail must collapse.
    let run = |fleet_spec: &str, router: Router| {
        let mut c = cfg("edge_only", 13);
        c.fleet = fleet_spec.into();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(
            &c,
            fleet.devices[0].env.dataset,
            8,
            Arrivals::Poisson { rate: 6.0 },
            5000,
        );
        let opts = FleetOpts {
            router,
            ..FleetOpts::default()
        };
        serve_fleet(&mut fleet, &mut g, 5, &opts)
    };
    let single = run("jetson-nano", Router::RoundRobin);
    let fleet = run("jetson-nano,jetson-tx2,xavier-nx", Router::ShortestQueue);
    assert_eq!(single.completed, 40);
    assert_eq!(fleet.completed, 40);
    assert!(fleet.per_device.iter().all(|d| d.served > 0));
    assert!(
        fleet.serve.e2e_ms.p95() < single.serve.e2e_ms.p95(),
        "fleet p95 {} vs single-device p95 {}",
        fleet.serve.e2e_ms.p95(),
        single.serve.e2e_ms.p95()
    );
}

#[test]
fn cloud_pool_is_shared_across_the_fleet() {
    // cloud_only traffic from every device lands in ONE bounded pool.
    // Batching dumps several offloads onto the pool at the same instant,
    // so a 1-slot pool serializes them and mean end-to-end latency must
    // come out strictly above the 8-slot run (the simulation is
    // deterministic, so any pool wait at all separates the two).
    let run = |slots: usize| {
        let mut c = cfg("cloud_only", 17);
        c.fleet = "xavier-nx,jetson-tx2".into();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&c, fleet.devices[0].env.dataset, 8, Arrivals::Sequential, 6000);
        let opts = FleetOpts {
            des: DesOpts {
                batch_window_s: 0.05,
                cloud_slots: slots,
                ..DesOpts::default()
            },
            ..FleetOpts::default()
        };
        serve_fleet(&mut fleet, &mut g, 4, &opts)
    };
    let tight = run(1);
    let wide = run(8);
    assert_eq!(tight.completed, 32);
    assert_eq!(wide.completed, 32);
    // batching actually grouped offloads
    assert!(tight.serve.batch_size.values().iter().any(|&b| b > 1.0));
    assert!(
        tight.serve.e2e_ms.mean() > wide.serve.e2e_ms.mean(),
        "1-slot pool mean {} must exceed 8-slot mean {}",
        tight.serve.e2e_ms.mean(),
        wide.serve.e2e_ms.mean()
    );
}

#[test]
fn cloud_batching_amortizes_dispatch_under_pool_contention() {
    // cloud_only herds from 2 devices into a 1-slot shared pool: with a
    // cloud batch window, invocations collapse, occupancy rises above 1
    // but never beyond the cap, and the amortized dispatch time follows
    // the ledger exactly: (jobs − invocations) × per-invocation overhead.
    let run = |cloud_batch_window_s: f64| {
        let mut c = cfg("cloud_only", 23);
        c.fleet = "xavier-nx,jetson-tx2".into();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&c, fleet.devices[0].env.dataset, 8, Arrivals::Sequential, 8000);
        let opts = FleetOpts {
            des: DesOpts {
                // wide uplink window: the t=0 herd ships as multi-member
                // uplink batches whose members co-arrive at the cloud
                // stage, so the cloud window deterministically merges
                batch_window_s: 10.0,
                cloud_batch_window_s,
                cloud_max_batch: 6,
                cloud_slots: 1,
                ..DesOpts::default()
            },
            ..FleetOpts::default()
        };
        serve_fleet(&mut fleet, &mut g, 4, &opts)
    };
    let solo = run(0.0);
    assert_eq!(solo.completed, 32);
    assert_eq!(solo.cloud_invocations, 32, "window 0: one invocation per job");
    assert!((solo.cloud_occupancy.mean() - 1.0).abs() < 1e-12);
    assert_eq!(solo.cloud_dispatch_saved_s, 0.0);

    let batched = run(0.05);
    assert_eq!(batched.completed, 32, "batching must not lose tasks");
    assert!(
        batched.cloud_invocations < 32,
        "window must merge invocations: {}",
        batched.cloud_invocations
    );
    assert!(batched.cloud_occupancy.mean() > 1.0);
    assert!(
        batched.cloud_occupancy.values().iter().all(|&o| o <= 6.0),
        "cap respected: {:?}",
        batched.cloud_occupancy.values()
    );
    let expected_saved =
        (32 - batched.cloud_invocations) as f64 * CLOUD_DISPATCH_OVERHEAD_S;
    assert!(
        (batched.cloud_dispatch_saved_s - expected_saved).abs() < 1e-12,
        "saved {} vs ledger {expected_saved}",
        batched.cloud_dispatch_saved_s
    );
}

#[test]
fn cloud_window_zero_is_invariant_to_the_cloud_batch_cap() {
    // at --cloud-batch-window 0 the cap must be inert: runs with wildly
    // different caps produce bit-identical summaries
    let run = |cloud_max_batch: usize| {
        let mut c = cfg("cloud_only", 29);
        c.fleet = "xavier-nx,jetson-nano".into();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let arr = Arrivals::Poisson { rate: 25.0 };
        let mut g = gens(&c, fleet.devices[0].env.dataset, 4, arr, 9000);
        let opts = FleetOpts {
            des: DesOpts {
                batch_window_s: 0.01,
                cloud_batch_window_s: 0.0,
                cloud_max_batch,
                ..DesOpts::default()
            },
            ..FleetOpts::default()
        };
        serve_fleet(&mut fleet, &mut g, 5, &opts)
    };
    let a = run(1);
    let b = run(64);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.serve.e2e_ms.mean().to_bits(), b.serve.e2e_ms.mean().to_bits());
    assert_eq!(a.serve.cost.mean().to_bits(), b.serve.cost.mean().to_bits());
    assert_eq!(a.cloud_invocations, b.cloud_invocations);
}
