//! Golden-trace gate for the des.rs/fleet.rs → engine.rs unification.
//!
//! `reference` below is a frozen, verbatim-behavior copy of the
//! PRE-refactor fleet event loop (the machinery that used to live in
//! `rust/src/coordinator/fleet.rs` before it was collapsed into the
//! unified kernel), kept alive here — against the public API only — as
//! the golden implementation. The gate: at `--cloud-batch-window 0` a
//! 2-device fleet run through the new kernel must be **byte-identical**
//! (every f64 compared by bit pattern) to the pre-refactor machinery,
//! across batched/unbatched uplinks, routers, policies, and the
//! admission paths whose estimator did not change (edge-only traffic,
//! where the cloud-detour term is provably zero).
//!
//! The gate also pins the cross-device rebalancing compat condition:
//! with re-routing off and `--rebalance-window 0` no rebalance event is
//! ever scheduled, and with a window but `--migrate-threshold inf` the
//! ticks fire yet are fully inert — both configurations must reproduce
//! the pre-rebalancing trace bit-for-bit.

use dvfo::configx::Config;
use dvfo::coordinator::des::DesOpts;
use dvfo::coordinator::fleet::{serve_fleet, Admission, Fleet, FleetOpts, Router};
use dvfo::coordinator::TaskReport;
use dvfo::workload::{Arrivals, SloClass, TaskGen};

// =====================================================================
// frozen pre-refactor fleet event loop (golden reference) — do not
// "improve" this code; its whole value is that it does not change
// =====================================================================
mod reference {
    use dvfo::coordinator::env::TaskReport;
    use dvfo::coordinator::fleet::{Admission, Fleet, FleetOpts, Router};
    use dvfo::coordinator::LoadSignals;
    use dvfo::util::Ewma;
    use dvfo::workload::{Task, TaskGen};
    use std::cmp::Ordering;
    use std::collections::{BinaryHeap, VecDeque};

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    enum Ev {
        Arrival { stream: usize },
        EdgeDone { dev: usize, job: usize },
        BatchClose { dev: usize, generation: usize },
        UplinkDone { dev: usize, batch: usize },
        CloudDone { job: usize },
    }

    #[derive(Clone, Debug)]
    struct Event {
        time: f64,
        seq: u64,
        ev: Ev,
    }

    impl PartialEq for Event {
        fn eq(&self, other: &Self) -> bool {
            self.seq == other.seq
        }
    }

    impl Eq for Event {}

    impl PartialOrd for Event {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }

    impl Ord for Event {
        fn cmp(&self, other: &Self) -> Ordering {
            other
                .time
                .total_cmp(&self.time)
                .then_with(|| other.seq.cmp(&self.seq))
        }
    }

    struct EventQueue {
        heap: BinaryHeap<Event>,
        seq: u64,
    }

    impl EventQueue {
        fn push(&mut self, time: f64, ev: Ev) {
            self.heap.push(Event {
                time,
                seq: self.seq,
                ev,
            });
            self.seq += 1;
        }

        fn pop(&mut self) -> Option<Event> {
            self.heap.pop()
        }
    }

    struct Job {
        task: Task,
        stream: usize,
        dev: usize,
        arrival_s: f64,
        queue_wait_s: f64,
        solo_off_s: f64,
        cloud_s: f64,
        payload_bytes: f64,
        downgraded: bool,
        report: Option<TaskReport>,
    }

    struct DevState {
        edge_queue: VecDeque<usize>,
        edge_busy: bool,
        residency: Ewma,
        open_batch: Vec<usize>,
        batch_open_id: usize,
        uplink_queue: VecDeque<usize>,
        uplink_busy: bool,
    }

    impl DevState {
        fn new() -> Self {
            Self {
                edge_queue: VecDeque::new(),
                edge_busy: false,
                residency: Ewma::new(0.2),
                open_batch: Vec::new(),
                batch_open_id: 0,
                uplink_queue: VecDeque::new(),
                uplink_busy: false,
            }
        }

        fn in_system(&self) -> usize {
            self.edge_queue.len() + self.edge_busy as usize
        }

        /// the PRE-refactor admission estimator: edge backlog only
        fn est_completion_s(&self) -> Option<f64> {
            self.residency
                .get()
                .map(|res| res * (self.in_system() as f64 + 1.0))
        }
    }

    struct FleetState {
        q: EventQueue,
        jobs: Vec<Job>,
        devs: Vec<DevState>,
        batches: Vec<Vec<usize>>,
        cloud_active: usize,
        cloud_queue: VecDeque<usize>,
        opts: FleetOpts,
        rr_next: usize,
        shed: usize,
        downgraded: usize,
    }

    impl FleetState {
        fn route(&mut self, fleet: &Fleet) -> usize {
            let n = self.devs.len();
            match self.opts.router {
                Router::RoundRobin => {
                    let d = self.rr_next % n;
                    self.rr_next += 1;
                    d
                }
                Router::ShortestQueue => (0..n)
                    .min_by_key(|&d| self.devs[d].in_system())
                    .unwrap_or(0),
                Router::LeastBacklog => {
                    let score = |d: usize| {
                        let res = self.devs[d].residency.get().unwrap_or(1.0);
                        let power = fleet.devices[d].env.edge.spec().max_power_w;
                        self.devs[d].in_system() as f64 * res * power
                    };
                    (0..n)
                        .min_by(|&a, &b| score(a).total_cmp(&score(b)))
                        .unwrap_or(0)
                }
            }
        }

        fn enqueue_edge(&mut self, id: usize) {
            let dev = self.jobs[id].dev;
            let prio = self.jobs[id].task.priority;
            if prio == 0 {
                self.devs[dev].edge_queue.push_back(id);
                return;
            }
            let pos = self.devs[dev]
                .edge_queue
                .iter()
                .position(|&j| self.jobs[j].task.priority < prio)
                .unwrap_or(self.devs[dev].edge_queue.len());
            self.devs[dev].edge_queue.insert(pos, id);
        }

        fn maybe_start_edge(&mut self, fleet: &mut Fleet, dev: usize, now: f64) {
            if self.devs[dev].edge_busy {
                return;
            }
            let Some(id) = self.devs[dev].edge_queue.pop_front() else {
                return;
            };
            let coord = &mut fleet.devices[dev];
            coord.load.queue_depth = self.devs[dev].edge_queue.len();
            coord.load.backlog_s = self.devs[dev].residency.get().unwrap_or(0.0)
                * self.devs[dev].edge_queue.len() as f64;
            let force_edge = self.jobs[id].downgraded;
            let r = coord.step_constrained(&self.jobs[id].task, false, force_edge);
            let residency = (r.tti_total_s - r.tti_off_s - r.tti_cloud_s).max(0.0);
            self.devs[dev].residency.push(residency);
            let job = &mut self.jobs[id];
            job.queue_wait_s = (now - job.arrival_s).max(0.0);
            job.solo_off_s = r.tti_off_s;
            job.cloud_s = r.tti_cloud_s;
            job.payload_bytes = r.payload_bytes;
            job.report = Some(r);
            self.devs[dev].edge_busy = true;
            self.q.push(now + residency, Ev::EdgeDone { dev, job: id });
        }

        fn freeze_batch(&mut self, members: Vec<usize>) -> usize {
            self.batches.push(members);
            self.batches.len() - 1
        }

        fn flush_open_batch(&mut self, fleet: &Fleet, dev: usize, now: f64) {
            if self.devs[dev].open_batch.is_empty() {
                return;
            }
            let members = std::mem::take(&mut self.devs[dev].open_batch);
            self.devs[dev].batch_open_id += 1;
            let b = self.freeze_batch(members);
            self.devs[dev].uplink_queue.push_back(b);
            self.maybe_start_uplink(fleet, dev, now);
        }

        fn maybe_start_uplink(&mut self, fleet: &Fleet, dev: usize, now: f64) {
            if self.devs[dev].uplink_busy {
                return;
            }
            let Some(b) = self.devs[dev].uplink_queue.pop_front() else {
                return;
            };
            let members = self.batches[b].clone();
            let tx_s = if members.len() == 1 {
                self.jobs[members[0]].solo_off_s
            } else {
                let payload: f64 =
                    members.iter().map(|&id| self.jobs[id].payload_bytes).sum();
                fleet.devices[dev].env.link.tx_time_s(payload)
            };
            let n = members.len();
            for &id in &members {
                if let Some(r) = self.jobs[id].report.as_mut() {
                    r.batch_size = n;
                }
            }
            self.devs[dev].uplink_busy = true;
            self.q.push(now + tx_s, Ev::UplinkDone { dev, batch: b });
        }

        fn dispatch_cloud(&mut self, id: usize, now: f64) {
            if self.cloud_active < self.opts.des.cloud_slots {
                self.cloud_active += 1;
                self.q
                    .push(now + self.jobs[id].cloud_s, Ev::CloudDone { job: id });
            } else {
                self.cloud_queue.push_back(id);
            }
        }

        fn finish(&mut self, id: usize, now: f64) {
            let job = &mut self.jobs[id];
            if let Some(r) = job.report.as_mut() {
                r.queue_wait_s = job.queue_wait_s;
                r.e2e_s = (now - job.arrival_s).max(0.0);
                r.stream = job.stream;
            }
        }

        fn admit(&self, dev: usize, task: &Task) -> Verdict {
            if self.opts.admission == Admission::Off || !task.deadline_s.is_finite() {
                return Verdict::Accept;
            }
            let Some(est) = self.devs[dev].est_completion_s() else {
                return Verdict::Accept;
            };
            if est <= task.deadline_s {
                return Verdict::Accept;
            }
            match self.opts.admission {
                Admission::Shed if task.priority == 0 => Verdict::Shed,
                _ => Verdict::Downgrade,
            }
        }
    }

    enum Verdict {
        Accept,
        Shed,
        Downgrade,
    }

    /// Outcome of one golden run: per-job reports in creation order plus
    /// the admission counters.
    pub struct GoldenRun {
        pub reports: Vec<TaskReport>,
        pub offered: usize,
        pub shed: usize,
        pub downgraded: usize,
    }

    pub fn serve_fleet(
        fleet: &mut Fleet,
        gens: &mut [TaskGen],
        per_stream: usize,
        opts: &FleetOpts,
    ) -> GoldenRun {
        for coord in fleet.devices.iter_mut() {
            coord.policy.set_training(false);
        }
        let streams = gens.len();
        let mut state = FleetState {
            q: EventQueue {
                heap: BinaryHeap::new(),
                seq: 0,
            },
            jobs: Vec::with_capacity(streams * per_stream),
            devs: (0..fleet.len()).map(|_| DevState::new()).collect(),
            batches: Vec::new(),
            cloud_active: 0,
            cloud_queue: VecDeque::new(),
            opts: opts.clone(),
            rr_next: 0,
            shed: 0,
            downgraded: 0,
        };
        let mut offered = 0usize;

        let mut next_task: Vec<Option<Task>> = Vec::with_capacity(streams);
        let mut remaining: Vec<usize> = vec![per_stream; streams];
        for (s, gen) in gens.iter_mut().enumerate() {
            let t = gen.next_task();
            remaining[s] -= 1;
            state.q.push(t.arrival_s, Ev::Arrival { stream: s });
            next_task.push(Some(t));
        }

        while let Some(ev) = state.q.pop() {
            let now = ev.time;
            match ev.ev {
                Ev::Arrival { stream } => {
                    let task = next_task[stream]
                        .take()
                        .expect("arrival without pending task");
                    if remaining[stream] > 0 {
                        remaining[stream] -= 1;
                        let t = gens[stream].next_task();
                        state.q.push(t.arrival_s, Ev::Arrival { stream });
                        next_task[stream] = Some(t);
                    }
                    offered += 1;
                    let dev = state.route(fleet);
                    let downgraded = match state.admit(dev, &task) {
                        Verdict::Shed => {
                            state.shed += 1;
                            continue;
                        }
                        Verdict::Downgrade => {
                            state.downgraded += 1;
                            true
                        }
                        Verdict::Accept => false,
                    };
                    let id = state.jobs.len();
                    state.jobs.push(Job {
                        task,
                        stream,
                        dev,
                        arrival_s: now,
                        queue_wait_s: 0.0,
                        solo_off_s: 0.0,
                        cloud_s: 0.0,
                        payload_bytes: 0.0,
                        downgraded,
                        report: None,
                    });
                    state.enqueue_edge(id);
                    state.maybe_start_edge(fleet, dev, now);
                }
                Ev::EdgeDone { dev, job: id } => {
                    state.devs[dev].edge_busy = false;
                    let offloads = state.jobs[id]
                        .report
                        .as_ref()
                        .map(|r| r.xi > 0.0)
                        .unwrap_or(false);
                    if offloads {
                        if state.opts.des.batch_window_s > 0.0 {
                            if state.devs[dev].open_batch.is_empty() {
                                state.q.push(
                                    now + state.opts.des.batch_window_s,
                                    Ev::BatchClose {
                                        dev,
                                        generation: state.devs[dev].batch_open_id,
                                    },
                                );
                            }
                            state.devs[dev].open_batch.push(id);
                            if state.devs[dev].open_batch.len() >= state.opts.des.max_batch {
                                state.flush_open_batch(fleet, dev, now);
                            }
                        } else {
                            let b = state.freeze_batch(vec![id]);
                            state.devs[dev].uplink_queue.push_back(b);
                            state.maybe_start_uplink(fleet, dev, now);
                        }
                    } else {
                        state.finish(id, now);
                    }
                    state.maybe_start_edge(fleet, dev, now);
                }
                Ev::BatchClose { dev, generation } => {
                    if generation == state.devs[dev].batch_open_id {
                        state.flush_open_batch(fleet, dev, now);
                    }
                }
                Ev::UplinkDone { dev, batch } => {
                    state.devs[dev].uplink_busy = false;
                    let members = state.batches[batch].clone();
                    for id in members {
                        state.dispatch_cloud(id, now);
                    }
                    state.maybe_start_uplink(fleet, dev, now);
                }
                Ev::CloudDone { job: id } => {
                    state.cloud_active -= 1;
                    state.finish(id, now);
                    if let Some(next) = state.cloud_queue.pop_front() {
                        state.cloud_active += 1;
                        state
                            .q
                            .push(now + state.jobs[next].cloud_s, Ev::CloudDone { job: next });
                    }
                }
            }
        }

        for coord in fleet.devices.iter_mut() {
            coord.load = LoadSignals::default();
        }

        GoldenRun {
            reports: state.jobs.into_iter().filter_map(|j| j.report).collect(),
            offered,
            shed: state.shed,
            downgraded: state.downgraded,
        }
    }
}

// =====================================================================
// the gate
// =====================================================================

fn assert_bits(a: f64, b: f64, what: &str) {
    assert_eq!(a.to_bits(), b.to_bits(), "{what}: {a} vs {b}");
}

fn assert_reports_byte_identical(golden: &TaskReport, new: &TaskReport, ctx: &str) {
    assert_bits(golden.tti_local_s, new.tti_local_s, &format!("{ctx}: tti_local"));
    assert_bits(golden.tti_comp_s, new.tti_comp_s, &format!("{ctx}: tti_comp"));
    assert_bits(golden.tti_off_s, new.tti_off_s, &format!("{ctx}: tti_off"));
    assert_bits(golden.tti_cloud_s, new.tti_cloud_s, &format!("{ctx}: tti_cloud"));
    assert_bits(
        golden.tti_decision_s,
        new.tti_decision_s,
        &format!("{ctx}: tti_decision"),
    );
    assert_bits(golden.tti_total_s, new.tti_total_s, &format!("{ctx}: tti_total"));
    assert_bits(
        golden.eti_compute_j,
        new.eti_compute_j,
        &format!("{ctx}: eti_compute"),
    );
    assert_bits(
        golden.eti_offload_j,
        new.eti_offload_j,
        &format!("{ctx}: eti_offload"),
    );
    assert_bits(golden.eti_total_j, new.eti_total_j, &format!("{ctx}: eti_total"));
    for u in 0..3 {
        assert_bits(
            golden.eti_per_unit_j[u],
            new.eti_per_unit_j[u],
            &format!("{ctx}: eti_per_unit[{u}]"),
        );
        assert_bits(golden.freqs[u], new.freqs[u], &format!("{ctx}: freqs[{u}]"));
        for p in 0..3 {
            assert_bits(
                golden.phase_freqs[p][u],
                new.phase_freqs[p][u],
                &format!("{ctx}: phase_freqs[{p}][{u}]"),
            );
        }
    }
    assert_bits(golden.cost, new.cost, &format!("{ctx}: cost"));
    assert_bits(golden.accuracy_pct, new.accuracy_pct, &format!("{ctx}: accuracy"));
    assert_bits(
        golden.accuracy_loss_pts,
        new.accuracy_loss_pts,
        &format!("{ctx}: accuracy_loss"),
    );
    assert_bits(golden.payload_bytes, new.payload_bytes, &format!("{ctx}: payload"));
    assert_bits(golden.xi, new.xi, &format!("{ctx}: xi"));
    assert_bits(golden.local_mass, new.local_mass, &format!("{ctx}: local_mass"));
    assert_bits(
        golden.bandwidth_mbps,
        new.bandwidth_mbps,
        &format!("{ctx}: bandwidth"),
    );
    assert_bits(golden.queue_wait_s, new.queue_wait_s, &format!("{ctx}: queue_wait"));
    assert_bits(golden.e2e_s, new.e2e_s, &format!("{ctx}: e2e"));
    assert_eq!(golden.stream, new.stream, "{ctx}: stream");
    assert_eq!(golden.batch_size, new.batch_size, "{ctx}: batch_size");
}

struct Scenario {
    name: &'static str,
    policy: &'static str,
    fleet: &'static str,
    streams: usize,
    per_stream: usize,
    arrivals: &'static str,
    slo: &'static str,
    batch_window_s: f64,
    cloud_slots: usize,
    router: Router,
    admission: Admission,
}

fn run_scenario(s: &Scenario) {
    let mk_cfg = || {
        let mut c = Config::default();
        c.policy = s.policy.into();
        c.fleet = s.fleet.into();
        c.seed = 42;
        c
    };
    let arrivals = Arrivals::parse(s.arrivals).unwrap();
    let slo = SloClass::parse(s.slo).unwrap();
    let mk_gens = |fleet: &Fleet| -> Vec<TaskGen> {
        (0..s.streams)
            .map(|i| {
                TaskGen::new(
                    fleet.devices[0].env.profile.name,
                    fleet.devices[0].env.dataset,
                    arrivals.clone(),
                    7 + i as u64,
                )
                .unwrap()
                .with_slo(slo)
            })
            .collect()
    };
    let opts = FleetOpts {
        des: DesOpts {
            batch_window_s: s.batch_window_s,
            cloud_slots: s.cloud_slots,
            // THE gate condition: cloud-side batching disabled must
            // reproduce the pre-refactor machinery exactly
            cloud_batch_window_s: 0.0,
            ..DesOpts::default()
        },
        router: s.router,
        admission: s.admission,
        // the rebalancing compat condition: no re-routing, no rebalance
        // ticks, migration threshold at infinity (FleetOpts::default()
        // pins the same values — spelled out here because this is what
        // the gate is gating)
        reroute: false,
        rebalance_window_s: 0.0,
        migrate_threshold_s: f64::INFINITY,
        ..FleetOpts::default()
    };

    let mut golden_fleet = Fleet::from_config(&mk_cfg()).unwrap();
    assert_eq!(golden_fleet.len(), 2, "{}: golden gate is 2-device", s.name);
    let mut golden_gens = mk_gens(&golden_fleet);
    let golden = reference::serve_fleet(&mut golden_fleet, &mut golden_gens, s.per_stream, &opts);

    let mut new_fleet = Fleet::from_config(&mk_cfg()).unwrap();
    let mut new_gens = mk_gens(&new_fleet);
    let new = serve_fleet(&mut new_fleet, &mut new_gens, s.per_stream, &opts);
    assert_matches_golden(&golden, &new, s.name);

    // rebalance ticks with the migration threshold at infinity must be
    // fully inert: the tick events interleave with the real trace but
    // never move work or perturb any report bit
    let ticking = FleetOpts {
        rebalance_window_s: 0.004,
        migrate_threshold_s: f64::INFINITY,
        ..opts.clone()
    };
    let mut tick_fleet = Fleet::from_config(&mk_cfg()).unwrap();
    let mut tick_gens = mk_gens(&tick_fleet);
    let tick = serve_fleet(&mut tick_fleet, &mut tick_gens, s.per_stream, &ticking);
    assert_eq!(tick.migrated, 0, "{}: inert ticks must not migrate", s.name);
    assert_matches_golden(&golden, &tick, &format!("{} (inert ticks)", s.name));
}

fn assert_matches_golden(
    golden: &reference::GoldenRun,
    new: &dvfo::coordinator::FleetSummary,
    name: &str,
) {
    assert_eq!(golden.offered, new.offered, "{name}: offered");
    assert_eq!(golden.shed, new.shed, "{name}: shed");
    assert_eq!(golden.downgraded, new.downgraded, "{name}: downgraded");
    assert_eq!(
        golden.reports.len(),
        new.serve.reports.len(),
        "{name}: completed"
    );
    for (i, (g, n)) in golden
        .reports
        .iter()
        .zip(new.serve.reports.iter())
        .enumerate()
    {
        assert_reports_byte_identical(g, n, &format!("{name} task {i}"));
    }
}

#[test]
fn two_device_fleet_is_byte_identical_to_prerefactor_machinery() {
    for scenario in [
        // cloud-heavy traffic, batched uplinks, contended shared pool
        Scenario {
            name: "cloud_only/rr/batched-uplink",
            policy: "cloud_only",
            fleet: "xavier-nx,jetson-tx2",
            streams: 6,
            per_stream: 5,
            arrivals: "poisson:40",
            slo: "none",
            batch_window_s: 0.02,
            cloud_slots: 2,
            router: Router::RoundRobin,
            admission: Admission::Off,
        },
        // untrained DQN policy, unbatched uplinks, JSQ routing
        Scenario {
            name: "dvfo/jsq/unbatched",
            policy: "dvfo",
            fleet: "xavier-nx,jetson-nano",
            streams: 4,
            per_stream: 4,
            arrivals: "mmpp:10,80,1,0.3",
            slo: "none",
            batch_window_s: 0.0,
            cloud_slots: 4,
            router: Router::ShortestQueue,
            admission: Admission::Off,
        },
        // admission shed on edge-only traffic: the completion estimator
        // is provably unchanged here (offload propensity is zero), so
        // the shed/queueing trace must also match bit-for-bit
        Scenario {
            name: "edge_only/jsq/shed",
            policy: "edge_only",
            fleet: "jetson-nano,jetson-tx2",
            streams: 8,
            per_stream: 4,
            arrivals: "sequential",
            slo: "200",
            batch_window_s: 0.0,
            cloud_slots: 4,
            router: Router::ShortestQueue,
            admission: Admission::Shed,
        },
    ] {
        run_scenario(&scenario);
    }
}
