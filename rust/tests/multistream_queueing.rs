//! Integration tests for the discrete-event multi-stream serving path
//! (`rust/src/coordinator/des.rs`, now a thin N=1 delegation to the
//! unified kernel in `rust/src/coordinator/engine.rs`) and the extended
//! arrival processes:
//!
//! * the N=1 parity gate — with one stream, sequential arrivals and
//!   batching disabled, the kernel must reproduce the legacy
//!   synchronous `Coordinator::serve` results task-for-task
//! * queueing/batching telemetry under 64-stream load
//! * reproducibility and rate calibration of the MMPP / diurnal
//!   arrival processes at the serving level
//! * cloud-side batching leaves per-task physics untouched

use dvfo::configx::Config;
use dvfo::coordinator::des::{serve_multistream, DesOpts};
use dvfo::coordinator::Coordinator;
use dvfo::perfmodel::Dataset;
use dvfo::workload::{Arrivals, TaskGen};

fn mk(policy: &str, seed: u64) -> (Config, Coordinator) {
    let mut cfg = Config::default();
    cfg.policy = policy.into();
    cfg.seed = seed;
    let coord = Coordinator::from_config(&cfg).unwrap();
    (cfg, coord)
}

#[test]
fn single_stream_matches_legacy_serve_exactly() {
    // The parity gate: per-task reports must be bit-identical between the
    // synchronous path and the discrete-event core for every policy kind
    // (fixed, stochastic discriminator, untrained DQN greedy).
    for policy in ["edge_only", "cloud_only", "appealnet", "dvfo"] {
        let (cfg, mut legacy) = mk(policy, 42);
        let mut gen =
            TaskGen::new(&cfg.model, legacy.env.dataset, Arrivals::Sequential, 7).unwrap();
        let tasks = gen.take(25);
        let a = legacy.serve(&tasks);

        let (cfg2, mut des) = mk(policy, 42);
        let mut gens =
            vec![TaskGen::new(&cfg2.model, des.env.dataset, Arrivals::Sequential, 7).unwrap()];
        let b = serve_multistream(&mut des, &mut gens, 25, &DesOpts::default());

        assert_eq!(a.count(), b.count(), "{policy}");
        for (x, y) in a.reports.iter().zip(b.reports.iter()) {
            assert_eq!(x.tti_total_s, y.tti_total_s, "{policy}: tti");
            assert_eq!(x.eti_total_j, y.eti_total_j, "{policy}: eti");
            assert_eq!(x.cost, y.cost, "{policy}: cost");
            assert_eq!(x.xi, y.xi, "{policy}: xi");
            assert_eq!(x.accuracy_pct, y.accuracy_pct, "{policy}: accuracy");
            assert_eq!(x.payload_bytes, y.payload_bytes, "{policy}: payload");
            assert_eq!(x.freqs, y.freqs, "{policy}: freqs");
        }
        // and the aggregate views agree too
        assert_eq!(a.tti_ms.mean(), b.tti_ms.mean(), "{policy}");
        assert_eq!(a.cost.mean(), b.cost.mean(), "{policy}");
    }
}

#[test]
fn sixty_four_streams_report_queueing_and_per_stream_energy() {
    let (cfg, mut coord) = mk("cloud_only", 5);
    let mut gens: Vec<TaskGen> = (0..64)
        .map(|s| {
            TaskGen::new(
                &cfg.model,
                coord.env.dataset,
                Arrivals::Poisson { rate: 5.0 },
                1000 + s,
            )
            .unwrap()
        })
        .collect();
    let opts = DesOpts {
        batch_window_s: 0.05,
        ..DesOpts::default()
    };
    let s = serve_multistream(&mut coord, &mut gens, 6, &opts);
    assert_eq!(s.count(), 64 * 6);

    // per-stream energy telemetry: one positive total per stream
    assert_eq!(s.per_stream_j.len(), 64);
    assert!(s.per_stream_j.iter().all(|&e| e > 0.0));

    // tail-latency telemetry is ordered and nonzero
    let (p50, p95, p99) = (s.e2e_ms.p50(), s.e2e_ms.p95(), s.e2e_ms.p99());
    assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");

    // 64 streams offering ~320 req/s must overload one edge: real waits
    assert!(
        s.queue_wait_ms.p99() > s.tti_ms.mean(),
        "queue p99 {} vs tti mean {}",
        s.queue_wait_ms.p99(),
        s.tti_ms.mean()
    );

    // cloud_only offloads every task: each rides in some uplink batch,
    // and the 50 ms window groups at least some of them
    assert!(s.batch_size.values().iter().all(|&b| b >= 1.0));
    assert!(
        s.batch_size.values().iter().any(|&b| b > 1.0),
        "window should batch some uplinks"
    );
}

#[test]
fn batching_disabled_ships_singletons() {
    let (cfg, mut coord) = mk("cloud_only", 9);
    let mut gens: Vec<TaskGen> = (0..8)
        .map(|s| {
            TaskGen::new(
                &cfg.model,
                coord.env.dataset,
                Arrivals::Poisson { rate: 50.0 },
                70 + s,
            )
            .unwrap()
        })
        .collect();
    let s = serve_multistream(&mut coord, &mut gens, 5, &DesOpts::default());
    assert_eq!(s.count(), 40);
    assert!(s
        .batch_size
        .values()
        .iter()
        .all(|&b| (b - 1.0).abs() < 1e-12));
}

#[test]
fn cloud_batching_changes_only_completion_telemetry() {
    // Per-task physics (tti, energy, cost, ξ, payload) are fixed at edge
    // service start, which the cloud stage cannot influence — so turning
    // the cloud batch window on must leave them bit-identical and only
    // move completion timing (e2e) and the cloud-batch metadata.
    let run = |cloud_batch_window_s: f64| {
        let (cfg, mut coord) = mk("cloud_only", 13);
        let mut gens: Vec<TaskGen> = (0..4)
            .map(|s| {
                TaskGen::new(&cfg.model, coord.env.dataset, Arrivals::Sequential, 600 + s)
                    .unwrap()
            })
            .collect();
        let opts = DesOpts {
            // a wide uplink window groups the t=0 herd into multi-member
            // uplink batches, whose members land on the cloud stage at
            // the same instant — guaranteeing the cloud window (when on)
            // has co-arrivals to merge
            batch_window_s: 10.0,
            cloud_batch_window_s,
            cloud_slots: 2,
            ..DesOpts::default()
        };
        serve_multistream(&mut coord, &mut gens, 5, &opts)
    };
    let solo = run(0.0);
    let batched = run(0.05);
    assert_eq!(solo.count(), batched.count());
    for (a, b) in solo.reports.iter().zip(batched.reports.iter()) {
        assert_eq!(a.tti_total_s.to_bits(), b.tti_total_s.to_bits(), "tti");
        assert_eq!(a.eti_total_j.to_bits(), b.eti_total_j.to_bits(), "eti");
        assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "cost");
        assert_eq!(a.xi.to_bits(), b.xi.to_bits(), "xi");
        assert_eq!(a.payload_bytes.to_bits(), b.payload_bytes.to_bits(), "payload");
        assert_eq!(a.queue_wait_s.to_bits(), b.queue_wait_s.to_bits(), "queue wait");
    }
    assert!(solo.reports.iter().all(|r| r.cloud_batch_size == 1));
    assert!(
        batched.reports.iter().any(|r| r.cloud_batch_size > 1),
        "the window must group some cloud invocations"
    );
}

#[test]
fn des_is_deterministic_per_seed() {
    let run = || {
        let (cfg, mut coord) = mk("cloud_only", 33);
        let mut gens: Vec<TaskGen> = (0..4)
            .map(|s| {
                TaskGen::new(
                    &cfg.model,
                    coord.env.dataset,
                    Arrivals::parse("mmpp:10,80,1,0.3").unwrap(),
                    900 + s,
                )
                .unwrap()
            })
            .collect();
        let opts = DesOpts {
            batch_window_s: 0.01,
            ..DesOpts::default()
        };
        let s = serve_multistream(&mut coord, &mut gens, 8, &opts);
        (s.e2e_ms.mean(), s.queue_wait_ms.mean(), s.cost.mean())
    };
    assert_eq!(run(), run());
}

#[test]
fn queue_aware_dvfo_trains_and_serves_multistream() {
    let mut cfg = Config::default();
    cfg.policy = "dvfo".into();
    cfg.queue_aware = true;
    cfg.seed = 21;
    let mut coord = Coordinator::from_config(&cfg).unwrap();
    let mut tgen =
        TaskGen::new(&cfg.model, coord.env.dataset, Arrivals::Sequential, 3).unwrap();
    coord.train(&mut tgen, 2, 8);
    let mut gens: Vec<TaskGen> = (0..4)
        .map(|s| {
            TaskGen::new(
                &cfg.model,
                coord.env.dataset,
                Arrivals::Poisson { rate: 20.0 },
                500 + s,
            )
            .unwrap()
        })
        .collect();
    let opts = DesOpts {
        batch_window_s: 0.002,
        ..DesOpts::default()
    };
    let s = serve_multistream(&mut coord, &mut gens, 10, &opts);
    assert_eq!(s.count(), 40);
    assert!(s.e2e_ms.mean() > 0.0);
    assert!(s.accuracy_pct.mean() > 70.0);
}

#[test]
fn mmpp_and_diurnal_streams_drive_the_core() {
    for spec in ["mmpp:10,60,2,0.5", "diurnal:30,0.7,20"] {
        let arr = Arrivals::parse(spec).unwrap();
        let (cfg, mut coord) = mk("edge_only", 2);
        let mut gens: Vec<TaskGen> = (0..3)
            .map(|s| TaskGen::new(&cfg.model, coord.env.dataset, arr, 40 + s).unwrap())
            .collect();
        let s = serve_multistream(&mut coord, &mut gens, 6, &DesOpts::default());
        assert_eq!(s.count(), 18, "{spec}");
        assert!(s.e2e_ms.mean() > 0.0, "{spec}");
    }
}

#[test]
fn arrival_rate_calibration_poisson_and_mmpp() {
    // Empirical interarrival means must track the configured rates at
    // the TaskGen level (the same generators the serving core consumes).
    for (spec, tol) in [("poisson:50", 0.2), ("mmpp:10,100,2,0.5", 0.3)] {
        let arr = Arrivals::parse(spec).unwrap();
        let mut g = TaskGen::new("resnet-18", Dataset::Cifar100, arr, 911).unwrap();
        let ts = g.take(3000);
        let rate = 3000.0 / ts.last().unwrap().arrival_s;
        let want = arr.mean_rate().unwrap();
        assert!(
            (rate - want).abs() / want < tol,
            "{spec}: empirical {rate} vs configured {want}"
        );
    }
}
