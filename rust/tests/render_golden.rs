//! Byte-identity gate for the shared telemetry renderers
//! (`rust/src/telemetry/render.rs`): `main.rs` and the experiment
//! sweeps used to carry their own copies of the summary-table and
//! accounting-line formatting; this file freezes those historical
//! format strings verbatim and pins the shared helpers against them
//! byte-for-byte — on a real serving run for the table, and on awkward
//! rounding inputs for the one-line formats.

use dvfo::configx::Config;
use dvfo::coordinator::des::{serve_multistream, DesOpts};
use dvfo::coordinator::{Coordinator, ServeSummary};
use dvfo::telemetry::{render, Table};
use dvfo::util::Samples;
use dvfo::workload::{Arrivals, TaskGen};

/// Verbatim copy of the `print_summary_table` body `main.rs` carried
/// before the renderers moved into `telemetry::render`. Do not edit —
/// it IS the golden.
fn frozen_summary_table(s: &ServeSummary) -> Table {
    let mut t = Table::new(vec!["metric", "mean", "p50", "p95", "p99"]);
    for (name, s) in [
        ("tti ms", &s.tti_ms),
        ("queue ms", &s.queue_wait_ms),
        ("e2e ms", &s.e2e_ms),
        ("eti mJ", &s.eti_mj),
        ("accuracy %", &s.accuracy_pct),
        ("xi", &s.xi),
        ("payload KB", &s.payload_kb),
    ] {
        t.row(vec![
            name.to_string(),
            format!("{:.2}", s.mean()),
            format!("{:.2}", s.p50()),
            format!("{:.2}", s.p95()),
            format!("{:.2}", s.p99()),
        ]);
    }
    t
}

fn real_run() -> ServeSummary {
    let mut cfg = Config::default();
    cfg.policy = "cloud_only".into();
    cfg.seed = 9;
    let mut des = Coordinator::from_config(&cfg).unwrap();
    let mut gens: Vec<TaskGen> = (0..3)
        .map(|s| {
            TaskGen::new(
                &cfg.model,
                des.env.dataset,
                Arrivals::Poisson { rate: 20.0 },
                40 + s as u64,
            )
            .unwrap()
        })
        .collect();
    let opts = DesOpts {
        batch_window_s: 0.004,
        ..DesOpts::default()
    };
    serve_multistream(&mut des, &mut gens, 10, &opts)
}

#[test]
fn summary_table_matches_the_frozen_cli_format() {
    let s = real_run();
    assert!(s.count() > 0);
    assert_eq!(render::summary_table(&s).render(), frozen_summary_table(&s).render());
}

#[test]
fn accounting_lines_match_the_frozen_cli_formats() {
    // each right-hand side is the literal `println!` format string the
    // fleet path in `main.rs` used, applied via `format!`
    assert_eq!(
        render::counters_line(271, 250, 21, 4, 17, 233),
        format!(
            "offered={} completed={} shed={} downgraded={} violations={} goodput={}",
            271, 250, 21, 4, 17, 233
        )
    );
    assert_eq!(
        render::rebalance_line(5, 3, 0.0275),
        format!(
            "rebalance: rerouted={} migrated={} migration-latency={:.1}ms",
            5,
            3,
            0.0275 * 1e3
        )
    );
    assert_eq!(
        render::cloud_line(12, 2.25, 4.0, 0.0061),
        format!(
            "cloud: invocations={} mean-occupancy={:.2} max-occupancy={:.0} \
             dispatch-saved={:.1}ms",
            12,
            2.25,
            4.0,
            0.0061 * 1e3
        )
    );
    assert_eq!(
        render::device_line("jetson-tx2", 88, 12.345, 6, None),
        format!(
            "  device {:<12} served={:<5} energy={:.1} J violations={}{}",
            "jetson-tx2", 88, 12.345, 6, ""
        )
    );
    // the historical fleet path computed the rebalance columns first,
    // then spliced them into the device line — reproduced verbatim
    let rebalance_cols = format!(" rerouted-in={} migrated-in={} migrated-out={}", 4, 2, 9);
    assert_eq!(
        render::device_line("jetson-nano", 7, 0.25, 1, Some((4, 2, 9))),
        format!(
            "  device {:<12} served={:<5} energy={:.1} J violations={}{}",
            "jetson-nano", 7, 0.25, 1, rebalance_cols
        )
    );
}

#[test]
fn quantile_cells_match_the_frozen_sweep_format() {
    // the experiment sweeps formatted every latency column as
    // `format!("{:.1}", samples.percentile(p))` — frozen here so the
    // sweep goldens in `sweep_determinism.rs` can never drift silently
    let mut s = Samples::new();
    for i in 0..250 {
        s.push((i as f64) * 0.731 + 3.0);
    }
    for p in [50.0, 95.0, 99.0] {
        assert_eq!(render::quantile_cells(&s, &[p]), vec![format!("{:.1}", s.percentile(p))]);
    }
    assert_eq!(render::quantile_cells(&s, &[50.0, 95.0, 99.0]).len(), 3);
}
