//! Bit-exactness gate for the packed GEMM kernels (`dqn::gemm`).
//!
//! The references below are VERBATIM copies of the pre-refactor naive
//! loops from `tensor.rs` (frozen here so they can never drift with the
//! kernel). The tiled kernels promise per-element full-K sequential
//! accumulation from +0.0, so:
//!
//!  * against the no-skip references they are bit-identical for ANY
//!    input, including NaN / ±inf / −0.0 anywhere (identical f32 op
//!    sequence per output element);
//!  * against the HISTORICAL skip references (`if a == 0.0 {continue}`)
//!    they are bit-identical whenever the non-skipped operand is
//!    finite: a ±0.0 · finite product is ±0.0, and adding ±0.0 never
//!    changes the accumulator's bits when it starts at +0.0 under
//!    round-to-nearest;
//!  * `matmul_into` fully overwrites its destination, even at k = 0;
//!  * `Mlp::infer_batch` is bit-identical to `Mlp::forward` (whose
//!    accumulation order it pins) and agrees with per-row `infer`
//!    within tolerance (`infer` adds the bias before accumulation, a
//!    different but equally valid order).

use dvfo::dqn::{BatchScratch, InferScratch, Mlp, Tensor2};
use dvfo::proptest_mini as pt;
use dvfo::util::Pcg32;

// ---- frozen pre-refactor references (do not modernize) ----------------

/// `Tensor2::matmul_into` as it stood before the packed kernels,
/// including the relu-sparsity skip.
fn ref_matmul_skip(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Same loop with the skip removed: the unconditional bit-reference.
fn ref_matmul_noskip(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Pre-refactor `matmul_tn` (A stored (k,m), skip included).
fn ref_matmul_tn_skip(k: usize, m: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

fn ref_matmul_tn_noskip(k: usize, m: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Pre-refactor `matmul_nt` (B stored (n,k)); it never had a skip.
fn ref_matmul_nt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

// ---- generators -------------------------------------------------------

/// A dimension biased toward edge sizes: 0, 1, tiny, around the MR/NR
/// register tiles, and straddling the 64-wide cache blocks.
fn dim(r: &mut Pcg32) -> usize {
    match r.below(10) {
        0 => 0,
        1 => 1,
        2 | 3 => 2 + r.below(7) as usize,  // 2..=8
        4..=6 => 8 + r.below(40) as usize, // 8..=47
        _ => 60 + r.below(16) as usize,    // 60..=75 (straddles MC/NC=64)
    }
}

/// One matrix entry. ~25% +0.0 / ~5% −0.0 so the historical skip path
/// is exercised hard; `wild` additionally injects NaN and ±inf.
fn entry(r: &mut Pcg32, wild: bool) -> f32 {
    let roll = r.below(100);
    if roll < 25 {
        return 0.0;
    }
    if roll < 30 {
        return -0.0;
    }
    if wild {
        if roll < 33 {
            return f32::NAN;
        }
        if roll < 36 {
            return f32::INFINITY;
        }
        if roll < 39 {
            return f32::NEG_INFINITY;
        }
    }
    4.0 * r.next_f32() - 2.0
}

fn mat(r: &mut Pcg32, len: usize, wild: bool) -> Vec<f32> {
    (0..len).map(|_| entry(r, wild)).collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Shapes + a data seed; matrices are rebuilt deterministically inside
/// the property so the failure report stays small.
fn case_gen(r: &mut Pcg32) -> (usize, usize, usize, u64) {
    (dim(r), dim(r), dim(r), r.next_u64())
}

// ---- gate 1: packed == no-skip reference, any data --------------------

#[test]
fn packed_kernels_match_noskip_reference_bitwise_on_wild_data() {
    pt::check("gemm wild-data bit parity", 0xB17, 300, case_gen, |&(m, k, n, ds)| {
        let mut dr = Pcg32::seeded(ds);
        let a = mat(&mut dr, m * k, true);
        let b = mat(&mut dr, k * n, true);
        let at = mat(&mut dr, k * m, true); // (k,m) for the tn kernel
        let bt = mat(&mut dr, n * k, true); // (n,k) for the nt kernel

        let ta = Tensor2::from_vec(m, k, a.clone());
        let tb = Tensor2::from_vec(k, n, b.clone());
        let got_nn = ta.matmul(&tb);
        if bits(&got_nn.data) != bits(&ref_matmul_noskip(m, k, n, &a, &b)) {
            return Err("nn kernel diverged from no-skip reference".into());
        }

        let tat = Tensor2::from_vec(k, m, at.clone());
        let got_tn = tat.matmul_tn(&tb);
        if bits(&got_tn.data) != bits(&ref_matmul_tn_noskip(k, m, n, &at, &b)) {
            return Err("tn kernel diverged from no-skip reference".into());
        }

        let tbt = Tensor2::from_vec(n, k, bt.clone());
        let got_nt = ta.matmul_nt(&tbt);
        if bits(&got_nt.data) != bits(&ref_matmul_nt(m, k, n, &a, &bt)) {
            return Err("nt kernel diverged from reference".into());
        }
        Ok(())
    });
}

// ---- gate 2: packed == historical skip reference when B is finite -----

#[test]
fn packed_kernels_match_historical_skip_reference_when_b_finite() {
    pt::check("gemm skip-drop neutrality", 0x5C1F, 300, case_gen, |&(m, k, n, ds)| {
        let mut dr = Pcg32::seeded(ds);
        // A may carry NaN/inf (the skip only ever fired on a == 0.0);
        // B finite is the precondition for dropping the skip bit-neutrally
        // — and is what trained weights always satisfy.
        let a = mat(&mut dr, m * k, true);
        let b = mat(&mut dr, k * n, false);
        let at = mat(&mut dr, k * m, true);

        let ta = Tensor2::from_vec(m, k, a.clone());
        let tb = Tensor2::from_vec(k, n, b.clone());
        if bits(&ta.matmul(&tb).data) != bits(&ref_matmul_skip(m, k, n, &a, &b)) {
            return Err("nn kernel diverged from historical skip reference".into());
        }

        let tat = Tensor2::from_vec(k, m, at.clone());
        if bits(&tat.matmul_tn(&tb).data) != bits(&ref_matmul_tn_skip(k, m, n, &at, &b)) {
            return Err("tn kernel diverged from historical skip reference".into());
        }
        Ok(())
    });
}

// ---- gate 3: matmul_into overwrites every destination element ---------

#[test]
fn matmul_into_fully_overwrites_output_including_empty_k() {
    pt::check("matmul_into overwrite", 0x0E77, 200, case_gen, |&(m, k, n, ds)| {
        let mut dr = Pcg32::seeded(ds);
        let a = mat(&mut dr, m * k, false);
        let b = mat(&mut dr, k * n, false);
        let ta = Tensor2::from_vec(m, k, a.clone());
        let tb = Tensor2::from_vec(k, n, b.clone());
        let mut out = Tensor2::from_vec(m, n, vec![7.5f32; m * n]);
        ta.matmul_into(&tb, &mut out);
        if bits(&out.data) != bits(&ref_matmul_noskip(m, k, n, &a, &b)) {
            return Err("stale sentinel survived matmul_into".into());
        }
        Ok(())
    });
}

// ---- gate 4: infer_batch vs forward (bitwise) and infer (tolerance) ---

#[test]
fn infer_batch_is_bitwise_forward_and_close_to_per_row_infer() {
    let gen = |r: &mut Pcg32| {
        let mut dims = vec![1 + r.below(5) as usize];
        for _ in 0..=r.below(2) {
            dims.push(1 + r.below(20) as usize);
        }
        dims.push(1 + r.below(8) as usize);
        (dims, 1 + r.below(20) as usize, r.next_u64())
    };
    pt::check("infer_batch parity", 0xBA7C4, 120, gen, |case: &(Vec<usize>, usize, u64)| {
        let (dims, batch, ds) = case;
        let mut dr = Pcg32::seeded(*ds);
        let mlp = Mlp::new(dims, &mut dr);
        let x = Tensor2::from_vec(
            *batch,
            dims[0],
            (0..batch * dims[0]).map(|_| 4.0 * dr.next_f32() - 2.0).collect(),
        );

        let mut scratch = BatchScratch::default();
        let got = mlp.infer_batch(&x, &mut scratch);
        let want = mlp.forward(&x).output;
        if (got.rows, got.cols) != (want.rows, want.cols) {
            return Err(format!(
                "shape mismatch: got {:?}, want {:?}",
                got.shape(),
                want.shape()
            ));
        }
        if bits(&got.data) != bits(&want.data) {
            return Err("infer_batch diverged bitwise from forward".into());
        }

        let mut inf = InferScratch::default();
        for r in 0..*batch {
            let qrow = mlp.infer(x.row(r), &mut inf);
            for (c, (&g, &q)) in got.row(r).iter().zip(qrow.iter()).enumerate() {
                if (g - q).abs() > 1e-5 * (1.0 + q.abs()) {
                    return Err(format!(
                        "row {r} col {c}: infer_batch {g} vs infer {q}"
                    ));
                }
            }
        }
        Ok(())
    });
}
