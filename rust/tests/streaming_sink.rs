//! Streaming-telemetry gates for the constant-memory `StreamingSink`
//! (`rust/src/telemetry/sink.rs`):
//!
//! * a property check that the DDSketch-style quantile estimates stay
//!   inside the sketch's relative-error bound of the exact `Samples`
//!   percentiles on randomized workloads
//! * exact counter equality between `serve_fleet` (collected reports)
//!   and `serve_fleet_streaming` at `shards = 1` — both drive the
//!   identical unsharded kernel trace, so every integer counter must
//!   agree exactly and every sketch must bracket the exact percentiles

use dvfo::configx::Config;
use dvfo::coordinator::fleet::{serve_fleet, serve_fleet_streaming, Admission, Fleet, FleetOpts};
use dvfo::coordinator::{FleetSummary, StreamSummary};
use dvfo::proptest_mini::{check, f64_in, vec_of};
use dvfo::telemetry::sink::QuantileSketch;
use dvfo::util::Samples;
use dvfo::workload::{Arrivals, SloClass, TaskGen};

/// Error-envelope check for a sketch estimate of percentile `p`: the
/// estimate must land within the sketch's relative error of the two
/// order statistics bracketing the rank (which covers both the
/// nearest-rank and interpolating percentile conventions).
fn sketch_brackets_exact(xs: &[f64], sk: &QuantileSketch, p: f64) -> Result<(), String> {
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let a = sorted[rank.floor() as usize];
    let b = sorted[rank.ceil() as usize];
    let (lo, hi) = (a.min(b), a.max(b));
    let err = sk.relative_error();
    let est = sk.percentile(p);
    let lo_bound = lo * (1.0 - err) - 1e-9;
    let hi_bound = hi * (1.0 + err) + 1e-9;
    if est >= lo_bound && est <= hi_bound {
        Ok(())
    } else {
        Err(format!(
            "p{p}: sketch estimate {est} outside [{lo_bound}, {hi_bound}] \
             (exact bracket [{lo}, {hi}])"
        ))
    }
}

#[test]
fn sketch_percentiles_stay_inside_the_error_bound_on_random_workloads() {
    check("sketch vs exact", 0xD05E, 60, vec_of(f64_in(0.0, 5000.0), 2, 400), |xs| {
        let mut sk = QuantileSketch::default();
        let mut exact = Samples::new();
        for &x in xs {
            sk.push(x);
            exact.push(x);
        }
        if sk.count() as usize != exact.len() {
            return Err("sketch lost samples".into());
        }
        // exact moments ride alongside the sketch
        if (sk.mean() - exact.mean()).abs() > 1e-9 * (1.0 + exact.mean().abs()) {
            return Err(format!("mean drifted: {} vs {}", sk.mean(), exact.mean()));
        }
        for p in [0.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            sketch_brackets_exact(xs, &sk, p)?;
        }
        Ok(())
    });
}

fn overload_cfg() -> Config {
    let mut c = Config::default();
    c.policy = "edge_only".into();
    c.fleet = "jetson-nano*2".into();
    c.seed = 11;
    c
}

fn overload_gens(c: &Config, fleet: &Fleet) -> Vec<TaskGen> {
    let slo = SloClass::parse("200").unwrap();
    (0..16)
        .map(|s| {
            TaskGen::new(
                &c.model,
                fleet.devices[0].env.dataset,
                Arrivals::Poisson { rate: 10.0 },
                3000 + s as u64,
            )
            .unwrap()
            .with_slo(slo)
        })
        .collect()
}

/// Run the identical overloaded workload through the collected and the
/// streaming (shards = 1) paths and pin every shared counter.
fn run_pair(admission: Admission) -> (FleetSummary, StreamSummary) {
    let opts = FleetOpts {
        admission,
        ..FleetOpts::default()
    };

    let c = overload_cfg();
    let mut fleet = Fleet::from_config(&c).unwrap();
    let mut g = overload_gens(&c, &fleet);
    let collected = serve_fleet(&mut fleet, &mut g, 6, &opts);

    let c = overload_cfg();
    let mut fleet = Fleet::from_config(&c).unwrap();
    let mut g = overload_gens(&c, &fleet);
    let streamed = serve_fleet_streaming(&mut fleet, &mut g, 6, &opts, 1);

    assert_eq!(streamed.shards, 1);
    assert_eq!(collected.offered, streamed.offered);
    assert_eq!(collected.completed, streamed.completed);
    assert_eq!(collected.shed, streamed.shed);
    assert_eq!(collected.downgraded, streamed.downgraded);
    assert_eq!(collected.slo_violations, streamed.slo_violations);
    assert_eq!(collected.goodput, streamed.goodput);
    assert_eq!(collected.rerouted, streamed.rerouted);
    assert_eq!(collected.migrated, streamed.migrated);
    assert_eq!(collected.cloud_invocations, streamed.cloud_invocations);
    assert_eq!(collected.events, streamed.events);
    assert_eq!(collected.offered, collected.completed + collected.shed);

    // the sink's own counters agree with the fleet fold
    let t = &streamed.telemetry;
    assert_eq!(t.completed, collected.completed);
    assert_eq!(t.violations, collected.slo_violations);
    assert_eq!(t.goodput, collected.goodput);
    assert_eq!(t.e2e_ms.count() as usize, collected.completed);
    let class_completed: usize = t.per_class.values().map(|c| c.completed).sum();
    assert_eq!(class_completed, collected.completed);

    // per-device: integer counters exact; energy is the same f64 set
    // summed in completion order instead of arrival order, so compare
    // to addition-reordering slop only
    assert_eq!(collected.per_device.len(), streamed.per_device.len());
    for (a, b) in collected.per_device.iter().zip(&streamed.per_device) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.served, b.served, "{}", a.name);
        assert_eq!(a.violations, b.violations, "{}", a.name);
        assert!(
            (a.energy_j - b.energy_j).abs() <= 1e-9 * (1.0 + a.energy_j.abs()),
            "{}: energy {} vs {}",
            a.name,
            a.energy_j,
            b.energy_j
        );
    }

    (collected, streamed)
}

#[test]
fn streaming_counters_match_collected_counters_on_the_identical_trace() {
    // without admission the overload drives real deadline misses; with
    // shed admission it drives real sheds — both paths must agree on
    // every counter either way
    let (no_admission, _) = run_pair(Admission::Off);
    assert!(no_admission.slo_violations > 0, "overload must produce violations");
    let (shed, _) = run_pair(Admission::Shed);
    assert!(shed.shed > 0, "overload must produce sheds");
}

#[test]
fn streaming_sketches_bracket_the_exact_percentiles_of_a_real_run() {
    let (collected, streamed) = run_pair(Admission::Shed);
    let t = &streamed.telemetry;
    for (name, samples, sketch) in [
        ("e2e", &collected.serve.e2e_ms, &t.e2e_ms),
        ("tti", &collected.serve.tti_ms, &t.tti_ms),
        ("queue", &collected.serve.queue_wait_ms, &t.queue_wait_ms),
        ("eti", &collected.serve.eti_mj, &t.eti_mj),
    ] {
        assert_eq!(sketch.count() as usize, samples.len(), "{name}");
        for p in [50.0, 95.0, 99.0] {
            sketch_brackets_exact(samples.values(), sketch, p)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }
}
