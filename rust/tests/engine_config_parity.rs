//! Parity gate for the `EngineConfig` consolidation: the one flat
//! builder (`rust/src/coordinator/config.rs`) must produce
//! **bit-identical** parameter blocks to the legacy
//! `DesOpts::from_config` + `FleetOpts::from_config` pair, on default
//! and non-default configs alike, so callers can migrate to the builder
//! without any behavioural drift. The legacy types stay valid as the
//! kernel's internal parameter blocks; this gate is what lets them be
//! documented as superseded.

use dvfo::configx::Config;
use dvfo::coordinator::{Admission, DesOpts, EngineConfig, FleetOpts, Router, SchedKind};

/// Every `DesOpts` field, floats as raw bits, for exact comparison.
fn des_bits(o: &DesOpts) -> (u64, usize, usize, u64, usize, SchedKind) {
    (
        o.batch_window_s.to_bits(),
        o.max_batch,
        o.cloud_slots,
        o.cloud_batch_window_s.to_bits(),
        o.cloud_max_batch,
        o.sched,
    )
}

/// Every non-`des` `FleetOpts` field, floats as raw bits.
fn fleet_bits(o: &FleetOpts) -> (Router, Admission, bool, u64, u64, u64) {
    (
        o.router,
        o.admission,
        o.reroute,
        o.rebalance_window_s.to_bits(),
        o.migrate_threshold_s.to_bits(),
        o.migrate_penalty_s.to_bits(),
    )
}

#[test]
fn from_config_matches_the_legacy_constructors_on_a_non_default_config() {
    let mut cfg = Config::default();
    cfg.batch_window_ms = 7.5;
    cfg.max_batch = 5;
    cfg.cloud_slots = 3;
    cfg.cloud_batch_window_ms = 6.25;
    cfg.cloud_max_batch = 9;
    cfg.router = "least_backlog".into();
    cfg.admission = "shed".into();
    cfg.reroute = true;
    cfg.rebalance_window_ms = 12.0;
    cfg.migrate_threshold_ms = 40.0;
    cfg.migrate_penalty_ms = 2.5;
    cfg.shards = 4;
    cfg.stream_telemetry = true;
    cfg.scheduler = "heap".into();

    let ec = EngineConfig::from_config(&cfg).unwrap();
    let legacy_fleet = FleetOpts::from_config(&cfg).unwrap();
    assert_eq!(des_bits(&ec.des_opts()), des_bits(&DesOpts::from_config(&cfg)));
    assert_eq!(des_bits(&ec.fleet_opts().des), des_bits(&legacy_fleet.des));
    assert_eq!(fleet_bits(&ec.fleet_opts()), fleet_bits(&legacy_fleet));

    // the scale-out keys only the builder carries
    assert_eq!(ec.shards, 4);
    assert!(ec.stream_telemetry);
    // spot-check the ms→s conversions landed (not just matched)
    assert_eq!(ec.batch_window_s, 0.0075);
    assert_eq!(ec.migrate_penalty_s, 0.0025);
    assert_eq!(ec.router, Router::LeastBacklog);
    assert_eq!(ec.admission, Admission::Shed);
    assert_eq!(ec.sched, SchedKind::Heap);
}

#[test]
fn from_config_matches_the_legacy_constructors_on_the_default_config() {
    let cfg = Config::default();
    let ec = EngineConfig::from_config(&cfg).unwrap();
    let legacy_fleet = FleetOpts::from_config(&cfg).unwrap();
    assert_eq!(des_bits(&ec.des_opts()), des_bits(&DesOpts::from_config(&cfg)));
    assert_eq!(fleet_bits(&ec.fleet_opts()), fleet_bits(&legacy_fleet));
    assert_eq!(ec.shards, 1);
    assert!(!ec.stream_telemetry);
}

#[test]
fn builder_defaults_equal_default_config_conversion() {
    // `EngineConfig::new()` and `EngineConfig::from_config(&default)`
    // must be two spellings of the same configuration
    let from_cfg = EngineConfig::from_config(&Config::default()).unwrap();
    let built = EngineConfig::new();
    assert_eq!(des_bits(&from_cfg.des_opts()), des_bits(&built.des_opts()));
    assert_eq!(fleet_bits(&from_cfg.fleet_opts()), fleet_bits(&built.fleet_opts()));
    assert_eq!(from_cfg.shards, built.shards);
    assert_eq!(from_cfg.shard_epoch_s.to_bits(), built.shard_epoch_s.to_bits());
    assert_eq!(from_cfg.stream_telemetry, built.stream_telemetry);
}

#[test]
fn infinite_migrate_threshold_survives_the_conversion() {
    // the "never migrate" sentinel must not be destroyed by the ms→s
    // division (inf / 1e3 == inf)
    let cfg = Config::default();
    assert!(cfg.migrate_threshold_ms.is_infinite());
    let ec = EngineConfig::from_config(&cfg).unwrap();
    assert!(ec.migrate_threshold_s.is_infinite());
    assert!(ec.fleet_opts().migrate_threshold_s.is_infinite());
}
