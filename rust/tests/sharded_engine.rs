//! Tentpole gates for the share-nothing sharded engine
//! (`rust/src/coordinator/shard.rs` + the `serve_fleet_sharded` /
//! `serve_fleet_streaming` entry points):
//!
//! * one shard IS the unsharded kernel — `serve_fleet_sharded(.., 1)`
//!   reproduces `serve_fleet` report-for-report, bit-for-bit
//! * task conservation — `offered == completed + shed` holds exactly
//!   for every shard count, and the per-device ledgers sum to it
//! * goodput equivalence — exact under a slack SLO (everything
//!   completes on time regardless of sharding), and within a stated
//!   tolerance on a genuinely loaded configuration where per-shard
//!   routing scopes and epoch-stale cloud signals may drift outcomes
//! * determinism — a fixed shard count over the epoch-sync protocol
//!   gives bit-identical results run-to-run despite the threads
//! * the `#[ignore]`d headline: 1,000,000 tasks over a 100-device
//!   fleet through 4 shards with streaming telemetry, in memory bounded
//!   by sketch spans and device counters rather than task count

use dvfo::configx::Config;
use dvfo::coordinator::fleet::{
    serve_fleet, serve_fleet_sharded, serve_fleet_streaming, Admission, Fleet, FleetOpts,
};
use dvfo::coordinator::{DesOpts, FleetSummary};
use dvfo::workload::{Arrivals, SloClass, TaskGen};

fn cfg(policy: &str, fleet: &str, seed: u64) -> Config {
    let mut c = Config::default();
    c.policy = policy.into();
    c.fleet = fleet.into();
    c.seed = seed;
    c
}

fn gens(c: &Config, fleet: &Fleet, n: usize, rate: f64, slo: &str, base: u64) -> Vec<TaskGen> {
    let slo = SloClass::parse(slo).unwrap();
    (0..n)
        .map(|s| {
            TaskGen::new(
                &c.model,
                fleet.devices[0].env.dataset,
                Arrivals::Poisson { rate },
                base + s as u64,
            )
            .unwrap()
            .with_slo(slo)
        })
        .collect()
}

/// A genuinely loaded run: shed admission + a tight SLO push four
/// identical boards well past capacity. The homogeneous fleet and the
/// 12-streams-over-4-devices split keep per-shard load balanced for
/// shard counts 1/2/4, so goodput differences isolate the sharding
/// itself rather than an unlucky partition.
fn loaded_run(shards: usize) -> FleetSummary {
    let c = cfg("edge_only", "jetson-nano*4", 77);
    let mut fleet = Fleet::from_config(&c).unwrap();
    let mut g = gens(&c, &fleet, 12, 15.0, "200", 3000);
    let opts = FleetOpts {
        admission: Admission::Shed,
        ..FleetOpts::default()
    };
    serve_fleet_sharded(&mut fleet, &mut g, 15, &opts, shards)
}

#[test]
fn one_shard_is_the_unsharded_kernel_bit_for_bit() {
    let opts = FleetOpts {
        des: DesOpts {
            batch_window_s: 0.004,
            cloud_batch_window_s: 0.005,
            cloud_slots: 2,
            ..DesOpts::default()
        },
        ..FleetOpts::default()
    };

    let c = cfg("cloud_only", "xavier-nx,jetson-nano", 23);
    let mut fleet = Fleet::from_config(&c).unwrap();
    let mut g = gens(&c, &fleet, 6, 25.0, "none", 900);
    let a = serve_fleet(&mut fleet, &mut g, 12, &opts);

    let c = cfg("cloud_only", "xavier-nx,jetson-nano", 23);
    let mut fleet = Fleet::from_config(&c).unwrap();
    let mut g = gens(&c, &fleet, 6, 25.0, "none", 900);
    let b = serve_fleet_sharded(&mut fleet, &mut g, 12, &opts, 1);

    assert_eq!(a.offered, b.offered);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.events, b.events);
    assert_eq!(a.serve.reports.len(), b.serve.reports.len());
    for (x, y) in a.serve.reports.iter().zip(&b.serve.reports) {
        assert_eq!(x.e2e_s.to_bits(), y.e2e_s.to_bits());
        assert_eq!(x.queue_wait_s.to_bits(), y.queue_wait_s.to_bits());
        assert_eq!(x.eti_total_j.to_bits(), y.eti_total_j.to_bits());
        assert_eq!(x.stream, y.stream);
    }
}

#[test]
fn counters_are_conserved_for_every_shard_count() {
    for shards in [1, 2, 3, 4] {
        let s = loaded_run(shards);
        assert_eq!(s.offered, 12 * 15, "shards={shards}");
        assert_eq!(s.offered, s.completed + s.shed, "shards={shards}: conservation");
        assert_eq!(s.serve.reports.len(), s.completed, "shards={shards}");
        let dev_served: usize = s.per_device.iter().map(|d| d.served).sum();
        assert_eq!(dev_served, s.completed, "shards={shards}: device ledger");
        let dev_violations: usize = s.per_device.iter().map(|d| d.violations).sum();
        assert_eq!(dev_violations, s.slo_violations, "shards={shards}: violations");
        assert_eq!(s.goodput, s.completed - s.slo_violations, "shards={shards}: goodput");
    }
}

#[test]
fn shard_count_clamps_to_the_fleet() {
    // more shards than devices cannot be honored; the streaming summary
    // reports the count the run actually used
    let c = cfg("edge_only", "xavier-nx,jetson-nano", 5);
    let mut fleet = Fleet::from_config(&c).unwrap();
    let mut g = gens(&c, &fleet, 4, 10.0, "none", 100);
    let s = serve_fleet_streaming(&mut fleet, &mut g, 5, &FleetOpts::default(), 16);
    assert_eq!(s.shards, 2);
    assert_eq!(s.offered, s.completed + s.shed);
}

#[test]
fn slack_slo_goodput_is_identical_sharded_and_unsharded() {
    // a 10-second deadline nothing in this workload can miss: every
    // task completes on time under any shard count, so goodput is
    // exactly offered on every path
    for shards in [1, 2, 4] {
        let c = cfg("edge_only", "xavier-nx*2,jetson-tx2,jetson-nano", 42);
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&c, &fleet, 8, 5.0, "10000", 500);
        let s = serve_fleet_sharded(&mut fleet, &mut g, 15, &FleetOpts::default(), shards);
        assert_eq!(s.shed, 0, "shards={shards}");
        assert_eq!(s.slo_violations, 0, "shards={shards}");
        assert_eq!(s.goodput, s.offered, "shards={shards}");
    }
}

/// Stated tolerance for sharded-vs-unsharded goodput on a loaded
/// configuration: shards route within their own device subset and see
/// epoch-stale cloud signals, so admission decisions (and therefore
/// goodput) may drift from the unsharded run — but by no more than
/// this fraction of the offered load.
const GOODPUT_TOLERANCE: f64 = 0.15;

#[test]
fn loaded_goodput_matches_unsharded_within_the_stated_tolerance() {
    let base = loaded_run(1);
    assert!(base.goodput > 0);
    assert!(base.shed > 0, "the reference run must actually be loaded");
    for shards in [2, 4] {
        let s = loaded_run(shards);
        assert_eq!(s.offered, base.offered);
        let drift = (s.goodput as f64 - base.goodput as f64).abs();
        assert!(
            drift <= GOODPUT_TOLERANCE * base.offered as f64,
            "shards={shards}: goodput {} vs unsharded {} drifts {} > {}% of offered {}",
            s.goodput,
            base.goodput,
            drift,
            GOODPUT_TOLERANCE * 100.0,
            base.offered
        );
    }
}

#[test]
fn sharded_runs_are_deterministic_for_a_fixed_shard_count() {
    // the epoch-sync protocol reads cross-shard signals in shard-index
    // order at barriers, so thread scheduling must never leak into the
    // results — including through the shared cloud pool
    let run = || {
        let c = cfg("cloud_only", "xavier-nx*2,jetson-tx2,jetson-nano", 4242);
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&c, &fleet, 8, 25.0, "none", 5000);
        let opts = FleetOpts {
            des: DesOpts {
                batch_window_s: 0.004,
                cloud_batch_window_s: 0.005,
                ..DesOpts::default()
            },
            ..FleetOpts::default()
        };
        serve_fleet_sharded(&mut fleet, &mut g, 20, &opts, 3)
    };
    let a = run();
    let b = run();
    assert_eq!(a.offered, b.offered);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.shed, b.shed);
    assert_eq!(a.goodput, b.goodput);
    assert_eq!(a.events, b.events);
    assert_eq!(a.cloud_invocations, b.cloud_invocations);
    assert_eq!(a.serve.e2e_ms.mean().to_bits(), b.serve.e2e_ms.mean().to_bits());
    assert_eq!(a.serve.eti_mj.mean().to_bits(), b.serve.eti_mj.mean().to_bits());
    for (x, y) in a.per_device.iter().zip(&b.per_device) {
        assert_eq!(x.served, y.served, "{}", x.name);
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{}", x.name);
    }
}

/// The headline scale demonstration: 1,000,000 tasks over a 100-device
/// fleet through 4 shards with streaming telemetry. The run never
/// materializes a report vector — telemetry lives in four quantile
/// sketches (a few hundred buckets each) plus per-device and per-class
/// counters, so resident memory is bounded by the fleet size, not the
/// task count. Run it manually:
///
/// ```text
/// cargo test --release --test sharded_engine million_tasks -- --ignored --nocapture
/// ```
#[test]
#[ignore = "minutes-long scale demonstration; run with --release -- --ignored"]
fn million_tasks_on_a_hundred_devices_in_bounded_memory() {
    let c = cfg("edge_only", "xavier-nx*34,jetson-tx2*33,jetson-nano*33", 1);
    let mut fleet = Fleet::from_config(&c).unwrap();
    assert_eq!(fleet.len(), 100);
    let streams = 200;
    let per_stream = 5_000; // 200 streams × 5k tasks = 1M offered
    let mut g = gens(&c, &fleet, streams, 20.0, "250", 9000);
    let opts = FleetOpts {
        admission: Admission::Shed,
        ..FleetOpts::default()
    };
    let s = serve_fleet_streaming(&mut fleet, &mut g, per_stream, &opts, 4);

    assert_eq!(s.shards, 4);
    assert_eq!(s.offered, 1_000_000);
    assert_eq!(s.offered, s.completed + s.shed);
    assert_eq!(s.telemetry.e2e_ms.count() as usize, s.completed);
    assert_eq!(s.per_device.len(), 100);
    let dev_served: usize = s.per_device.iter().map(|d| d.served).sum();
    assert_eq!(dev_served, s.completed);

    // the bounded-memory claim, stated as a bound: all four sketches
    // together hold a few thousand buckets regardless of task count
    let buckets = s.telemetry.e2e_ms.buckets()
        + s.telemetry.tti_ms.buckets()
        + s.telemetry.queue_wait_ms.buckets()
        + s.telemetry.eti_mj.buckets();
    assert!(
        buckets < 8_192,
        "sketch footprint must stay bounded, got {buckets} buckets for {} tasks",
        s.completed
    );
}
