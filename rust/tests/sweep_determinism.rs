//! Determinism gate for the parallel sweep runner: a `--threads 4`
//! experiment sweep must render **byte-identical** tables (same rows,
//! same ordering, same formatting) to `--threads 1`.
//!
//! The contract this gates: every sweep cell builds its own config,
//! coordinator, and per-cell-seeded task generators, shares no mutable
//! state with its siblings, and `util::parallel::sweep` reassembles
//! results in cell-index order — so worker scheduling can never leak
//! into the output. The two sweeps checked here cover both harness
//! shapes named by the issue: `load` (single-edge multistream cells,
//! including trained-DQN cells) and `rebalance` (fleet cells with
//! re-routing and migration armed).

use dvfo::experiments::run_by_name;

fn assert_thread_invariant(id: &str) {
    let serial = run_by_name(id, true, 1).unwrap();
    let threaded = run_by_name(id, true, 4).unwrap();
    assert_eq!(
        serial.to_csv(),
        threaded.to_csv(),
        "experiment `{id}`: --threads 4 CSV differs from --threads 1"
    );
    assert_eq!(
        serial.render(),
        threaded.render(),
        "experiment `{id}`: --threads 4 rendering differs from --threads 1"
    );
}

#[test]
fn load_sweep_is_byte_identical_across_thread_counts() {
    assert_thread_invariant("load");
}

#[test]
fn rebalance_sweep_is_byte_identical_across_thread_counts() {
    assert_thread_invariant("rebalance");
}
