//! §Perf — hot-path microbenchmarks (EXPERIMENTS.md §Perf):
//!   * DQN policy inference (the per-request decision, L3's hottest op)
//!   * simulator step (env.execute)
//!   * full coordinator step (observe → decide → execute)
//!   * real-artifact pipeline request (PJRT path), cold vs warm

use dvfo::bench_harness::bench;
use dvfo::configx::Config;
use dvfo::coordinator::pipeline::{Pipeline, PipelineRequest};
use dvfo::coordinator::{Coordinator, Decision};
use dvfo::dqn::{InferScratch, Mlp};
use dvfo::util::Pcg32;
use dvfo::workload::{Arrivals, TaskGen};
use std::path::Path;

fn main() {
    // ---- L3: DQN policy inference (128/64/32 head, 41 actions)
    let mut rng = Pcg32::seeded(1);
    let mlp = Mlp::new(&[8, 128, 64, 32, 41], &mut rng);
    let state: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
    let mut scratch = InferScratch::default();
    let r = bench("dqn_infer (scratch, zero-skip)", 100, 5000, || {
        std::hint::black_box(mlp.infer(&state, &mut scratch));
    });
    println!("{}", r.report());

    // naive baseline: full batched forward with allocations
    let x = dvfo::dqn::Tensor2::from_vec(1, 8, state.clone());
    let r = bench("dqn_infer (naive alloc forward)", 100, 5000, || {
        std::hint::black_box(mlp.forward(&x).output.data[0]);
    });
    println!("{}", r.report());

    // ---- simulator: one env.execute
    let cfg = Config::default();
    let mut coord = Coordinator::from_config(&cfg).unwrap();
    let mut gen =
        TaskGen::new(&cfg.model, coord.env.dataset, Arrivals::Sequential, 2).unwrap();
    let task = gen.next_task();
    let d = Decision::edge_only_max(coord.env.levels());
    let r = bench("env.execute (simulated task)", 50, 5000, || {
        std::hint::black_box(coord.env.execute(&task, &d, 0.0));
    });
    println!("{}", r.report());

    // ---- full coordinator step (deployed policy)
    let r = bench("coordinator.step (greedy dvfo)", 50, 2000, || {
        std::hint::black_box(coord.step(&task, false));
    });
    println!("{}", r.report());

    // ---- one DQN learn() gradient step (batch 128)
    {
        use dvfo::dqn::{ActionSpace, DqnAgent, DqnConfig, Transition};
        let mut agent = DqnAgent::new(
            DqnConfig::default(),
            ActionSpace::new(vec![10, 10, 10, 11]),
            3,
        );
        let mut trng = Pcg32::seeded(9);
        for _ in 0..512 {
            agent.remember(Transition {
                state: (0..8).map(|_| trng.next_f32()).collect(),
                action: vec![1, 2, 3, 4],
                reward: trng.next_f64(),
                next_state: (0..8).map(|_| trng.next_f32()).collect(),
                done: false,
                gamma_pow: 1.0,
            });
        }
        let r = bench("dqn.learn (PER batch 128)", 10, 300, || {
            std::hint::black_box(agent.learn());
        });
        println!("{}", r.report());
    }

    // ---- real PJRT pipeline (skipped without artifacts)
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let pipeline = Pipeline::load(dir).unwrap();
        let (imgs, labels) = pipeline.engine().manifest.load_testset(dir).unwrap();
        let img_len: usize = pipeline.engine().manifest.img_shape.iter().product();
        let req = |i: usize| PipelineRequest {
            id: i as u64,
            image: imgs[..img_len].to_vec(),
            label: Some(labels[0]),
            xi: 0.5,
            lambda: 0.5,
        };
        // cold: includes per-serve cloud-engine spin-up
        let t0 = std::time::Instant::now();
        pipeline.serve(vec![req(0)]).unwrap();
        println!(
            "{:<40} cold first request: {:?}",
            "pipeline.serve (PJRT)", t0.elapsed()
        );
        // warm: amortized over a batch
        let t0 = std::time::Instant::now();
        let n = 128;
        pipeline
            .serve((0..n).map(req).collect::<Vec<_>>())
            .unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<40} warm batch: {:.3} ms/req ({:.0} req/s)",
            "pipeline.serve (PJRT)",
            1e3 * dt / n as f64,
            n as f64 / dt
        );
    } else {
        println!("pipeline benches skipped (run `make artifacts`)");
    }
}
