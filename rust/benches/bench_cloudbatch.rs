//! Cloud-batch sweep — goodput and executor occupancy vs the
//! cloud-side cross-device batching window
//! (`rust/src/coordinator/engine.rs`): cloud-heavy traffic from a
//! 2-device fleet into a tight shared executor pool, sweeping
//! `--cloud-batch-window` from 0 (pre-batching behavior) upward and
//! emitting invocation counts, batch occupancy, amortized dispatch
//! time, total executor busy time, and latency telemetry
//! (`DVFO_BENCH_FULL=1` for the full-size sweep).
fn main() {
    dvfo::bench_harness::run_experiment_bench("cloudbatch");
}
