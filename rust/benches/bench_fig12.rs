//! Fig. 12 — sensitivity to summation weight lambda
//!
//! Regenerates the paper's rows/series on the simulator substrate
//! (`DVFO_BENCH_FULL=1` for the full-size sweep). See DESIGN.md §4.
fn main() {
    dvfo::bench_harness::run_experiment_bench("fig12");
}
