//! Table 5 — scalability on CIFAR-100 (6 models x Nano/TX2)
//!
//! Regenerates the paper's rows/series on the simulator substrate
//! (`DVFO_BENCH_FULL=1` for the full-size sweep). See DESIGN.md §4.
fn main() {
    dvfo::bench_harness::run_experiment_bench("tab05");
}
