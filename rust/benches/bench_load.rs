//! Load sweep — latency vs offered load through the discrete-event
//! multi-stream serving core: p50/p95/p99 end-to-end latency, queue
//! wait, uplink batch size, and per-stream energy as the number of
//! concurrent user streams grows (`DVFO_BENCH_FULL=1` for the full-size
//! sweep). See rust/src/coordinator/des.rs.
fn main() {
    dvfo::bench_harness::run_experiment_bench("load");
}
