//! Fleet sweep — goodput/energy/violation curves vs offered load through
//! the multi-edge dispatcher (`rust/src/coordinator/fleet.rs`): a
//! heterogeneous 3-device fleet under energy-aware routing with a
//! per-stream SLO, comparing admission control off / shed / downgrade at
//! each load point (`DVFO_BENCH_FULL=1` for the full-size sweep).
fn main() {
    dvfo::bench_harness::run_experiment_bench("fleet");
}
