//! §Perf — whole-engine throughput bench: drives the unified DES kernel
//! (`src/coordinator/engine.rs`) end-to-end on pinned reference configs
//! and reports **events/sec** and wall-clock, recording the full
//! per-iteration trajectory into `BENCH_7.json` (CI uploads it as an
//! artifact; the numbers are recorded, never gated, so shared-runner
//! noise cannot break the build).
//!
//! Every pinned config runs as a **heap-vs-calendar pair** (suffixes
//! `-heap` / `-calendar`): same fleet, streams, and seeds, differing
//! only in the event-scheduler backend, so the artifact directly
//! records the calendar queue's speedup (or lack of it) on this host.
//! The two backends must process the identical event count — asserted
//! per pair, the same contract `rust/tests/sched_parity.rs` gates.
//!
//! Pinned configs:
//!   * `ref-1dev`  — one xavier-nx, cloud-heavy traffic through batched
//!     uplink + cloud windows into a 2-slot shared pool (exercises the
//!     batch-slot free lists and the cloud stage).
//!   * `ref-3dev`  — the paper's three edge boards under shed admission
//!     with re-route-before-shed and mid-run migration armed (exercises
//!     the O(1) backlog accumulators, sibling scans, and work stealing).
//!   * `ref-4dev-s1` / `ref-4dev-s4` — the same four-board cloud-heavy
//!     config through the unsharded kernel vs 4 share-nothing shards,
//!     so every run records the scale-out speedup (or lack of it) on
//!     this host.
//!
//! `DVFO_BENCH_FULL=1` scales the task counts up ~10×;
//! `DVFO_BENCH_JSON=path` overrides the output path (default
//! `BENCH_7.json` in the working directory).

use dvfo::configx::Config;
use dvfo::coordinator::des::DesOpts;
use dvfo::coordinator::fleet::{serve_fleet_sharded, Admission, Fleet, FleetOpts};
use dvfo::coordinator::SchedKind;
use dvfo::workload::{Arrivals, SloClass, TaskGen};
use std::time::Instant;

#[derive(Clone)]
struct RefCase {
    name: String,
    policy: &'static str,
    fleet: &'static str,
    streams: usize,
    per_stream: usize,
    rate: f64,
    slo: &'static str,
    shards: usize,
    opts: FleetOpts,
}

fn cases(full: bool) -> Vec<RefCase> {
    let scale = if full { 10 } else { 1 };
    let shard_opts = || FleetOpts {
        des: DesOpts {
            batch_window_s: 0.004,
            cloud_batch_window_s: 0.005,
            ..DesOpts::default()
        },
        ..FleetOpts::default()
    };
    vec![
        RefCase {
            name: "ref-1dev".into(),
            policy: "cloud_only",
            fleet: "xavier-nx",
            streams: 8,
            per_stream: 25 * scale,
            rate: 40.0,
            slo: "none",
            shards: 1,
            opts: FleetOpts {
                des: DesOpts {
                    batch_window_s: 0.004,
                    cloud_batch_window_s: 0.005,
                    cloud_slots: 2,
                    ..DesOpts::default()
                },
                ..FleetOpts::default()
            },
        },
        RefCase {
            name: "ref-3dev".into(),
            policy: "edge_only",
            fleet: "xavier-nx,jetson-tx2,jetson-nano",
            streams: 9,
            per_stream: 20 * scale,
            rate: 10.0,
            slo: "250",
            shards: 1,
            opts: FleetOpts {
                admission: Admission::Shed,
                reroute: true,
                rebalance_window_s: 0.01,
                migrate_threshold_s: 0.05,
                migrate_penalty_s: 0.002,
                ..FleetOpts::default()
            },
        },
        RefCase {
            name: "ref-4dev-s1".into(),
            policy: "cloud_only",
            fleet: "xavier-nx*2,jetson-tx2,jetson-nano",
            streams: 8,
            per_stream: 25 * scale,
            rate: 40.0,
            slo: "none",
            shards: 1,
            opts: shard_opts(),
        },
        RefCase {
            name: "ref-4dev-s4".into(),
            policy: "cloud_only",
            fleet: "xavier-nx*2,jetson-tx2,jetson-nano",
            streams: 8,
            per_stream: 25 * scale,
            rate: 40.0,
            slo: "none",
            shards: 4,
            opts: shard_opts(),
        },
    ]
}

/// One timed run: fleet/generator construction is excluded from the
/// clock — the figure is kernel throughput, not setup cost. Returns
/// (events, completed, wall_s).
fn run_once(c: &RefCase) -> (usize, usize, f64) {
    let mut cfg = Config::default();
    cfg.policy = c.policy.into();
    cfg.fleet = c.fleet.into();
    cfg.seed = 4242;
    let mut fleet = Fleet::from_config(&cfg).expect("pinned fleet builds");
    let slo = SloClass::parse(c.slo).expect("pinned slo parses");
    let mut gens: Vec<TaskGen> = (0..c.streams)
        .map(|s| {
            TaskGen::new(
                &cfg.model,
                fleet.devices[0].env.dataset,
                Arrivals::Poisson { rate: c.rate },
                5000 + s as u64,
            )
            .expect("pinned generator builds")
            .with_slo(slo)
        })
        .collect();
    let t0 = Instant::now();
    let s = serve_fleet_sharded(&mut fleet, &mut gens, c.per_stream, &c.opts, c.shards);
    let wall = t0.elapsed().as_secs_f64();
    (s.events, s.completed, wall)
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.9}")
    } else {
        "null".into()
    }
}

fn main() {
    let full = std::env::var("DVFO_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let iters = if full { 10 } else { 5 };
    let out_path =
        std::env::var("DVFO_BENCH_JSON").unwrap_or_else(|_| "BENCH_7.json".to_string());

    let mut case_jsons = Vec::new();
    for base in cases(full) {
        // heap-vs-calendar pair: same config, same seeds, only the
        // scheduler backend differs — and the event count must not
        let mut pair_events: Option<usize> = None;
        for kind in [SchedKind::Heap, SchedKind::Calendar] {
            let mut c = base.clone();
            c.name = format!("{}-{}", base.name, kind.as_str());
            c.opts.des.sched = kind;
            // warmup (allocator, page cache, branch predictors)
            let (events, completed, _) = run_once(&c);
            match pair_events {
                None => pair_events = Some(events),
                Some(he) => assert_eq!(
                    he, events,
                    "heap and calendar must process identical event counts"
                ),
            }
            let mut walls = Vec::with_capacity(iters);
            for _ in 0..iters {
                let (e, done, wall) = run_once(&c);
                assert_eq!(e, events, "pinned config must be deterministic");
                assert_eq!(done, completed, "pinned config must be deterministic");
                walls.push(wall);
            }
            let mean = walls.iter().sum::<f64>() / walls.len() as f64;
            let best = walls.iter().cloned().fold(f64::INFINITY, f64::min);
            let eps_mean = events as f64 / mean;
            let eps_best = events as f64 / best;
            println!(
                "{:<21} shards={} events={events:<7} tasks={completed:<5} iters={iters} \
                 mean={:.3} ms  best={:.3} ms  events/sec mean={:.0} best={:.0}",
                c.name,
                c.shards,
                mean * 1e3,
                best * 1e3,
                eps_mean,
                eps_best,
            );
            let trajectory: Vec<String> = walls.iter().map(|&w| json_num(w)).collect();
            case_jsons.push(format!(
                "{{\"name\":\"{}\",\"sched\":\"{}\",\"shards\":{},\"events\":{events},\
                 \"tasks\":{completed},\
                 \"iters\":{iters},\"mean_s\":{},\"best_s\":{},\
                 \"events_per_sec_mean\":{},\"events_per_sec_best\":{},\
                 \"wall_s_trajectory\":[{}]}}",
                c.name,
                kind.as_str(),
                c.shards,
                json_num(mean),
                json_num(best),
                json_num(eps_mean),
                json_num(eps_best),
                trajectory.join(","),
            ));
        }
    }

    let json = format!(
        "{{\"bench\":\"engine_throughput\",\"full\":{full},\"configs\":[{}]}}\n",
        case_jsons.join(",")
    );
    if let Err(e) = std::fs::write(&out_path, &json) {
        eprintln!("[engine_throughput] could not write {out_path}: {e}");
        std::process::exit(1);
    }
    println!("[engine_throughput] wrote {out_path}");
}
