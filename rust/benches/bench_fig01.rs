//! Fig. 1 — CPU/GPU/MEM energy breakdown, 4 models on Xavier NX
//!
//! Regenerates the paper's rows/series on the simulator substrate
//! (`DVFO_BENCH_FULL=1` for the full-size sweep). See DESIGN.md §4.
fn main() {
    dvfo::bench_harness::run_experiment_bench("fig01");
}
