//! Chaos — goodput/violations/failed vs deterministic fault intensity
//! on a skewed fleet, re-route + migration on vs off
//! (`DVFO_BENCH_FULL=1` for the full-size sweep).
fn main() {
    dvfo::bench_harness::run_experiment_bench("chaos");
}
