//! §Perf — DQN training-path bench: packed GEMM kernels vs the frozen
//! naive loops on learn-shaped matrices, raw `learn()` steps/sec, and
//! the decide-path cost of inline vs background gradient placement.
//! Results land in `BENCH_8.json` (CI uploads it as an artifact; the
//! numbers are recorded, never gated, so shared-runner noise cannot
//! break the build).
//!
//! Sections:
//!   * `kernels` — the minibatch forward/backward matmul shapes of the
//!     default DQN (batch 128 through 10→128→64→32→41), timed through
//!     the frozen pre-refactor loops and through `Tensor2`'s packed
//!     kernels, with the A operand ~50% zeros (post-relu activations).
//!     Bit-equality naive-vs-packed is asserted per shape — the same
//!     contract `rust/tests/gemm_parity.rs` gates.
//!   * `learn` — gradient steps/sec of `DqnAgent::learn()` on a
//!     pre-filled replay buffer (the whole-path number: sampling,
//!     forward, batched target forward, backward, Adam).
//!   * `policy` — an inline-vs-bg `DvfoPolicy` pair driving identical
//!     decide→feedback cycles, recording per-decision latency and the
//!     `set_training(false)` drain cost of the background learner.
//!
//! `DVFO_BENCH_FULL=1` scales reps/cycles up; `DVFO_BENCH_JSON=path`
//! overrides the output path (default `BENCH_8.json`).

use dvfo::dqn::{ActionSpace, DqnAgent, DqnConfig, LearnerMode, LearnerOpts, Transition};
use dvfo::policy::{DvfoPolicy, Feedback, Obs, Policy};
use dvfo::util::Pcg32;
use std::time::Instant;

// ---- frozen pre-refactor loops (same references as gemm_parity.rs) ----

fn naive_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

fn naive_tn(k: usize, m: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    out.fill(0.0);
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

fn naive_nt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            out[i * n + j] = acc;
        }
    }
}

// ---- kernel micro-bench ----------------------------------------------

#[derive(Clone, Copy)]
enum Op {
    Nn,
    Tn,
    Nt,
}

impl Op {
    fn as_str(self) -> &'static str {
        match self {
            Op::Nn => "nn",
            Op::Tn => "tn",
            Op::Nt => "nt",
        }
    }
}

/// Learn-shaped cases: the default agent's minibatch forward (nn) and
/// backward (tn for dW, nt for dx) shapes through 10→128→64→32→41.
/// (op, m, k, n) in the kernel's own convention; `sparse_a` marks the
/// operand the historical skip fired on (post-relu activations).
fn kernel_cases() -> Vec<(Op, usize, usize, usize, bool)> {
    vec![
        (Op::Nn, 128, 10, 128, false), // x @ W1 (input layer, dense x)
        (Op::Nn, 128, 128, 64, true),  // a1 @ W2 (relu-sparse a1)
        (Op::Nn, 128, 64, 32, true),   // a2 @ W3
        (Op::Nn, 128, 32, 41, true),   // a3 @ W4 (Q head)
        (Op::Tn, 128, 128, 64, true),  // a1^T @ dz2 (dW2)
        (Op::Tn, 128, 32, 41, true),   // a3^T @ dout (dW4)
        (Op::Nt, 128, 64, 128, false), // dz2 @ W2^T (dx, dense grads)
    ]
}

/// ~50% exact zeros when sparse (post-relu statistics), else dense.
fn fill(rng: &mut Pcg32, xs: &mut [f32], sparse: bool) {
    for x in xs.iter_mut() {
        *x = if sparse && rng.chance(0.5) {
            0.0
        } else {
            2.0 * rng.next_f32() - 1.0
        };
    }
}

fn bench_kernels(reps: usize) -> Vec<String> {
    let mut out = Vec::new();
    for (op, d0, d1, d2, sparse_a) in kernel_cases() {
        // shapes per op convention: nn (m,k,n); tn (k,m,n); nt (m,k,n)
        let (m, k, n, a_len, b_len) = match op {
            Op::Nn => (d0, d1, d2, d0 * d1, d1 * d2),
            Op::Tn => (d1, d0, d2, d0 * d1, d0 * d2),
            Op::Nt => (d0, d1, d2, d0 * d1, d2 * d1),
        };
        let mut rng = Pcg32::seeded(0x8E88 ^ ((a_len as u64) << 16) ^ (b_len as u64));
        let mut a = vec![0.0f32; a_len];
        let mut b = vec![0.0f32; b_len];
        fill(&mut rng, &mut a, sparse_a);
        fill(&mut rng, &mut b, false);
        let mut naive = vec![0.0f32; m * n];
        let mut packed = vec![0.0f32; m * n];

        let run_naive = |dst: &mut [f32]| match op {
            Op::Nn => naive_nn(d0, d1, d2, &a, &b, dst),
            Op::Tn => naive_tn(d0, d1, d2, &a, &b, dst),
            Op::Nt => naive_nt(d0, d1, d2, &a, &b, dst),
        };
        let run_packed = |dst: &mut [f32]| match op {
            Op::Nn => dvfo::dqn::gemm::gemm_nn(d0, d1, d2, &a, &b, dst),
            Op::Tn => dvfo::dqn::gemm::gemm_tn(d0, d1, d2, &a, &b, dst),
            Op::Nt => dvfo::dqn::gemm::gemm_nt(d0, d1, d2, &a, &b, dst),
        };

        // warmup + the bit-equality contract (finite data, B finite)
        run_naive(&mut naive);
        run_packed(&mut packed);
        assert_eq!(
            naive.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            packed.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "packed {} kernel must be bit-identical to the naive loop",
            op.as_str()
        );

        let t0 = Instant::now();
        for _ in 0..reps {
            run_naive(&mut naive);
        }
        let naive_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        for _ in 0..reps {
            run_packed(&mut packed);
        }
        let packed_s = t0.elapsed().as_secs_f64();
        std::hint::black_box((&naive, &packed));

        let flops = 2.0 * (m * k * n) as f64 * reps as f64;
        let speedup = naive_s / packed_s;
        println!(
            "kernel {}  {}x{}x{}  sparse_a={}  reps={reps}  naive={:.3} ms  \
             packed={:.3} ms  speedup={speedup:.2}x  {:.0} mflop/s",
            op.as_str(),
            m,
            k,
            n,
            sparse_a,
            naive_s * 1e3,
            packed_s * 1e3,
            flops / packed_s / 1e6,
        );
        out.push(format!(
            "{{\"op\":\"{}\",\"m\":{m},\"k\":{k},\"n\":{n},\"sparse_a\":{sparse_a},\
             \"reps\":{reps},\"naive_s\":{},\"packed_s\":{},\"speedup\":{},\
             \"packed_mflops\":{}}}",
            op.as_str(),
            json_num(naive_s),
            json_num(packed_s),
            json_num(speedup),
            json_num(flops / packed_s / 1e6),
        ));
    }
    out
}

// ---- learn-steps/sec --------------------------------------------------

fn bench_learn(steps: usize) -> String {
    let cfg = DqnConfig {
        state_dim: 10,
        ..DqnConfig::default()
    };
    let space = ActionSpace::new(vec![10, 10, 10, 11]);
    let mut agent = DqnAgent::new(cfg, space, 4242);
    let mut rng = Pcg32::seeded(0x1EA2);
    for i in 0..4096usize {
        let state: Vec<f32> = (0..10).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        let next_state: Vec<f32> = (0..10).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
        let action = agent.space.random(&mut rng);
        agent.remember(Transition {
            state,
            action,
            reward: rng.next_f64() - 0.5,
            next_state,
            done: i % 24 == 23,
            gamma_pow: 1.0,
        });
    }
    for _ in 0..10 {
        agent.learn(); // warmup (arena + scratch sizing, target syncs)
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        std::hint::black_box(agent.learn());
    }
    let wall = t0.elapsed().as_secs_f64();
    let sps = steps as f64 / wall;
    println!(
        "learn  batch=128 net=10-128-64-32-41  steps={steps}  wall={:.3} s  \
         {:.0} steps/sec  {:.3} ms/step",
        wall,
        sps,
        wall / steps as f64 * 1e3,
    );
    format!(
        "{{\"batch\":128,\"steps\":{steps},\"wall_s\":{},\"steps_per_sec\":{},\
         \"ms_per_step\":{}}}",
        json_num(wall),
        json_num(sps),
        json_num(wall / steps as f64 * 1e3),
    )
}

// ---- inline vs background policy loop ---------------------------------

fn obs_i(i: usize) -> Obs {
    let x = (i % 17) as f64 / 17.0;
    Obs {
        lambda: 0.5,
        eta: 0.5,
        bandwidth_mbps: 2.0 + 6.0 * x,
        top_quarter_mass: 0.3 + 0.4 * x,
        skewness: 1.0 - 2.0 * x,
        entropy_norm: 0.5,
        intensity_norm: 0.4 + 0.2 * x,
        prev_xi: x,
        queue_depth_norm: 0.0,
        backlog_norm: 0.0,
    }
}

fn bench_policy(mode: LearnerMode, cycles: usize) -> String {
    let mut p = DvfoPolicy::new(5, 5, true, false, 4242).with_learner(LearnerOpts {
        mode,
        publish_every: 32,
        ..LearnerOpts::default()
    });
    let t0 = Instant::now();
    for i in 0..cycles {
        let obs = obs_i(i);
        let next = obs_i(i + 1);
        let d = p.decide(&obs);
        let fb = Feedback {
            reward: -(0.1 + 0.05 * (i % 7) as f64),
            gamma_pow: 1.0,
            done: i % 24 == 23,
        };
        p.feedback(&obs, &d, &next, fb);
    }
    let wall = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    p.set_training(false); // bg: drain the queue + join; inline: no-op
    let drain_s = t0.elapsed().as_secs_f64();
    let per_us = wall / cycles as f64 * 1e6;
    println!(
        "policy mode={:<6} cycles={cycles}  wall={:.3} s  {per_us:.1} us/decision  \
         drain={:.3} ms",
        mode.as_str(),
        wall,
        drain_s * 1e3,
    );
    format!(
        "{{\"mode\":\"{}\",\"publish_every\":32,\"cycles\":{cycles},\"wall_s\":{},\
         \"per_decision_us\":{},\"drain_s\":{}}}",
        mode.as_str(),
        json_num(wall),
        json_num(per_us),
        json_num(drain_s),
    )
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.9}")
    } else {
        "null".into()
    }
}

fn main() {
    let full = std::env::var("DVFO_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("DVFO_BENCH_JSON").unwrap_or_else(|_| "BENCH_8.json".to_string());
    let (kernel_reps, learn_steps, cycles) =
        if full { (2000, 1000, 3000) } else { (400, 200, 600) };

    let kernels = bench_kernels(kernel_reps);
    let learn = bench_learn(learn_steps);
    let policy: Vec<String> = [LearnerMode::Inline, LearnerMode::Background]
        .into_iter()
        .map(|m| bench_policy(m, cycles))
        .collect();

    let json = format!(
        "{{\"bench\":\"learner_throughput\",\"full\":{full},\"kernels\":[{}],\
         \"learn\":{learn},\"policy\":[{}]}}\n",
        kernels.join(","),
        policy.join(",")
    );
    if let Err(e) = std::fs::write(&out_path, &json) {
        eprintln!("[learner_throughput] could not write {out_path}: {e}");
        std::process::exit(1);
    }
    println!("[learner_throughput] wrote {out_path}");
}
