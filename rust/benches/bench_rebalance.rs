//! Rebalance sweep — goodput/shed/violation vs backlog skew under an
//! imbalanced round-robin router (`rust/src/coordinator/engine.rs`):
//! increasingly heterogeneous fleets at the same offered load, comparing
//! plain round-robin + shed admission against + re-route-before-shed
//! and + mid-run queued-task migration (work stealing)
//! (`DVFO_BENCH_FULL=1` for the full-size sweep).
fn main() {
    dvfo::bench_harness::run_experiment_bench("rebalance");
}
