//! DVFO: learning-based DVFS for energy-efficient edge-cloud collaborative
//! inference — full-system reproduction (see DESIGN.md).
//!
//! Layering (Python never on the request path):
//! * L1/L2 live in `python/compile` and are AOT-lowered to `artifacts/`.
//! * L3 (this crate) is the coordinator: DVFS control, DRL policy,
//!   offloading, edge/cloud workers, and the PJRT runtime that executes
//!   the AOT artifacts.

pub mod accuracy;
pub mod bench_harness;
pub mod cli;
pub mod coordinator;
pub mod configx;
pub mod device;
pub mod net;
pub mod offload;
pub mod perfmodel;
pub mod policy;
pub mod proptest_mini;
pub mod dqn;
pub mod experiments;
pub mod runtime;
pub mod scam;
pub mod telemetry;
pub mod util;
pub mod workload;
