//! Feature-map offloading: payload sizing, compression (int8
//! quantization, paper §5.2 after SPINN), and the per-policy offload
//! configurations the baselines use.

use crate::perfmodel::{Dataset, ModelProfile};

/// Compression applied to the offloaded payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compression {
    /// raw f32 feature maps (DRLDO offloads uncompressed data)
    None,
    /// symmetric int8 quantization (DVFO, AppealNet, Cloud-only)
    Int8,
}

impl Compression {
    pub fn bytes_per_value(&self) -> f64 {
        match self {
            Compression::None => 4.0,
            Compression::Int8 => 1.0,
        }
    }

    /// Whether a compression pass runs on the edge (costs time, Eq. 7).
    pub fn has_compress_phase(&self) -> bool {
        matches!(self, Compression::Int8)
    }
}

/// Wire header: scale factor + shape metadata + framing.
pub const WIRE_HEADER_BYTES: f64 = 64.0;

/// Size of the offloaded payload for proportion ξ of the feature maps of
/// `profile` on `ds` (Eq. 8's m_cloud).
pub fn payload_bytes(
    profile: &ModelProfile,
    ds: Dataset,
    xi: f64,
    comp: Compression,
) -> f64 {
    let xi = xi.clamp(0.0, 1.0);
    if xi <= 0.0 {
        return 0.0;
    }
    let values = profile.act_bytes(ds) / 4.0; // act_bytes is f32-sized
    values * xi * comp.bytes_per_value() + WIRE_HEADER_BYTES
}

/// Relative RMS error introduced by quantizing to int8 (used by the
/// accuracy model; the measured artifact path quantizes for real).
pub fn quant_rel_error(comp: Compression) -> f64 {
    match comp {
        Compression::None => 0.0,
        // symmetric int8: quantization SNR ≈ 6.02*8 dB → rel err ~0.2-0.4%
        Compression::Int8 => 0.003,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::find_model;

    #[test]
    fn int8_is_quarter_size() {
        let m = find_model("efficientnet-b0").unwrap();
        let raw = payload_bytes(&m, Dataset::Cifar100, 1.0, Compression::None);
        let q = payload_bytes(&m, Dataset::Cifar100, 1.0, Compression::Int8);
        let ratio = (q - WIRE_HEADER_BYTES) / (raw - WIRE_HEADER_BYTES);
        assert!((ratio - 0.25).abs() < 1e-9);
    }

    #[test]
    fn payload_scales_with_xi() {
        let m = find_model("resnet-18").unwrap();
        let half = payload_bytes(&m, Dataset::Cifar100, 0.5, Compression::Int8);
        let full = payload_bytes(&m, Dataset::Cifar100, 1.0, Compression::Int8);
        assert!(half < full && half > 0.4 * full);
        assert_eq!(payload_bytes(&m, Dataset::Cifar100, 0.0, Compression::Int8), 0.0);
    }

    #[test]
    fn imagenet_payloads_larger() {
        let m = find_model("vit-b16").unwrap();
        assert!(
            payload_bytes(&m, Dataset::Imagenet, 0.5, Compression::Int8)
                > payload_bytes(&m, Dataset::Cifar100, 0.5, Compression::Int8)
        );
    }

    #[test]
    fn compression_flags() {
        assert!(Compression::Int8.has_compress_phase());
        assert!(!Compression::None.has_compress_phase());
        assert_eq!(quant_rel_error(Compression::None), 0.0);
        assert!(quant_rel_error(Compression::Int8) > 0.0);
    }
}
