//! Edge/cloud device DVFS simulator.
//!
//! Substitutes the paper's physical Jetson boards (Table 3) + `nvpmodel`:
//! per-device frequency ladders for CPU/GPU/memory, a voltage-frequency
//! curve, the dynamic power model p = p_static + Σ_u k_u · V_u² · f_u
//! (paper §4.2: p ∝ V²·f), and an energy integrator. The DVFO frequency
//! controller actuates this instead of sysfs.

pub mod spec;

pub use spec::{device_zoo, DeviceSpec, Unit, UNITS};

use crate::util::clampf;
use anyhow::{bail, Result};

/// A frequency setting for the three DVFS-controlled units, in MHz.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FreqVector {
    pub cpu_mhz: f64,
    pub gpu_mhz: f64,
    pub mem_mhz: f64,
}

impl FreqVector {
    pub fn get(&self, u: Unit) -> f64 {
        match u {
            Unit::Cpu => self.cpu_mhz,
            Unit::Gpu => self.gpu_mhz,
            Unit::Mem => self.mem_mhz,
        }
    }

    pub fn set(&mut self, u: Unit, v: f64) {
        match u {
            Unit::Cpu => self.cpu_mhz = v,
            Unit::Gpu => self.gpu_mhz = v,
            Unit::Mem => self.mem_mhz = v,
        }
    }
}

/// The discrete frequency ladder for one unit: `levels` points evenly
/// spaced in [min, max] (the paper samples levels "evenly between the
/// minimum frequency that satisfies system operation and the maximum").
#[derive(Clone, Debug)]
pub struct Ladder {
    pub min_mhz: f64,
    pub max_mhz: f64,
    pub levels: usize,
}

impl Ladder {
    pub fn new(min_mhz: f64, max_mhz: f64, levels: usize) -> Self {
        assert!(levels >= 2 && max_mhz > min_mhz);
        Self {
            min_mhz,
            max_mhz,
            levels,
        }
    }

    pub fn freq_at(&self, level: usize) -> f64 {
        let l = level.min(self.levels - 1);
        self.min_mhz
            + (self.max_mhz - self.min_mhz) * l as f64 / (self.levels - 1) as f64
    }

    /// Nearest ladder level for a frequency.
    pub fn level_of(&self, mhz: f64) -> usize {
        let t = (mhz - self.min_mhz) / (self.max_mhz - self.min_mhz);
        (clampf(t, 0.0, 1.0) * (self.levels - 1) as f64).round() as usize
    }
}

/// Voltage model: V(f) rises roughly linearly with frequency in the DVFS
/// operating region; normalized so V(f_max) = 1. Dynamic power then goes
/// ~ f·V² ~ f·(a+b·f)² — the superlinear growth that makes max-frequency
/// operation energy-inefficient (paper Fig. 2 observation 1).
pub fn voltage(f_mhz: f64, f_max_mhz: f64) -> f64 {
    let x = clampf(f_mhz / f_max_mhz, 0.0, 1.2);
    0.55 + 0.45 * x
}

/// Instantaneous power (W) of a device at a frequency vector under a given
/// utilization per unit (0..1).
pub fn power_w(spec: &DeviceSpec, f: &FreqVector, util: &[f64; 3]) -> f64 {
    let mut p = spec.static_w;
    for (i, &u) in UNITS.iter().enumerate() {
        let ladder = spec.ladder(u);
        let v = voltage(f.get(u), ladder.max_mhz);
        let dyn_max = spec.dyn_max_w(u);
        // p_dyn = k·V²·f scaled so that (V=1, f=f_max, util=1) → dyn_max
        p += dyn_max * util[i] * v * v * (f.get(u) / ladder.max_mhz);
    }
    p.min(spec.max_power_w)
}

/// Idle power: static only (paper assumes devices idle between tasks).
pub fn idle_power_w(spec: &DeviceSpec) -> f64 {
    spec.static_w
}

/// Energy integrator over execution phases.
#[derive(Clone, Debug, Default)]
pub struct EnergyMeter {
    total_j: f64,
    per_unit_j: [f64; 3],
}

impl EnergyMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate a phase of `dt` seconds at frequency `f` and utilization
    /// `util`; returns the phase energy (J).
    pub fn accumulate(
        &mut self,
        spec: &DeviceSpec,
        f: &FreqVector,
        util: &[f64; 3],
        dt_s: f64,
    ) -> f64 {
        let p = power_w(spec, f, util);
        let e = p * dt_s;
        self.total_j += e;
        for (i, &u) in UNITS.iter().enumerate() {
            let ladder = spec.ladder(u);
            let v = voltage(f.get(u), ladder.max_mhz);
            self.per_unit_j[i] +=
                spec.dyn_max_w(u) * util[i] * v * v * (f.get(u) / ladder.max_mhz) * dt_s;
        }
        e
    }

    pub fn total_j(&self) -> f64 {
        self.total_j
    }

    /// Per-unit dynamic energy split (CPU, GPU, MEM) — drives Fig. 1.
    pub fn per_unit_j(&self) -> [f64; 3] {
        self.per_unit_j
    }
}

/// The DVFS actuator: tracks the current frequency vector, models the
/// (small) transition latency of a frequency switch, and clamps every
/// request into the ladder.
#[derive(Clone, Debug)]
pub struct FrequencyController {
    spec: DeviceSpec,
    current: FreqVector,
    /// seconds per DVFS transition (datasheet-scale ~100 µs)
    pub transition_s: f64,
    transitions: u64,
}

impl FrequencyController {
    pub fn new(spec: DeviceSpec) -> Self {
        let current = FreqVector {
            cpu_mhz: spec.cpu.max_mhz,
            gpu_mhz: spec.gpu.max_mhz,
            mem_mhz: spec.mem.max_mhz,
        };
        Self {
            spec,
            current,
            transition_s: 1e-4,
            transitions: 0,
        }
    }

    pub fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    pub fn current(&self) -> FreqVector {
        self.current
    }

    pub fn transitions(&self) -> u64 {
        self.transitions
    }

    /// Apply a frequency vector; returns the transition latency incurred
    /// (0 when nothing changes).
    pub fn set(&mut self, target: FreqVector) -> Result<f64> {
        let mut t = target;
        for &u in &UNITS {
            let l = self.spec.ladder(u);
            let v = t.get(u);
            if !(l.min_mhz..=l.max_mhz).contains(&v) {
                if v < l.min_mhz * 0.99 || v > l.max_mhz * 1.01 {
                    bail!(
                        "{:?} frequency {v} MHz outside [{}, {}]",
                        u,
                        l.min_mhz,
                        l.max_mhz
                    );
                }
                t.set(u, clampf(v, l.min_mhz, l.max_mhz));
            }
        }
        if t != self.current {
            self.current = t;
            self.transitions += 1;
            Ok(self.transition_s)
        } else {
            Ok(0.0)
        }
    }

    /// Apply ladder levels (the DQN action encoding).
    pub fn set_levels(&mut self, cpu: usize, gpu: usize, mem: usize) -> Result<f64> {
        let t = FreqVector {
            cpu_mhz: self.spec.cpu.freq_at(cpu),
            gpu_mhz: self.spec.gpu.freq_at(gpu),
            mem_mhz: self.spec.mem.freq_at(mem),
        };
        self.set(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nx() -> DeviceSpec {
        device_zoo().into_iter().find(|d| d.name == "xavier-nx").unwrap()
    }

    #[test]
    fn ladder_endpoints_and_roundtrip() {
        let l = Ladder::new(200.0, 1200.0, 11);
        assert_eq!(l.freq_at(0), 200.0);
        assert_eq!(l.freq_at(10), 1200.0);
        for lev in 0..11 {
            assert_eq!(l.level_of(l.freq_at(lev)), lev);
        }
    }

    #[test]
    fn voltage_monotone() {
        let vs: Vec<f64> = (1..=10).map(|i| voltage(i as f64 * 100.0, 1000.0)).collect();
        assert!(vs.windows(2).all(|w| w[0] < w[1]));
        assert!((voltage(1000.0, 1000.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn power_superlinear_in_frequency() {
        let d = nx();
        let util = [1.0, 1.0, 1.0];
        let f_half = FreqVector {
            cpu_mhz: d.cpu.max_mhz / 2.0,
            gpu_mhz: d.gpu.max_mhz / 2.0,
            mem_mhz: d.mem.max_mhz / 2.0,
        };
        let f_full = FreqVector {
            cpu_mhz: d.cpu.max_mhz,
            gpu_mhz: d.gpu.max_mhz,
            mem_mhz: d.mem.max_mhz,
        };
        let p_half = power_w(&d, &f_half, &util) - d.static_w;
        let p_full = power_w(&d, &f_full, &util) - d.static_w;
        // dynamic power more than doubles when frequency doubles (V² term)
        assert!(p_full > 2.0 * p_half, "p_full={p_full} p_half={p_half}");
    }

    #[test]
    fn power_capped_at_max() {
        let d = nx();
        let f = FreqVector {
            cpu_mhz: d.cpu.max_mhz,
            gpu_mhz: d.gpu.max_mhz,
            mem_mhz: d.mem.max_mhz,
        };
        assert!(power_w(&d, &f, &[1.0, 1.0, 1.0]) <= d.max_power_w + 1e-9);
    }

    #[test]
    fn gpu_dominates_energy_under_gpu_load() {
        // Fig. 1: GPU energy is 3.1-3.5x CPU energy for DNN inference —
        // with the utilization vector the roofline model actually emits.
        let d = nx();
        let f = FreqVector {
            cpu_mhz: d.cpu.max_mhz,
            gpu_mhz: d.gpu.max_mhz,
            mem_mhz: d.mem.max_mhz,
        };
        let profile = crate::perfmodel::find_model("resnet-18").unwrap();
        let phase = crate::perfmodel::edge_compute(
            &profile,
            crate::perfmodel::Dataset::Cifar100,
            &d,
            &f,
            1.0,
        );
        let mut m = EnergyMeter::new();
        m.accumulate(&d, &f, &phase.util, phase.total_s);
        let [cpu, gpu, _mem] = m.per_unit_j();
        let ratio = gpu / cpu;
        assert!(
            (2.5..=4.5).contains(&ratio),
            "gpu/cpu energy ratio {ratio} outside Fig.1 band (util {:?})",
            phase.util
        );
    }

    #[test]
    fn controller_counts_transitions_and_clamps() {
        let mut c = FrequencyController::new(nx());
        let t0 = c.set_levels(0, 0, 0).unwrap();
        assert!(t0 > 0.0);
        let t1 = c.set_levels(0, 0, 0).unwrap();
        assert_eq!(t1, 0.0);
        assert_eq!(c.transitions(), 1);
        assert!(c
            .set(FreqVector {
                cpu_mhz: 50.0,
                gpu_mhz: 100.0,
                mem_mhz: 100.0
            })
            .is_err());
    }

    #[test]
    fn meter_integrates_linearly_in_time() {
        let d = nx();
        let f = FrequencyController::new(d.clone()).current();
        let mut a = EnergyMeter::new();
        let mut b = EnergyMeter::new();
        a.accumulate(&d, &f, &[0.5, 0.5, 0.5], 2.0);
        b.accumulate(&d, &f, &[0.5, 0.5, 0.5], 1.0);
        b.accumulate(&d, &f, &[0.5, 0.5, 0.5], 1.0);
        assert!((a.total_j() - b.total_j()).abs() < 1e-9);
    }
}
