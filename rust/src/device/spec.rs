//! Device specifications — Table 3 of the paper, plus derived power-model
//! constants.

use super::Ladder;

/// DVFS-controlled compute unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unit {
    Cpu,
    Gpu,
    Mem,
}

pub const UNITS: [Unit; 3] = [Unit::Cpu, Unit::Gpu, Unit::Mem];

/// One device of Table 3: frequency ladders, power envelope, and peak
/// compute/bandwidth numbers used by the roofline latency model.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub cpu: Ladder,
    pub gpu: Ladder,
    pub mem: Ladder,
    /// Board power ceiling (W) — "MaxPower" in the cost metric Eq. (4).
    pub max_power_w: f64,
    /// Static/leakage + board baseline power (W).
    pub static_w: f64,
    /// Max dynamic power per unit at f_max, V_max, util=1 (W).
    pub cpu_dyn_w: f64,
    pub gpu_dyn_w: f64,
    pub mem_dyn_w: f64,
    /// Peak GPU throughput at f_max (GFLOP/s, fp32 — what un-tensorized
    /// mobile inference stacks actually sustain against).
    pub gpu_peak_gflops: f64,
    /// Peak CPU throughput at f_max (GFLOP/s).
    pub cpu_peak_gflops: f64,
    /// Peak DRAM bandwidth at mem f_max (GB/s).
    pub mem_peak_gbps: f64,
    /// Radio/NIC transmit power (W) for offload energy Eq. (12); 0 for
    /// cloud machines.
    pub radio_w: f64,
    /// Kernel-dispatch discount: 1.0 for eager-mode edge stacks; server
    /// runtimes (TensorRT/CUDA-graph style) amortize launches, so the
    /// cloud box dispatches far cheaper per kernel.
    pub dispatch_discount: f64,
}

impl DeviceSpec {
    pub fn ladder(&self, u: Unit) -> &Ladder {
        match u {
            Unit::Cpu => &self.cpu,
            Unit::Gpu => &self.gpu,
            Unit::Mem => &self.mem,
        }
    }

    pub fn dyn_max_w(&self, u: Unit) -> f64 {
        match u {
            Unit::Cpu => self.cpu_dyn_w,
            Unit::Gpu => self.gpu_dyn_w,
            Unit::Mem => self.mem_dyn_w,
        }
    }

    /// Re-quantize the ladders to `levels` points per unit (the paper's
    /// §5.1 uses 100; the default action space uses 10 like Table 3's
    /// grid — see DESIGN.md §7).
    pub fn with_levels(mut self, levels: usize) -> Self {
        for l in [&mut self.cpu, &mut self.gpu, &mut self.mem] {
            l.levels = levels;
        }
        self
    }
}

/// Table 3 devices. Frequency maxima are the paper's numbers; minima are
/// the lowest operating points of the boards' nvpmodel profiles; peak
/// GFLOPs/bandwidth from vendor datasheets (used only as roofline scale
/// factors, so relative magnitudes are what matters).
pub fn device_zoo() -> Vec<DeviceSpec> {
    vec![
        DeviceSpec {
            name: "jetson-nano",
            cpu: Ladder::new(102.0, 1479.0, 10),
            gpu: Ladder::new(76.8, 921.6, 10),
            mem: Ladder::new(204.0, 1600.0, 10),
            max_power_w: 10.0,
            static_w: 1.25,
            cpu_dyn_w: 2.4,
            gpu_dyn_w: 4.4,
            mem_dyn_w: 1.5,
            gpu_peak_gflops: 236.0,
            cpu_peak_gflops: 12.0,
            mem_peak_gbps: 25.6,
            radio_w: 1.1,
            dispatch_discount: 1.0,
        },
        DeviceSpec {
            name: "jetson-tx2",
            cpu: Ladder::new(345.6, 2000.0, 10),
            gpu: Ladder::new(114.75, 1300.0, 10),
            mem: Ladder::new(408.0, 1866.0, 10),
            max_power_w: 15.0,
            static_w: 2.2,
            cpu_dyn_w: 3.4,
            gpu_dyn_w: 6.3,
            mem_dyn_w: 2.0,
            gpu_peak_gflops: 665.0,
            cpu_peak_gflops: 20.0,
            mem_peak_gbps: 59.7,
            radio_w: 1.3,
            dispatch_discount: 1.0,
        },
        DeviceSpec {
            name: "xavier-nx",
            cpu: Ladder::new(115.2, 1900.0, 10),
            gpu: Ladder::new(114.75, 1100.0, 10),
            mem: Ladder::new(204.0, 1866.0, 10),
            max_power_w: 20.0,
            static_w: 2.8,
            cpu_dyn_w: 4.5,
            gpu_dyn_w: 9.2,
            mem_dyn_w: 2.7,
            gpu_peak_gflops: 1690.0,
            cpu_peak_gflops: 45.0,
            mem_peak_gbps: 59.7,
            radio_w: 1.3,
            dispatch_discount: 1.0,
        },
        DeviceSpec {
            // cloud comparator — Table 3 bottom row
            name: "rtx3080",
            cpu: Ladder::new(1200.0, 2900.0, 10),
            gpu: Ladder::new(210.0, 1440.0, 10),
            mem: Ladder::new(810.0, 2933.0, 10),
            max_power_w: 320.0,
            static_w: 55.0,
            cpu_dyn_w: 65.0,
            gpu_dyn_w: 180.0,
            mem_dyn_w: 20.0,
            gpu_peak_gflops: 29_750.0,
            cpu_peak_gflops: 600.0,
            mem_peak_gbps: 760.0,
            radio_w: 0.0,
            dispatch_discount: 0.15,
        },
    ]
}

/// Look a device up by name.
pub fn find_device(name: &str) -> anyhow::Result<DeviceSpec> {
    device_zoo()
        .into_iter()
        .find(|d| d.name == name)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown device `{name}` (known: {:?})",
                device_zoo().iter().map(|d| d.name).collect::<Vec<_>>()
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_matches_table3_maxima() {
        let nx = find_device("xavier-nx").unwrap();
        assert_eq!(nx.cpu.max_mhz, 1900.0);
        assert_eq!(nx.gpu.max_mhz, 1100.0);
        assert_eq!(nx.mem.max_mhz, 1866.0);
        assert_eq!(nx.max_power_w, 20.0);
        let nano = find_device("jetson-nano").unwrap();
        assert_eq!(nano.cpu.max_mhz, 1479.0);
        assert_eq!(nano.max_power_w, 10.0);
        let tx2 = find_device("jetson-tx2").unwrap();
        assert_eq!(tx2.gpu.max_mhz, 1300.0);
        let cloud = find_device("rtx3080").unwrap();
        assert_eq!(cloud.max_power_w, 320.0);
    }

    #[test]
    fn unknown_device_is_error() {
        assert!(find_device("tpu-v5").is_err());
    }

    #[test]
    fn with_levels_requantizes() {
        let d = find_device("xavier-nx").unwrap().with_levels(100);
        assert_eq!(d.cpu.levels, 100);
        assert_eq!(d.gpu.levels, 100);
        // endpoints preserved (up to float rounding)
        assert!((d.cpu.freq_at(99) - 1900.0).abs() < 1e-9);
    }

    #[test]
    fn cloud_outclasses_edge() {
        let nx = find_device("xavier-nx").unwrap();
        let cloud = find_device("rtx3080").unwrap();
        assert!(cloud.gpu_peak_gflops > 5.0 * nx.gpu_peak_gflops);
        assert!(cloud.mem_peak_gbps > 5.0 * nx.mem_peak_gbps);
    }
}
