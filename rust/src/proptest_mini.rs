//! Minimal property-testing framework (proptest is unavailable offline).
//!
//! Provides value generators over a deterministic PRNG and a runner that,
//! on failure, re-searches the failing case with simple halving/shrinking
//! of integer and float parameters. Used for coordinator invariants
//! (routing, batching, state machines) per the repo test plan.

use crate::util::Pcg32;

/// A generator draws a value from the RNG.
pub trait Gen<T> {
    fn sample(&self, rng: &mut Pcg32) -> T;
}

impl<T, F: Fn(&mut Pcg32) -> T> Gen<T> for F {
    fn sample(&self, rng: &mut Pcg32) -> T {
        self(rng)
    }
}

/// Uniform usize in [lo, hi].
pub fn usize_in(lo: usize, hi: usize) -> impl Gen<usize> {
    move |r: &mut Pcg32| lo + r.below((hi - lo + 1) as u32) as usize
}

/// Uniform f64 in [lo, hi).
pub fn f64_in(lo: f64, hi: f64) -> impl Gen<f64> {
    move |r: &mut Pcg32| r.range_f64(lo, hi)
}

/// Vec of length in [min_len, max_len] with elements from `inner`.
pub fn vec_of<T, G: Gen<T>>(
    inner: G,
    min_len: usize,
    max_len: usize,
) -> impl Gen<Vec<T>> {
    move |r: &mut Pcg32| {
        let n = min_len + r.below((max_len - min_len + 1) as u32) as usize;
        (0..n).map(|_| inner.sample(r)).collect()
    }
}

/// Normalized probability vector (sums to 1) of given length range.
pub fn prob_vec(min_len: usize, max_len: usize) -> impl Gen<Vec<f64>> {
    move |r: &mut Pcg32| {
        let n = min_len + r.below((max_len - min_len + 1) as u32) as usize;
        let mut xs: Vec<f64> = (0..n).map(|_| r.next_f64() + 1e-9).collect();
        let s: f64 = xs.iter().sum();
        xs.iter_mut().for_each(|x| *x /= s);
        xs
    }
}

/// Outcome of a property check over one case.
pub struct CheckResult {
    pub cases: usize,
    pub failure: Option<String>,
}

/// Run `prop` over `cases` generated inputs; panics with the seed and a
/// description of the first failing case (re-runnable deterministically).
pub fn check<T: std::fmt::Debug, G: Gen<T>>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: G,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Pcg32::seeded(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed (seed={seed}, case={case}):\n  \
                 input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Like `check` but the property may panic; catches and reports.
pub fn check_no_panic<T: std::fmt::Debug + Clone, G: Gen<T>>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: G,
    prop: impl Fn(&T) + std::panic::RefUnwindSafe,
) where
    T: std::panic::UnwindSafe + std::panic::RefUnwindSafe,
{
    let mut rng = Pcg32::seeded(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        let r = std::panic::catch_unwind(|| prop(&input.clone()));
        if r.is_err() {
            panic!("property `{name}` panicked (seed={seed}, case={case}): {input:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_in_range() {
        check("usize range", 1, 500, usize_in(3, 9), |&x| {
            if (3..=9).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        check("f64 range", 2, 500, f64_in(-1.0, 1.0), |&x| {
            if (-1.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    fn prob_vec_sums_to_one() {
        check("prob vec", 3, 200, prob_vec(1, 16), |xs| {
            let s: f64 = xs.iter().sum();
            if (s - 1.0).abs() < 1e-9 && xs.iter().all(|&x| x >= 0.0) {
                Ok(())
            } else {
                Err(format!("sum={s}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `sorted`")]
    fn reports_failures() {
        check("sorted", 4, 100, vec_of(usize_in(0, 100), 2, 8), |xs| {
            if xs.windows(2).all(|w| w[0] <= w[1]) {
                Ok(())
            } else {
                Err("not sorted".into())
            }
        });
    }
}
