//! `artifacts/manifest.json` schema — written by python/compile/aot.py,
//! parsed here with the in-repo JSON parser.

use crate::configx::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct DqnMeta {
    pub state_dim: usize,
    pub hidden: Vec<usize>,
    pub action_dim: usize,
    pub freq_levels: usize,
    pub xi_levels: usize,
    pub weight_shapes: Vec<Vec<usize>>,
}

#[derive(Clone, Debug)]
pub struct ProbeMeta {
    pub mask_topk: usize,
    pub lambda: f64,
    pub expected_logits: Vec<f64>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub img_shape: Vec<usize>,
    pub feat_channels: usize,
    pub feat_hw: usize,
    pub num_classes: usize,
    pub dqn: DqnMeta,
    pub testset_file: String,
    pub testset_count: usize,
    pub accuracy: BTreeMap<String, f64>,
    pub mean_importance: Vec<f64>,
    pub probe: ProbeMeta,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let model = j.req("model")?;
        let dqn = j.req("dqn")?;
        let testset = j.req("testset")?;
        let probe = j.req("probe")?;

        let usize_list = |v: &Json| -> Result<Vec<usize>> {
            Ok(v.f64_list()
                .context("expected number list")?
                .into_iter()
                .map(|x| x as usize)
                .collect())
        };

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().context("artifacts")? {
            let inputs = a
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|i| {
                    Ok(InputSpec {
                        shape: usize_list(i.req("shape")?)?,
                        dtype: i
                            .req("dtype")?
                            .as_str()
                            .context("dtype")?
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .filter_map(|o| o.as_str().map(String::from))
                .collect();
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    file: a.req("file")?.as_str().context("file")?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }

        let mut accuracy = BTreeMap::new();
        for (k, v) in j.req("accuracy")?.as_obj().context("accuracy")? {
            accuracy.insert(k.clone(), v.as_f64().context("accuracy value")?);
        }

        Ok(Manifest {
            img_shape: usize_list(model.req("img_shape")?)?,
            feat_channels: model.req("feat_channels")?.as_usize().context("feat_channels")?,
            feat_hw: model.req("feat_hw")?.as_usize().context("feat_hw")?,
            num_classes: model.req("num_classes")?.as_usize().context("num_classes")?,
            dqn: DqnMeta {
                state_dim: dqn.req("state_dim")?.as_usize().context("state_dim")?,
                hidden: usize_list(dqn.req("hidden")?)?,
                action_dim: dqn.req("action_dim")?.as_usize().context("action_dim")?,
                freq_levels: dqn.req("freq_levels")?.as_usize().context("freq_levels")?,
                xi_levels: dqn.req("xi_levels")?.as_usize().context("xi_levels")?,
                weight_shapes: dqn
                    .req("weight_shapes")?
                    .as_arr()
                    .context("weight_shapes")?
                    .iter()
                    .map(|s| usize_list(s))
                    .collect::<Result<Vec<_>>>()?,
            },
            testset_file: testset.req("file")?.as_str().context("file")?.to_string(),
            testset_count: testset.req("count")?.as_usize().context("count")?,
            accuracy,
            mean_importance: j
                .req("mean_importance")?
                .f64_list()
                .context("mean_importance")?,
            probe: ProbeMeta {
                mask_topk: probe.req("mask_topk")?.as_usize().context("mask_topk")?,
                lambda: probe.req("lambda")?.as_f64().context("lambda")?,
                expected_logits: probe
                    .req("expected_logits")?
                    .f64_list()
                    .context("expected_logits")?,
            },
            artifacts,
        })
    }

    /// Load the raw testset: (images flat f32, labels).
    pub fn load_testset(&self, dir: &Path) -> Result<(Vec<f32>, Vec<u32>)> {
        let bytes = std::fs::read(dir.join(&self.testset_file))?;
        let img_elems: usize =
            self.testset_count * self.img_shape.iter().product::<usize>();
        anyhow::ensure!(
            bytes.len() == img_elems * 4 + self.testset_count * 4,
            "testset size mismatch"
        );
        let mut imgs = Vec::with_capacity(img_elems);
        for c in bytes[..img_elems * 4].chunks_exact(4) {
            imgs.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        let mut labels = Vec::with_capacity(self.testset_count);
        for c in bytes[img_elems * 4..].chunks_exact(4) {
            labels.push(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok((imgs, labels))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"img_shape": [3, 32, 32], "feat_channels": 16,
                "feat_hw": 16, "num_classes": 8},
      "dqn": {"state_dim": 8, "hidden": [128, 64, 32], "action_dim": 41,
              "freq_levels": 10, "xi_levels": 11,
              "weight_shapes": [[8, 128], [128]]},
      "testset": {"file": "testset.bin", "count": 4, "img_f32_count": 12288},
      "accuracy": {"edge_only": 0.95},
      "mean_importance": [0.5, 0.5],
      "probe": {"mask_topk": 8, "lambda": 0.5, "expected_logits": [1.0, -1.0]},
      "artifacts": {
        "fusion": {"file": "fusion.hlo.txt",
                   "inputs": [{"shape": [1, 8], "dtype": "float32"}],
                   "outputs": ["fused_logits"]}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j).unwrap();
        assert_eq!(m.feat_channels, 16);
        assert_eq!(m.dqn.action_dim, 41);
        assert_eq!(m.artifacts["fusion"].inputs[0].shape, vec![1, 8]);
        assert_eq!(m.probe.expected_logits, vec![1.0, -1.0]);
        assert_eq!(m.accuracy["edge_only"], 0.95);
    }

    #[test]
    fn missing_field_is_error() {
        let j = Json::parse(r#"{"model": {}}"#).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir.join("manifest.json")).unwrap();
            assert!(m.artifacts.contains_key("extractor"));
            assert!(m.artifacts.contains_key("dqn_q"));
            let (imgs, labels) = m.load_testset(&dir).unwrap();
            assert_eq!(labels.len(), m.testset_count);
            assert_eq!(
                imgs.len(),
                m.testset_count * m.img_shape.iter().product::<usize>()
            );
        }
    }
}
