//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! rust request path (Python is build-time only).
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Every artifact is lowered with
//! `return_tuple=True`, so outputs are always unwrapped as a tuple.

pub mod manifest;

pub use manifest::{ArtifactMeta, Manifest};

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A compiled artifact plus its manifest metadata.
pub struct Artifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The engine owns the PJRT client and all compiled executables.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    artifacts: BTreeMap<String, Artifact>,
    pub manifest: Manifest,
    pub dir: PathBuf,
}

impl Engine {
    /// Load the manifest and compile every artifact on the CPU PJRT
    /// client. Compilation happens once at startup; execution is the
    /// only per-request work.
    pub fn load(dir: &Path) -> Result<Engine> {
        Self::load_filtered(dir, None)
    }

    /// Load only the named artifacts (each worker process/thread owns its
    /// own PJRT client — the xla handles are not Send, and the edge and
    /// cloud workers are separate machines in the real deployment anyway).
    pub fn load_filtered(dir: &Path, only: Option<&[&str]>) -> Result<Engine> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut artifacts = BTreeMap::new();
        for (name, meta) in &manifest.artifacts {
            if let Some(filter) = only {
                if !filter.contains(&name.as_str()) {
                    continue;
                }
            }
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact `{name}`"))?;
            artifacts.insert(
                name.clone(),
                Artifact {
                    meta: meta.clone(),
                    exe,
                },
            );
        }
        Ok(Engine {
            client,
            artifacts,
            manifest,
            dir: dir.to_path_buf(),
        })
    }

    pub fn has(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(String::as_str).collect()
    }

    /// Execute an artifact with literal inputs; returns the flattened
    /// tuple outputs.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let art = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact `{name}`"))?;
        anyhow::ensure!(
            inputs.len() == art.meta.inputs.len(),
            "artifact `{name}` wants {} inputs, got {}",
            art.meta.inputs.len(),
            inputs.len()
        );
        let result = art
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing `{name}`"))?[0][0]
            .to_literal_sync()?;
        result
            .to_tuple()
            .with_context(|| format!("unwrapping `{name}` output tuple"))
    }

    /// Convenience: f32 slices in, f32 vectors out (shapes from the
    /// manifest for inputs; outputs flattened).
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let art = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact `{name}`"))?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (slice, spec) in inputs.iter().zip(art.meta.inputs.iter()) {
            lits.push(literal_f32(slice, &spec.shape)?);
        }
        let outs = self.execute(name, &lits)?;
        outs.into_iter().map(read_f32).collect()
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(
        n == data.len(),
        "shape {shape:?} wants {n} elements, got {}",
        data.len()
    );
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Read any f32 literal back into a flat Vec.
pub fn read_f32(lit: xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    // Engine tests that need built artifacts live in
    // rust/tests/runtime_parity.rs (integration) — they skip gracefully
    // when `make artifacts` has not run. Unit-testable pieces:
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        let back = read_f32(lit).unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }
}
