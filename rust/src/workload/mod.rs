//! Workload generation: inference tasks with Poisson/burst arrivals,
//! per-task SCAM importance draws, and dataset mixing.

use crate::perfmodel::{find_model, Dataset, ModelProfile};
use crate::scam::ImportanceDist;
use crate::util::Pcg32;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: u64,
    pub arrival_s: f64,
    pub dataset: Dataset,
    /// per-task importance distribution (the SCAM output for this input)
    pub importance: ImportanceDist,
    /// index into the synthetic test set (real-artifact path only)
    pub sample_idx: usize,
    /// end-to-end deadline relative to arrival (∞ = best-effort)
    pub deadline_s: f64,
    /// SLO priority class (higher = more important; 0 = best-effort)
    pub priority: u8,
}

/// Per-stream service-level objective: a relative deadline plus a
/// priority class. The fleet dispatcher counts deadline misses as SLO
/// violations, jumps high-priority tasks ahead in per-device queues, and
/// (under admission control) sheds or downgrades tasks whose estimated
/// completion would blow the deadline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloClass {
    /// relative deadline in seconds (∞ = no deadline)
    pub deadline_s: f64,
    /// priority (higher wins the queue; admission never sheds prio > 0)
    pub priority: u8,
}

impl Default for SloClass {
    fn default() -> Self {
        Self {
            deadline_s: f64::INFINITY,
            priority: 0,
        }
    }
}

impl SloClass {
    /// Parse an SLO spec: `none` | `<deadline_ms>` | `<deadline_ms>,<priority>`.
    pub fn parse(spec: &str) -> Result<SloClass> {
        let s = spec.trim();
        if s.is_empty() || s == "none" {
            return Ok(SloClass::default());
        }
        let (dl, prio) = match s.split_once(',') {
            Some((d, p)) => (
                d.trim(),
                p.trim()
                    .parse::<u8>()
                    .with_context(|| format!("slo priority `{p}`"))?,
            ),
            None => (s, 0),
        };
        let ms: f64 = dl
            .parse()
            .with_context(|| format!("slo deadline `{dl}` (want ms)"))?;
        if !(ms > 0.0 && ms.is_finite()) {
            bail!("slo deadline must be a positive finite ms value, got `{dl}`");
        }
        Ok(SloClass {
            deadline_s: ms / 1e3,
            priority: prio,
        })
    }

    /// True when the class imposes nothing (no deadline, base priority).
    pub fn is_none(&self) -> bool {
        self.deadline_s.is_infinite() && self.priority == 0
    }
}

/// Arrival process shapes.
#[derive(Clone, Debug)]
pub enum Arrivals {
    /// Poisson with given rate (req/s)
    Poisson { rate: f64 },
    /// back-to-back (closed loop — the paper's per-task evaluation)
    Sequential,
    /// Poisson baseline with periodic bursts
    Bursty { rate: f64, burst_every_s: f64, burst_len: usize },
    /// 2-state Markov-modulated Poisson process: exponential dwell in a
    /// low-rate and a high-rate regime (bursty multi-user traffic).
    Mmpp {
        rate_lo: f64,
        rate_hi: f64,
        dwell_lo_s: f64,
        dwell_hi_s: f64,
    },
    /// Diurnal-trace process: a Poisson process whose rate follows a
    /// sinusoidal day/night profile,
    /// `rate(t) = base · (1 + amplitude · sin(2πt / period))`,
    /// simulated by Lewis thinning (deterministic per seed).
    Diurnal {
        base_rate: f64,
        amplitude: f64,
        period_s: f64,
    },
    /// Recorded-trace replay (`trace:<path>`): inter-arrival gaps
    /// derived from a file of non-decreasing, finite, non-negative
    /// timestamps (seconds). The gap sequence loops when a stream
    /// outlives the recording, so replay is fully deterministic and
    /// RNG-free. Shared behind an `Arc` so per-stream generators clone
    /// the handle, not the trace.
    Trace { gaps: Arc<Vec<f64>> },
}

impl Arrivals {
    /// Parse a spec string:
    /// `sequential` | `poisson:<rate>` | `bursty:<rate>,<every_s>,<len>` |
    /// `mmpp:<rate_lo>,<rate_hi>,<dwell_lo_s>,<dwell_hi_s>` |
    /// `diurnal:<base_rate>,<amplitude>,<period_s>` | `trace:<path>`.
    pub fn parse(spec: &str) -> Result<Arrivals> {
        if spec == "sequential" {
            return Ok(Arrivals::Sequential);
        }
        let (kind, rest) = spec
            .split_once(':')
            .context("arrivals spec wants `kind:args` (or `sequential`)")?;
        if kind == "trace" {
            let path = rest.trim();
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("arrivals trace `{path}`"))?;
            return Self::from_trace_text(&text)
                .with_context(|| format!("arrivals trace `{path}`"));
        }
        let nums: Vec<f64> = rest
            .split(',')
            .map(|x| x.trim().parse::<f64>())
            .collect::<Result<_, _>>()
            .with_context(|| format!("arrivals `{kind}` wants comma-separated numbers"))?;
        match (kind, nums.as_slice()) {
            ("poisson", [rate]) => {
                // `!(x > 0)` rather than `x <= 0` so NaN is rejected too
                if !(*rate > 0.0 && rate.is_finite()) {
                    bail!("poisson rate must be positive and finite");
                }
                Ok(Arrivals::Poisson { rate: *rate })
            }
            ("bursty", [rate, every_s, len]) => {
                if !(*rate > 0.0 && *every_s > 0.0 && *len >= 1.0) {
                    bail!("bursty wants rate>0, every_s>0, len>=1");
                }
                Ok(Arrivals::Bursty {
                    rate: *rate,
                    burst_every_s: *every_s,
                    burst_len: *len as usize,
                })
            }
            ("mmpp", [lo, hi, dw_lo, dw_hi]) => {
                if !(*lo > 0.0 && *hi >= *lo && *dw_lo > 0.0 && *dw_hi > 0.0) {
                    bail!("mmpp wants 0 < rate_lo <= rate_hi and positive dwells");
                }
                Ok(Arrivals::Mmpp {
                    rate_lo: *lo,
                    rate_hi: *hi,
                    dwell_lo_s: *dw_lo,
                    dwell_hi_s: *dw_hi,
                })
            }
            ("diurnal", [base, amp, period]) => {
                if !(*base > 0.0 && (0.0..=1.0).contains(amp) && *period > 0.0) {
                    bail!("diurnal wants base>0, amplitude in [0,1], period>0");
                }
                Ok(Arrivals::Diurnal {
                    base_rate: *base,
                    amplitude: *amp,
                    period_s: *period,
                })
            }
            (other, _) => bail!(
                "unknown or malformed arrivals `{other}:{rest}` (want sequential | \
                 poisson:<r> | bursty:<r>,<every>,<len> | mmpp:<lo>,<hi>,<dlo>,<dhi> | \
                 diurnal:<base>,<amp>,<period> | trace:<path>)"
            ),
        }
    }

    /// Build a [`Arrivals::Trace`] from recorded timestamp text: either
    /// a JSON array of numbers (`[0.0, 0.5, 1.2]`) or CSV/whitespace
    /// separated floats, one timestamp (seconds) per entry. Timestamps
    /// must be finite, non-negative, and non-decreasing; an empty trace
    /// is rejected.
    pub fn from_trace_text(text: &str) -> Result<Arrivals> {
        let trimmed = text.trim();
        let times: Vec<f64> = if trimmed.starts_with('[') {
            let doc = crate::configx::Json::parse(trimmed)
                .map_err(|e| anyhow::anyhow!("bad JSON: {e}"))?;
            doc.as_arr()
                .context("JSON trace must be an array of timestamps")?
                .iter()
                .map(|v| v.as_f64().context("JSON trace entries must be numbers"))
                .collect::<Result<_>>()?
        } else {
            trimmed
                .split(|c: char| c.is_whitespace() || c == ',')
                .filter(|t| !t.is_empty())
                .map(|t| {
                    t.parse::<f64>()
                        .with_context(|| format!("trace timestamp `{t}`"))
                })
                .collect::<Result<_>>()?
        };
        Self::from_timestamps(&times)
    }

    /// Build a [`Arrivals::Trace`] from already-parsed arrival
    /// timestamps (seconds), validating them and converting to
    /// inter-arrival gaps.
    pub fn from_timestamps(times: &[f64]) -> Result<Arrivals> {
        if times.is_empty() {
            bail!("trace must contain at least one arrival timestamp");
        }
        let mut gaps = Vec::with_capacity(times.len());
        let mut prev = 0.0f64;
        for (i, &t) in times.iter().enumerate() {
            if !(t.is_finite() && t >= 0.0) {
                bail!("trace timestamp #{i} must be finite and non-negative, got {t}");
            }
            if t < prev {
                bail!(
                    "trace timestamps must be non-decreasing, got {t} after {prev} at #{i}"
                );
            }
            gaps.push(t - prev);
            prev = t;
        }
        Ok(Arrivals::Trace {
            gaps: Arc::new(gaps),
        })
    }

    /// Long-run mean arrival rate (req/s); `None` for the closed-loop
    /// `Sequential` process. For `Bursty` this is the baseline rate (the
    /// bursts add extra mass on top).
    pub fn mean_rate(&self) -> Option<f64> {
        match *self {
            Arrivals::Sequential => None,
            Arrivals::Poisson { rate } => Some(rate),
            Arrivals::Bursty { rate, .. } => Some(rate),
            Arrivals::Mmpp {
                rate_lo,
                rate_hi,
                dwell_lo_s,
                dwell_hi_s,
            } => Some((rate_lo * dwell_lo_s + rate_hi * dwell_hi_s) / (dwell_lo_s + dwell_hi_s)),
            Arrivals::Diurnal { base_rate, .. } => Some(base_rate),
            Arrivals::Trace { ref gaps } => {
                let span: f64 = gaps.iter().sum();
                (span > 0.0).then(|| gaps.len() as f64 / span)
            }
        }
    }
}

/// Generates the task stream for one model/dataset configuration.
pub struct TaskGen {
    profile: ModelProfile,
    dataset: Dataset,
    arrivals: Arrivals,
    channels: usize,
    rng: Pcg32,
    next_id: u64,
    clock_s: f64,
    burst_left: usize,
    /// MMPP regime state: currently in the high-rate regime?
    mmpp_high: bool,
    /// remaining dwell in the current MMPP regime (<0 = uninitialized)
    mmpp_left_s: f64,
    /// replay cursor into a `Trace` gap sequence (wraps at the end)
    trace_idx: usize,
    testset_count: usize,
    /// SLO class stamped on every generated task
    slo: SloClass,
}

impl TaskGen {
    pub fn new(
        model: &str,
        dataset: Dataset,
        arrivals: Arrivals,
        seed: u64,
    ) -> Result<Self> {
        Ok(Self {
            profile: find_model(model)?,
            dataset,
            arrivals,
            channels: 16,
            rng: Pcg32::seeded(seed ^ 0x7A5C),
            next_id: 0,
            clock_s: 0.0,
            burst_left: 0,
            mmpp_high: false,
            mmpp_left_s: -1.0,
            trace_idx: 0,
            testset_count: 256,
            slo: SloClass::default(),
        })
    }

    /// Attach an SLO class: every task this generator produces carries
    /// the class's deadline and priority.
    pub fn with_slo(mut self, slo: SloClass) -> Self {
        self.slo = slo;
        self
    }

    pub fn slo(&self) -> SloClass {
        self.slo
    }

    pub fn profile(&self) -> &ModelProfile {
        &self.profile
    }

    pub fn dataset(&self) -> Dataset {
        self.dataset
    }

    /// Draw the next task (advances the arrival clock).
    pub fn next_task(&mut self) -> Task {
        let dt = match self.arrivals {
            Arrivals::Sequential => 0.0,
            Arrivals::Poisson { rate } => self.rng.exponential(rate),
            Arrivals::Bursty {
                rate,
                burst_every_s,
                burst_len,
            } => {
                if self.burst_left > 0 {
                    self.burst_left -= 1;
                    0.0005
                } else if self.clock_s > 0.0
                    && (self.clock_s / burst_every_s).fract() < 0.02
                {
                    self.burst_left = burst_len;
                    0.0005
                } else {
                    self.rng.exponential(rate)
                }
            }
            Arrivals::Mmpp {
                rate_lo,
                rate_hi,
                dwell_lo_s,
                dwell_hi_s,
            } => {
                if self.mmpp_left_s < 0.0 {
                    // enter the low regime with an exponential dwell
                    self.mmpp_left_s = self.rng.exponential(1.0 / dwell_lo_s);
                }
                let mut dt = 0.0;
                loop {
                    let rate = if self.mmpp_high { rate_hi } else { rate_lo };
                    let x = self.rng.exponential(rate);
                    if x <= self.mmpp_left_s {
                        self.mmpp_left_s -= x;
                        break dt + x;
                    }
                    // regime switch before the candidate arrival lands
                    dt += self.mmpp_left_s;
                    self.mmpp_high = !self.mmpp_high;
                    let dwell = if self.mmpp_high { dwell_hi_s } else { dwell_lo_s };
                    self.mmpp_left_s = self.rng.exponential(1.0 / dwell);
                }
            }
            Arrivals::Diurnal {
                base_rate,
                amplitude,
                period_s,
            } => {
                // Lewis thinning against the peak rate
                let peak = base_rate * (1.0 + amplitude);
                let mut dt = 0.0;
                loop {
                    dt += self.rng.exponential(peak);
                    let t = self.clock_s + dt;
                    let inst = base_rate
                        * (1.0
                            + amplitude
                                * (2.0 * std::f64::consts::PI * t / period_s).sin());
                    if self.rng.next_f64() * peak <= inst {
                        break dt;
                    }
                }
            }
            Arrivals::Trace { ref gaps } => {
                let dt = gaps[self.trace_idx % gaps.len()];
                self.trace_idx += 1;
                dt
            }
        };
        self.clock_s += dt;
        let id = self.next_id;
        self.next_id += 1;
        // per-task importance: model-level skew + small per-input jitter
        let skew =
            self.profile.importance_skew * (0.85 + 0.3 * self.rng.next_f64());
        Task {
            id,
            arrival_s: self.clock_s,
            dataset: self.dataset,
            importance: ImportanceDist::synthetic(self.channels, skew, &mut self.rng),
            sample_idx: (self.rng.below(self.testset_count as u32)) as usize,
            deadline_s: self.slo.deadline_s,
            priority: self.slo.priority,
        }
    }

    pub fn take(&mut self, n: usize) -> Vec<Task> {
        (0..n).map(|_| self.next_task()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_tasks_have_zero_gaps() {
        let mut g =
            TaskGen::new("resnet-18", Dataset::Cifar100, Arrivals::Sequential, 1)
                .unwrap();
        let ts = g.take(5);
        assert!(ts.iter().all(|t| t.arrival_s == 0.0));
        assert_eq!(ts.last().unwrap().id, 4);
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut g = TaskGen::new(
            "resnet-18",
            Dataset::Cifar100,
            Arrivals::Poisson { rate: 50.0 },
            2,
        )
        .unwrap();
        let ts = g.take(2000);
        let span = ts.last().unwrap().arrival_s;
        let rate = 2000.0 / span;
        assert!((40.0..60.0).contains(&rate), "rate {rate}");
    }

    #[test]
    fn tasks_are_deterministic_per_seed() {
        let mk = || {
            TaskGen::new("vit-b16", Dataset::Imagenet, Arrivals::Sequential, 9)
                .unwrap()
                .take(3)
        };
        let a = mk();
        let b = mk();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.sample_idx, y.sample_idx);
            assert_eq!(x.importance.probs(), y.importance.probs());
        }
    }

    #[test]
    fn importance_skew_tracks_model() {
        let take_skew = |name: &str| {
            let mut g =
                TaskGen::new(name, Dataset::Cifar100, Arrivals::Sequential, 3)
                    .unwrap();
            let ts = g.take(64);
            ts.iter().map(|t| t.importance.skewness()).sum::<f64>() / 64.0
        };
        // vit-b16 has the most concentrated importance in the zoo
        assert!(take_skew("vit-b16") > take_skew("deepspeech"));
    }

    #[test]
    fn unknown_model_errors() {
        assert!(
            TaskGen::new("nope", Dataset::Cifar100, Arrivals::Sequential, 0).is_err()
        );
    }

    #[test]
    fn parse_accepts_every_process_kind() {
        assert!(matches!(
            Arrivals::parse("sequential").unwrap(),
            Arrivals::Sequential
        ));
        assert!(matches!(
            Arrivals::parse("poisson:50").unwrap(),
            Arrivals::Poisson { rate } if rate == 50.0
        ));
        assert!(matches!(
            Arrivals::parse("bursty:20,2,10").unwrap(),
            Arrivals::Bursty { burst_len: 10, .. }
        ));
        assert!(matches!(
            Arrivals::parse("mmpp:5,50,2,0.5").unwrap(),
            Arrivals::Mmpp { .. }
        ));
        assert!(matches!(
            Arrivals::parse("diurnal:30,0.5,60").unwrap(),
            Arrivals::Diurnal { .. }
        ));
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "mmpp:1",
            "mmpp:0,5,1,1",
            "mmpp:5,1,1,1",
            "diurnal:10,1.5,60",
            "diurnal:-1,0.5,60",
            "poisson:-3",
            "poisson:x",
            "bursty:1,2",
            "warp:1",
            "poisson",
        ] {
            assert!(Arrivals::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn parse_rejects_garbage_and_nonpositive_rates() {
        for bad in [
            "",
            ":",
            "poisson:",
            "poisson:0",
            "poisson:-1",
            "poisson:abc",
            "poisson:NaN",
            "poisson:inf",
            "poisson:1,2",
            "bursty:-5,1,3",
            "bursty:5,-1,3",
            "bursty:5,1,0",
            "bursty:NaN,1,3",
            "mmpp:1,2,3",
            "mmpp:1,2,3,4,5",
            "mmpp:1,2,-3,4",
            "mmpp:1,2,3,-4",
            "mmpp:NaN,2,3,4",
            "diurnal:10,0.5,-2",
            "diurnal:10,NaN,2",
            "sequential:1",
            "🚀:1",
        ] {
            assert!(Arrivals::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn slo_class_parses_and_rejects() {
        assert!(SloClass::parse("none").unwrap().is_none());
        assert!(SloClass::parse("").unwrap().is_none());
        let c = SloClass::parse("250").unwrap();
        assert!((c.deadline_s - 0.25).abs() < 1e-12 && c.priority == 0);
        let c = SloClass::parse("100,3").unwrap();
        assert!((c.deadline_s - 0.1).abs() < 1e-12 && c.priority == 3);
        assert!(!c.is_none());
        for bad in ["-5", "0", "NaN", "inf", "abc", "100,-1", "100,x", "100,300"] {
            assert!(SloClass::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn taskgen_stamps_slo_on_every_task() {
        let slo = SloClass::parse("200,2").unwrap();
        let mut g = TaskGen::new("resnet-18", Dataset::Cifar100, Arrivals::Sequential, 4)
            .unwrap()
            .with_slo(slo);
        for t in g.take(5) {
            assert_eq!(t.deadline_s, 0.2);
            assert_eq!(t.priority, 2);
        }
        // default: best-effort
        let mut g = TaskGen::new("resnet-18", Dataset::Cifar100, Arrivals::Sequential, 4)
            .unwrap();
        let t = g.next_task();
        assert!(t.deadline_s.is_infinite());
        assert_eq!(t.priority, 0);
    }

    #[test]
    fn mmpp_mean_rate_formula() {
        let a = Arrivals::parse("mmpp:10,100,2,0.5").unwrap();
        // (10·2 + 100·0.5) / 2.5 = 28
        assert!((a.mean_rate().unwrap() - 28.0).abs() < 1e-9);
        assert!(Arrivals::Sequential.mean_rate().is_none());
    }

    #[test]
    fn mmpp_interarrivals_hit_configured_mean() {
        let a = Arrivals::parse("mmpp:10,100,2,0.5").unwrap();
        let mut g = TaskGen::new("resnet-18", Dataset::Cifar100, a.clone(), 11).unwrap();
        let ts = g.take(4000);
        let rate = 4000.0 / ts.last().unwrap().arrival_s;
        let want = a.mean_rate().unwrap();
        assert!(
            (rate - want).abs() / want < 0.3,
            "empirical {rate} vs configured {want}"
        );
        // arrivals are strictly increasing
        assert!(ts.windows(2).all(|w| w[1].arrival_s > w[0].arrival_s));
    }

    #[test]
    fn diurnal_mean_tracks_base_rate() {
        let a = Arrivals::parse("diurnal:40,0.8,10").unwrap();
        let mut g = TaskGen::new("resnet-18", Dataset::Cifar100, a, 13).unwrap();
        let ts = g.take(4000);
        let span = ts.last().unwrap().arrival_s;
        let rate = 4000.0 / span;
        assert!(
            (rate - 40.0).abs() / 40.0 < 0.35,
            "empirical {rate} vs base 40 over {span}s"
        );
        assert!(span > 5.0 * 10.0, "must cover several periods, got {span}s");
    }

    #[test]
    fn trace_arrivals_replay_timestamps_and_cycle() {
        let p = std::env::temp_dir().join("dvfo_arrivals_trace_ok.json");
        std::fs::write(&p, "[0.0, 0.5, 1.25]").unwrap();
        let a = Arrivals::parse(&format!("trace:{}", p.display())).unwrap();
        // 3 arrivals over 1.25 s of recording
        assert!((a.mean_rate().unwrap() - 3.0 / 1.25).abs() < 1e-12);
        let mut g = TaskGen::new("resnet-18", Dataset::Cifar100, a, 5).unwrap();
        let got: Vec<f64> = g.take(5).iter().map(|t| t.arrival_s).collect();
        // exact replay of the recorded timestamps, then the gap sequence
        // loops: gaps (0.0, 0.5, 0.75) resume from t = 1.25
        assert_eq!(got, vec![0.0, 0.5, 1.25, 1.25, 1.75]);
    }

    #[test]
    fn trace_arrivals_parse_csv_and_share_one_buffer() {
        let p = std::env::temp_dir().join("dvfo_arrivals_trace_ok.csv");
        std::fs::write(&p, "0.0, 0.25\n0.75\n").unwrap();
        let a = Arrivals::parse(&format!("trace:{}", p.display())).unwrap();
        let Arrivals::Trace { ref gaps } = a else {
            panic!("csv trace should parse to Trace");
        };
        assert_eq!(gaps.as_slice(), &[0.0, 0.25, 0.5]);
        // per-stream generators clone the handle, not the recording, and
        // each keeps an independent replay cursor
        let mut g0 = TaskGen::new("resnet-18", Dataset::Cifar100, a.clone(), 1).unwrap();
        let mut g1 = TaskGen::new("resnet-18", Dataset::Cifar100, a, 2).unwrap();
        let _ = g0.next_task();
        let x = g0.next_task();
        let y = g1.next_task();
        assert_eq!(x.arrival_s, 0.25);
        assert_eq!(y.arrival_s, 0.0);
    }

    #[test]
    fn trace_arrivals_reject_garbage_files() {
        let dir = std::env::temp_dir();
        let write = |name: &str, body: &str| {
            let p = dir.join(name);
            std::fs::write(&p, body).unwrap();
            format!("trace:{}", p.display())
        };
        for (name, body) in [
            ("dvfo_trace_bad_tokens.csv", "not,a,number"),
            ("dvfo_trace_bad_empty.csv", ""),
            ("dvfo_trace_bad_order.csv", "0.5,0.25"),
            ("dvfo_trace_bad_negative.csv", "-1.0,2.0"),
            ("dvfo_trace_bad_nan.csv", "0.0,NaN"),
            ("dvfo_trace_bad_inf.csv", "0.0,inf"),
            ("dvfo_trace_bad_entry.json", "[0.0, \"x\"]"),
            ("dvfo_trace_bad_syntax.json", "[0.0,"),
            ("dvfo_trace_bad_shape.json", "{\"t\": 1}"),
            ("dvfo_trace_bad_json_empty.json", "[]"),
        ] {
            assert!(Arrivals::parse(&write(name, body)).is_err(), "{name}");
        }
        // a missing file is a parse error, not a panic
        assert!(Arrivals::parse("trace:/no/such/dvfo_trace.csv").is_err());
    }

    #[test]
    fn new_processes_are_seed_deterministic() {
        for spec in ["mmpp:5,50,1,0.2", "diurnal:40,0.8,10"] {
            let a = Arrivals::parse(spec).unwrap();
            let mk = || {
                TaskGen::new("resnet-18", Dataset::Cifar100, a.clone(), 77)
                    .unwrap()
                    .take(200)
            };
            let xs = mk();
            let ys = mk();
            for (x, y) in xs.iter().zip(ys.iter()) {
                assert_eq!(x.arrival_s, y.arrival_s, "{spec}");
                assert_eq!(x.sample_idx, y.sample_idx, "{spec}");
            }
        }
    }
}
