//! Workload generation: inference tasks with Poisson/burst arrivals,
//! per-task SCAM importance draws, and dataset mixing.

use crate::perfmodel::{find_model, Dataset, ModelProfile};
use crate::scam::ImportanceDist;
use crate::util::Pcg32;
use anyhow::Result;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: u64,
    pub arrival_s: f64,
    pub dataset: Dataset,
    /// per-task importance distribution (the SCAM output for this input)
    pub importance: ImportanceDist,
    /// index into the synthetic test set (real-artifact path only)
    pub sample_idx: usize,
}

/// Arrival process shapes.
#[derive(Clone, Copy, Debug)]
pub enum Arrivals {
    /// Poisson with given rate (req/s)
    Poisson { rate: f64 },
    /// back-to-back (closed loop — the paper's per-task evaluation)
    Sequential,
    /// Poisson baseline with periodic bursts
    Bursty { rate: f64, burst_every_s: f64, burst_len: usize },
}

/// Generates the task stream for one model/dataset configuration.
pub struct TaskGen {
    profile: ModelProfile,
    dataset: Dataset,
    arrivals: Arrivals,
    channels: usize,
    rng: Pcg32,
    next_id: u64,
    clock_s: f64,
    burst_left: usize,
    testset_count: usize,
}

impl TaskGen {
    pub fn new(
        model: &str,
        dataset: Dataset,
        arrivals: Arrivals,
        seed: u64,
    ) -> Result<Self> {
        Ok(Self {
            profile: find_model(model)?,
            dataset,
            arrivals,
            channels: 16,
            rng: Pcg32::seeded(seed ^ 0x7A5C),
            next_id: 0,
            clock_s: 0.0,
            burst_left: 0,
            testset_count: 256,
        })
    }

    pub fn profile(&self) -> &ModelProfile {
        &self.profile
    }

    pub fn dataset(&self) -> Dataset {
        self.dataset
    }

    /// Draw the next task (advances the arrival clock).
    pub fn next_task(&mut self) -> Task {
        let dt = match self.arrivals {
            Arrivals::Sequential => 0.0,
            Arrivals::Poisson { rate } => self.rng.exponential(rate),
            Arrivals::Bursty {
                rate,
                burst_every_s,
                burst_len,
            } => {
                if self.burst_left > 0 {
                    self.burst_left -= 1;
                    0.0005
                } else if self.clock_s > 0.0
                    && (self.clock_s / burst_every_s).fract() < 0.02
                {
                    self.burst_left = burst_len;
                    0.0005
                } else {
                    self.rng.exponential(rate)
                }
            }
        };
        self.clock_s += dt;
        let id = self.next_id;
        self.next_id += 1;
        // per-task importance: model-level skew + small per-input jitter
        let skew =
            self.profile.importance_skew * (0.85 + 0.3 * self.rng.next_f64());
        Task {
            id,
            arrival_s: self.clock_s,
            dataset: self.dataset,
            importance: ImportanceDist::synthetic(self.channels, skew, &mut self.rng),
            sample_idx: (self.rng.below(self.testset_count as u32)) as usize,
        }
    }

    pub fn take(&mut self, n: usize) -> Vec<Task> {
        (0..n).map(|_| self.next_task()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_tasks_have_zero_gaps() {
        let mut g =
            TaskGen::new("resnet-18", Dataset::Cifar100, Arrivals::Sequential, 1)
                .unwrap();
        let ts = g.take(5);
        assert!(ts.iter().all(|t| t.arrival_s == 0.0));
        assert_eq!(ts.last().unwrap().id, 4);
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut g = TaskGen::new(
            "resnet-18",
            Dataset::Cifar100,
            Arrivals::Poisson { rate: 50.0 },
            2,
        )
        .unwrap();
        let ts = g.take(2000);
        let span = ts.last().unwrap().arrival_s;
        let rate = 2000.0 / span;
        assert!((40.0..60.0).contains(&rate), "rate {rate}");
    }

    #[test]
    fn tasks_are_deterministic_per_seed() {
        let mk = || {
            TaskGen::new("vit-b16", Dataset::Imagenet, Arrivals::Sequential, 9)
                .unwrap()
                .take(3)
        };
        let a = mk();
        let b = mk();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.sample_idx, y.sample_idx);
            assert_eq!(x.importance.probs(), y.importance.probs());
        }
    }

    #[test]
    fn importance_skew_tracks_model() {
        let take_skew = |name: &str| {
            let mut g =
                TaskGen::new(name, Dataset::Cifar100, Arrivals::Sequential, 3)
                    .unwrap();
            let ts = g.take(64);
            ts.iter().map(|t| t.importance.skewness()).sum::<f64>() / 64.0
        };
        // vit-b16 has the most concentrated importance in the zoo
        assert!(take_skew("vit-b16") > take_skew("deepspeech"));
    }

    #[test]
    fn unknown_model_errors() {
        assert!(
            TaskGen::new("nope", Dataset::Cifar100, Arrivals::Sequential, 0).is_err()
        );
    }
}
