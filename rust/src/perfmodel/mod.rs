//! Roofline latency/energy model for DNN inference on DVFS-scaled devices.
//!
//! Substitutes measuring real DNNs on real Jetsons: per-model profiles
//! (FLOPs, bytes moved, operational intensity, activation sizes) drive a
//! roofline `t = max(t_compute, t_memory) + t_cpu` where each term scales
//! with the corresponding DVFS frequency. This reproduces the paper's two
//! motivating observations by construction:
//!   1. latency saturates past the roofline knee while power keeps growing
//!      with f·V² — so max frequency is energy-inefficient (Fig. 2).
//!   2. memory-bound models (EfficientNet-B0) are governed by CPU/MEM
//!      frequency, compute-bound ones (ViT-B16) by GPU frequency.

pub mod zoo;

pub use zoo::{find_model, model_zoo, Dataset, ModelProfile};

use crate::device::{DeviceSpec, FreqVector};

/// Execution-time breakdown of one inference phase on one device.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTime {
    pub total_s: f64,
    /// utilization of [cpu, gpu, mem] during the phase (drives power)
    pub util: [f64; 3],
}

/// Effective throughputs at a frequency vector. Sub-linear saturation
/// (Amdahl-style serial fraction) produces the diminishing-returns knee.
fn effective(spec: &DeviceSpec, f: &FreqVector) -> (f64, f64, f64) {
    let knee = |x: f64, serial: f64| x / (serial + (1.0 - serial) * x).max(1e-9) * x;
    let gx = f.gpu_mhz / spec.gpu.max_mhz;
    let cx = f.cpu_mhz / spec.cpu.max_mhz;
    let mx = f.mem_mhz / spec.mem.max_mhz;
    // serial fractions: fixed overheads that frequency cannot remove
    let gpu = spec.gpu_peak_gflops * knee(gx, 0.12).min(gx);
    let cpu = spec.cpu_peak_gflops * knee(cx, 0.10).min(cx);
    let mem = spec.mem_peak_gbps * knee(mx, 0.08).min(mx);
    (cpu.max(1e-6), gpu.max(1e-6), mem.max(1e-6))
}

/// Achievable fraction of peak: CPU numeric work and DRAM streams of
/// framework-driven inference (GPU efficiency is per-model, see zoo).
const CPU_EFF: f64 = 0.45;
const MEM_EFF: f64 = 0.35;

/// Kernel-dispatch cost constant: seconds·√GFLOPs — the per-launch driver
/// overhead of an eager-mode framework, inversely related to how beefy
/// the host CPU is (√peak as a proxy for single-core speed).
const DISPATCH_K: f64 = 0.65e-3;

/// Per-kernel dispatch latency on this device at CPU frequency `f_c`.
fn dispatch_s(spec: &DeviceSpec, cpu_ratio_knee: f64) -> f64 {
    spec.dispatch_discount * DISPATCH_K / spec.cpu_peak_gflops.sqrt()
        / cpu_ratio_knee.max(1e-3)
}

/// Latency + utilization of running `work_frac` of a model's DNN body on
/// `spec` at frequencies `f` (generalizes Eq. 5 with roofline saturation
/// and a CPU dispatch term).
///
/// The structure reproduces the paper's Fig. 1 + Fig. 2 dichotomy:
/// * latency: small/fragmented models (EfficientNet-B0) are bound by CPU
///   dispatch + memory; dense models (ViT-B16) by GPU flops.
/// * energy: the GPU stays clocked while memory-stalled or being fed by
///   dispatch, so GPU energy dominates for *all* models (Fig. 1).
pub fn edge_compute(
    profile: &ModelProfile,
    ds: Dataset,
    spec: &DeviceSpec,
    f: &FreqVector,
    work_frac: f64,
) -> PhaseTime {
    let w = work_frac.max(0.0);
    let (cpu_t, gpu_t, mem_t) = effective(spec, f);
    let cpu_knee = cpu_t / spec.cpu_peak_gflops; // knee-scaled cpu ratio
    let flops = profile.flops_g(ds) * w;
    let bytes = profile.bytes_g(ds) * w;
    let cpu_flops = flops * profile.cpu_frac;
    let gpu_flops = flops * (1.0 - profile.cpu_frac);

    let t_gpu = gpu_flops / (gpu_t * profile.gpu_eff);
    let t_mem = bytes / (mem_t * MEM_EFF);
    let t_cpu = cpu_flops / (cpu_t * CPU_EFF);
    let t_disp = profile.n_kernels * w * dispatch_s(spec, cpu_knee);

    // GPU and memory streams overlap (roofline body); dispatch pipelines
    // against the body but the longer of the two gates completion.
    let body = t_gpu.max(t_mem);
    let total = body.max(t_disp) + 0.3 * body.min(t_disp) + 0.5 * t_cpu;
    if total <= 0.0 {
        return PhaseTime::default();
    }
    // Power-model utilizations: the GPU stays busy while executing,
    // memory-stalled, or being fed back-to-back kernels — which is what
    // jetson-stats measures and why GPU energy dominates (Fig. 1).
    let gpu_busy = t_gpu.max(t_mem).max(0.7 * t_disp) / total;
    PhaseTime {
        total_s: total,
        util: [
            (0.40 + 0.3 * (t_disp + t_cpu) / total).min(1.0),
            (0.92 * gpu_busy).min(1.0),
            (t_mem / total).min(1.0),
        ],
    }
}

/// Per-invocation service-runtime overhead of the cloud executor
/// (scheduling + kernel-launch chain). Paid once per invocation, so the
/// serving engine's cloud-side batching amortizes it across the batch.
pub const CLOUD_DISPATCH_OVERHEAD_S: f64 = 0.0015;

/// Cloud-side compute (Eq. 6): same roofline on the cloud spec at max
/// frequency, plus a queuing/runtime constant.
pub fn cloud_compute(
    profile: &ModelProfile,
    ds: Dataset,
    cloud: &DeviceSpec,
    work_frac: f64,
) -> PhaseTime {
    let f = FreqVector {
        cpu_mhz: cloud.cpu.max_mhz,
        gpu_mhz: cloud.gpu.max_mhz,
        mem_mhz: cloud.mem.max_mhz,
    };
    let mut t = edge_compute(profile, ds, cloud, &f, work_frac);
    t.total_s += CLOUD_DISPATCH_OVERHEAD_S; // service runtime overhead
    t
}

/// Compression (int8 quantization) time on edge (Eq. 7): a memory-bound
/// pass over the offloaded payload.
pub fn compress_time_s(
    payload_bytes: f64,
    spec: &DeviceSpec,
    f: &FreqVector,
) -> f64 {
    let (_c, _g, mem_t) = effective(spec, f);
    // read f32 + write int8 ≈ 1.25 passes over the f32 buffer
    1.25 * payload_bytes / (mem_t * MEM_EFF * 1e9) + 2e-4
}

/// Latency-per-mJ metric of Fig. 2 (higher = better perf per energy).
pub fn latency_per_mj(tti_s: f64, eti_j: f64) -> f64 {
    if eti_j <= 0.0 {
        return 0.0;
    }
    // the paper plots "inference performance (latency per mJ)": work done
    // per unit time per unit energy; we use 1/(TTI·ETI) normalized to mJ.
    1.0 / (tti_s * (eti_j * 1000.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::spec::find_device;

    fn maxf(d: &DeviceSpec) -> FreqVector {
        FreqVector {
            cpu_mhz: d.cpu.max_mhz,
            gpu_mhz: d.gpu.max_mhz,
            mem_mhz: d.mem.max_mhz,
        }
    }

    #[test]
    fn latency_decreases_with_frequency() {
        let d = find_device("xavier-nx").unwrap();
        let m = find_model("efficientnet-b0").unwrap();
        let lo = FreqVector {
            cpu_mhz: d.cpu.min_mhz,
            gpu_mhz: d.gpu.min_mhz,
            mem_mhz: d.mem.min_mhz,
        };
        let t_lo = edge_compute(&m, Dataset::Cifar100, &d, &lo, 1.0).total_s;
        let t_hi = edge_compute(&m, Dataset::Cifar100, &d, &maxf(&d), 1.0).total_s;
        assert!(t_lo > t_hi * 1.5, "t_lo={t_lo} t_hi={t_hi}");
    }

    #[test]
    fn latency_saturates_near_max() {
        // Fig. 2 observation 1: going from 80% to 100% frequency barely
        // helps latency.
        let d = find_device("xavier-nx").unwrap();
        let m = find_model("efficientnet-b0").unwrap();
        let f80 = FreqVector {
            cpu_mhz: d.cpu.max_mhz * 0.8,
            gpu_mhz: d.gpu.max_mhz * 0.8,
            mem_mhz: d.mem.max_mhz * 0.8,
        };
        let t80 = edge_compute(&m, Dataset::Cifar100, &d, &f80, 1.0).total_s;
        let t100 = edge_compute(&m, Dataset::Cifar100, &d, &maxf(&d), 1.0).total_s;
        let gain = (t80 - t100) / t80;
        assert!(gain < 0.25, "latency gain {gain} should be saturating");
    }

    #[test]
    fn efficientnet_is_memory_bound_on_nx() {
        // Fig. 2(b): EfficientNet-B0 bottleneck is CPU/MEM frequency.
        let d = find_device("xavier-nx").unwrap();
        let m = find_model("efficientnet-b0").unwrap();
        let base = maxf(&d);
        let mut slow_mem = base;
        slow_mem.mem_mhz = d.mem.min_mhz;
        let mut slow_gpu = base;
        slow_gpu.gpu_mhz = d.gpu.min_mhz;
        let t_mem = edge_compute(&m, Dataset::Cifar100, &d, &slow_mem, 1.0).total_s;
        let t_gpu = edge_compute(&m, Dataset::Cifar100, &d, &slow_gpu, 1.0).total_s;
        assert!(
            t_mem > t_gpu,
            "mem throttle should hurt more: mem={t_mem} gpu={t_gpu}"
        );
    }

    #[test]
    fn vit_is_compute_bound_on_nx() {
        // Fig. 2(d): ViT-B16 bottleneck is GPU frequency.
        let d = find_device("xavier-nx").unwrap();
        let m = find_model("vit-b16").unwrap();
        let base = maxf(&d);
        let mut slow_mem = base;
        slow_mem.mem_mhz = d.mem.min_mhz;
        let mut slow_gpu = base;
        slow_gpu.gpu_mhz = d.gpu.min_mhz;
        let t_mem = edge_compute(&m, Dataset::Cifar100, &d, &slow_mem, 1.0).total_s;
        let t_gpu = edge_compute(&m, Dataset::Cifar100, &d, &slow_gpu, 1.0).total_s;
        assert!(
            t_gpu > t_mem,
            "gpu throttle should hurt more: gpu={t_gpu} mem={t_mem}"
        );
    }

    #[test]
    fn both_compute_bound_on_nano() {
        // Fig. 2(a)(c): on Jetson Nano (weak GPU) both models are
        // compute-bound.
        let d = find_device("jetson-nano").unwrap();
        for name in ["efficientnet-b0", "vit-b16"] {
            let m = find_model(name).unwrap();
            let base = maxf(&d);
            let mut slow_mem = base;
            slow_mem.mem_mhz = d.mem.min_mhz;
            let mut slow_gpu = base;
            slow_gpu.gpu_mhz = d.gpu.min_mhz;
            let t_mem =
                edge_compute(&m, Dataset::Cifar100, &d, &slow_mem, 1.0).total_s;
            let t_gpu =
                edge_compute(&m, Dataset::Cifar100, &d, &slow_gpu, 1.0).total_s;
            assert!(t_gpu > t_mem, "{name}: gpu={t_gpu} mem={t_mem}");
        }
    }

    #[test]
    fn cloud_much_faster_than_edge() {
        let edge = find_device("xavier-nx").unwrap();
        let cloud = find_device("rtx3080").unwrap();
        let m = find_model("resnet-18").unwrap();
        let t_e = edge_compute(&m, Dataset::Imagenet, &edge, &maxf(&edge), 1.0).total_s;
        let t_c = cloud_compute(&m, Dataset::Imagenet, &cloud, 1.0).total_s;
        // fixed dispatch overheads bound the gap at batch size 1, but the
        // cloud must still clearly win on raw compute
        assert!(t_e > 1.8 * t_c, "edge={t_e} cloud={t_c}");
    }

    #[test]
    fn work_fraction_scales_latency() {
        let d = find_device("xavier-nx").unwrap();
        let m = find_model("resnet-18").unwrap();
        let full = edge_compute(&m, Dataset::Cifar100, &d, &maxf(&d), 1.0).total_s;
        let half = edge_compute(&m, Dataset::Cifar100, &d, &maxf(&d), 0.5).total_s;
        assert!(half < full);
        assert!(half > 0.3 * full);
    }

    #[test]
    fn edge_latency_magnitudes_match_paper_band() {
        // Table 5: Nano end-to-end latencies are ~12-36 ms with
        // collaboration; Edge-only should land in the same decade
        // (units: ms, not µs or s).
        let d = find_device("jetson-nano").unwrap();
        for name in ["resnet-18", "mobilenet-v2", "yolov3-tiny"] {
            let m = find_model(name).unwrap();
            let t = edge_compute(&m, Dataset::Cifar100, &d, &maxf(&d), 1.0).total_s;
            assert!(
                (0.005..0.30).contains(&t),
                "{name} edge latency {t}s outside plausible band"
            );
        }
    }

    #[test]
    fn compression_is_fast_but_nonzero() {
        let d = find_device("xavier-nx").unwrap();
        let f = maxf(&d);
        let t = compress_time_s(200_000.0, &d, &f);
        assert!(t > 0.0 && t < 0.005, "compress {t}");
    }
}
