//! Model zoo: profiles for the eight DNNs the paper evaluates.
//!
//! Numbers are datasheet/paper-derived: FLOPs and parameter-traffic from
//! the original model papers; operational intensity chosen so that the
//! memory-bound vs compute-bound classification of paper Fig. 2 holds;
//! accuracy bases from Table 4 / Fig. 9 magnitudes. Absolute values only
//! set the scale — the reproduction targets relative shapes.

use anyhow::Result;

/// Evaluation dataset (paper §6.2.1). ImageNet inputs are larger, so
/// activations (and thus offload payloads) grow, and effective FLOPs rise.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    Cifar100,
    Imagenet,
}

impl Dataset {
    pub fn parse(s: &str) -> Result<Dataset> {
        match s {
            "cifar100" | "cifar-100" => Ok(Dataset::Cifar100),
            "imagenet" | "imagenet-2012" => Ok(Dataset::Imagenet),
            other => anyhow::bail!("unknown dataset `{other}`"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Cifar100 => "cifar100",
            Dataset::Imagenet => "imagenet",
        }
    }
}

/// Static profile of one benchmark DNN.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: &'static str,
    /// GFLOPs per inference (CIFAR-100-sized input, batch 1).
    pub flops_g_cifar: f64,
    /// bytes moved per inference in GB (weights + activations traffic).
    pub bytes_g_cifar: f64,
    /// feature-map size at the split point (KB, f32, CIFAR input).
    pub act_kb_cifar: f64,
    /// fraction of FLOPs on CPU (pre/post-processing, NMS, decoding...).
    pub cpu_frac: f64,
    /// kernel launches per inference — small models are *dispatch-bound*
    /// on edge CPUs (the Fig. 2 "CPU frequency dominates EfficientNet"
    /// effect); big dense models are GPU-bound.
    pub n_kernels: f64,
    /// fraction of GPU peak this model's kernels achieve (depthwise convs
    /// and RNN steps are far below dense-matmul efficiency).
    pub gpu_eff: f64,
    /// top-1 accuracy of the uncompressed single-device model (%).
    pub base_acc_cifar: f64,
    pub base_acc_imagenet: f64,
    /// skewness knob of the SCAM importance distribution for this model:
    /// how concentrated feature importance is (higher = more offloadable).
    pub importance_skew: f64,
}

/// ImageNet scale factors relative to CIFAR (inputs are resized to each
/// model's canonical resolution; larger inputs → more activation traffic,
/// moderately more FLOPs — consistent with Table 5 vs Table 6 ratios).
const IMAGENET_FLOPS_SCALE: f64 = 1.30;
const IMAGENET_BYTES_SCALE: f64 = 1.45;
const IMAGENET_ACT_SCALE: f64 = 1.85;

impl ModelProfile {
    pub fn flops_g(&self, ds: Dataset) -> f64 {
        match ds {
            Dataset::Cifar100 => self.flops_g_cifar,
            Dataset::Imagenet => self.flops_g_cifar * IMAGENET_FLOPS_SCALE,
        }
    }

    pub fn bytes_g(&self, ds: Dataset) -> f64 {
        match ds {
            Dataset::Cifar100 => self.bytes_g_cifar,
            Dataset::Imagenet => self.bytes_g_cifar * IMAGENET_BYTES_SCALE,
        }
    }

    /// Split-point activation size in bytes (f32).
    pub fn act_bytes(&self, ds: Dataset) -> f64 {
        let kb = match ds {
            Dataset::Cifar100 => self.act_kb_cifar,
            Dataset::Imagenet => self.act_kb_cifar * IMAGENET_ACT_SCALE,
        };
        kb * 1024.0
    }

    pub fn base_acc(&self, ds: Dataset) -> f64 {
        match ds {
            Dataset::Cifar100 => self.base_acc_cifar,
            Dataset::Imagenet => self.base_acc_imagenet,
        }
    }

    /// Operational intensity (FLOP/byte) — classifies compute- vs
    /// memory-bound (roofline).
    pub fn intensity(&self, ds: Dataset) -> f64 {
        self.flops_g(ds) / self.bytes_g(ds)
    }
}

pub fn model_zoo() -> Vec<ModelProfile> {
    vec![
        ModelProfile {
            name: "resnet-18",
            flops_g_cifar: 1.82,
            bytes_g_cifar: 0.060,
            act_kb_cifar: 16.0,
            cpu_frac: 0.015,
            n_kernels: 60.0,
            gpu_eff: 0.06,
            base_acc_cifar: 91.84,
            base_acc_imagenet: 74.52,
            importance_skew: 2.2,
        },
        ModelProfile {
            name: "inception-v4",
            flops_g_cifar: 12.3,
            bytes_g_cifar: 0.210,
            act_kb_cifar: 24.0,
            cpu_frac: 0.012,
            n_kernels: 280.0,
            gpu_eff: 0.07,
            base_acc_cifar: 93.10,
            base_acc_imagenet: 80.10,
            importance_skew: 2.0,
        },
        ModelProfile {
            name: "mobilenet-v2",
            flops_g_cifar: 0.31,
            bytes_g_cifar: 0.055,
            act_kb_cifar: 28.0,
            cpu_frac: 0.030,
            n_kernels: 120.0,
            gpu_eff: 0.03,
            base_acc_cifar: 90.25,
            base_acc_imagenet: 71.80,
            importance_skew: 1.8,
        },
        ModelProfile {
            name: "yolov3-tiny",
            flops_g_cifar: 5.56,
            bytes_g_cifar: 0.085,
            act_kb_cifar: 26.0,
            cpu_frac: 0.040, // NMS + box decode on CPU
            n_kernels: 80.0,
            gpu_eff: 0.09,
            base_acc_cifar: 88.40, // detection mAP-as-accuracy proxy
            base_acc_imagenet: 72.90,
            importance_skew: 1.9,
        },
        ModelProfile {
            name: "retinanet",
            flops_g_cifar: 17.5,
            bytes_g_cifar: 0.290,
            act_kb_cifar: 36.0,
            cpu_frac: 0.035,
            n_kernels: 300.0,
            gpu_eff: 0.08,
            base_acc_cifar: 89.70,
            base_acc_imagenet: 75.60,
            importance_skew: 2.1,
        },
        ModelProfile {
            name: "deepspeech",
            flops_g_cifar: 1.10,
            bytes_g_cifar: 0.140, // RNN: weight-traffic heavy
            act_kb_cifar: 12.0,
            cpu_frac: 0.060,
            n_kernels: 90.0,
            gpu_eff: 0.025,
            base_acc_cifar: 92.50, // WER-derived accuracy proxy
            base_acc_imagenet: 85.30,
            importance_skew: 1.6,
        },
        ModelProfile {
            name: "efficientnet-b0",
            // memory-bound: depthwise convs have low arithmetic intensity
            flops_g_cifar: 0.40,
            bytes_g_cifar: 0.095,
            act_kb_cifar: 24.0,
            cpu_frac: 0.025,
            n_kernels: 250.0,
            gpu_eff: 0.12,
            base_acc_cifar: 92.70,
            base_acc_imagenet: 77.10,
            importance_skew: 2.4,
        },
        ModelProfile {
            name: "vit-b16",
            // compute-bound: dense matmuls, high arithmetic intensity
            flops_g_cifar: 17.6,
            bytes_g_cifar: 0.105,
            act_kb_cifar: 36.0,
            cpu_frac: 0.010,
            n_kernels: 140.0,
            gpu_eff: 0.12,
            base_acc_cifar: 93.80,
            base_acc_imagenet: 81.10,
            importance_skew: 2.6,
        },
    ]
}

pub fn find_model(name: &str) -> Result<ModelProfile> {
    model_zoo()
        .into_iter()
        .find(|m| m.name == name)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown model `{name}` (known: {:?})",
                model_zoo().iter().map(|m| m.name).collect::<Vec<_>>()
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_all_paper_models() {
        for name in [
            "resnet-18",
            "inception-v4",
            "mobilenet-v2",
            "yolov3-tiny",
            "retinanet",
            "deepspeech",
            "efficientnet-b0",
            "vit-b16",
        ] {
            find_model(name).unwrap();
        }
        assert!(find_model("alexnet").is_err());
    }

    #[test]
    fn intensity_ordering_matches_fig2() {
        // ViT must be far more compute-intense than EfficientNet.
        let vit = find_model("vit-b16").unwrap();
        let eff = find_model("efficientnet-b0").unwrap();
        assert!(vit.intensity(Dataset::Cifar100) > 10.0 * eff.intensity(Dataset::Cifar100));
    }

    #[test]
    fn imagenet_scales_up() {
        let m = find_model("resnet-18").unwrap();
        assert!(m.flops_g(Dataset::Imagenet) > m.flops_g(Dataset::Cifar100));
        assert!(m.act_bytes(Dataset::Imagenet) > m.act_bytes(Dataset::Cifar100));
        assert!(m.base_acc(Dataset::Imagenet) < m.base_acc(Dataset::Cifar100));
    }

    #[test]
    fn dataset_parse() {
        assert_eq!(Dataset::parse("cifar100").unwrap(), Dataset::Cifar100);
        assert_eq!(Dataset::parse("imagenet-2012").unwrap(), Dataset::Imagenet);
        assert!(Dataset::parse("mnist").is_err());
    }

    #[test]
    fn importance_skew_positive() {
        for m in model_zoo() {
            assert!(m.importance_skew > 1.0, "{}", m.name);
        }
    }
}
