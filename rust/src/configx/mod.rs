//! Configuration system: JSON-backed typed configs with defaults,
//! file/str loading, override strings (`key=value` dotted paths), and
//! validation. Stands in for serde+figment in the offline crate set.
pub mod json;

pub use json::Json;

use anyhow::{bail, Context, Result};

/// Top-level run configuration for the DVFO coordinator and experiments.
#[derive(Clone, Debug)]
pub struct Config {
    /// Edge device name (must exist in the device zoo; Table 3).
    pub device: String,
    /// Cloud device name.
    pub cloud: String,
    /// DNN model name (perfmodel zoo) driven through the simulator.
    pub model: String,
    /// Dataset name ("cifar100" | "imagenet") — picks input sizes.
    pub dataset: String,
    /// Energy/latency trade-off weight η ∈ [0,1] (Eq. 4).
    pub eta: f64,
    /// Fusion summation weight λ ∈ (0,1) (paper §5.3).
    pub lambda: f64,
    /// Network bandwidth model: "static:<mbps>" | "markov:<lo>,<hi>" |
    /// "trace:<path>".
    pub bandwidth: String,
    /// Frequency levels per unit in the action ladder.
    pub freq_levels: usize,
    /// Offload-proportion levels (ξ grid).
    pub xi_levels: usize,
    /// Serving policy: dvfo|drldo|appealnet|cloud_only|edge_only|oracle.
    pub policy: String,
    /// Requests to serve / simulate.
    pub requests: usize,
    /// DQN training episodes before deployment (offline phase).
    pub train_episodes: usize,
    /// Use thinking-while-moving concurrent policy inference.
    pub concurrent: bool,
    /// Concurrent user streams fed through the discrete-event serving
    /// core (1 = the paper's single-stream evaluation).
    pub streams: usize,
    /// Uplink batching window in milliseconds (0 = no batching):
    /// offloaded feature maps arriving within the window ship as one
    /// transmission.
    pub batch_window_ms: f64,
    /// Arrival process spec per stream: "sequential" | "poisson:<r>" |
    /// "bursty:<r>,<every_s>,<len>" | "mmpp:<lo>,<hi>,<dlo>,<dhi>" |
    /// "diurnal:<base>,<amp>,<period_s>".
    pub arrivals: String,
    /// Maximum offloads per uplink batch (a full batch flushes before
    /// the window closes).
    pub max_batch: usize,
    /// Concurrent cloud executors shared by the whole fleet (beyond
    /// this, cloud work queues).
    pub cloud_slots: usize,
    /// Cloud-side batching window in milliseconds (0 = no batching):
    /// cloud work arriving within the window — across devices in a
    /// fleet — merges into one batched executor invocation that pays
    /// the service-runtime dispatch overhead once.
    pub cloud_batch_window_ms: f64,
    /// Maximum jobs per batched cloud invocation (a full batch flushes
    /// before the window closes).
    pub cloud_max_batch: usize,
    /// Fleet spec: comma-separated edge device names, `name*count` for
    /// repeats (e.g. "xavier-nx,jetson-nano*2"). Empty = one device of
    /// `device` (the single-edge configuration).
    pub fleet: String,
    /// Fleet dispatch policy: "round_robin" | "shortest_queue" |
    /// "least_backlog" (energy-aware).
    pub router: String,
    /// Per-stream SLO class: "none" | "<deadline_ms>" |
    /// "<deadline_ms>,<priority>".
    pub slo: String,
    /// Admission control under overload: "off" | "shed" | "downgrade"
    /// (downgrade forces edge-only execution instead of dropping).
    pub admission: String,
    /// Re-route-before-shed: when the routed device's completion
    /// estimate would blow a task's deadline, re-route to the cheapest
    /// feasible sibling device; only shed/downgrade when no device can
    /// make it (takes effect with admission shed|downgrade).
    pub reroute: bool,
    /// Cross-device rebalance tick period in milliseconds; 0 disables
    /// mid-run migration entirely (no tick events are scheduled).
    pub rebalance_window_ms: f64,
    /// Backlog divergence (ms) between the most- and least-backlogged
    /// devices above which queued tasks migrate at a rebalance tick
    /// ("inf" = never migrate).
    pub migrate_threshold_ms: f64,
    /// Latency penalty (ms) each migrated task pays in transit before it
    /// re-enqueues on the destination device.
    pub migrate_penalty_ms: f64,
    /// Widen the DVFO DQN state with queue-depth/backlog features so the
    /// policy reacts to load (changes the network shape, so off by
    /// default to preserve the paper's 8-dim formulation).
    pub queue_aware: bool,
    /// Share-nothing engine shards for fleet serving: each shard runs a
    /// full event kernel on its own thread over a disjoint device
    /// subset, synchronizing cloud-pool signals at epoch boundaries.
    /// 1 = the unsharded (bit-exact replay) path.
    pub shards: usize,
    /// Stream telemetry through constant-memory sinks (quantile sketches
    /// + counters) instead of collecting every per-task report —
    /// bounded RSS for million-task runs.
    pub stream_telemetry: bool,
    /// Event-scheduler backend for the discrete-event kernel:
    /// "calendar" (bucketed calendar queue, amortized O(1)) | "heap"
    /// (binary heap, O(log n)). Both pop events in the identical
    /// (time, seq) total order, so this is purely a performance knob.
    pub scheduler: String,
    /// DQN gradient-step placement for the training policies
    /// (dvfo/drldo): "inline" (feedback blocks on the gradient step —
    /// the historical, bit-exact behavior) | "bg" (gradient steps on a
    /// background learner thread; the decide path pushes transitions
    /// and adopts weight snapshots at a fixed cadence).
    pub learner: String,
    /// Background learner snapshot cadence: adopt fresh weights every
    /// this-many transitions (ignored by "inline").
    pub learner_publish_every: usize,
    /// Deterministic fault schedule: `;`-separated entries
    /// `down:<dev>@<at_ms>+<dur_ms>` | `bw:<dev>@<at_ms>+<dur_ms>*<scale>`
    /// | `cloud@<at_ms>+<dur_ms>` | `file:<path>` (JSON fault-trace
    /// array). Empty = no faults (bit-exact fault-free traces).
    pub chaos: String,
    /// Retry budget for fault-killed in-flight work: how many
    /// re-enqueues before a task terminally fails.
    pub retry_max: usize,
    /// Backoff (ms) before a killed task's first retry; doubles per
    /// attempt (deterministic exponential backoff).
    pub retry_backoff_ms: f64,
    /// Worker threads for the experiment grid sweeps (1 = serial).
    /// Cells share nothing and seed their own RNGs, so any value
    /// renders byte-identical tables — only the wall clock changes.
    pub threads: usize,
    /// RNG seed for the whole run.
    pub seed: u64,
    /// Artifacts directory (PJRT-loadable HLO text).
    pub artifacts_dir: String,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            device: "xavier-nx".into(),
            cloud: "rtx3080".into(),
            model: "efficientnet-b0".into(),
            dataset: "cifar100".into(),
            eta: 0.5,
            lambda: 0.5,
            bandwidth: "static:5".into(),
            freq_levels: 10,
            xi_levels: 11,
            policy: "dvfo".into(),
            requests: 200,
            train_episodes: 60,
            concurrent: true,
            streams: 1,
            batch_window_ms: 0.0,
            max_batch: 16,
            cloud_slots: 4,
            cloud_batch_window_ms: 0.0,
            cloud_max_batch: 16,
            fleet: String::new(),
            router: "round_robin".into(),
            slo: "none".into(),
            admission: "off".into(),
            reroute: false,
            rebalance_window_ms: 0.0,
            migrate_threshold_ms: f64::INFINITY,
            migrate_penalty_ms: 5.0,
            arrivals: "sequential".into(),
            queue_aware: false,
            shards: 1,
            stream_telemetry: false,
            scheduler: "calendar".into(),
            learner: "inline".into(),
            learner_publish_every: 32,
            chaos: String::new(),
            retry_max: 3,
            retry_backoff_ms: 10.0,
            threads: 1,
            seed: 0,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl Config {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = Config::default();
        let obj = j.as_obj().context("config must be a json object")?;
        for (k, v) in obj {
            c.apply(k, v)
                .with_context(|| format!("config field `{k}`"))?;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    /// Apply one `key=value` override (all values accepted as strings).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let j = match key {
            // every numeric key rides through Json::Num; apply() picks
            // the float vs integer interpretation per field
            "eta" | "lambda" | "batch_window_ms" | "cloud_batch_window_ms"
            | "freq_levels" | "xi_levels" | "requests" | "train_episodes"
            | "streams" | "seed" | "max_batch" | "cloud_slots" | "cloud_max_batch"
            | "rebalance_window_ms" | "migrate_threshold_ms" | "migrate_penalty_ms"
            | "shards" => Json::Num(value.parse::<f64>()?),
            "threads" | "learner_publish_every" | "retry_max" | "retry_backoff_ms" => {
                Json::Num(value.parse::<f64>()?)
            }
            "concurrent" | "queue_aware" | "reroute" | "stream_telemetry" => {
                Json::Bool(value.parse::<bool>()?)
            }
            _ => Json::Str(value.to_string()),
        };
        self.apply(key, &j)?;
        self.validate()
    }

    fn apply(&mut self, key: &str, v: &Json) -> Result<()> {
        macro_rules! str_field {
            ($f:ident) => {{
                self.$f = v
                    .as_str()
                    .context("expected string")?
                    .to_string();
            }};
        }
        match key {
            "device" => str_field!(device),
            "cloud" => str_field!(cloud),
            "model" => str_field!(model),
            "dataset" => str_field!(dataset),
            "bandwidth" => str_field!(bandwidth),
            "policy" => str_field!(policy),
            "artifacts_dir" => str_field!(artifacts_dir),
            "eta" => self.eta = v.as_f64().context("expected number")?,
            "lambda" => self.lambda = v.as_f64().context("expected number")?,
            "freq_levels" => {
                self.freq_levels = v.as_usize().context("expected int")?
            }
            "xi_levels" => self.xi_levels = v.as_usize().context("expected int")?,
            "requests" => self.requests = v.as_usize().context("expected int")?,
            "train_episodes" => {
                self.train_episodes = v.as_usize().context("expected int")?
            }
            "concurrent" => self.concurrent = v.as_bool().context("expected bool")?,
            "streams" => self.streams = v.as_usize().context("expected int")?,
            "batch_window_ms" => {
                self.batch_window_ms = v.as_f64().context("expected number")?
            }
            "max_batch" => self.max_batch = v.as_usize().context("expected int")?,
            "cloud_slots" => self.cloud_slots = v.as_usize().context("expected int")?,
            "cloud_batch_window_ms" => {
                self.cloud_batch_window_ms = v.as_f64().context("expected number")?
            }
            "cloud_max_batch" => {
                self.cloud_max_batch = v.as_usize().context("expected int")?
            }
            "fleet" => str_field!(fleet),
            "router" => str_field!(router),
            "slo" => str_field!(slo),
            "admission" => str_field!(admission),
            "reroute" => self.reroute = v.as_bool().context("expected bool")?,
            "rebalance_window_ms" => {
                self.rebalance_window_ms = v.as_f64().context("expected number")?
            }
            "migrate_threshold_ms" => {
                self.migrate_threshold_ms = v.as_f64().context("expected number")?
            }
            "migrate_penalty_ms" => {
                self.migrate_penalty_ms = v.as_f64().context("expected number")?
            }
            "arrivals" => str_field!(arrivals),
            "queue_aware" => self.queue_aware = v.as_bool().context("expected bool")?,
            "shards" => self.shards = v.as_usize().context("expected int")?,
            "stream_telemetry" => {
                self.stream_telemetry = v.as_bool().context("expected bool")?
            }
            "scheduler" => str_field!(scheduler),
            "learner" => str_field!(learner),
            "learner_publish_every" => {
                self.learner_publish_every = v.as_usize().context("expected int")?
            }
            "chaos" => str_field!(chaos),
            "retry_max" => self.retry_max = v.as_usize().context("expected int")?,
            "retry_backoff_ms" => {
                self.retry_backoff_ms = v.as_f64().context("expected number")?
            }
            "threads" => self.threads = v.as_usize().context("expected int")?,
            "seed" => self.seed = v.as_f64().context("expected number")? as u64,
            other => bail!("unknown config key `{other}`"),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.eta) {
            bail!("eta must be in [0,1], got {}", self.eta);
        }
        if !(0.0..=1.0).contains(&self.lambda) {
            bail!("lambda must be in [0,1], got {}", self.lambda);
        }
        if self.freq_levels < 2 {
            bail!("freq_levels must be >= 2");
        }
        if self.xi_levels < 2 {
            bail!("xi_levels must be >= 2");
        }
        let policies = [
            "dvfo",
            "drldo",
            "appealnet",
            "cloud_only",
            "edge_only",
            "oracle",
        ];
        if !policies.contains(&self.policy.as_str()) {
            bail!("unknown policy `{}` (want one of {policies:?})", self.policy);
        }
        if self.streams == 0 {
            bail!("streams must be >= 1");
        }
        if self.shards == 0 {
            bail!("shards must be >= 1");
        }
        if self.threads == 0 {
            bail!("threads must be >= 1");
        }
        if !(self.batch_window_ms.is_finite() && self.batch_window_ms >= 0.0) {
            bail!(
                "batch_window_ms must be a finite non-negative number, got {}",
                self.batch_window_ms
            );
        }
        if self.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        if self.cloud_slots == 0 {
            bail!("cloud_slots must be >= 1");
        }
        if !(self.cloud_batch_window_ms.is_finite() && self.cloud_batch_window_ms >= 0.0) {
            bail!(
                "cloud_batch_window_ms must be a finite non-negative number, got {}",
                self.cloud_batch_window_ms
            );
        }
        if self.cloud_max_batch == 0 {
            bail!("cloud_max_batch must be >= 1");
        }
        if !(self.rebalance_window_ms.is_finite() && self.rebalance_window_ms >= 0.0) {
            bail!(
                "rebalance_window_ms must be a finite non-negative number, got {}",
                self.rebalance_window_ms
            );
        }
        // the threshold may be +inf ("never migrate"), but not NaN/negative
        if self.migrate_threshold_ms.is_nan() || self.migrate_threshold_ms < 0.0 {
            bail!(
                "migrate_threshold_ms must be a non-negative number (inf allowed), got {}",
                self.migrate_threshold_ms
            );
        }
        if !(self.migrate_penalty_ms.is_finite() && self.migrate_penalty_ms >= 0.0) {
            bail!(
                "migrate_penalty_ms must be a finite non-negative number, got {}",
                self.migrate_penalty_ms
            );
        }
        crate::coordinator::SchedKind::parse(&self.scheduler).context("scheduler spec")?;
        crate::dqn::LearnerMode::parse(&self.learner).context("learner spec")?;
        if self.learner_publish_every == 0 {
            bail!("learner_publish_every must be >= 1");
        }
        crate::workload::Arrivals::parse(&self.arrivals).context("arrivals spec")?;
        crate::workload::SloClass::parse(&self.slo).context("slo spec")?;
        crate::coordinator::fleet::Router::parse(&self.router).context("router spec")?;
        crate::coordinator::fleet::Admission::parse(&self.admission)
            .context("admission spec")?;
        crate::coordinator::fleet::parse_fleet_spec(&self.fleet, &self.device)
            .context("fleet spec")?;
        crate::net::Bandwidth::parse(&self.bandwidth, self.seed)
            .context("bandwidth spec")?;
        let schedule =
            crate::coordinator::chaos::FaultSchedule::parse(&self.chaos).context("chaos spec")?;
        let fleet_size =
            crate::coordinator::fleet::parse_fleet_spec(&self.fleet, &self.device)?.len();
        schedule.validate_for(fleet_size).context("chaos spec")?;
        if !(self.retry_backoff_ms.is_finite() && self.retry_backoff_ms >= 0.0) {
            bail!(
                "retry_backoff_ms must be a finite non-negative number, got {}",
                self.retry_backoff_ms
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn from_json_overrides() {
        let j = Json::parse(
            r#"{"device": "jetson-nano", "eta": 0.3, "requests": 10,
                "concurrent": false}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.device, "jetson-nano");
        assert_eq!(c.eta, 0.3);
        assert_eq!(c.requests, 10);
        assert!(!c.concurrent);
        // untouched fields keep defaults
        assert_eq!(c.lambda, 0.5);
    }

    #[test]
    fn rejects_bad_values() {
        let mut c = Config::default();
        assert!(c.set("eta", "1.5").is_err());
        assert!(c.set("policy", "nonexistent").is_err());
        assert!(c.set("bandwidth", "bogus:x").is_err());
        assert!(c.set("streams", "0").is_err());
        assert!(c.set("batch_window_ms", "-1").is_err());
        assert!(c.set("arrivals", "warp:9").is_err());
        assert!(Config::from_json(&Json::parse(r#"{"nope": 1}"#).unwrap()).is_err());
    }

    #[test]
    fn multistream_fields_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.streams, 1);
        assert_eq!(c.batch_window_ms, 0.0);
        assert!(!c.queue_aware);
        c.set("streams", "64").unwrap();
        c.set("batch_window_ms", "5.5").unwrap();
        c.set("arrivals", "mmpp:5,50,2,0.5").unwrap();
        c.set("queue_aware", "true").unwrap();
        assert_eq!(c.streams, 64);
        assert_eq!(c.batch_window_ms, 5.5);
        assert_eq!(c.arrivals, "mmpp:5,50,2,0.5");
        assert!(c.queue_aware);
        let j = Json::parse(
            r#"{"streams": 8, "batch_window_ms": 2.0, "arrivals": "poisson:20",
                "queue_aware": true}"#,
        )
        .unwrap();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.streams, 8);
        assert_eq!(c2.arrivals, "poisson:20");
    }

    #[test]
    fn fleet_fields_parse_and_validate() {
        let mut c = Config::default();
        assert!(c.fleet.is_empty());
        assert_eq!(c.router, "round_robin");
        assert_eq!(c.slo, "none");
        assert_eq!(c.admission, "off");
        assert_eq!(c.max_batch, 16);
        assert_eq!(c.cloud_slots, 4);
        assert_eq!(c.cloud_batch_window_ms, 0.0);
        assert_eq!(c.cloud_max_batch, 16);
        c.set("fleet", "xavier-nx,jetson-nano*2").unwrap();
        c.set("router", "least_backlog").unwrap();
        c.set("slo", "250,1").unwrap();
        c.set("admission", "shed").unwrap();
        c.set("max_batch", "8").unwrap();
        c.set("cloud_slots", "2").unwrap();
        c.set("cloud_batch_window_ms", "5.5").unwrap();
        c.set("cloud_max_batch", "4").unwrap();
        assert_eq!(c.fleet, "xavier-nx,jetson-nano*2");
        assert_eq!(c.max_batch, 8);
        assert_eq!(c.cloud_slots, 2);
        assert_eq!(c.cloud_batch_window_ms, 5.5);
        assert_eq!(c.cloud_max_batch, 4);
        // bad values are rejected
        let mut c = Config::default();
        assert!(c.set("fleet", "warp-drive").is_err());
        assert!(c.set("fleet", "xavier-nx*0").is_err());
        assert!(c.set("router", "psychic").is_err());
        assert!(c.set("slo", "-5").is_err());
        assert!(c.set("admission", "maybe").is_err());
        assert!(c.set("max_batch", "0").is_err());
        assert!(c.set("cloud_slots", "0").is_err());
        assert!(c.set("cloud_batch_window_ms", "-1").is_err());
        assert!(c.set("cloud_batch_window_ms", "NaN").is_err());
        assert!(c.set("cloud_max_batch", "0").is_err());
        let j = Json::parse(
            r#"{"fleet": "jetson-tx2*2", "router": "shortest_queue",
                "slo": "100", "admission": "downgrade", "cloud_slots": 3,
                "cloud_batch_window_ms": 2.0, "cloud_max_batch": 8}"#,
        )
        .unwrap();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.fleet, "jetson-tx2*2");
        assert_eq!(c2.admission, "downgrade");
        assert_eq!(c2.cloud_slots, 3);
        assert_eq!(c2.cloud_batch_window_ms, 2.0);
        assert_eq!(c2.cloud_max_batch, 8);
    }

    #[test]
    fn rebalance_fields_parse_and_validate() {
        let mut c = Config::default();
        assert!(!c.reroute);
        assert_eq!(c.rebalance_window_ms, 0.0);
        assert!(c.migrate_threshold_ms.is_infinite());
        assert_eq!(c.migrate_penalty_ms, 5.0);
        c.set("reroute", "true").unwrap();
        c.set("rebalance_window_ms", "10").unwrap();
        c.set("migrate_threshold_ms", "40").unwrap();
        c.set("migrate_penalty_ms", "2.5").unwrap();
        assert!(c.reroute);
        assert_eq!(c.rebalance_window_ms, 10.0);
        assert_eq!(c.migrate_threshold_ms, 40.0);
        assert_eq!(c.migrate_penalty_ms, 2.5);
        // "inf" disables migration at any tick
        c.set("migrate_threshold_ms", "inf").unwrap();
        assert!(c.migrate_threshold_ms.is_infinite());
        // bad values are rejected
        let mut c = Config::default();
        assert!(c.set("rebalance_window_ms", "-1").is_err());
        assert!(c.set("rebalance_window_ms", "inf").is_err());
        assert!(c.set("migrate_threshold_ms", "-5").is_err());
        assert!(c.set("migrate_threshold_ms", "NaN").is_err());
        assert!(c.set("migrate_penalty_ms", "-1").is_err());
        assert!(c.set("migrate_penalty_ms", "inf").is_err());
        assert!(c.set("reroute", "maybe").is_err());
        let j = Json::parse(
            r#"{"reroute": true, "rebalance_window_ms": 8.0,
                "migrate_penalty_ms": 1.0}"#,
        )
        .unwrap();
        let c2 = Config::from_json(&j).unwrap();
        assert!(c2.reroute);
        assert_eq!(c2.rebalance_window_ms, 8.0);
        assert_eq!(c2.migrate_penalty_ms, 1.0);
    }

    #[test]
    fn scaleout_fields_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.shards, 1);
        assert!(!c.stream_telemetry);
        c.set("shards", "4").unwrap();
        c.set("stream_telemetry", "true").unwrap();
        assert_eq!(c.shards, 4);
        assert!(c.stream_telemetry);
        assert!(c.set("shards", "0").is_err());
        assert!(c.set("stream_telemetry", "maybe").is_err());
        let j = Json::parse(r#"{"shards": 2, "stream_telemetry": true}"#).unwrap();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.shards, 2);
        assert!(c2.stream_telemetry);
    }

    #[test]
    fn scheduler_field_parses_and_validates() {
        let mut c = Config::default();
        assert_eq!(c.scheduler, "calendar");
        c.set("scheduler", "heap").unwrap();
        assert_eq!(c.scheduler, "heap");
        c.set("scheduler", "calendar").unwrap();
        assert_eq!(c.scheduler, "calendar");
        assert!(c.set("scheduler", "fibonacci").is_err());
        let j = Json::parse(r#"{"scheduler": "heap"}"#).unwrap();
        assert_eq!(Config::from_json(&j).unwrap().scheduler, "heap");
    }

    #[test]
    fn learner_fields_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.learner, "inline");
        assert_eq!(c.learner_publish_every, 32);
        c.set("learner", "bg").unwrap();
        c.set("learner_publish_every", "16").unwrap();
        assert_eq!(c.learner, "bg");
        assert_eq!(c.learner_publish_every, 16);
        c.set("learner", "background").unwrap();
        c.set("learner", "inline").unwrap();
        assert!(c.set("learner", "turbo").is_err());
        assert!(c.set("learner_publish_every", "0").is_err());
        let j = Json::parse(r#"{"learner": "bg", "learner_publish_every": 8}"#).unwrap();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.learner, "bg");
        assert_eq!(c2.learner_publish_every, 8);
    }

    #[test]
    fn chaos_fields_parse_and_validate() {
        let mut c = Config::default();
        assert!(c.chaos.is_empty());
        assert_eq!(c.retry_max, 3);
        assert_eq!(c.retry_backoff_ms, 10.0);
        c.set("fleet", "xavier-nx,jetson-nano*2").unwrap();
        c.set("chaos", "down:1@200+500; cloud@100+50; bw:0@50+100*0.25")
            .unwrap();
        c.set("retry_max", "5").unwrap();
        c.set("retry_backoff_ms", "2.5").unwrap();
        assert_eq!(c.retry_max, 5);
        assert_eq!(c.retry_backoff_ms, 2.5);
        // bad values are rejected
        let mut c = Config::default();
        assert!(c.set("chaos", "down:0@200").is_err(), "missing duration");
        assert!(c.set("chaos", "warp:0@1+1").is_err(), "unknown fault kind");
        assert!(
            c.set("chaos", "down:3@200+500").is_err(),
            "device outside the (1-device default) fleet"
        );
        assert!(c.set("retry_backoff_ms", "-1").is_err());
        assert!(c.set("retry_backoff_ms", "NaN").is_err());
        let j = Json::parse(
            r#"{"fleet": "jetson-nano*2", "chaos": "down:1@100+200",
                "retry_max": 2, "retry_backoff_ms": 5.0}"#,
        )
        .unwrap();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.chaos, "down:1@100+200");
        assert_eq!(c2.retry_max, 2);
        assert_eq!(c2.retry_backoff_ms, 5.0);
    }

    #[test]
    fn set_parses_types() {
        let mut c = Config::default();
        c.set("eta", "0.7").unwrap();
        c.set("requests", "42").unwrap();
        c.set("concurrent", "false").unwrap();
        assert_eq!(c.eta, 0.7);
        assert_eq!(c.requests, 42);
        assert!(!c.concurrent);
    }

    #[test]
    fn threads_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.threads, 1);
        c.set("threads", "4").unwrap();
        assert_eq!(c.threads, 4);
        assert!(c.set("threads", "0").is_err());
        let j = Json::parse(r#"{"threads": 8}"#).unwrap();
        assert_eq!(Config::from_json(&j).unwrap().threads, 8);
    }
}
