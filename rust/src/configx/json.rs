//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) — enough to read `artifacts/manifest.json`,
//! experiment configs, and DQN checkpoints, and to write reports.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ------------------------------------------------------- accessors ---
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `j.get("a").get("b")` style via chained index.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        self.as_arr().and_then(|a| a.get(i))
    }

    /// Convenience: required field with context-bearing error.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field `{key}`"))
    }

    pub fn f64_list(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_f64).collect())
    }

    // --------------------------------------------------------- parsing ---
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ------------------------------------------------------- serialize ---
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    Json::Str(k.clone()).write(out, indent + 1, pretty);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = (start + len).min(self.b.len());
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

/// Builder helpers for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn nums(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("b").unwrap().idx(2).unwrap().as_str(), Some("x\ny"));
        assert_eq!(j.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        // serialize → reparse → equal
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn parses_unicode_escapes() {
        let j = Json::parse(r#""éA""#).unwrap();
        assert_eq!(j.as_str(), Some("éA"));
    }

    #[test]
    fn parses_utf8_passthrough() {
        let j = Json::parse(r#"{"k": "λ·η—µ"}"#).unwrap();
        assert_eq!(j.get("k").unwrap().as_str(), Some("λ·η—µ"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-0.5", -0.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn pretty_is_reparseable() {
        let j = obj(vec![
            ("name", s("dvfo")),
            ("xs", nums(&[1.0, 2.5, -3.0])),
            ("nested", obj(vec![("ok", Json::Bool(true))])),
        ]);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert!(m.get("artifacts").is_some());
            assert!(m.get("dqn").unwrap().get("state_dim").unwrap().as_f64().unwrap() > 0.0);
        }
    }
}
