//! Edge↔cloud network simulator.
//!
//! Substitutes the paper's `trickle`-shaped WiFi link: static bandwidth,
//! Markov-modulated stochastic bandwidth (bursty WiFi), and trace-driven
//! playback. Transmission latency is Eq. (8) `m/B`; offload energy is
//! Eq. (12) `m·p_radio/B`.

use crate::util::{clampf, Pcg32, RingBuf};
use anyhow::{bail, Context, Result};

/// Bandwidth process observed by the coordinator (Mbps).
#[derive(Clone, Debug)]
pub enum Bandwidth {
    /// Constant link rate.
    Static { mbps: f64 },
    /// Markov-modulated: mean-reverting random walk between lo and hi,
    /// resampled every `step_s` of simulated time.
    Markov {
        lo: f64,
        hi: f64,
        current: f64,
        step_s: f64,
        elapsed: f64,
        rng: Pcg32,
    },
    /// Trace playback (cyclic), one sample per `step_s`.
    Trace {
        samples: Vec<f64>,
        step_s: f64,
        elapsed: f64,
    },
}

impl Bandwidth {
    /// Parse a spec string: `static:<mbps>` | `markov:<lo>,<hi>` |
    /// `trace:<path>` (one Mbps value per line).
    pub fn parse(spec: &str, seed: u64) -> Result<Bandwidth> {
        let (kind, rest) = spec
            .split_once(':')
            .context("bandwidth spec wants `kind:args`")?;
        match kind {
            "static" => {
                let mbps: f64 = rest.parse().context("static:<mbps>")?;
                // `!(x > 0)` so NaN is rejected too; infinite rates would
                // make every transmission free and hide payload bugs
                if !(mbps > 0.0 && mbps.is_finite()) {
                    bail!("bandwidth must be positive and finite");
                }
                Ok(Bandwidth::Static { mbps })
            }
            "markov" => {
                let (lo, hi) = rest
                    .split_once(',')
                    .context("markov:<lo>,<hi>")?;
                let lo: f64 = lo.parse()?;
                let hi: f64 = hi.parse()?;
                if !(lo > 0.0 && hi > lo && hi.is_finite()) {
                    bail!("markov wants 0 < lo < hi, both finite");
                }
                Ok(Bandwidth::Markov {
                    lo,
                    hi,
                    current: (lo + hi) / 2.0,
                    step_s: 0.25,
                    elapsed: 0.0,
                    rng: Pcg32::seeded(seed ^ 0xBA2D),
                })
            }
            "trace" => {
                let text = std::fs::read_to_string(rest)
                    .with_context(|| format!("reading trace {rest}"))?;
                let samples: Vec<f64> = text
                    .lines()
                    .filter(|l| !l.trim().is_empty())
                    .map(|l| l.trim().parse::<f64>())
                    .collect::<Result<_, _>>()
                    .context("trace lines must be Mbps floats")?;
                if samples.is_empty() {
                    bail!("empty bandwidth trace");
                }
                // a NaN, zero, or infinite sample would surface later as
                // a NaN/∞/negative remaining-transfer time mid-run —
                // reject at parse, where the bad line is identifiable
                for (i, &s) in samples.iter().enumerate() {
                    if !(s > 0.0 && s.is_finite()) {
                        bail!(
                            "trace sample #{i} must be positive and finite, got {s}"
                        );
                    }
                }
                Ok(Bandwidth::Trace {
                    samples,
                    step_s: 0.25,
                    elapsed: 0.0,
                })
            }
            other => bail!("unknown bandwidth kind `{other}`"),
        }
    }

    /// Current rate in Mbps.
    pub fn mbps(&self) -> f64 {
        match self {
            Bandwidth::Static { mbps } => *mbps,
            Bandwidth::Markov { current, .. } => *current,
            Bandwidth::Trace {
                samples,
                step_s,
                elapsed,
            } => {
                let idx = (elapsed / step_s) as usize % samples.len();
                samples[idx]
            }
        }
    }

    /// Advance simulated time.
    pub fn advance(&mut self, dt_s: f64) {
        match self {
            Bandwidth::Static { .. } => {}
            Bandwidth::Markov {
                lo,
                hi,
                current,
                step_s,
                elapsed,
                rng,
            } => {
                *elapsed += dt_s;
                let steps = (*elapsed / *step_s) as usize;
                *elapsed -= steps as f64 * *step_s;
                let mid = (*lo + *hi) / 2.0;
                let span = *hi - *lo;
                for _ in 0..steps.min(64) {
                    // mean-reverting with gaussian perturbation
                    let pull = 0.25 * (mid - *current);
                    let noise = 0.18 * span * rng.normal();
                    *current = clampf(*current + pull + noise, *lo, *hi);
                }
            }
            Bandwidth::Trace { elapsed, .. } => {
                *elapsed += dt_s;
            }
        }
    }
}

/// A point-to-point link with the bandwidth process and a base RTT.
#[derive(Clone, Debug)]
pub struct Link {
    pub bandwidth: Bandwidth,
    /// one-way propagation + protocol latency (s)
    pub base_latency_s: f64,
    history: RingBuf<f64>,
}

impl Link {
    pub fn new(bandwidth: Bandwidth) -> Self {
        Self {
            bandwidth,
            base_latency_s: 0.002,
            history: RingBuf::new(256),
        }
    }

    pub fn mbps(&self) -> f64 {
        self.bandwidth.mbps()
    }

    /// Transmission time for a payload (Eq. 8) + base latency.
    pub fn tx_time_s(&self, payload_bytes: f64) -> f64 {
        if payload_bytes <= 0.0 {
            return 0.0;
        }
        let bits = payload_bytes * 8.0;
        self.base_latency_s + bits / (self.mbps() * 1e6)
    }

    /// Radio energy to push the payload (Eq. 12): tx_time × p_radio.
    pub fn tx_energy_j(&self, payload_bytes: f64, radio_w: f64) -> f64 {
        self.tx_time_s(payload_bytes) * radio_w
    }

    /// Advance time and record a bandwidth observation.
    pub fn advance(&mut self, dt_s: f64) {
        self.bandwidth.advance(dt_s);
        self.history.push(self.bandwidth.mbps());
    }

    /// Smoothed bandwidth estimate the DRL state observes (the agent sees
    /// measurements, not the hidden true process).
    pub fn observed_mbps(&self) -> f64 {
        if self.history.is_empty() {
            return self.mbps();
        }
        let (n, sum) = self
            .history
            .iter()
            .fold((0usize, 0.0), |(n, s), &x| (n + 1, s + x));
        sum / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_variants() {
        assert!(matches!(
            Bandwidth::parse("static:5", 0).unwrap(),
            Bandwidth::Static { mbps } if mbps == 5.0
        ));
        assert!(matches!(
            Bandwidth::parse("markov:2,8", 0).unwrap(),
            Bandwidth::Markov { .. }
        ));
        assert!(Bandwidth::parse("static:-1", 0).is_err());
        assert!(Bandwidth::parse("markov:8,2", 0).is_err());
        assert!(Bandwidth::parse("nope:1", 0).is_err());
        assert!(Bandwidth::parse("static", 0).is_err());
    }

    #[test]
    fn tx_time_matches_eq8() {
        let link = Link::new(Bandwidth::Static { mbps: 8.0 });
        // 1 MB at 8 Mbps = 1 s + base latency
        let t = link.tx_time_s(1_000_000.0);
        assert!((t - (1.0 + link.base_latency_s)).abs() < 1e-9);
        assert_eq!(link.tx_time_s(0.0), 0.0);
    }

    #[test]
    fn tx_energy_matches_eq12() {
        let link = Link::new(Bandwidth::Static { mbps: 4.0 });
        let e = link.tx_energy_j(500_000.0, 1.3);
        let t = link.tx_time_s(500_000.0);
        assert!((e - t * 1.3).abs() < 1e-12);
    }

    #[test]
    fn markov_stays_in_bounds_and_moves() {
        let mut bw = Bandwidth::parse("markov:2,8", 7).unwrap();
        let mut seen = Vec::new();
        for _ in 0..200 {
            bw.advance(0.25);
            let x = bw.mbps();
            assert!((2.0..=8.0).contains(&x));
            seen.push(x);
        }
        let distinct = seen
            .windows(2)
            .filter(|w| (w[0] - w[1]).abs() > 1e-9)
            .count();
        assert!(distinct > 50, "bandwidth should fluctuate, got {distinct}");
    }

    #[test]
    fn markov_is_seed_deterministic() {
        let mut a = Bandwidth::parse("markov:2,8", 42).unwrap();
        let mut b = Bandwidth::parse("markov:2,8", 42).unwrap();
        for _ in 0..50 {
            a.advance(0.3);
            b.advance(0.3);
            assert_eq!(a.mbps(), b.mbps());
        }
    }

    #[test]
    fn observed_is_smoothed() {
        let mut link = Link::new(Bandwidth::parse("markov:2,8", 3).unwrap());
        for _ in 0..100 {
            link.advance(0.25);
        }
        let obs = link.observed_mbps();
        assert!((2.0..=8.0).contains(&obs));
    }

    #[test]
    fn parse_rejects_nonfinite_rates() {
        for bad in [
            "static:NaN",
            "static:inf",
            "static:0",
            "markov:NaN,8",
            "markov:2,NaN",
            "markov:2,inf",
        ] {
            assert!(Bandwidth::parse(bad, 0).is_err(), "`{bad}` should not parse");
        }
        let dir = std::env::temp_dir();
        for (name, body) in [
            ("dvfo_bw_trace_nan.txt", "1.0\nNaN\n"),
            ("dvfo_bw_trace_zero.txt", "1.0\n0.0\n"),
            ("dvfo_bw_trace_neg.txt", "1.0\n-2.0\n"),
            ("dvfo_bw_trace_inf.txt", "1.0\ninf\n"),
        ] {
            let p = dir.join(name);
            std::fs::write(&p, body).unwrap();
            let spec = format!("trace:{}", p.display());
            assert!(Bandwidth::parse(&spec, 0).is_err(), "{name}");
        }
    }

    #[test]
    fn mid_transfer_bandwidth_swings_keep_remaining_time_sane() {
        // regression: a trace swinging over four orders of magnitude
        // mid-transfer must never produce a negative, NaN, or infinite
        // remaining-transfer time estimate
        let p = std::env::temp_dir().join("dvfo_bw_trace_swing.txt");
        std::fs::write(&p, "0.1\n1000.0\n0.5\n800.0\n").unwrap();
        let mut link =
            Link::new(Bandwidth::parse(&format!("trace:{}", p.display()), 0).unwrap());
        let total_bytes = 2_000_000.0;
        let mut sent = 0.0;
        let mut steps = 0;
        while sent < total_bytes {
            let t = link.tx_time_s(total_bytes - sent);
            assert!(
                t.is_finite() && t >= 0.0,
                "remaining-transfer time {t} after {sent} bytes"
            );
            // drain one 0.25 s window at the current rate, then let the
            // trace move on to the next (wildly different) sample
            sent += link.mbps() * 1e6 / 8.0 * 0.25;
            link.advance(0.25);
            steps += 1;
            assert!(steps < 10_000, "transfer must make progress");
        }
        assert_eq!(link.tx_time_s(0.0), 0.0);
    }

    #[test]
    fn trace_cycles() {
        let dir = std::env::temp_dir().join("dvfo_trace_test.txt");
        std::fs::write(&dir, "1.0\n2.0\n3.0\n").unwrap();
        let mut bw = Bandwidth::parse(&format!("trace:{}", dir.display()), 0).unwrap();
        assert_eq!(bw.mbps(), 1.0);
        bw.advance(0.25);
        assert_eq!(bw.mbps(), 2.0);
        bw.advance(0.5);
        assert_eq!(bw.mbps(), 1.0); // wrapped
    }
}
