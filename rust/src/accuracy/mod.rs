//! Analytic accuracy model for the eight paper DNNs.
//!
//! The real small model (artifacts/) has its accuracy *measured*; the big
//! models cannot be trained here, so their accuracy under a given
//! (split, compression, fusion) configuration comes from this mechanism
//! model, calibrated against the paper's Table 4 / Tables 5-6 bands:
//!
//!   loss = fusion_term + quantization_term + imbalance_term
//!          + misallocation_term (+ discriminator_term for AppealNet)
//!
//! * fusion_term — weighted summation preserves logit alignment (≈0.15
//!   pt); FC/conv fusion layers break it (Table 4: 3.9-4.5 / 6.3-8.9 pt).
//! * quantization_term — int8 noise on the *offloaded importance mass*.
//! * imbalance_term — the λ bowl of Fig. 12: under-weighting local
//!   primary features (λ small) or starving the remote path (λ large).
//! * misallocation_term — offloading without importance guidance (DRLDO
//!   offloads arbitrary data) hurts in proportion to mass misallocated.

use crate::offload::{quant_rel_error, Compression};
use crate::scam::SplitPlan;

/// How the two partial results are merged (paper §5.3 / Table 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fusion {
    /// point-to-point weighted summation (DVFO)
    WeightedSum,
    /// extra fully-connected fusion layer
    FcLayer,
    /// extra convolutional fusion layer
    ConvLayer,
    /// no fusion: one side produces the whole result (Edge-/Cloud-only,
    /// AppealNet's binary offload)
    Single,
}

impl Fusion {
    fn base_loss_pts(&self) -> f64 {
        match self {
            Fusion::WeightedSum => 0.15,
            Fusion::FcLayer => 3.6,
            Fusion::ConvLayer => 6.1,
            Fusion::Single => 0.0,
        }
    }
}

/// Accuracy-relevant configuration of one serving decision.
#[derive(Clone, Debug)]
pub struct AccuracyInputs {
    /// base accuracy of the uncompressed single-device model (%)
    pub base_acc: f64,
    /// the channel split actually executed
    pub local_mass: f64,
    pub xi: f64,
    /// was the split importance-guided (SCAM) or arbitrary?
    pub importance_guided: bool,
    pub compression: Compression,
    pub fusion: Fusion,
    /// summation weight λ (ignored for non-WeightedSum fusion)
    pub lambda: f64,
}

impl AccuracyInputs {
    pub fn from_plan(base_acc: f64, plan: &SplitPlan) -> Self {
        Self {
            base_acc,
            local_mass: plan.local_mass,
            xi: plan.xi,
            importance_guided: true,
            compression: Compression::Int8,
            fusion: Fusion::WeightedSum,
            lambda: 0.5,
        }
    }
}

/// Accuracy loss in percentage points (≥ 0).
pub fn accuracy_loss_pts(inp: &AccuracyInputs) -> f64 {
    let offload_mass = (1.0 - inp.local_mass).clamp(0.0, 1.0);

    // Everything on one side, no compression, no fusion → no loss.
    if inp.xi <= 0.0 && inp.fusion == Fusion::Single {
        return 0.0;
    }

    let fusion = inp.fusion.base_loss_pts();

    // int8 noise applied to whatever crossed the wire, weighted by how
    // much of the decision-relevant mass it carries.
    let quant = quant_rel_error(inp.compression) * 100.0 * (0.4 + 2.2 * offload_mass);

    // λ bowl (Fig. 12): optimum shifts toward the side holding more mass.
    let imbalance = if inp.fusion == Fusion::WeightedSum {
        let lam_star = 0.35 + 0.3 * inp.local_mass;
        let d = (inp.lambda - lam_star).abs();
        // gentle inside ±0.2, steep outside (paper: λ≤0.2 or ≥0.8 is bad)
        9.0 * (d - 0.15).max(0.0).powi(2) + 0.8 * d * d
    } else {
        0.0
    };

    // offloading *important* features blindly loses information that the
    // shallow local head cannot recover.
    let misalloc = if inp.importance_guided {
        0.25 * offload_mass * inp.xi
    } else {
        // arbitrary split: expected offloaded mass ≈ ξ, and high-value
        // channels leave with probability ξ
        2.4 * inp.xi
    };

    (fusion + quant + imbalance + misalloc).max(0.0)
}

/// Final accuracy (%) for a decision.
pub fn accuracy_pct(inp: &AccuracyInputs) -> f64 {
    (inp.base_acc - accuracy_loss_pts(inp)).clamp(0.0, 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dvfo_like(local_mass: f64, xi: f64, lambda: f64) -> AccuracyInputs {
        AccuracyInputs {
            base_acc: 91.84,
            local_mass,
            xi,
            importance_guided: true,
            compression: Compression::Int8,
            fusion: Fusion::WeightedSum,
            lambda,
        }
    }

    #[test]
    fn dvfo_loss_under_one_point() {
        // Table 4: DVFO loses 0.68 pt (CIFAR) with λ=0.5. An
        // importance-guided split keeps ~85% of mass local at ξ=0.6.
        let loss = accuracy_loss_pts(&dvfo_like(0.85, 0.6, 0.5));
        assert!((0.1..1.0).contains(&loss), "loss {loss}");
    }

    #[test]
    fn table4_fusion_ordering() {
        // weighted sum ≪ FC < conv (Table 4: 0.68 / 4.45 / 8.91).
        let ws = accuracy_loss_pts(&dvfo_like(0.85, 0.6, 0.5));
        let fc = accuracy_loss_pts(&AccuracyInputs {
            fusion: Fusion::FcLayer,
            ..dvfo_like(0.85, 0.6, 0.5)
        });
        let conv = accuracy_loss_pts(&AccuracyInputs {
            fusion: Fusion::ConvLayer,
            ..dvfo_like(0.85, 0.6, 0.5)
        });
        assert!(ws < 1.0 && fc > 3.0 && conv > fc);
        assert!(
            fc / ws > 4.0 && conv / ws > 7.0,
            "ratios {:.1} {:.1} vs paper 6.7x/12.3x",
            fc / ws,
            conv / ws
        );
    }

    #[test]
    fn lambda_bowl_matches_fig12() {
        // extremes are bad, the 0.4-0.6 plateau is good
        let mid = accuracy_loss_pts(&dvfo_like(0.8, 0.5, 0.5));
        let low = accuracy_loss_pts(&dvfo_like(0.8, 0.5, 0.05));
        let high = accuracy_loss_pts(&dvfo_like(0.8, 0.5, 0.98));
        assert!(low > mid + 0.5, "low {low} mid {mid}");
        assert!(high > mid + 0.2, "high {high} mid {mid}");
    }

    #[test]
    fn unguided_split_is_worse() {
        let guided = accuracy_loss_pts(&dvfo_like(0.6, 0.5, 0.5));
        let blind = accuracy_loss_pts(&AccuracyInputs {
            importance_guided: false,
            ..dvfo_like(0.6, 0.5, 0.5)
        });
        assert!(blind > guided + 0.5, "blind {blind} guided {guided}");
    }

    #[test]
    fn edge_only_lossless() {
        let inp = AccuracyInputs {
            base_acc: 91.84,
            local_mass: 1.0,
            xi: 0.0,
            importance_guided: true,
            compression: Compression::None,
            fusion: Fusion::Single,
            lambda: 0.5,
        };
        assert_eq!(accuracy_loss_pts(&inp), 0.0);
        assert_eq!(accuracy_pct(&inp), 91.84);
    }

    #[test]
    fn cloud_only_compressed_loses_points() {
        // Fig. 9: binary offload of compressed whole features costs
        // multiple points.
        let inp = AccuracyInputs {
            base_acc: 91.84,
            local_mass: 0.0,
            xi: 1.0,
            importance_guided: false,
            compression: Compression::Int8,
            fusion: Fusion::Single,
            lambda: 0.5,
        };
        let loss = accuracy_loss_pts(&inp);
        assert!((2.0..6.0).contains(&loss), "loss {loss}");
    }

    #[test]
    fn loss_monotone_in_offloaded_mass() {
        let a = accuracy_loss_pts(&dvfo_like(0.9, 0.5, 0.5));
        let b = accuracy_loss_pts(&dvfo_like(0.6, 0.5, 0.5));
        let c = accuracy_loss_pts(&dvfo_like(0.3, 0.5, 0.5));
        assert!(a < b && b < c, "{a} {b} {c}");
    }
}
