//! Serving policies: DVFO (the paper's system) and the four comparison
//! schemes of §6.2.3, behind one trait.

use crate::accuracy::Fusion;
use crate::coordinator::env::Decision;
use crate::dqn::{
    ActionSpace, BgLearner, DqnAgent, DqnConfig, LearnerMode, LearnerOpts, Transition,
};
use crate::offload::Compression;
use crate::util::Pcg32;

/// What a policy observes before deciding (paper §5.1 state space
/// S = {λ, η, x~p(a), B}, with the importance distribution summarized to
/// fixed-width features, plus the previous action for the concurrent
/// formulation).
///
/// The observation is strictly **per-device**: in fleet serving every
/// edge device owns its own policy instance, and the dispatcher
/// publishes that device's `LoadSignals` (queue depth + backlog) before
/// each decision — so the featurization stays 8-dim (10-dim with
/// `queue_aware`) no matter how many devices the fleet has.
#[derive(Clone, Debug)]
pub struct Obs {
    pub lambda: f64,
    pub eta: f64,
    pub bandwidth_mbps: f64,
    pub top_quarter_mass: f64,
    pub skewness: f64,
    pub entropy_norm: f64,
    /// operational intensity of the model, log-normalized
    pub intensity_norm: f64,
    pub prev_xi: f64,
    /// edge-queue depth, normalized (0 outside the discrete-event core)
    pub queue_depth_norm: f64,
    /// estimated edge backlog seconds, normalized (0 outside the
    /// discrete-event core)
    pub backlog_norm: f64,
}

impl Obs {
    /// Fixed 8-dim featurization written into a caller buffer — the
    /// deployment path reuses one buffer per policy so featurizing a
    /// decision allocates nothing.
    pub fn features_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend_from_slice(&[
            self.lambda as f32,
            self.eta as f32,
            (self.bandwidth_mbps / 10.0).min(2.0) as f32,
            self.top_quarter_mass as f32,
            (self.skewness / 4.0).clamp(-1.0, 1.0) as f32,
            self.entropy_norm as f32,
            self.intensity_norm as f32,
            self.prev_xi as f32,
        ]);
    }

    /// Fixed 8-dim featurization — must match python `DQN_STATE_DIM`.
    pub fn features(&self) -> Vec<f32> {
        let mut f = Vec::with_capacity(8);
        self.features_into(&mut f);
        f
    }

    /// Queue-aware 10-dim featurization into a caller buffer.
    pub fn features_ext_into(&self, out: &mut Vec<f32>) {
        self.features_into(out);
        out.push(self.queue_depth_norm.clamp(0.0, 2.0) as f32);
        out.push(self.backlog_norm.clamp(0.0, 2.0) as f32);
    }

    /// Queue-aware 10-dim featurization for multi-stream serving: the
    /// base 8 features plus edge queue depth and backlog, so the policy
    /// can trade frequency/offloading against load.
    pub fn features_ext(&self) -> Vec<f32> {
        let mut f = Vec::with_capacity(10);
        self.features_ext_into(&mut f);
        f
    }
}

/// Outcome summary handed back to learning policies.
#[derive(Clone, Copy, Debug)]
pub struct Feedback {
    /// reward r = −C (Eq. 14), pre-scaled by the caller
    pub reward: f64,
    /// fractional-discount exponent t_AS/H (Eq. 15); 1.0 when blocking
    pub gamma_pow: f64,
    pub done: bool,
}

pub trait Policy: Send {
    fn name(&self) -> &'static str;

    fn decide(&mut self, obs: &Obs) -> Decision;

    /// Learning hook (no-op for fixed policies).
    fn feedback(&mut self, _obs: &Obs, _decision: &Decision, _next_obs: &Obs, _fb: Feedback) {}

    /// Policy-inference latency (lands on the critical path only for
    /// blocking policies — thinking-while-moving overlaps it, §5.1).
    fn decision_latency_s(&self) -> f64 {
        2e-5
    }

    fn concurrent(&self) -> bool {
        false
    }

    /// Switch exploration on/off (training vs deployment).
    fn set_training(&mut self, _on: bool) {}
}

/// Quantize ξ from a ladder level.
fn xi_of_level(lvl: usize, xi_levels: usize) -> f64 {
    lvl as f64 / (xi_levels - 1) as f64
}

// ======================================================================
// DVFO — DQN over (f_C, f_G, f_M, ξ), SCAM-guided int8 offload, weighted
// summation fusion, thinking-while-moving policy inference.
// ======================================================================
pub struct DvfoPolicy {
    /// `None` only while a background learner owns the agent
    agent: Option<DqnAgent>,
    /// live background learner (training + `LearnerMode::Background`)
    learner: Option<BgLearner>,
    learner_opts: LearnerOpts,
    seed: u64,
    xi_levels: usize,
    training: bool,
    concurrent: bool,
    /// widen the DQN state with queue-depth/backlog features (10-dim)
    queue_aware: bool,
    /// measured DQN inference latency (updated by the coordinator)
    pub latency_s: f64,
    /// reusable featurization buffer: the deployed decide() path is
    /// allocation-free end-to-end (obs → features → Q → argmax)
    feat: Vec<f32>,
    /// reusable greedy-action buffer (same contract as `feat`)
    act: Vec<usize>,
}

impl DvfoPolicy {
    pub fn new(
        freq_levels: usize,
        xi_levels: usize,
        concurrent: bool,
        queue_aware: bool,
        seed: u64,
    ) -> Self {
        let space = ActionSpace::new(vec![freq_levels, freq_levels, freq_levels, xi_levels]);
        let cfg = DqnConfig {
            state_dim: if queue_aware { 10 } else { 8 },
            ..DqnConfig::default()
        };
        let agent = DqnAgent::new(cfg, space, seed);
        Self {
            agent: Some(agent),
            learner: None,
            learner_opts: LearnerOpts::default(),
            seed,
            xi_levels,
            training: true,
            concurrent,
            queue_aware,
            latency_s: 2e-5,
            feat: Vec::with_capacity(10),
            act: Vec::with_capacity(4),
        }
    }

    /// Builder: choose inline vs background gradient-step placement and
    /// the snapshot cadence. Default (`LearnerMode::Inline`) reproduces
    /// the historical blocking behavior exactly.
    pub fn with_learner(mut self, opts: LearnerOpts) -> Self {
        self.learner_opts = opts;
        self
    }

    /// The resident agent (panics while a background learner owns it —
    /// call `set_training(false)` first to drain and reclaim).
    pub fn agent(&self) -> &DqnAgent {
        self.agent
            .as_ref()
            .expect("agent is owned by the background learner; set_training(false) reclaims it")
    }

    pub fn agent_mut(&mut self) -> &mut DqnAgent {
        self.agent
            .as_mut()
            .expect("agent is owned by the background learner; set_training(false) reclaims it")
    }

    /// Move the agent onto the learner thread (idempotent).
    fn ensure_bg_learner(&mut self) {
        if self.learner.is_none() {
            let agent = self
                .agent
                .take()
                .expect("agent resident before learner spawn");
            self.learner = Some(BgLearner::spawn(agent, &self.learner_opts, self.seed));
        }
    }

    /// Drain the learner queue and take the trained agent back.
    fn reclaim_agent(&mut self) {
        if let Some(l) = self.learner.take() {
            self.agent = Some(l.finish());
        }
    }

    fn obs_features(&self, obs: &Obs) -> Vec<f32> {
        if self.queue_aware {
            obs.features_ext()
        } else {
            obs.features()
        }
    }

    fn to_decision(&self, a: &[usize]) -> Decision {
        Decision {
            cpu_lvl: a[0],
            gpu_lvl: a[1],
            mem_lvl: a[2],
            xi: xi_of_level(a[3], self.xi_levels),
            compression: Compression::Int8,
            fusion: if a[3] == 0 { Fusion::Single } else { Fusion::WeightedSum },
            importance_guided: true,
            phase_scaling: true,
        }
    }

    fn to_action(&self, d: &Decision) -> Vec<usize> {
        let xi_lvl = (d.xi * (self.xi_levels - 1) as f64).round() as usize;
        vec![d.cpu_lvl, d.gpu_lvl, d.mem_lvl, xi_lvl]
    }
}

impl Policy for DvfoPolicy {
    fn name(&self) -> &'static str {
        "dvfo"
    }

    fn decide(&mut self, obs: &Obs) -> Decision {
        if self.queue_aware {
            obs.features_ext_into(&mut self.feat);
        } else {
            obs.features_into(&mut self.feat);
        }
        if self.training {
            if self.learner_opts.mode == LearnerMode::Background {
                // concurrent path: ε-greedy off the learner's snapshot
                self.ensure_bg_learner();
                let a = self.learner.as_mut().expect("just ensured").act(&self.feat);
                return self.to_decision(&a);
            }
            // the exploration path owns its action (it may feed a
            // Transition later); allocation here is train-time only
            let a = self.agent_mut().act(&self.feat);
            self.to_decision(&a)
        } else {
            // deployment: features, Q-row, and argmax all land in
            // reusable buffers — no allocation per decision
            self.reclaim_agent();
            let DvfoPolicy { agent, feat, act, .. } = self;
            agent
                .as_mut()
                .expect("agent reclaimed for deployment")
                .greedy_into(feat, act);
            self.to_decision(&self.act)
        }
    }

    fn feedback(&mut self, obs: &Obs, decision: &Decision, next_obs: &Obs, fb: Feedback) {
        let t = Transition {
            state: self.obs_features(obs),
            action: self.to_action(decision),
            reward: fb.reward,
            next_state: self.obs_features(next_obs),
            done: fb.done,
            gamma_pow: fb.gamma_pow,
        };
        if self.training && self.learner_opts.mode == LearnerMode::Background {
            self.ensure_bg_learner();
            self.learner.as_mut().expect("just ensured").push(t);
            return;
        }
        let agent = self.agent_mut();
        agent.remember(t);
        if self.training {
            agent.learn();
        }
    }

    fn decision_latency_s(&self) -> f64 {
        self.latency_s
    }

    fn concurrent(&self) -> bool {
        self.concurrent
    }

    fn set_training(&mut self, on: bool) {
        self.training = on;
        if !on {
            // leaving training: drain the learner queue so deployment
            // sees the fully trained weights
            self.reclaim_agent();
        }
    }
}

// ======================================================================
// DRLDO (baseline, §6.2.3): DQN over CPU frequency + offload proportion
// only; GPU/memory stay at max; offloads *uncompressed* data with no
// importance guidance; conventional blocking policy inference.
// ======================================================================
pub struct DrldoPolicy {
    /// `None` only while a background learner owns the agent
    agent: Option<DqnAgent>,
    learner: Option<BgLearner>,
    learner_opts: LearnerOpts,
    seed: u64,
    freq_levels: usize,
    xi_levels: usize,
    training: bool,
}

impl DrldoPolicy {
    pub fn new(freq_levels: usize, xi_levels: usize, seed: u64) -> Self {
        let space = ActionSpace::new(vec![freq_levels, xi_levels]);
        let agent = DqnAgent::new(DqnConfig::default(), space, seed);
        Self {
            agent: Some(agent),
            learner: None,
            learner_opts: LearnerOpts::default(),
            seed,
            freq_levels,
            xi_levels,
            training: true,
        }
    }

    /// Builder: gradient-step placement (see `DvfoPolicy::with_learner`).
    pub fn with_learner(mut self, opts: LearnerOpts) -> Self {
        self.learner_opts = opts;
        self
    }

    /// The resident agent (panics while a background learner owns it).
    pub fn agent(&self) -> &DqnAgent {
        self.agent
            .as_ref()
            .expect("agent is owned by the background learner; set_training(false) reclaims it")
    }

    fn agent_mut(&mut self) -> &mut DqnAgent {
        self.agent
            .as_mut()
            .expect("agent is owned by the background learner; set_training(false) reclaims it")
    }

    fn ensure_bg_learner(&mut self) {
        if self.learner.is_none() {
            let agent = self
                .agent
                .take()
                .expect("agent resident before learner spawn");
            self.learner = Some(BgLearner::spawn(agent, &self.learner_opts, self.seed));
        }
    }

    fn reclaim_agent(&mut self) {
        if let Some(l) = self.learner.take() {
            self.agent = Some(l.finish());
        }
    }
}

impl Policy for DrldoPolicy {
    fn name(&self) -> &'static str {
        "drldo"
    }

    fn decide(&mut self, obs: &Obs) -> Decision {
        let s = obs.features();
        let a = if self.training {
            if self.learner_opts.mode == LearnerMode::Background {
                self.ensure_bg_learner();
                self.learner.as_mut().expect("just ensured").act(&s)
            } else {
                self.agent_mut().act(&s)
            }
        } else {
            self.reclaim_agent();
            self.agent_mut().greedy(&s)
        };
        Decision {
            cpu_lvl: a[0],
            gpu_lvl: self.freq_levels - 1,
            mem_lvl: self.freq_levels - 1,
            xi: xi_of_level(a[1], self.xi_levels),
            compression: Compression::None,
            fusion: if a[1] == 0 { Fusion::Single } else { Fusion::WeightedSum },
            importance_guided: false,
            phase_scaling: false,
        }
    }

    fn feedback(&mut self, obs: &Obs, decision: &Decision, next_obs: &Obs, fb: Feedback) {
        let xi_lvl = (decision.xi * (self.xi_levels - 1) as f64).round() as usize;
        let t = Transition {
            state: obs.features(),
            action: vec![decision.cpu_lvl, xi_lvl],
            reward: fb.reward,
            next_state: next_obs.features(),
            done: fb.done,
            // DRLDO uses the standard blocking DQN formulation
            gamma_pow: 1.0,
        };
        if self.training && self.learner_opts.mode == LearnerMode::Background {
            self.ensure_bg_learner();
            self.learner.as_mut().expect("just ensured").push(t);
            return;
        }
        let agent = self.agent_mut();
        agent.remember(t);
        if self.training {
            agent.learn();
        }
    }

    /// Conventional RL inference is slower than TwM (paper §6.4 notes
    /// DVFO's concurrent offloading beats DRLDO's).
    fn decision_latency_s(&self) -> f64 {
        8e-4
    }

    fn set_training(&mut self, on: bool) {
        self.training = on;
        if !on {
            self.reclaim_agent();
        }
    }
}

// ======================================================================
// AppealNet (baseline): binary offload via a hard-case discriminator; no
// DVFS (max frequency); whole input compressed when offloaded.
// ======================================================================
pub struct AppealNetPolicy {
    levels: usize,
    rng: Pcg32,
}

impl AppealNetPolicy {
    pub fn new(levels: usize, seed: u64) -> Self {
        Self {
            levels,
            rng: Pcg32::seeded(seed ^ 0xA99E),
        }
    }
}

impl Policy for AppealNetPolicy {
    fn name(&self) -> &'static str {
        "appealnet"
    }

    fn decide(&mut self, obs: &Obs) -> Decision {
        // hard-case discriminator: diffuse importance (high entropy) means
        // the lightweight edge model will struggle → offload everything.
        let hardness = obs.entropy_norm + 0.08 * self.rng.normal();
        let offload = hardness > 0.52;
        Decision {
            cpu_lvl: self.levels - 1,
            gpu_lvl: self.levels - 1,
            mem_lvl: self.levels - 1,
            xi: if offload { 1.0 } else { 0.0 },
            compression: Compression::Int8,
            fusion: Fusion::Single,
            importance_guided: false,
            phase_scaling: false,
        }
    }

    /// The discriminator forward pass adds fixed overhead (paper §6.4:
    /// "the hard-case discriminator of AppealNet adds additional
    /// overhead").
    fn decision_latency_s(&self) -> f64 {
        1.6e-3
    }
}

// ======================================================================
// Cloud-only / Edge-only (baselines)
// ======================================================================
pub struct CloudOnlyPolicy {
    levels: usize,
}

impl CloudOnlyPolicy {
    pub fn new(levels: usize) -> Self {
        Self { levels }
    }
}

impl Policy for CloudOnlyPolicy {
    fn name(&self) -> &'static str {
        "cloud_only"
    }

    fn decide(&mut self, _obs: &Obs) -> Decision {
        Decision {
            // minimal edge frequencies: the device only captures/sends
            cpu_lvl: (self.levels - 1) / 3,
            gpu_lvl: 0,
            mem_lvl: (self.levels - 1) / 3,
            xi: 1.0,
            compression: Compression::Int8,
            fusion: Fusion::Single,
            importance_guided: false,
            phase_scaling: false,
        }
    }
}

pub struct EdgeOnlyPolicy {
    levels: usize,
}

impl EdgeOnlyPolicy {
    pub fn new(levels: usize) -> Self {
        Self { levels }
    }
}

impl Policy for EdgeOnlyPolicy {
    fn name(&self) -> &'static str {
        "edge_only"
    }

    fn decide(&mut self, _obs: &Obs) -> Decision {
        Decision::edge_only_max(self.levels)
    }
}

// ======================================================================
// Oracle: exhaustive grid search over a coarsened action grid using a
// clone of the environment — the upper bound DVFO is measured against in
// the ablation benches.
// ======================================================================
pub struct OraclePolicy {
    pub levels: usize,
    pub xi_levels: usize,
    /// grid stride (1 = exhaustive; 3 = every third level)
    pub stride: usize,
    /// charged decision latency (exhaustive search is slow by design —
    /// ablations can zero it to isolate decision quality)
    pub latency_s: f64,
    pub eval: Box<dyn FnMut(&Decision) -> f64 + Send>,
}

impl Policy for OraclePolicy {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn decide(&mut self, _obs: &Obs) -> Decision {
        let mut best: Option<(f64, Decision)> = None;
        let lv: Vec<usize> = (0..self.levels).step_by(self.stride.max(1)).collect();
        let xv: Vec<usize> = (0..self.xi_levels).step_by(self.stride.max(1)).collect();
        for &c in &lv {
            for &g in &lv {
                for &m in &lv {
                    for &x in &xv {
                        let xi = xi_of_level(x, self.xi_levels);
                        let d = Decision {
                            cpu_lvl: c,
                            gpu_lvl: g,
                            mem_lvl: m,
                            xi,
                            compression: Compression::Int8,
                            fusion: if x == 0 { Fusion::Single } else { Fusion::WeightedSum },
                            importance_guided: true,
                            phase_scaling: true,
                        };
                        let cost = (self.eval)(&d);
                        if best.as_ref().map(|(b, _)| cost < *b).unwrap_or(true) {
                            best = Some((cost, d));
                        }
                    }
                }
            }
        }
        best.unwrap().1
    }

    /// Exhaustive search is far too slow for deployment — the latency is
    /// charged accordingly in ablations.
    fn decision_latency_s(&self) -> f64 {
        self.latency_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs() -> Obs {
        Obs {
            lambda: 0.5,
            eta: 0.5,
            bandwidth_mbps: 5.0,
            top_quarter_mass: 0.6,
            skewness: 2.0,
            entropy_norm: 0.7,
            intensity_norm: 0.4,
            prev_xi: 0.5,
            queue_depth_norm: 0.25,
            backlog_norm: 0.1,
        }
    }

    #[test]
    fn features_are_8dim_and_bounded() {
        let f = obs().features();
        assert_eq!(f.len(), 8);
        assert!(f.iter().all(|x| x.is_finite() && x.abs() <= 2.0));
    }

    #[test]
    fn features_into_matches_the_allocating_variants() {
        let o = obs();
        let mut buf = Vec::new();
        o.features_into(&mut buf);
        assert_eq!(buf, o.features());
        o.features_ext_into(&mut buf);
        assert_eq!(buf, o.features_ext());
        // the buffer is cleared and rewritten, never appended-to
        o.features_into(&mut buf);
        assert_eq!(buf, o.features());
    }

    #[test]
    fn extended_features_append_queue_signals() {
        let f = obs().features_ext();
        assert_eq!(f.len(), 10);
        assert_eq!(f[..8], obs().features()[..]);
        assert!((f[8] - 0.25).abs() < 1e-6 && (f[9] - 0.1).abs() < 1e-6);
        assert!(f.iter().all(|x| x.is_finite() && x.abs() <= 2.0));
    }

    #[test]
    fn dvfo_decisions_in_range() {
        let mut p = DvfoPolicy::new(10, 11, true, false, 1);
        for _ in 0..50 {
            let d = p.decide(&obs());
            assert!(d.cpu_lvl < 10 && d.gpu_lvl < 10 && d.mem_lvl < 10);
            assert!((0.0..=1.0).contains(&d.xi));
            assert!(d.importance_guided);
            assert_eq!(d.compression, Compression::Int8);
        }
    }

    #[test]
    fn queue_aware_dvfo_decides_and_learns_on_10dim_state() {
        let mut p = DvfoPolicy::new(10, 11, true, true, 4);
        let d = p.decide(&obs());
        assert!(d.cpu_lvl < 10 && (0.0..=1.0).contains(&d.xi));
        p.feedback(
            &obs(),
            &d,
            &obs(),
            Feedback {
                reward: -0.5,
                gamma_pow: 1.0,
                done: false,
            },
        );
        // load changes must be able to change the greedy action over
        // training life; at minimum the featurization differs
        let mut hot = obs();
        hot.queue_depth_norm = 2.0;
        hot.backlog_norm = 2.0;
        assert_ne!(obs().features_ext(), hot.features_ext());
    }

    #[test]
    fn dvfo_greedy_is_deterministic_when_deployed() {
        let mut p = DvfoPolicy::new(10, 11, true, false, 2);
        p.set_training(false);
        let d1 = p.decide(&obs());
        let d2 = p.decide(&obs());
        assert_eq!(format!("{d1:?}"), format!("{d2:?}"));
    }

    #[test]
    fn drldo_fixes_gpu_mem_and_skips_compression() {
        let mut p = DrldoPolicy::new(10, 11, 3);
        for _ in 0..20 {
            let d = p.decide(&obs());
            assert_eq!(d.gpu_lvl, 9);
            assert_eq!(d.mem_lvl, 9);
            assert_eq!(d.compression, Compression::None);
            assert!(!d.importance_guided);
        }
    }

    fn obs_i(i: usize) -> Obs {
        let mut o = obs();
        o.lambda = (i % 7) as f64 / 7.0;
        o.eta = 1.0 - o.lambda;
        o.prev_xi = (i % 5) as f64 / 4.0;
        o
    }

    fn weights_bits(mlp: &crate::dqn::Mlp) -> Vec<u32> {
        let mut out = Vec::new();
        for w in &mlp.ws {
            out.extend(w.data.iter().map(|x| x.to_bits()));
        }
        for b in &mlp.bs {
            out.extend(b.iter().map(|x| x.to_bits()));
        }
        out
    }

    #[test]
    fn inline_learner_is_bit_identical_to_legacy_agent_loop() {
        // default (inline) mode must reproduce the historical behavior
        // exactly: a bare DqnAgent driven with the same feature/reward
        // sequence lands on bit-identical weights and actions
        let mut p = DvfoPolicy::new(4, 5, true, false, 31);
        let mut twin = DqnAgent::new(
            DqnConfig {
                state_dim: 8,
                ..DqnConfig::default()
            },
            ActionSpace::new(vec![4, 4, 4, 5]),
            31,
        );
        for i in 0..40 {
            let o = obs_i(i);
            let no = obs_i(i + 1);
            let d = p.decide(&o);
            let ta = twin.act(&o.features());
            assert_eq!(
                (d.cpu_lvl, d.gpu_lvl, d.mem_lvl),
                (ta[0], ta[1], ta[2]),
                "step {i}: policy and twin diverged"
            );
            let fb = Feedback {
                reward: -0.1 * (i % 3) as f64,
                gamma_pow: 1.0,
                done: i % 10 == 9,
            };
            p.feedback(&o, &d, &no, fb);
            twin.remember(Transition {
                state: o.features(),
                action: ta,
                reward: fb.reward,
                next_state: no.features(),
                done: fb.done,
                gamma_pow: fb.gamma_pow,
            });
            twin.learn();
        }
        assert_eq!(
            weights_bits(&p.agent().online),
            weights_bits(&twin.online),
            "inline learner must stay bit-identical to the legacy loop"
        );
    }

    #[test]
    fn bg_learner_policy_runs_are_reproducible() {
        // fixed cadence ⇒ two identical runs make identical decisions
        // and land on identical weights, despite the worker thread
        let run = || {
            let mut p = DvfoPolicy::new(4, 5, true, false, 17).with_learner(LearnerOpts {
                mode: LearnerMode::Background,
                publish_every: 8,
                queue_cap: 32,
            });
            let mut decisions = Vec::new();
            for i in 0..48 {
                let o = obs_i(i);
                let d = p.decide(&o);
                decisions.push(format!("{d:?}"));
                p.feedback(
                    &o,
                    &d,
                    &obs_i(i + 1),
                    Feedback {
                        reward: -0.2 * (i % 4) as f64,
                        gamma_pow: 1.0,
                        done: i % 12 == 11,
                    },
                );
            }
            p.set_training(false);
            decisions.push(format!("{:?}", p.decide(&obs_i(99))));
            (decisions, weights_bits(&p.agent().online))
        };
        let (d1, w1) = run();
        let (d2, w2) = run();
        assert_eq!(d1, d2, "decision sequences must match run-to-run");
        assert_eq!(w1, w2, "final weights must match run-to-run");
    }

    #[test]
    fn bg_learner_trains_and_deploys() {
        let mut p = DrldoPolicy::new(4, 5, 23).with_learner(LearnerOpts {
            mode: LearnerMode::Background,
            publish_every: 4,
            queue_cap: 16,
        });
        for i in 0..30 {
            let o = obs_i(i);
            let d = p.decide(&o);
            p.feedback(
                &o,
                &d,
                &obs_i(i + 1),
                Feedback {
                    reward: -0.1,
                    gamma_pow: 1.0,
                    done: false,
                },
            );
        }
        // leaving training drains the queue and reclaims the agent
        p.set_training(false);
        assert_eq!(p.agent().replay.len(), 30, "every transition retained");
        let d = p.decide(&obs_i(0));
        assert!(d.cpu_lvl < 4 && (0.0..=1.0).contains(&d.xi));
    }

    #[test]
    fn appealnet_is_binary() {
        let mut p = AppealNetPolicy::new(10, 4);
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..200 {
            let mut o = obs();
            o.entropy_norm = (i % 100) as f64 / 100.0;
            let d = p.decide(&o);
            assert!(d.xi == 0.0 || d.xi == 1.0);
            seen.insert((d.xi * 10.0) as u8);
        }
        assert_eq!(seen.len(), 2, "discriminator must use both branches");
    }

    #[test]
    fn fixed_policies() {
        let mut c = CloudOnlyPolicy::new(10);
        assert_eq!(c.decide(&obs()).xi, 1.0);
        let mut e = EdgeOnlyPolicy::new(10);
        let d = e.decide(&obs());
        assert_eq!(d.xi, 0.0);
        assert_eq!(d.cpu_lvl, 9);
    }

    #[test]
    fn oracle_minimizes_its_objective() {
        // cost = distance from a known optimum → oracle must find it.
        let target = (3usize, 5usize, 7usize);
        let mut p = OraclePolicy {
            levels: 10,
            xi_levels: 11,
            stride: 1,
            latency_s: 0.05,
            eval: Box::new(move |d: &Decision| {
                (d.cpu_lvl as f64 - target.0 as f64).powi(2)
                    + (d.gpu_lvl as f64 - target.1 as f64).powi(2)
                    + (d.mem_lvl as f64 - target.2 as f64).powi(2)
                    + (d.xi - 0.3).powi(2)
            }),
        };
        let d = p.decide(&obs());
        assert_eq!((d.cpu_lvl, d.gpu_lvl, d.mem_lvl), target);
        assert!((d.xi - 0.3).abs() < 0.051);
    }
}
