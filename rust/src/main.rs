//! `dvfo` — the coordinator CLI.
//!
//! Subcommands:
//!   serve        train (if learning policy) + serve a simulated stream
//!   pipeline     run the REAL artifact pipeline on the bundled test set
//!   experiment   regenerate a paper table/figure (or `all`)
//!   train        offline DQN training only, with the learning curve
//!   devices      list the device zoo (Table 3)
//!   models       list the model zoo

use dvfo::cli::{parse, Cmd};
use dvfo::configx::Config;
use dvfo::coordinator::pipeline::{Pipeline, PipelineRequest};
use dvfo::coordinator::{
    serve_fleet_sharded, serve_fleet_streaming, serve_multistream, Admission, Coordinator,
    DesOpts, Fleet, FleetOpts, Router,
};
use dvfo::telemetry::{render, Table};
use dvfo::workload::{Arrivals, SloClass, TaskGen};
use std::path::Path;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "dvfo — learning-based DVFS for energy-efficient edge-cloud collaborative inference

USAGE: dvfo <subcommand> [options]

SUBCOMMANDS:
  serve        simulate serving a request stream with a policy
               (single edge, or a multi-device fleet via --fleet/--router/
               --slo/--admission, with cross-device rebalancing via
               --reroute/--rebalance-window/--migrate-threshold)
  pipeline     run the real AOT-artifact pipeline (edge+cloud workers)
  experiment   regenerate a paper table/figure: fig01..fig16, tab04..tab06,
               ablation, load (multi-stream load sweep), fleet (multi-edge
               goodput/energy/violation curves), cloudbatch (goodput/energy
               vs cloud batch window), rebalance (goodput/shed vs backlog
               skew with re-route + migration), chaos (goodput/failed vs
               fault intensity with and without re-route + migration), or
               `all`
  train        offline DQN training, prints the learning curve
  devices      list the edge/cloud device zoo (paper Table 3)
  models       list the DNN model zoo

Run `dvfo <subcommand> --help` for options."
        .to_string()
}

fn config_from(args: &dvfo::cli::Args) -> anyhow::Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::default(),
    };
    for (k, v) in &args.overrides {
        cfg.set(k, v)?;
    }
    Ok(cfg)
}

fn print_reports(reports: &[dvfo::coordinator::TaskReport]) {
    for r in reports {
        println!(
            "s={} xi={:.2} tti={:.1}ms queue={:.1}ms e2e={:.1}ms eti={:.0}mJ \
             acc={:.2}% batch={} f=({:.0},{:.0},{:.0})",
            r.stream,
            r.xi,
            r.tti_total_s * 1e3,
            r.queue_wait_s * 1e3,
            r.e2e_s.max(r.queue_wait_s + r.tti_total_s) * 1e3,
            r.eti_total_j * 1e3,
            r.accuracy_pct,
            r.batch_size,
            r.freqs[0],
            r.freqs[1],
            r.freqs[2]
        );
    }
}

fn real_main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(sub) = argv.first().cloned() else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &argv[1..];

    match sub.as_str() {
        "serve" => {
            let cmd = Cmd::new("dvfo serve", "simulate serving a request stream")
                .opt("config", "JSON config file", None)
                .opt("requests", "number of requests (total across streams)", Some("200"))
                .opt("streams", "concurrent user streams", None)
                .opt("batch-window", "uplink batching window (ms, 0 = off)", None)
                .opt("max-batch", "max offloads per uplink batch", None)
                .opt("cloud-slots", "concurrent cloud executors (shared pool)", None)
                .opt(
                    "cloud-batch-window",
                    "cloud-side cross-device batching window (ms, 0 = off)",
                    None,
                )
                .opt("cloud-max-batch", "max jobs per batched cloud invocation", None)
                .opt(
                    "fleet",
                    "edge fleet: comma-separated device names, name*count for \
                     repeats (empty = single --set device=...)",
                    None,
                )
                .opt(
                    "router",
                    "fleet dispatch: round_robin | shortest_queue | least_backlog",
                    None,
                )
                .opt(
                    "slo",
                    "per-stream SLO class: none | <deadline_ms> | <deadline_ms>,<priority>",
                    None,
                )
                .opt("admission", "admission control: off | shed | downgrade", None)
                .flag(
                    "reroute",
                    "re-route-before-shed: try the cheapest feasible sibling device \
                     before shedding/downgrading (with --admission shed|downgrade)",
                )
                .opt(
                    "rebalance-window",
                    "cross-device rebalance tick (ms, 0 = no mid-run migration)",
                    None,
                )
                .opt(
                    "migrate-threshold",
                    "backlog divergence (ms) that triggers queued-task migration \
                     (inf = never)",
                    None,
                )
                .opt(
                    "migrate-penalty",
                    "latency penalty per migrated task in transit (ms)",
                    None,
                )
                .opt(
                    "shards",
                    "share-nothing engine shards over disjoint device subsets \
                     (fleet path; 1 = the unsharded bit-exact kernel)",
                    None,
                )
                .opt(
                    "chaos",
                    "deterministic fault schedule: `;`-separated \
                     down:<dev>@<at_ms>+<dur_ms> | \
                     bw:<dev>@<at_ms>+<dur_ms>*<scale> | cloud@<at_ms>+<dur_ms> \
                     | file:<trace.json> (empty = no faults)",
                    None,
                )
                .opt(
                    "retry-max",
                    "retry budget for fault-killed work before a task is \
                     marked failed",
                    None,
                )
                .opt(
                    "retry-backoff",
                    "base retry backoff (ms); attempt k waits base*2^(k-1)",
                    None,
                )
                .flag(
                    "stream-telemetry",
                    "constant-memory telemetry: online quantile sketches + counters \
                     instead of collected per-task reports",
                )
                .opt(
                    "scheduler",
                    "event-scheduler backend: calendar (bucketed, amortized O(1)) | \
                     heap (binary heap); identical event order either way",
                    None,
                )
                .opt(
                    "arrivals",
                    "per-stream arrival process: sequential | poisson:<r> | \
                     bursty:<r>,<every_s>,<len> | mmpp:<lo>,<hi>,<dlo>,<dhi> | \
                     diurnal:<base>,<amp>,<period_s>",
                    None,
                )
                .opt(
                    "learner",
                    "DQN gradient-step placement for training policies: \
                     inline (historical, bit-identical) | bg (background \
                     learner thread, deterministic at fixed cadence)",
                    None,
                )
                .opt(
                    "learner-publish",
                    "background-learner snapshot cadence (transitions per \
                     weight publish; only with --learner bg)",
                    None,
                )
                .flag("verbose", "per-request reports");
            let a = parse(&cmd, rest)?;
            let mut cfg = config_from(&a)?;
            cfg.requests = a.parse_or("requests", cfg.requests)?;
            cfg.streams = a.parse_or("streams", cfg.streams)?;
            cfg.batch_window_ms = a.parse_or("batch-window", cfg.batch_window_ms)?;
            cfg.max_batch = a.parse_or("max-batch", cfg.max_batch)?;
            cfg.cloud_slots = a.parse_or("cloud-slots", cfg.cloud_slots)?;
            cfg.cloud_batch_window_ms =
                a.parse_or("cloud-batch-window", cfg.cloud_batch_window_ms)?;
            cfg.cloud_max_batch = a.parse_or("cloud-max-batch", cfg.cloud_max_batch)?;
            cfg.rebalance_window_ms =
                a.parse_or("rebalance-window", cfg.rebalance_window_ms)?;
            cfg.migrate_threshold_ms =
                a.parse_or("migrate-threshold", cfg.migrate_threshold_ms)?;
            cfg.migrate_penalty_ms = a.parse_or("migrate-penalty", cfg.migrate_penalty_ms)?;
            cfg.shards = a.parse_or("shards", cfg.shards)?;
            cfg.retry_max = a.parse_or("retry-max", cfg.retry_max)?;
            cfg.retry_backoff_ms = a.parse_or("retry-backoff", cfg.retry_backoff_ms)?;
            cfg.learner_publish_every =
                a.parse_or("learner-publish", cfg.learner_publish_every)?;
            if a.flag("reroute") {
                cfg.reroute = true;
            }
            if a.flag("stream-telemetry") {
                cfg.stream_telemetry = true;
            }
            // `fleet` before `chaos`: the chaos validator checks fault
            // device indices against the (possibly just-overridden) fleet
            for (key, flag) in [
                ("arrivals", "arrivals"),
                ("fleet", "fleet"),
                ("router", "router"),
                ("slo", "slo"),
                ("admission", "admission"),
                ("scheduler", "scheduler"),
                ("learner", "learner"),
                ("chaos", "chaos"),
            ] {
                if let Some(spec) = a.get(flag) {
                    cfg.set(key, spec)?;
                }
            }
            cfg.validate()?;
            let arrivals = Arrivals::parse(&cfg.arrivals)?;
            let slo = SloClass::parse(&cfg.slo)?;
            let router = Router::parse(&cfg.router)?;
            let admission = Admission::parse(&cfg.admission)?;
            // the fleet path switches on when any fleet knob leaves its
            // default (compared post-parse so aliases like `rr` or `none`
            // don't flip the path); otherwise the legacy single-edge core
            // runs
            let fleet_mode = !cfg.fleet.trim().is_empty()
                || router != Router::RoundRobin
                || !slo.is_none()
                || admission != Admission::Off
                || cfg.reroute
                || cfg.rebalance_window_ms > 0.0
                || cfg.shards > 1
                || cfg.stream_telemetry
                || !cfg.chaos.trim().is_empty();
            let per_stream = (cfg.requests / cfg.streams).max(1);
            if per_stream * cfg.streams != cfg.requests {
                eprintln!(
                    "[serve] rounding --requests {} to {} ({} per stream x {} streams)",
                    cfg.requests,
                    per_stream * cfg.streams,
                    per_stream,
                    cfg.streams
                );
            }
            let mk_gens = |dataset| -> anyhow::Result<Vec<TaskGen>> {
                (0..cfg.streams)
                    .map(|stream| {
                        Ok(TaskGen::new(
                            &cfg.model,
                            dataset,
                            arrivals.clone(),
                            cfg.seed ^ 0x5E ^ ((stream as u64) << 8),
                        )?
                        .with_slo(slo))
                    })
                    .collect()
            };
            let learning = matches!(cfg.policy.as_str(), "dvfo" | "drldo");
            if fleet_mode {
                let mut fleet = Fleet::from_config(&cfg)?;
                if learning {
                    eprintln!(
                        "[train] {} episodes offline x {} devices...",
                        cfg.train_episodes,
                        fleet.len()
                    );
                    fleet.train_offline(cfg.train_episodes, 24, cfg.seed)?;
                }
                let mut gens = mk_gens(fleet.devices[0].env.dataset)?;
                let opts = FleetOpts::from_config(&cfg)?;
                println!(
                    "policy={} model={} dataset={} fleet=[{}] router={} slo={} admission={} \
                     bw={} streams={} arrivals={} batch-window={}ms cloud-slots={} \
                     cloud-batch-window={}ms shards={}",
                    cfg.policy,
                    cfg.model,
                    cfg.dataset,
                    fleet.names.join(","),
                    cfg.router,
                    cfg.slo,
                    cfg.admission,
                    cfg.bandwidth,
                    cfg.streams,
                    cfg.arrivals,
                    cfg.batch_window_ms,
                    cfg.cloud_slots,
                    cfg.cloud_batch_window_ms,
                    cfg.shards
                );
                // the rebalance/cloud lines gate on their knobs: with the
                // feature off, zero counts are implied, not news
                let rebalancing = cfg.reroute || cfg.rebalance_window_ms > 0.0;
                if cfg.stream_telemetry {
                    // constant-memory path: per-task reports are folded
                    // into sketches/counters as they complete and never
                    // collected, so --verbose has nothing to print
                    if a.flag("verbose") {
                        eprintln!(
                            "[serve] --verbose has no per-request reports under \
                             --stream-telemetry"
                        );
                    }
                    let s = serve_fleet_streaming(
                        &mut fleet,
                        &mut gens,
                        per_stream,
                        &opts,
                        cfg.shards,
                    );
                    println!("{}", render::streaming_table(&s.telemetry).render());
                    println!(
                        "{}",
                        render::counters_line(
                            s.offered,
                            s.completed,
                            s.shed,
                            s.downgraded,
                            s.slo_violations,
                            s.goodput
                        )
                    );
                    if rebalancing {
                        println!(
                            "{}",
                            render::rebalance_line(
                                s.rerouted,
                                s.migrated,
                                s.migration_latency_s
                            )
                        );
                    }
                    if !opts.chaos.is_empty() {
                        println!(
                            "{}",
                            render::chaos_line(
                                s.faults_injected,
                                s.retries,
                                s.failed,
                                s.drained_on_dropout
                            )
                        );
                    }
                    if cfg.cloud_batch_window_ms > 0.0 && s.cloud_invocations > 0 {
                        println!(
                            "{}",
                            render::cloud_line(
                                s.cloud_invocations,
                                s.cloud_occupancy.mean(),
                                s.cloud_occupancy.max(),
                                s.cloud_dispatch_saved_s
                            )
                        );
                    }
                    if s.window_flushes > 0 {
                        println!("{}", render::stale_line(s.window_flushes, s.stale_closes));
                    }
                    for d in &s.per_device {
                        let rb = rebalancing
                            .then_some((d.rerouted_in, d.migrated_in, d.migrated_out));
                        let chaos_cols = if opts.chaos.is_empty() {
                            String::new()
                        } else {
                            render::device_chaos_suffix(d.faults, d.failed)
                        };
                        println!(
                            "{}{chaos_cols}",
                            render::device_line(&d.name, d.served, d.energy_j, d.violations, rb)
                        );
                    }
                    for line in render::class_lines(&s.telemetry) {
                        println!("{line}");
                    }
                } else {
                    let s =
                        serve_fleet_sharded(&mut fleet, &mut gens, per_stream, &opts, cfg.shards);
                    if a.flag("verbose") {
                        print_reports(&s.serve.reports);
                    }
                    println!("{}", render::summary_table(&s.serve).render());
                    println!(
                        "{}",
                        render::counters_line(
                            s.offered,
                            s.completed,
                            s.shed,
                            s.downgraded,
                            s.slo_violations,
                            s.goodput
                        )
                    );
                    if rebalancing {
                        println!(
                            "{}",
                            render::rebalance_line(
                                s.rerouted,
                                s.migrated,
                                s.migration_latency_s
                            )
                        );
                    }
                    if !opts.chaos.is_empty() {
                        println!(
                            "{}",
                            render::chaos_line(
                                s.faults_injected,
                                s.retries,
                                s.failed,
                                s.drained_on_dropout
                            )
                        );
                    }
                    if cfg.cloud_batch_window_ms > 0.0 && s.cloud_invocations > 0 {
                        println!(
                            "{}",
                            render::cloud_line(
                                s.cloud_invocations,
                                s.cloud_occupancy.mean(),
                                s.cloud_occupancy.percentile(100.0),
                                s.cloud_dispatch_saved_s
                            )
                        );
                    }
                    if s.window_flushes > 0 {
                        println!("{}", render::stale_line(s.window_flushes, s.stale_closes));
                    }
                    for d in &s.per_device {
                        let rb = rebalancing
                            .then_some((d.rerouted_in, d.migrated_in, d.migrated_out));
                        let chaos_cols = if opts.chaos.is_empty() {
                            String::new()
                        } else {
                            render::device_chaos_suffix(d.faults, d.failed)
                        };
                        println!(
                            "{}{chaos_cols}",
                            render::device_line(&d.name, d.served, d.energy_j, d.violations, rb)
                        );
                    }
                }
            } else {
                let mut coord = Coordinator::from_config(&cfg)?;
                if learning {
                    eprintln!("[train] {} episodes offline...", cfg.train_episodes);
                    // dedicated closed-loop generator: training must not
                    // advance any serving stream's arrival clock
                    let mut tgen = TaskGen::new(
                        &cfg.model,
                        coord.env.dataset,
                        Arrivals::Sequential,
                        cfg.seed ^ 0x7341,
                    )?;
                    coord.train(&mut tgen, cfg.train_episodes, 24);
                }
                let mut gens = mk_gens(coord.env.dataset)?;
                let opts = DesOpts::from_config(&cfg);
                let s = serve_multistream(&mut coord, &mut gens, per_stream, &opts);
                if a.flag("verbose") {
                    print_reports(&s.reports);
                }
                println!(
                    "policy={} model={} dataset={} device={} bw={} streams={} arrivals={} \
                     batch-window={}ms cloud-batch-window={}ms",
                    cfg.policy,
                    cfg.model,
                    cfg.dataset,
                    cfg.device,
                    cfg.bandwidth,
                    cfg.streams,
                    cfg.arrivals,
                    cfg.batch_window_ms,
                    cfg.cloud_batch_window_ms
                );
                println!("{}", render::summary_table(&s).render());
                if cfg.streams > 1 {
                    let mean_mj = 1e3 * s.per_stream_j.iter().sum::<f64>()
                        / s.per_stream_j.len().max(1) as f64;
                    let max_mj = 1e3
                        * s.per_stream_j
                            .iter()
                            .fold(f64::NEG_INFINITY, |acc, &x| acc.max(x));
                    println!(
                        "per-stream energy: mean {mean_mj:.0} mJ, max {max_mj:.0} mJ \
                         over {} streams",
                        s.per_stream_j.len()
                    );
                }
                if cfg.cloud_batch_window_ms > 0.0 {
                    // task-weighted occupancy (same convention as the
                    // uplink batch_size telemetry): each cloud job
                    // reports the size of the invocation it rode in
                    let occ: Vec<f64> = s
                        .cloud_batch_size
                        .values()
                        .iter()
                        .copied()
                        .filter(|&b| b > 0.0)
                        .collect();
                    if !occ.is_empty() {
                        println!(
                            "cloud batching: mean occupancy {:.2} (task-weighted) \
                             across {} cloud jobs",
                            occ.iter().sum::<f64>() / occ.len() as f64,
                            occ.len()
                        );
                    }
                }
            }
        }
        "pipeline" => {
            let cmd = Cmd::new("dvfo pipeline", "run the real AOT-artifact pipeline")
                .opt("artifacts", "artifacts directory", Some("artifacts"))
                .opt("requests", "number of requests", Some("64"))
                .opt("xi", "offload proportion", Some("0.5"))
                .opt("lambda", "fusion weight", Some("0.5"));
            let a = parse(&cmd, rest)?;
            let dir = Path::new(a.get_or("artifacts", "artifacts"));
            let n: usize = a.parse_or("requests", 64)?;
            let xi: f64 = a.parse_or("xi", 0.5)?;
            let lambda: f32 = a.parse_or("lambda", 0.5)?;
            let pipeline = Pipeline::load(dir)?;
            let (imgs, labels) = pipeline.engine().manifest.load_testset(dir)?;
            let img_len: usize = pipeline.engine().manifest.img_shape.iter().product();
            let n = n.min(labels.len());
            let reqs: Vec<PipelineRequest> = (0..n)
                .map(|i| PipelineRequest {
                    id: i as u64,
                    image: imgs[i * img_len..(i + 1) * img_len].to_vec(),
                    label: Some(labels[i]),
                    xi,
                    lambda,
                })
                .collect();
            let t0 = std::time::Instant::now();
            let rs = pipeline.serve(reqs)?;
            let wall = t0.elapsed().as_secs_f64();
            let correct = rs.iter().filter(|r| r.correct == Some(true)).count();
            let mean =
                |f: fn(&dvfo::coordinator::pipeline::PipelineResponse) -> f64| -> f64 {
                    rs.iter().map(f).sum::<f64>() / rs.len() as f64
                };
            println!("requests      : {n}");
            println!(
                "accuracy      : {:.2}% ({correct}/{n})",
                100.0 * correct as f64 / n as f64
            );
            println!("throughput    : {:.1} req/s", n as f64 / wall);
            println!("mean extract  : {:.3} ms", 1e3 * mean(|r| r.t_extract_s));
            println!("mean local    : {:.3} ms", 1e3 * mean(|r| r.t_local_s));
            println!("mean remote   : {:.3} ms", 1e3 * mean(|r| r.t_remote_s));
            println!("mean fusion   : {:.3} ms", 1e3 * mean(|r| r.t_fusion_s));
            println!("mean total    : {:.3} ms", 1e3 * mean(|r| r.t_total_s));
            println!("mean payload  : {:.0} B", mean(|r| r.payload_bytes as f64));
        }
        "experiment" => {
            let cmd = Cmd::new("dvfo experiment", "regenerate a paper table/figure")
                .positional(
                    "id",
                    "fig01..fig16 | tab04..tab06 | ablation | load | fleet | cloudbatch \
                     | rebalance | all",
                )
                .flag("full", "full-size sweep (slower)")
                .opt("config", "JSON config file", None)
                .opt(
                    "threads",
                    "worker threads for the grid sweeps (cells share nothing; any N \
                     renders byte-identical tables to 1; overrides config `threads`)",
                    None,
                )
                .opt("csv", "also write CSV to this directory", None);
            let a = parse(&cmd, rest)?;
            let cfg = config_from(&a)?;
            let id = a.positional(0).unwrap_or("all").to_string();
            let quick = !a.flag("full");
            let threads: usize = a.parse_or("threads", cfg.threads)?;
            let ids: Vec<&str> = if id == "all" {
                dvfo::experiments::ALL.to_vec()
            } else {
                vec![id.as_str()]
            };
            for id in ids {
                let t0 = std::time::Instant::now();
                let table = dvfo::experiments::run_by_name(id, quick, threads)?;
                println!("== {id} ==");
                println!("{}", table.render());
                if let Some(dir) = a.get("csv") {
                    dvfo::bench_harness::save_csv(&table, &format!("{dir}/{id}.csv"));
                }
                eprintln!("[{id}] {:?}", t0.elapsed());
            }
        }
        "train" => {
            let cmd = Cmd::new("dvfo train", "offline DQN training with learning curve")
                .opt("config", "JSON config file", None)
                .opt("episodes", "training episodes", Some("40"))
                .opt(
                    "learner",
                    "DQN gradient-step placement: inline | bg (background \
                     learner thread, deterministic at fixed cadence)",
                    None,
                )
                .opt(
                    "learner-publish",
                    "background-learner snapshot cadence (transitions per \
                     weight publish; only with --learner bg)",
                    None,
                );
            let a = parse(&cmd, rest)?;
            let mut cfg = config_from(&a)?;
            cfg.train_episodes = a.parse_or("episodes", cfg.train_episodes)?;
            if let Some(spec) = a.get("learner") {
                cfg.set("learner", spec)?;
            }
            cfg.learner_publish_every =
                a.parse_or("learner-publish", cfg.learner_publish_every)?;
            cfg.validate()?;
            let mut coord = Coordinator::from_config(&cfg)?;
            let mut gen = TaskGen::new(
                &cfg.model,
                coord.env.dataset,
                Arrivals::Sequential,
                cfg.seed ^ 0x7,
            )?;
            let curve = coord.train(&mut gen, cfg.train_episodes, 24);
            for (i, r) in curve.iter().enumerate() {
                println!("episode {i:3}  mean reward {r:+.4}");
            }
        }
        "devices" => {
            let mut t = Table::new(vec![
                "device", "cpu max MHz", "gpu max MHz", "mem max MHz", "max W",
            ]);
            for d in dvfo::device::device_zoo() {
                t.row(vec![
                    d.name.to_string(),
                    format!("{:.0}", d.cpu.max_mhz),
                    format!("{:.0}", d.gpu.max_mhz),
                    format!("{:.0}", d.mem.max_mhz),
                    format!("{:.0}", d.max_power_w),
                ]);
            }
            println!("{}", t.render());
        }
        "models" => {
            let mut t = Table::new(vec![
                "model", "GFLOPs (cifar)", "intensity F/B", "acc cifar %", "acc imagenet %",
            ]);
            for m in dvfo::perfmodel::model_zoo() {
                t.row(vec![
                    m.name.to_string(),
                    format!("{:.2}", m.flops_g(dvfo::perfmodel::Dataset::Cifar100)),
                    format!("{:.0}", m.intensity(dvfo::perfmodel::Dataset::Cifar100)),
                    format!("{:.1}", m.base_acc_cifar),
                    format!("{:.1}", m.base_acc_imagenet),
                ]);
            }
            println!("{}", t.render());
        }
        "--help" | "-h" | "help" => println!("{}", usage()),
        other => {
            eprintln!("unknown subcommand `{other}`\n\n{}", usage());
            std::process::exit(2);
        }
    }
    Ok(())
}
