//! Declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--opt value` / `--opt=value`, and
//! positional arguments, with generated `--help` text.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Clone, Debug, Default)]
pub struct Cmd {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
    pub positionals: Vec<(&'static str, &'static str)>,
}

impl Cmd {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            ..Default::default()
        }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.opts.push(Opt {
            name,
            help,
            takes_value: true,
            default,
        });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n\nOPTIONS:\n");
        for o in &self.opts {
            let val = if o.takes_value { " <value>" } else { "" };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{val}\n      {}{def}\n", o.name, o.help));
        }
        s
    }
}

/// Parsed arguments for one command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
    /// Free-form `key=value` overrides collected from `--set k=v`.
    pub overrides: Vec<(String, String)>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {v}: {e}")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(String::as_str)
    }
}

/// Parse `argv` (without the program name) against a command spec.
/// Returns Err with the usage text when `--help` is requested.
pub fn parse(cmd: &Cmd, argv: &[String]) -> Result<Args> {
    let mut args = Args::default();
    for o in &cmd.opts {
        if let (true, Some(d)) = (o.takes_value, o.default) {
            args.values.insert(o.name.to_string(), d.to_string());
        }
    }
    let mut it = argv.iter().peekable();
    while let Some(a) = it.next() {
        if a == "--help" || a == "-h" {
            bail!("{}", cmd.usage());
        }
        if let Some(rest) = a.strip_prefix("--") {
            let (name, inline) = match rest.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (rest, None),
            };
            if name == "set" {
                let v = match inline {
                    Some(v) => v,
                    None => it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--set needs k=v"))?
                        .clone(),
                };
                let (k, val) = v
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("--set wants k=v, got `{v}`"))?;
                args.overrides.push((k.to_string(), val.to_string()));
                continue;
            }
            let spec = cmd
                .opts
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| anyhow::anyhow!("unknown option --{name}\n\n{}", cmd.usage()))?;
            if spec.takes_value {
                let v = match inline {
                    Some(v) => v,
                    None => it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                        .clone(),
                };
                args.values.insert(name.to_string(), v);
            } else {
                if inline.is_some() {
                    bail!("--{name} does not take a value");
                }
                args.flags.push(name.to_string());
            }
        } else {
            args.positionals.push(a.clone());
        }
    }
    if args.positionals.len() > cmd.positionals.len() {
        bail!(
            "too many positional arguments\n\n{}",
            cmd.usage()
        );
    }
    Ok(args)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Cmd {
        Cmd::new("serve", "run the coordinator")
            .opt("config", "config file", None)
            .opt("requests", "request count", Some("100"))
            .flag("verbose", "chatty output")
            .positional("trace", "workload trace")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = parse(
            &cmd(),
            &sv(&["--config=run.json", "--verbose", "t.json", "--requests", "7"]),
        )
        .unwrap();
        assert_eq!(a.get("config"), Some("run.json"));
        assert_eq!(a.get("requests"), Some("7"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(0), Some("t.json"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&cmd(), &sv(&[])).unwrap();
        assert_eq!(a.get("requests"), Some("100"));
        assert_eq!(a.get("config"), None);
        assert_eq!(a.parse_or("requests", 0usize).unwrap(), 100);
    }

    #[test]
    fn collects_set_overrides() {
        let a = parse(&cmd(), &sv(&["--set", "eta=0.3", "--set=lambda=0.6"])).unwrap();
        assert_eq!(
            a.overrides,
            vec![
                ("eta".to_string(), "0.3".to_string()),
                ("lambda".to_string(), "0.6".to_string())
            ]
        );
    }

    #[test]
    fn rejects_unknown_and_help() {
        assert!(parse(&cmd(), &sv(&["--nope"])).is_err());
        let err = parse(&cmd(), &sv(&["--help"])).unwrap_err().to_string();
        assert!(err.contains("USAGE"));
        assert!(err.contains("--requests"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&cmd(), &sv(&["--config"])).is_err());
        assert!(parse(&cmd(), &sv(&["--verbose=1"])).is_err());
    }
}
