//! Importance distributions from the spatial-channel attention module.
//!
//! On the real-artifact path the distribution comes out of the
//! `extractor` artifact (L1 Pallas SCAM). For the eight big paper models
//! (which we cannot run), per-task distributions are *synthesized* with
//! the Zipf-like skew profile of Fig. 7: a few channels dominate, with
//! per-task noise. Skewness is the model-level knob
//! (`ModelProfile::importance_skew`).

use crate::util::{entropy, skewness, Pcg32};

/// A normalized per-channel importance distribution x ~ p(a) (paper Eq. 18
/// epilogue).
#[derive(Clone, Debug)]
pub struct ImportanceDist {
    probs: Vec<f64>,
}

impl ImportanceDist {
    /// Normalize arbitrary non-negative weights.
    pub fn from_weights(ws: &[f64]) -> Self {
        let sum: f64 = ws.iter().map(|x| x.max(0.0)).sum();
        let probs = if sum <= 0.0 {
            vec![1.0 / ws.len().max(1) as f64; ws.len().max(1)]
        } else {
            ws.iter().map(|x| x.max(0.0) / sum).collect()
        };
        Self { probs }
    }

    /// Zipf-like synthetic distribution: p_i ∝ 1/(i+1)^skew over a random
    /// channel permutation, with multiplicative noise. `skew` ≥ 0; higher
    /// means more concentrated (Fig. 7 shows top-3 of 16+ holding ~60%).
    pub fn synthetic(channels: usize, skew: f64, rng: &mut Pcg32) -> Self {
        assert!(channels > 0);
        let mut ws: Vec<f64> = (0..channels)
            .map(|i| {
                let base = 1.0 / ((i + 1) as f64).powf(skew);
                base * (0.7 + 0.6 * rng.next_f64())
            })
            .collect();
        rng.shuffle(&mut ws);
        Self::from_weights(&ws)
    }

    pub fn len(&self) -> usize {
        self.probs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.probs.is_empty()
    }

    pub fn probs(&self) -> &[f64] {
        &self.probs
    }

    /// Channel indices sorted by descending importance.
    pub fn ranked(&self) -> Vec<usize> {
        // total_cmp never panics on NaN, but a NaN prob would rank as
        // the most important channel — keep the fault loud in debug
        debug_assert!(
            self.probs.iter().all(|p| !p.is_nan()),
            "NaN importance prob"
        );
        let mut idx: Vec<usize> = (0..self.probs.len()).collect();
        idx.sort_by(|&a, &b| self.probs[b].total_cmp(&self.probs[a]));
        idx
    }

    /// Total importance mass of the top-k channels.
    pub fn topk_mass(&self, k: usize) -> f64 {
        self.ranked()
            .into_iter()
            .take(k)
            .map(|i| self.probs[i])
            .sum()
    }

    /// Mass of the top quarter of channels — a fixed-width state feature.
    pub fn top_quarter_mass(&self) -> f64 {
        self.topk_mass((self.len() / 4).max(1))
    }

    pub fn skewness(&self) -> f64 {
        skewness(&self.probs)
    }

    /// Entropy normalized to [0,1] by ln(C) (1 = uniform).
    pub fn entropy_norm(&self) -> f64 {
        if self.probs.len() <= 1 {
            return 0.0;
        }
        entropy(&self.probs) / (self.probs.len() as f64).ln()
    }

    /// Split for offload proportion ξ: keep the ⌈(1-ξ)·C⌉ most important
    /// channels locally, offload the rest (the paper's example: ξ=0.7 →
    /// 30% executed locally). Returns (local, offload) channel sets and
    /// the local importance mass.
    pub fn split(&self, xi: f64) -> SplitPlan {
        let c = self.probs.len();
        let xi = xi.clamp(0.0, 1.0);
        let local_count = ((1.0 - xi) * c as f64).round() as usize;
        let ranked = self.ranked();
        let local: Vec<usize> = ranked[..local_count.min(c)].to_vec();
        let offload: Vec<usize> = ranked[local_count.min(c)..].to_vec();
        let local_mass: f64 = local.iter().map(|&i| self.probs[i]).sum();
        SplitPlan {
            local,
            offload,
            local_mass,
            xi,
        }
    }
}

/// The channel partition the offloader executes.
#[derive(Clone, Debug)]
pub struct SplitPlan {
    pub local: Vec<usize>,
    pub offload: Vec<usize>,
    /// importance mass retained on the edge
    pub local_mass: f64,
    pub xi: f64,
}

impl SplitPlan {
    pub fn offload_mass(&self) -> f64 {
        (1.0 - self.local_mass).max(0.0)
    }

    /// Channel mask (1.0 = local) for the artifact heads.
    pub fn local_mask(&self, channels: usize) -> Vec<f32> {
        let mut m = vec![0.0f32; channels];
        for &i in &self.local {
            if i < channels {
                m[i] = 1.0;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_mini as pt;

    #[test]
    fn synthetic_is_normalized_and_skewed() {
        let mut rng = Pcg32::seeded(1);
        let d = ImportanceDist::synthetic(16, 2.2, &mut rng);
        assert_eq!(d.len(), 16);
        let sum: f64 = d.probs().iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!(d.skewness() > 1.0, "skew {}", d.skewness());
        // Fig. 7: top few channels dominate
        assert!(d.topk_mass(3) > 0.4, "top3 {}", d.topk_mass(3));
    }

    #[test]
    fn higher_skew_concentrates_mass() {
        let mut r1 = Pcg32::seeded(2);
        let mut r2 = Pcg32::seeded(2);
        let lo = ImportanceDist::synthetic(32, 0.8, &mut r1);
        let hi = ImportanceDist::synthetic(32, 3.0, &mut r2);
        assert!(hi.topk_mass(4) > lo.topk_mass(4));
        assert!(hi.entropy_norm() < lo.entropy_norm());
    }

    #[test]
    fn split_respects_xi_and_importance() {
        let d = ImportanceDist::from_weights(&[0.4, 0.3, 0.2, 0.05, 0.03, 0.02, 0.0, 0.0]);
        let plan = d.split(0.5);
        assert_eq!(plan.local.len(), 4);
        assert_eq!(plan.offload.len(), 4);
        // top channels stay local
        assert!(plan.local.contains(&0) && plan.local.contains(&1));
        assert!(plan.local_mass > 0.9);
        let mask = plan.local_mask(8);
        assert_eq!(mask.iter().filter(|&&x| x == 1.0).count(), 4);
    }

    #[test]
    fn split_extremes() {
        let d = ImportanceDist::from_weights(&[0.5, 0.5]);
        assert_eq!(d.split(0.0).local.len(), 2);
        assert_eq!(d.split(1.0).local.len(), 0);
        assert!((d.split(1.0).offload_mass() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn split_partition_property() {
        // local ∪ offload is a partition of channels, local_mass matches,
        // for random distributions and ξ.
        pt::check(
            "split partition",
            7,
            300,
            pt::prob_vec(1, 64),
            |ps| {
                let d = ImportanceDist::from_weights(ps);
                let mut rng = Pcg32::seeded(ps.len() as u64);
                let xi = rng.next_f64();
                let plan = d.split(xi);
                let mut all: Vec<usize> =
                    plan.local.iter().chain(plan.offload.iter()).copied().collect();
                all.sort_unstable();
                if all != (0..ps.len()).collect::<Vec<_>>() {
                    return Err("not a partition".into());
                }
                let mass: f64 = plan.local.iter().map(|&i| d.probs()[i]).sum();
                if (mass - plan.local_mass).abs() > 1e-9 {
                    return Err("mass mismatch".into());
                }
                // every local channel outranks every offloaded one
                let min_local = plan
                    .local
                    .iter()
                    .map(|&i| d.probs()[i])
                    .fold(f64::INFINITY, f64::min);
                let max_off = plan
                    .offload
                    .iter()
                    .map(|&i| d.probs()[i])
                    .fold(f64::NEG_INFINITY, f64::max);
                if !plan.local.is_empty()
                    && !plan.offload.is_empty()
                    && min_local < max_off - 1e-12
                {
                    return Err("importance ordering violated".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn degenerate_weights_fall_back_to_uniform() {
        let d = ImportanceDist::from_weights(&[0.0, 0.0, 0.0]);
        assert!((d.probs()[0] - 1.0 / 3.0).abs() < 1e-12);
    }
}
