//! Scoped-thread parallel sweep runner for the experiment harness.
//!
//! The paper sweeps are embarrassingly parallel: every cell of a
//! (model × dataset × policy × knob) grid builds its own config,
//! coordinator, and task generators from scratch, seeds every RNG from
//! cell constants, and shares no mutable state with its siblings — so
//! running cells on worker threads cannot change any cell's output,
//! only the wall clock. `sweep` preserves that contract structurally:
//! results come back in cell-index order (never completion order), so a
//! `--threads N` sweep renders byte-identical tables to `--threads 1`
//! (gated end-to-end by `rust/tests/sweep_determinism.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Run `f(0), f(1), …, f(n-1)` across up to `threads` scoped workers
/// and return the results in index order.
///
/// * `threads <= 1` (or `n <= 1`) runs inline on the caller's thread —
///   no pool, bit-for-bit the serial harness.
/// * Workers pull the next cell index from a shared atomic counter
///   (dynamic scheduling: cells have wildly different costs, e.g. a
///   trained-DQN cell vs an `edge_only` cell), collect `(index,
///   result)` pairs locally, and the caller reassembles them in order.
/// * A worker panic propagates to the caller once the scope joins.
pub fn sweep<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads.max(1).min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut done: Vec<(usize, T)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            return out;
                        }
                        out.push((i, f(i)));
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });
    done.sort_by_key(|&(i, _)| i);
    debug_assert_eq!(done.len(), n, "every cell produced exactly one result");
    done.into_iter().map(|(_, v)| v).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_index_order() {
        // stagger the cells so late indices finish first under threads
        let out = sweep(4, 16, |i| {
            std::thread::sleep(std::time::Duration::from_millis((16 - i as u64) % 5));
            i * 10
        });
        assert_eq!(out, (0..16).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_threaded_agree() {
        let f = |i: usize| (i * i) ^ 0x5a;
        assert_eq!(sweep(1, 33, f), sweep(4, 33, f));
        assert_eq!(sweep(64, 33, f), sweep(1, 33, f), "threads > cells");
    }

    #[test]
    fn degenerate_sizes() {
        assert!(sweep(8, 0, |i| i).is_empty());
        assert_eq!(sweep(8, 1, |i| i + 1), vec![1]);
        assert_eq!(sweep(0, 3, |i| i), vec![0, 1, 2], "threads 0 = inline");
    }

    #[test]
    #[should_panic(expected = "sweep worker panicked")]
    fn worker_panics_propagate() {
        sweep(2, 8, |i| {
            if i == 5 {
                panic!("cell exploded");
            }
            i
        });
    }
}
