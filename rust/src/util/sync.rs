//! §Determinism — loom-checkable synchronization primitives.
//!
//! The two concurrency protocols in this crate that no replay gate can
//! cover — the shard epoch exchange (`coordinator/shard.rs`: publish →
//! barrier → index-ordered read → adopt) and the background-learner
//! handshake (`dqn/learner.rs`: bounded push / `Publish` marker /
//! double-buffered snapshot / finish-drain) — are built from the
//! primitives in this module instead of raw `std::sync` machinery.
//! Under `--cfg loom` the primitives swap `std::sync` for `loom::sync`,
//! and `rust/tests/loom_models.rs` model-checks both protocols across
//! every feasible interleaving (see the "Determinism contract" section
//! of the README). A plain build compiles against `std` and never
//! resolves the loom crate.
//!
//! Design rule: everything here is expressed with `Mutex` + `Condvar`
//! only — the intersection of `std::sync` and `loom::sync` — so the
//! checked model and the shipped code are the *same* code.

use std::collections::VecDeque;

#[cfg(loom)]
use loom::sync::{Condvar, Mutex};
#[cfg(not(loom))]
use std::sync::{Condvar, Mutex};

const POISONED: &str = "sync mutex poisoned";

/// A cyclic sense-reversing barrier. `std::sync::Barrier` is absent
/// from `loom::sync`, so the epoch exchange carries its own; the
/// generation counter is what makes reuse across epochs safe (a waiter
/// from epoch `e` can never be released by epoch `e+1`'s arrivals).
pub struct SenseBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
    parties: usize,
}

struct BarrierState {
    arrived: usize,
    generation: u64,
}

impl SenseBarrier {
    pub fn new(parties: usize) -> Self {
        assert!(parties >= 1, "a barrier needs at least one party");
        Self {
            state: Mutex::new(BarrierState {
                arrived: 0,
                generation: 0,
            }),
            cv: Condvar::new(),
            parties,
        }
    }

    /// Block until all `parties` threads have called `wait` for the
    /// current generation.
    pub fn wait(&self) {
        let mut st = self.state.lock().expect(POISONED);
        let gen = st.generation;
        st.arrived += 1;
        if st.arrived == self.parties {
            st.arrived = 0;
            st.generation = st.generation.wrapping_add(1);
            self.cv.notify_all();
        } else {
            while st.generation == gen {
                st = self.cv.wait(st).expect(POISONED);
            }
        }
    }
}

/// The shard-boundary exchange cell: `N` published slots plus a shared
/// barrier. One `exchange_with` call is one epoch boundary for one
/// participant:
///
/// 1. publish this participant's value into its own slot,
/// 2. barrier — every slot holds this epoch's publication before anyone
///    reads,
/// 3. read *all* slots in ascending index order (thread scheduling can
///    never leak into the fold order),
/// 4. barrier — nobody re-publishes until everyone has consumed this
///    epoch's snapshots.
///
/// Without step 4 a fast participant could overwrite its slot with the
/// next epoch's value while a slow one is still reading — the exact
/// interleaving `tests/loom_models.rs` proves impossible and the
/// regression seed in `coordinator/shard.rs` pins.
pub struct EpochExchange<T> {
    slots: Vec<Mutex<T>>,
    barrier: SenseBarrier,
}

impl<T: Clone> EpochExchange<T> {
    pub fn new(parties: usize, init: T) -> Self {
        assert!(parties >= 1, "an exchange needs at least one party");
        Self {
            slots: (0..parties).map(|_| Mutex::new(init.clone())).collect(),
            barrier: SenseBarrier::new(parties),
        }
    }

    pub fn parties(&self) -> usize {
        self.slots.len()
    }

    /// Publish `value` as participant `k`, then hand every participant's
    /// published value (own included) to `read` in ascending index
    /// order. Returns only after *all* participants have both published
    /// and read, so the next epoch's publications can never race this
    /// epoch's reads.
    pub fn exchange_with<F: FnMut(usize, &T)>(&self, k: usize, value: T, mut read: F) {
        *self.slots[k].lock().expect(POISONED) = value;
        self.barrier.wait();
        for (i, slot) in self.slots.iter().enumerate() {
            read(i, &slot.lock().expect(POISONED));
        }
        self.barrier.wait();
    }
}

/// A bounded MPSC-style queue with explicit close semantics, replacing
/// `std::sync::mpsc::sync_channel` (which `loom::sync` does not
/// provide) in the learner handshake:
///
/// * `push` blocks while the queue is full (backpressure, never loss)
///   and fails only once the queue is closed;
/// * `pop` blocks while the queue is empty and still open, and keeps
///   draining queued items *after* close — `None` means closed **and**
///   empty, which is what makes finish-drain lossless;
/// * `close` wakes every blocked pusher and popper.
pub struct BoundedQueue<T> {
    inner: Mutex<QueueState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        Self {
            inner: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap: cap.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Blocking push; `Err(value)` once the queue is closed.
    pub fn push(&self, value: T) -> Result<(), T> {
        let mut st = self.inner.lock().expect(POISONED);
        while st.items.len() >= self.cap && !st.closed {
            st = self.not_full.wait(st).expect(POISONED);
        }
        if st.closed {
            return Err(value);
        }
        st.items.push_back(value);
        self.not_empty.notify_all();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.lock().expect(POISONED);
        while st.items.is_empty() && !st.closed {
            st = self.not_empty.wait(st).expect(POISONED);
        }
        let item = st.items.pop_front();
        if item.is_some() {
            self.not_full.notify_all();
        }
        item
    }

    /// Non-blocking push (regression seeds drive the protocol from a
    /// single thread); `Err(value)` when full or closed.
    pub fn try_push(&self, value: T) -> Result<(), T> {
        let mut st = self.inner.lock().expect(POISONED);
        if st.closed || st.items.len() >= self.cap {
            return Err(value);
        }
        st.items.push_back(value);
        self.not_empty.notify_all();
        Ok(())
    }

    /// Non-blocking pop; `None` when currently empty.
    pub fn try_pop(&self) -> Option<T> {
        let mut st = self.inner.lock().expect(POISONED);
        let item = st.items.pop_front();
        if item.is_some() {
            self.not_full.notify_all();
        }
        item
    }

    /// Close the queue: pending and future `push`es fail, `pop` drains
    /// what is already queued and then reports `None`. Idempotent.
    pub fn close(&self) {
        let mut st = self.inner.lock().expect(POISONED);
        st.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().expect(POISONED).closed
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect(POISONED).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Worker half of the double-buffered snapshot handshake: prefer the
/// locally parked spare buffer, otherwise block for the buffer the
/// actor returned after its last adoption. `None` means the actor hung
/// up — the worker should stop publishing.
pub fn take_publish_buf<W>(spare: &mut Option<W>, returns: &BoundedQueue<W>) -> Option<W> {
    match spare.take() {
        Some(buf) => Some(buf),
        None => returns.pop(),
    }
}

/// Actor half of the handshake: block for the freshly published
/// snapshot, adopt it, and hand the previous buffer back to the worker
/// for reuse. Returns `false` when the worker hung up (no snapshot will
/// ever arrive).
pub fn adopt_snapshot<W>(
    current: &mut W,
    snaps: &BoundedQueue<W>,
    returns: &BoundedQueue<W>,
) -> bool {
    match snaps.pop() {
        Some(fresh) => {
            let old = std::mem::replace(current, fresh);
            // the worker may already have exited; the buffer is then
            // simply dropped
            let _ = returns.push(old);
            true
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn bounded_queue_is_fifo_and_drains_after_close() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        q.close();
        assert!(q.push(99).is_err(), "push after close must fail");
        let drained: Vec<i32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(drained, vec![0, 1, 2, 3, 4]);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn bounded_queue_try_ops_respect_capacity() {
        let q = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert!(q.try_push(3).is_err(), "capacity 2 is full");
        assert_eq!(q.try_pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn bounded_queue_backpressures_until_a_pop_frees_a_slot() {
        let q = BoundedQueue::new(1);
        q.push(0u32).unwrap();
        std::thread::scope(|s| {
            let qr = &q;
            let pusher = s.spawn(move || qr.push(1).is_ok());
            // the queue is full, so the pusher must be blocked until
            // this pop frees the slot
            assert_eq!(q.pop(), Some(0));
            assert!(pusher.join().unwrap());
        });
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn close_releases_a_blocked_pusher() {
        let q = BoundedQueue::new(1);
        q.push(7u32).unwrap();
        std::thread::scope(|s| {
            let qr = &q;
            let pusher = s.spawn(move || qr.push(8).is_err());
            q.close();
            assert!(pusher.join().unwrap(), "blocked push must fail on close");
        });
        // the queued item survives the close
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn sense_barrier_keeps_generations_separate() {
        let barrier = SenseBarrier::new(2);
        let turns = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let (b, t) = (&barrier, &turns);
            for _ in 0..2 {
                s.spawn(move || {
                    for round in 0..100 {
                        b.wait();
                        // both threads observe every round boundary: the
                        // counter is exactly 2 * round after each wait
                        let seen = t.fetch_add(1, Ordering::SeqCst);
                        assert!(seen / 2 == round, "round {round} saw counter {seen}");
                        b.wait();
                    }
                });
            }
        });
        assert_eq!(turns.load(Ordering::SeqCst), 200);
    }

    #[test]
    fn epoch_exchange_reads_every_slot_in_index_order() {
        let ex = EpochExchange::new(3, 0u64);
        std::thread::scope(|s| {
            let exr = &ex;
            for k in 0..3usize {
                s.spawn(move || {
                    for epoch in 1..=10u64 {
                        let mut seen = Vec::new();
                        exr.exchange_with(k, epoch * 10 + k as u64, |i, &v| seen.push((i, v)));
                        let want: Vec<(usize, u64)> =
                            (0..3).map(|i| (i, epoch * 10 + i as u64)).collect();
                        assert_eq!(seen, want, "epoch {epoch} participant {k}");
                    }
                });
            }
        });
    }

    #[test]
    fn snapshot_handshake_helpers_cycle_buffers() {
        let snaps = BoundedQueue::new(1);
        let rets = BoundedQueue::new(2);
        let mut spare = Some(Box::new(0u64));
        // worker publishes 41 out of its spare buffer
        let mut buf = take_publish_buf(&mut spare, &rets).unwrap();
        *buf = 41;
        snaps.push(buf).unwrap();
        // actor adopts and returns its old buffer
        let mut net = Box::new(7u64);
        assert!(adopt_snapshot(&mut net, &snaps, &rets));
        assert_eq!(*net, 41);
        // the spare is gone, so the next publish reuses the returned one
        assert!(spare.is_none());
        let mut buf = take_publish_buf(&mut spare, &rets).unwrap();
        assert_eq!(*buf, 7, "worker got the actor's old buffer back");
        *buf = 42;
        snaps.push(buf).unwrap();
        assert!(adopt_snapshot(&mut net, &snaps, &rets));
        assert_eq!(*net, 42);
        // worker hung up: adoption reports failure
        snaps.close();
        assert!(!adopt_snapshot(&mut net, &snaps, &rets));
        assert_eq!(*net, 42, "failed adoption leaves the snapshot alone");
    }
}
