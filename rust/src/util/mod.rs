//! Small shared utilities: deterministic PRNGs, online statistics, a ring
//! buffer, formatting helpers, and the scoped-thread sweep runner. These
//! stand in for `rand`/`statrs`/`rayon` which are unavailable in the
//! offline crate set (DESIGN.md §Substitutions).
pub mod parallel;
pub mod rng;
pub mod stats;
pub mod sync;

pub use parallel::sweep;
pub use rng::{Pcg32, SplitMix64};
pub use stats::{entropy, skewness, Ewma, Running, Samples};

/// Fixed-capacity ring buffer (used for bandwidth traces and telemetry
/// windows).
#[derive(Clone, Debug)]
pub struct RingBuf<T> {
    buf: Vec<T>,
    head: usize,
    len: usize,
    cap: usize,
}

impl<T: Clone> RingBuf<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            buf: Vec::with_capacity(cap),
            head: 0,
            len: 0,
            cap,
        }
    }

    pub fn push(&mut self, x: T) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
            self.len = self.buf.len();
        } else {
            self.buf[self.head] = x;
            self.head = (self.head + 1) % self.cap;
            self.len = self.cap;
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Oldest-to-newest iteration.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        let (a, b) = self.buf.split_at(self.head.min(self.buf.len()));
        b.iter().chain(a.iter())
    }

    pub fn latest(&self) -> Option<&T> {
        if self.len == 0 {
            return None;
        }
        let idx = if self.buf.len() < self.cap {
            self.buf.len() - 1
        } else {
            (self.head + self.cap - 1) % self.cap
        };
        self.buf.get(idx)
    }
}

/// Human-readable engineering formatting: `fmt_si(1_500_000.0, "B") = "1.50 MB"`.
pub fn fmt_si(x: f64, unit: &str) -> String {
    let (v, p) = if x.abs() >= 1e9 {
        (x / 1e9, "G")
    } else if x.abs() >= 1e6 {
        (x / 1e6, "M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, "k")
    } else if x.abs() >= 1.0 || x == 0.0 {
        (x, "")
    } else if x.abs() >= 1e-3 {
        (x * 1e3, "m")
    } else {
        (x * 1e6, "µ")
    };
    format!("{v:.2} {p}{unit}")
}

/// Clamp helper for f64 (std's clamp panics on NaN bounds edge cases in
/// hot loops where we want a plain min/max chain).
#[inline]
pub fn clampf(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ringbuf_wraps() {
        let mut rb = RingBuf::new(3);
        for i in 0..5 {
            rb.push(i);
        }
        assert_eq!(rb.len(), 3);
        let xs: Vec<_> = rb.iter().copied().collect();
        assert_eq!(xs, vec![2, 3, 4]);
        assert_eq!(*rb.latest().unwrap(), 4);
    }

    #[test]
    fn ringbuf_partial() {
        let mut rb = RingBuf::new(8);
        rb.push(1);
        rb.push(2);
        assert_eq!(rb.len(), 2);
        assert_eq!(rb.iter().copied().collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(*rb.latest().unwrap(), 2);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(fmt_si(1_500_000.0, "B"), "1.50 MB");
        assert_eq!(fmt_si(0.0123, "s"), "12.30 ms");
        assert_eq!(fmt_si(42.0, "J"), "42.00 J");
    }

    #[test]
    fn clamp_works() {
        assert_eq!(clampf(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clampf(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clampf(0.5, 0.0, 1.0), 0.5);
    }
}
