//! Deterministic PRNGs (the offline crate set has no `rand`).
//!
//! `SplitMix64` for seeding / cheap streams, `Pcg32` as the general-purpose
//! generator. Both are well-studied, tiny, and reproducible across runs —
//! which the experiment harness relies on (every figure is regenerated from
//! a fixed seed).

/// SplitMix64: 64-bit state, one multiply-xorshift round per output.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR): 64-bit state, 32-bit output, stream-selectable.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed both state and stream from one value via SplitMix64.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = sm.next_u64();
        let t = sm.next_u64();
        Self::new(s, t)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64).wrapping_mul(n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64).wrapping_mul(n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with given rate (for Poisson inter-arrivals).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.next_f64().max(1e-300).ln() / rate
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_is_deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::new(1, 1);
        let mut b = Pcg32::new(1, 2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiasedish() {
        let mut r = Pcg32::seeded(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg32::seeded(13);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
