//! Running statistics and percentile helpers for telemetry and benches.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }

    pub fn merge(&mut self, other: &Running) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact-percentile sample buffer. The sorted view is computed lazily on
/// the first percentile query after a push and cached until the next
/// push, so a p50/p95/p99 triple costs one O(n log n) sort instead of
/// three clone-and-sorts per summary.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
    /// lazily sorted copy of `xs` (total_cmp order); `None` = stale
    sorted: std::cell::RefCell<Option<Vec<f64>>>,
}

impl Samples {
    pub fn new() -> Self {
        Self {
            xs: Vec::new(),
            sorted: std::cell::RefCell::new(None),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        *self.sorted.get_mut() = None;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Percentile in [0, 100] with linear interpolation.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut cache = self.sorted.borrow_mut();
        let s = cache.get_or_insert_with(|| {
            let mut v = self.xs.clone();
            // total_cmp: a NaN sample sorts after +inf instead of
            // panicking the comparator
            v.sort_by(f64::total_cmp);
            v
        });
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Pearson skewness (third standardized moment) of a probability
/// distribution given as weights — used for the importance-distribution
/// state feature (paper: offloading effectiveness depends on skewness).
pub fn skewness(p: &[f64]) -> f64 {
    let n = p.len() as f64;
    if p.is_empty() {
        return 0.0;
    }
    let mean = p.iter().sum::<f64>() / n;
    let var = p.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
    if var <= 1e-18 {
        return 0.0;
    }
    let m3 = p.iter().map(|x| (x - mean).powi(3)).sum::<f64>() / n;
    m3 / var.powf(1.5)
}

/// Shannon entropy (nats) of a normalized distribution.
pub fn entropy(p: &[f64]) -> f64 {
    p.iter()
        .filter(|&&x| x > 0.0)
        .map(|&x| -x * x.ln())
        .sum()
}

/// Exponentially-weighted moving average.
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    /// Overwrite the smoothed value directly. Used by the sharded engine
    /// runner: at epoch boundaries every shard adopts the same blended
    /// global estimate, then keeps smoothing locally from that point.
    /// `None` resets the filter to its cold state.
    pub fn set(&mut self, v: Option<f64>) {
        self.value = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_naive() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((r.mean() - mean).abs() < 1e-12);
        assert!((r.variance() - var).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn running_merge_equals_concat() {
        let a = [1.0, 2.0, 3.0];
        let b = [10.0, 20.0];
        let mut ra = Running::new();
        let mut rb = Running::new();
        a.iter().for_each(|&x| ra.push(x));
        b.iter().for_each(|&x| rb.push(x));
        let mut rc = Running::new();
        a.iter().chain(b.iter()).for_each(|&x| rc.push(x));
        ra.merge(&rb);
        assert!((ra.mean() - rc.mean()).abs() < 1e-12);
        assert!((ra.variance() - rc.variance()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!(s.p95() > 94.0 && s.p95() < s.p99());
        assert!(s.p99() > 98.0);
    }

    /// The pre-cache implementation, verbatim: clone + sort on every
    /// query. The cached path must agree with it exactly.
    fn naive_percentile(xs: &[f64], p: f64) -> f64 {
        if xs.is_empty() {
            return f64::NAN;
        }
        let mut s = xs.to_vec();
        // detlint: allow(R1, frozen pre-cache reference kept verbatim; inputs are NaN-free)
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }

    #[test]
    fn cached_percentiles_match_the_old_implementation() {
        let mut s = Samples::new();
        let mut xs = Vec::new();
        // deterministic scrambled sequence with duplicates
        for i in 0u64..257 {
            let x = ((i * 37) % 101) as f64 - 50.0;
            s.push(x);
            xs.push(x);
        }
        for p in [0.0, 10.0, 50.0, 95.0, 99.0, 100.0] {
            let want = naive_percentile(&xs, p);
            let got = s.percentile(p);
            assert_eq!(got.to_bits(), want.to_bits(), "p{p}: {got} vs {want}");
        }
        // pushes after a query must invalidate the cached sort
        for x in [1e6, -1e6, 0.25] {
            s.push(x);
            xs.push(x);
        }
        for p in [50.0, 95.0, 99.0] {
            let want = naive_percentile(&xs, p);
            let got = s.percentile(p);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "post-push p{p}: {got} vs {want}"
            );
        }
        // a clone must not share (or miss) the original's cache
        let mut c = s.clone();
        c.push(42.0);
        xs.push(42.0);
        assert_eq!(c.p50().to_bits(), naive_percentile(&xs, 50.0).to_bits());
        xs.pop();
        assert_eq!(s.p50().to_bits(), naive_percentile(&xs, 50.0).to_bits());
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // regression: the old partial_cmp().unwrap() comparator panicked
        // the moment a NaN landed in the buffer; total_cmp gives NaN a
        // fixed slot after +inf instead
        let mut s = Samples::new();
        s.push(1.0);
        s.push(f64::NAN);
        s.push(3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.p50(), 3.0);
        assert!(s.percentile(100.0).is_nan(), "NaN sorts last");
        // interpolation across the NaN slot propagates NaN, no panic
        assert!(s.percentile(75.0).is_nan());
    }

    #[test]
    fn skewness_sign() {
        // right-skewed: a few large values
        let right = [0.01, 0.01, 0.01, 0.01, 0.96];
        assert!(skewness(&right) > 0.5);
        let uniform = [0.2; 5];
        assert!(skewness(&uniform).abs() < 1e-9);
    }

    #[test]
    fn entropy_bounds() {
        let uniform = [0.25; 4];
        assert!((entropy(&uniform) - (4.0f64).ln()).abs() < 1e-12);
        let point = [1.0, 0.0, 0.0, 0.0];
        assert!(entropy(&point).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..64 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn ewma_set_seeds_the_filter() {
        let mut e = Ewma::new(0.5);
        e.set(Some(4.0));
        assert_eq!(e.get(), Some(4.0));
        // a push after set() smooths from the injected value, exactly as
        // if 4.0 had been the accumulated history
        assert!((e.push(8.0) - 6.0).abs() < 1e-12);
        e.set(None);
        assert_eq!(e.get(), None);
        assert_eq!(e.push(3.0), 3.0, "None resets to cold start");
    }
}
