//! `EngineConfig`: the one flat parameter surface of the serving engine.
//!
//! Five PRs of knob growth left the engine's tunables scattered across
//! [`DesOpts`] (batching windows, cloud pool) and [`FleetOpts`]
//! (routing, admission, reroute/rebalance/migrate) with the sharding
//! and telemetry controls about to pile on top. This module flattens
//! all of them into one builder-style struct: construct with
//! [`EngineConfig::new`] (or [`EngineConfig::from_config`] for the CLI
//! path), chain the setters you care about, and convert to the
//! engine-internal blocks with [`EngineConfig::fleet_opts`] /
//! [`EngineConfig::des_opts`] at the call boundary. The legacy types
//! stay as the kernel's internal parameter blocks; the parity test in
//! `rust/tests/engine_config_parity.rs` pins both construction paths to
//! identical values so downstream callers can migrate incrementally.

use super::chaos::{FaultSchedule, RetryPolicy};
use super::des::DesOpts;
use super::fleet::{Admission, FleetOpts, Router};
use super::sched::SchedKind;
use super::shard::SHARD_EPOCH_S;
use crate::configx::Config;
use crate::dqn::LearnerMode;
use anyhow::Result;

/// Every engine tunable in one flat, builder-style block: uplink/cloud
/// batching, the shared executor pool, routing, admission, the
/// rebalancing knobs, and the scale-out (sharding + streaming
/// telemetry) controls.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// uplink batching window in seconds; 0 disables batching
    pub batch_window_s: f64,
    /// maximum offloads per uplink batch (a full batch flushes early)
    pub max_batch: usize,
    /// concurrent cloud executors (beyond this, cloud work queues)
    pub cloud_slots: usize,
    /// cloud-side cross-device batching window in seconds; 0 disables
    pub cloud_batch_window_s: f64,
    /// maximum jobs per batched cloud invocation
    pub cloud_max_batch: usize,
    /// event-scheduler backend (heap or calendar queue); both pop in
    /// the identical (time, seq) order — purely a performance knob
    pub sched: SchedKind,
    /// fleet dispatch policy
    pub router: Router,
    /// admission policy for deadline-doomed tasks
    pub admission: Admission,
    /// re-route-before-shed across sibling devices
    pub reroute: bool,
    /// cross-device rebalance tick period in seconds; 0 = no ticks
    pub rebalance_window_s: f64,
    /// backlog divergence (s) that triggers queued-task migration
    pub migrate_threshold_s: f64,
    /// latency penalty per migrated task in transit (s)
    pub migrate_penalty_s: f64,
    /// deterministic fault schedule; empty schedules no fault events
    pub chaos: FaultSchedule,
    /// retry budget + exponential backoff for fault-killed work
    pub retry: RetryPolicy,
    /// share-nothing engine shards; <= 1 runs the unsharded kernel
    pub shards: usize,
    /// epoch length (simulated s) for cross-shard cloud-signal sync
    pub shard_epoch_s: f64,
    /// constant-memory telemetry (streaming sinks) instead of collected
    /// per-task reports
    pub stream_telemetry: bool,
    /// DQN gradient-step placement for training policies (dvfo/drldo):
    /// consumed at policy construction (`build_policy`), not by
    /// `des_opts()`/`fleet_opts()`
    pub learner: LearnerMode,
    /// background-learner snapshot cadence (transitions per publish);
    /// same consumption point as `learner`
    pub learner_publish_every: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        let des = DesOpts::default();
        let fleet = FleetOpts::default();
        Self {
            batch_window_s: des.batch_window_s,
            max_batch: des.max_batch,
            cloud_slots: des.cloud_slots,
            cloud_batch_window_s: des.cloud_batch_window_s,
            cloud_max_batch: des.cloud_max_batch,
            sched: des.sched,
            router: fleet.router,
            admission: fleet.admission,
            reroute: fleet.reroute,
            rebalance_window_s: fleet.rebalance_window_s,
            migrate_threshold_s: fleet.migrate_threshold_s,
            migrate_penalty_s: fleet.migrate_penalty_s,
            chaos: fleet.chaos,
            retry: fleet.retry,
            shards: 1,
            shard_epoch_s: SHARD_EPOCH_S,
            stream_telemetry: false,
            learner: LearnerMode::Inline,
            learner_publish_every: 32,
        }
    }
}

impl EngineConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from a run config: the same key set (and the same ms→s
    /// conversions) as `DesOpts::from_config` + `FleetOpts::from_config`,
    /// plus the `shards` / `stream_telemetry` scale-out keys.
    pub fn from_config(cfg: &Config) -> Result<Self> {
        Ok(Self {
            batch_window_s: cfg.batch_window_ms / 1e3,
            max_batch: cfg.max_batch,
            cloud_slots: cfg.cloud_slots,
            cloud_batch_window_s: cfg.cloud_batch_window_ms / 1e3,
            cloud_max_batch: cfg.cloud_max_batch,
            sched: SchedKind::parse(&cfg.scheduler)?,
            router: Router::parse(&cfg.router)?,
            admission: Admission::parse(&cfg.admission)?,
            reroute: cfg.reroute,
            rebalance_window_s: cfg.rebalance_window_ms / 1e3,
            migrate_threshold_s: cfg.migrate_threshold_ms / 1e3,
            migrate_penalty_s: cfg.migrate_penalty_ms / 1e3,
            chaos: FaultSchedule::parse(&cfg.chaos)?,
            retry: RetryPolicy {
                max_retries: cfg.retry_max as u32,
                backoff_base_s: cfg.retry_backoff_ms / 1e3,
            },
            shards: cfg.shards,
            shard_epoch_s: SHARD_EPOCH_S,
            stream_telemetry: cfg.stream_telemetry,
            learner: LearnerMode::parse(&cfg.learner)?,
            learner_publish_every: cfg.learner_publish_every,
        })
    }

    pub fn batch_window_s(mut self, v: f64) -> Self {
        self.batch_window_s = v;
        self
    }

    pub fn max_batch(mut self, v: usize) -> Self {
        self.max_batch = v;
        self
    }

    pub fn cloud_slots(mut self, v: usize) -> Self {
        self.cloud_slots = v;
        self
    }

    pub fn cloud_batch_window_s(mut self, v: f64) -> Self {
        self.cloud_batch_window_s = v;
        self
    }

    pub fn cloud_max_batch(mut self, v: usize) -> Self {
        self.cloud_max_batch = v;
        self
    }

    pub fn sched(mut self, v: SchedKind) -> Self {
        self.sched = v;
        self
    }

    pub fn router(mut self, v: Router) -> Self {
        self.router = v;
        self
    }

    pub fn admission(mut self, v: Admission) -> Self {
        self.admission = v;
        self
    }

    pub fn reroute(mut self, v: bool) -> Self {
        self.reroute = v;
        self
    }

    pub fn rebalance_window_s(mut self, v: f64) -> Self {
        self.rebalance_window_s = v;
        self
    }

    pub fn migrate_threshold_s(mut self, v: f64) -> Self {
        self.migrate_threshold_s = v;
        self
    }

    pub fn migrate_penalty_s(mut self, v: f64) -> Self {
        self.migrate_penalty_s = v;
        self
    }

    pub fn chaos(mut self, v: FaultSchedule) -> Self {
        self.chaos = v;
        self
    }

    pub fn retry(mut self, v: RetryPolicy) -> Self {
        self.retry = v;
        self
    }

    pub fn shards(mut self, v: usize) -> Self {
        self.shards = v;
        self
    }

    pub fn shard_epoch_s(mut self, v: f64) -> Self {
        self.shard_epoch_s = v;
        self
    }

    pub fn stream_telemetry(mut self, v: bool) -> Self {
        self.stream_telemetry = v;
        self
    }

    pub fn learner(mut self, v: LearnerMode) -> Self {
        self.learner = v;
        self
    }

    pub fn learner_publish_every(mut self, v: usize) -> Self {
        self.learner_publish_every = v;
        self
    }

    /// The DES parameter block (uplink/cloud batching + executor pool).
    pub fn des_opts(&self) -> DesOpts {
        DesOpts {
            batch_window_s: self.batch_window_s,
            max_batch: self.max_batch,
            cloud_slots: self.cloud_slots,
            cloud_batch_window_s: self.cloud_batch_window_s,
            cloud_max_batch: self.cloud_max_batch,
            sched: self.sched,
        }
    }

    /// The fleet parameter block the engine entry points take.
    pub fn fleet_opts(&self) -> FleetOpts {
        FleetOpts {
            des: self.des_opts(),
            router: self.router,
            admission: self.admission,
            reroute: self.reroute,
            rebalance_window_s: self.rebalance_window_s,
            migrate_threshold_s: self.migrate_threshold_s,
            migrate_penalty_s: self.migrate_penalty_s,
            chaos: self.chaos.clone(),
            retry: self.retry,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains_and_converts() {
        #![allow(clippy::unwrap_used)]
        let schedule = FaultSchedule::parse("down:0@100+50").unwrap();
        let ec = EngineConfig::new()
            .batch_window_s(0.004)
            .cloud_slots(2)
            .sched(SchedKind::Heap)
            .router(Router::LeastBacklog)
            .admission(Admission::Shed)
            .reroute(true)
            .rebalance_window_s(0.01)
            .migrate_threshold_s(0.05)
            .migrate_penalty_s(0.002)
            .chaos(schedule.clone())
            .retry(RetryPolicy {
                max_retries: 5,
                backoff_base_s: 0.002,
            })
            .shards(4)
            .stream_telemetry(true)
            .learner(LearnerMode::Background)
            .learner_publish_every(16);
        let fo = ec.fleet_opts();
        assert_eq!(fo.des.batch_window_s, 0.004);
        assert_eq!(fo.des.cloud_slots, 2);
        assert_eq!(fo.des.sched, SchedKind::Heap);
        assert_eq!(fo.router, Router::LeastBacklog);
        assert_eq!(fo.admission, Admission::Shed);
        assert!(fo.reroute);
        assert_eq!(fo.rebalance_window_s, 0.01);
        assert_eq!(fo.migrate_threshold_s, 0.05);
        assert_eq!(fo.migrate_penalty_s, 0.002);
        assert_eq!(fo.chaos, schedule);
        assert_eq!(fo.retry.max_retries, 5);
        assert_eq!(fo.retry.backoff_base_s, 0.002);
        assert_eq!(ec.shards, 4);
        assert!(ec.stream_telemetry);
        assert_eq!(ec.learner, LearnerMode::Background);
        assert_eq!(ec.learner_publish_every, 16);
    }

    #[test]
    fn default_matches_legacy_defaults() {
        let ec = EngineConfig::default();
        let fo = ec.fleet_opts();
        let legacy = FleetOpts::default();
        assert_eq!(fo.des.batch_window_s, legacy.des.batch_window_s);
        assert_eq!(fo.des.max_batch, legacy.des.max_batch);
        assert_eq!(fo.des.cloud_slots, legacy.des.cloud_slots);
        assert_eq!(fo.des.cloud_batch_window_s, legacy.des.cloud_batch_window_s);
        assert_eq!(fo.des.cloud_max_batch, legacy.des.cloud_max_batch);
        assert_eq!(fo.des.sched, legacy.des.sched);
        assert_eq!(fo.router, legacy.router);
        assert_eq!(fo.admission, legacy.admission);
        assert_eq!(fo.reroute, legacy.reroute);
        assert_eq!(fo.rebalance_window_s, legacy.rebalance_window_s);
        assert_eq!(fo.migrate_threshold_s, legacy.migrate_threshold_s);
        assert_eq!(fo.migrate_penalty_s, legacy.migrate_penalty_s);
        assert_eq!(fo.chaos, legacy.chaos);
        assert!(fo.chaos.is_empty());
        assert_eq!(fo.retry, legacy.retry);
        assert_eq!(fo.retry.max_retries, 3);
        assert_eq!(ec.shards, 1);
        assert!(!ec.stream_telemetry);
        assert_eq!(ec.learner, LearnerMode::Inline);
        assert_eq!(ec.learner_publish_every, 32);
    }
}
