//! Discrete-event, multi-stream serving: the single-edge entry point.
//!
//! `serve_multistream` simulates one loaded edge node — N concurrent
//! user streams (each a `TaskGen` with its own seed and arrival
//! process) feed a FIFO edge queue; offloaded feature maps queue on a
//! single uplink where they can be **batched** within a configurable
//! window; cloud execution runs on a bounded executor pool with its own
//! cross-device batching window.
//!
//! The event machinery itself lives in the unified kernel
//! (`super::engine`) shared with the fleet dispatcher; this module is
//! the N = 1 delegation plus the [`DesOpts`] tunables. With one stream,
//! sequential arrivals and batching disabled, the kernel reproduces the
//! legacy synchronous `Coordinator::serve` results task-for-task (the
//! parity gate in `rust/tests/multistream_queueing.rs`). What the
//! discrete-event path adds on top is *queueing*: per-task queue wait,
//! batching delay, and an end-to-end latency that includes them, plus
//! per-stream energy totals.
//!
//! Before each decision the kernel publishes `Coordinator::load`
//! (queue depth + backlog estimate), which queue-aware policies fold
//! into the DQN state (`Obs::features_ext`).

use super::engine;
use super::fleet::FleetOpts;
use super::sched::SchedKind;
use super::{Coordinator, ServeSummary};
use crate::workload::TaskGen;

/// Tunables of the discrete-event serving core.
///
/// Deprecated as a construction surface: prefer
/// [`EngineConfig`](super::EngineConfig) and convert with
/// [`EngineConfig::des_opts`](super::EngineConfig::des_opts). This type
/// remains the kernel-internal parameter block (the parity test in
/// `rust/tests/engine_config_parity.rs` pins both paths to identical
/// values).
#[derive(Clone, Debug)]
pub struct DesOpts {
    /// uplink batching window in seconds; 0 disables batching (every
    /// offload ships alone, preserving legacy timing exactly)
    pub batch_window_s: f64,
    /// maximum offloads per uplink batch (a full batch flushes early)
    pub max_batch: usize,
    /// concurrent cloud executors (beyond this, cloud work queues)
    pub cloud_slots: usize,
    /// cloud-side batching window in seconds; co-arriving cloud work —
    /// across devices in a fleet — merges into one batched executor
    /// invocation. 0 disables batching (every cloud job runs in its own
    /// invocation, preserving pre-batching timing exactly)
    pub cloud_batch_window_s: f64,
    /// maximum jobs per batched cloud invocation (a full batch flushes
    /// before the window closes)
    pub cloud_max_batch: usize,
    /// event-scheduler backend (`heap` or `calendar`); both produce the
    /// identical event order, so this is purely a performance knob
    pub sched: SchedKind,
}

impl Default for DesOpts {
    fn default() -> Self {
        Self {
            batch_window_s: 0.0,
            max_batch: 16,
            cloud_slots: 4,
            cloud_batch_window_s: 0.0,
            cloud_max_batch: 16,
            sched: SchedKind::default(),
        }
    }
}

impl DesOpts {
    /// Build from a run config (`batch_window_ms`, `max_batch`,
    /// `cloud_slots`, `cloud_batch_window_ms`, `cloud_max_batch`,
    /// `scheduler` config keys / CLI flags).
    pub fn from_config(cfg: &crate::configx::Config) -> Self {
        Self {
            batch_window_s: cfg.batch_window_ms / 1e3,
            max_batch: cfg.max_batch,
            cloud_slots: cfg.cloud_slots,
            cloud_batch_window_s: cfg.cloud_batch_window_ms / 1e3,
            cloud_max_batch: cfg.cloud_max_batch,
            // `Config::validate` rejects unknown schedulers before any
            // serving path reaches this conversion; fall back to the
            // default rather than panicking on an unvalidated config
            sched: SchedKind::parse(&cfg.scheduler).unwrap_or_default(),
        }
    }
}

/// Serve `per_stream` tasks from each of the given streams through the
/// unified discrete-event kernel with a single edge device. Reports are
/// accumulated in job-creation (arrival) order, so with one stream the
/// summary is task-ordered exactly like `Coordinator::serve`.
pub fn serve_multistream(
    coord: &mut Coordinator,
    gens: &mut [TaskGen],
    per_stream: usize,
    opts: &DesOpts,
) -> ServeSummary {
    let fopts = FleetOpts {
        des: opts.clone(),
        ..FleetOpts::default()
    };
    let result = engine::serve(std::slice::from_mut(coord), gens, per_stream, &fopts);
    let mut summary = ServeSummary::default();
    for job in result.jobs {
        if let Some(r) = job.report {
            summary.push(r);
        }
    }
    summary
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::configx::Config;
    use crate::workload::Arrivals;

    fn coord(policy: &str) -> (Config, Coordinator) {
        let mut cfg = Config::default();
        cfg.policy = policy.into();
        cfg.seed = 17;
        let c = Coordinator::from_config(&cfg).unwrap();
        (cfg, c)
    }

    #[test]
    fn opts_from_config_picks_up_knobs() {
        let mut cfg = Config::default();
        cfg.batch_window_ms = 8.0;
        cfg.max_batch = 5;
        cfg.cloud_slots = 2;
        cfg.cloud_batch_window_ms = 6.0;
        cfg.cloud_max_batch = 7;
        cfg.scheduler = "heap".into();
        let o = DesOpts::from_config(&cfg);
        assert_eq!(o.batch_window_s, 0.008);
        assert_eq!(o.max_batch, 5);
        assert_eq!(o.cloud_slots, 2);
        assert_eq!(o.cloud_batch_window_s, 0.006);
        assert_eq!(o.cloud_max_batch, 7);
        assert_eq!(o.sched, SchedKind::Heap);
        assert_eq!(DesOpts::default().sched, SchedKind::Calendar);
    }

    #[test]
    fn streams_tag_reports_and_energy() {
        let (cfg, mut c) = coord("edge_only");
        let mut gens: Vec<TaskGen> = (0..3)
            .map(|s| {
                TaskGen::new(
                    &cfg.model,
                    c.env.dataset,
                    Arrivals::Poisson { rate: 30.0 },
                    200 + s,
                )
                .unwrap()
            })
            .collect();
        let s = serve_multistream(&mut c, &mut gens, 4, &DesOpts::default());
        assert_eq!(s.count(), 12);
        assert_eq!(s.per_stream_j.len(), 3);
        assert!(s.per_stream_j.iter().all(|&e| e > 0.0));
        let mut seen = [0usize; 3];
        for r in &s.reports {
            seen[r.stream] += 1;
            assert!(r.e2e_s >= r.tti_total_s - 1e-12, "e2e includes service");
        }
        assert_eq!(seen, [4, 4, 4]);
    }

    #[test]
    fn queue_wait_grows_under_closed_loop_herd() {
        // 2 streams of back-to-back arrivals all land at t=0: the second
        // half of tasks must observe real queueing delay.
        let (cfg, mut c) = coord("edge_only");
        let mut gens: Vec<TaskGen> = (0..2)
            .map(|s| {
                TaskGen::new(&cfg.model, c.env.dataset, Arrivals::Sequential, 300 + s).unwrap()
            })
            .collect();
        let s = serve_multistream(&mut c, &mut gens, 5, &DesOpts::default());
        assert_eq!(s.count(), 10);
        // first-served task has zero wait, later ones wait behind it
        let waits: Vec<f64> = s.reports.iter().map(|r| r.queue_wait_s).collect();
        assert!(waits.iter().any(|&w| w == 0.0));
        assert!(waits.iter().any(|&w| w > 0.0), "{waits:?}");
    }

    #[test]
    fn max_batch_caps_batch_size() {
        let (cfg, mut c) = coord("cloud_only");
        let mut gens: Vec<TaskGen> = (0..6)
            .map(|s| {
                TaskGen::new(&cfg.model, c.env.dataset, Arrivals::Sequential, 400 + s).unwrap()
            })
            .collect();
        let opts = DesOpts {
            batch_window_s: 10.0, // effectively unbounded window
            max_batch: 3,
            ..DesOpts::default()
        };
        let s = serve_multistream(&mut c, &mut gens, 3, &opts);
        assert!(s.reports.iter().all(|r| (1..=3).contains(&r.batch_size)));
        assert!(s.reports.iter().any(|r| r.batch_size == 3));
    }

    #[test]
    fn cloud_batch_window_groups_and_caps_on_a_single_edge() {
        // cloud_only herd through one edge: with a wide cloud window and
        // a cap of 3, cloud invocations must group (some size > 1) and
        // never exceed the cap; without a window every invocation is a
        // singleton.
        let run = |cloud_batch_window_s: f64| {
            let (cfg, mut c) = coord("cloud_only");
            let mut gens: Vec<TaskGen> = (0..6)
                .map(|s| {
                    TaskGen::new(&cfg.model, c.env.dataset, Arrivals::Sequential, 500 + s)
                        .unwrap()
                })
                .collect();
            let opts = DesOpts {
                batch_window_s: 0.01,
                cloud_batch_window_s,
                cloud_max_batch: 3,
                cloud_slots: 2,
                ..DesOpts::default()
            };
            serve_multistream(&mut c, &mut gens, 3, &opts)
        };
        let batched = run(10.0);
        assert!(batched
            .reports
            .iter()
            .all(|r| (1..=3).contains(&r.cloud_batch_size)));
        assert!(batched.reports.iter().any(|r| r.cloud_batch_size > 1));
        // the summary aggregates the same telemetry (single-edge CLI
        // prints its task-weighted mean)
        assert!(batched.cloud_batch_size.values().iter().any(|&b| b > 1.0));
        let solo = run(0.0);
        assert!(solo.reports.iter().all(|r| r.cloud_batch_size == 1));
    }
}
