//! Discrete-event, multi-stream serving core.
//!
//! Replaces the synchronous per-task stepping of `Coordinator::serve`
//! with an event-driven simulation of a loaded edge node: N concurrent
//! user streams (each a `TaskGen` with its own seed and arrival process)
//! feed a FIFO edge queue; offloaded feature maps queue on a single
//! uplink where they can be **batched** within a configurable window;
//! cloud execution runs on a bounded pool of executors with its own
//! queue. Events (arrival, edge-compute-done, batch-window-close,
//! uplink-done, cloud-compute-done) are processed off a time-ordered
//! heap.
//!
//! Per-task physics (latency phases, energy, accuracy, cost) still come
//! from `EdgeCloudEnv::execute`, invoked exactly once per task at edge
//! service start through `Coordinator::step` — so with one stream,
//! sequential arrivals and batching disabled, the discrete-event core
//! reproduces the legacy synchronous results task-for-task (the parity
//! gate in `rust/tests/multistream_queueing.rs`). What the core adds on
//! top is *queueing*: per-task queue wait, batching delay, and an
//! end-to-end latency that includes them, plus per-stream energy totals.
//!
//! Before each decision the core publishes `Coordinator::load`
//! (queue depth + backlog estimate), which queue-aware policies fold
//! into the DQN state (`Obs::features_ext`).

use super::{Coordinator, ServeSummary};
use crate::coordinator::env::TaskReport;
use crate::util::Ewma;
use crate::workload::{Task, TaskGen};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Tunables of the discrete-event serving core.
#[derive(Clone, Debug)]
pub struct DesOpts {
    /// uplink batching window in seconds; 0 disables batching (every
    /// offload ships alone, preserving legacy timing exactly)
    pub batch_window_s: f64,
    /// maximum offloads per uplink batch (a full batch flushes early)
    pub max_batch: usize,
    /// concurrent cloud executors (beyond this, cloud work queues)
    pub cloud_slots: usize,
}

impl Default for DesOpts {
    fn default() -> Self {
        Self {
            batch_window_s: 0.0,
            max_batch: 16,
            cloud_slots: 4,
        }
    }
}

impl DesOpts {
    /// Build from a run config (`batch_window_ms`, `max_batch`,
    /// `cloud_slots` config keys / CLI flags).
    pub fn from_config(cfg: &crate::configx::Config) -> Self {
        Self {
            batch_window_s: cfg.batch_window_ms / 1e3,
            max_batch: cfg.max_batch,
            cloud_slots: cfg.cloud_slots,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EventKind {
    /// payload = stream index
    Arrival,
    /// payload = job id
    EdgeDone,
    /// payload = batch-generation id (guards stale closes)
    BatchClose,
    /// payload = frozen-batch index
    UplinkDone,
    /// payload = job id
    CloudDone,
}

/// Heap entry; the `seq` tiebreak makes simultaneous events FIFO and the
/// whole simulation deterministic.
#[derive(Clone, Debug)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
    payload: usize,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first.
        // total_cmp gives NaN a fixed place in the order instead of
        // silently collapsing it to Equal, so a NaN time can never
        // reorder the heap nondeterministically.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
}

impl EventQueue {
    fn push(&mut self, time: f64, kind: EventKind, payload: usize) {
        self.heap.push(Event {
            time,
            seq: self.seq,
            kind,
            payload,
        });
        self.seq += 1;
    }

    fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }
}

/// One in-flight task.
struct Job {
    task: Task,
    stream: usize,
    arrival_s: f64,
    queue_wait_s: f64,
    /// solo transmission time computed by the env (used for singleton
    /// batches so unbatched timing matches the legacy path exactly)
    solo_off_s: f64,
    cloud_s: f64,
    payload_bytes: f64,
    report: Option<TaskReport>,
}

impl Job {
    fn new(task: Task, stream: usize, arrival_s: f64) -> Self {
        Self {
            task,
            stream,
            arrival_s,
            queue_wait_s: 0.0,
            solo_off_s: 0.0,
            cloud_s: 0.0,
            payload_bytes: 0.0,
            report: None,
        }
    }
}

struct DesState {
    q: EventQueue,
    jobs: Vec<Job>,
    edge_queue: VecDeque<usize>,
    edge_busy: bool,
    /// EWMA of edge residency, drives the backlog estimate in LoadSignals
    residency: Ewma,
    open_batch: Vec<usize>,
    /// bumps on every flush so stale BatchClose events are ignored
    batch_open_id: usize,
    /// flushed batches, addressed by UplinkDone payload
    batches: Vec<Vec<usize>>,
    uplink_queue: VecDeque<usize>,
    uplink_busy: bool,
    cloud_active: usize,
    cloud_queue: VecDeque<usize>,
    opts: DesOpts,
}

impl DesState {
    /// Start edge service on the next queued job if the edge is idle:
    /// publish load signals, run decide→execute via the coordinator, and
    /// schedule the edge-completion event after the edge-side residency
    /// (local compute + compression + decision overhead + DVFS switch).
    fn maybe_start_edge(&mut self, coord: &mut Coordinator, now: f64) {
        if self.edge_busy {
            return;
        }
        let Some(id) = self.edge_queue.pop_front() else {
            return;
        };
        coord.load.queue_depth = self.edge_queue.len();
        coord.load.backlog_s =
            self.residency.get().unwrap_or(0.0) * self.edge_queue.len() as f64;
        let r = coord.step(&self.jobs[id].task, false);
        let residency = (r.tti_total_s - r.tti_off_s - r.tti_cloud_s).max(0.0);
        self.residency.push(residency);
        let job = &mut self.jobs[id];
        job.queue_wait_s = (now - job.arrival_s).max(0.0);
        job.solo_off_s = r.tti_off_s;
        job.cloud_s = r.tti_cloud_s;
        job.payload_bytes = r.payload_bytes;
        job.report = Some(r);
        self.edge_busy = true;
        self.q.push(now + residency, EventKind::EdgeDone, id);
    }

    fn freeze_batch(&mut self, members: Vec<usize>) -> usize {
        self.batches.push(members);
        self.batches.len() - 1
    }

    fn flush_open_batch(&mut self, coord: &Coordinator, now: f64) {
        if self.open_batch.is_empty() {
            return;
        }
        let members = std::mem::take(&mut self.open_batch);
        self.batch_open_id += 1;
        let b = self.freeze_batch(members);
        self.uplink_queue.push_back(b);
        self.maybe_start_uplink(coord, now);
    }

    /// Start transmitting the next batch if the uplink is idle. A
    /// singleton batch reuses the env-computed solo transmission time; a
    /// real batch transmits the summed payload in one go (one wire
    /// header amortized, one bandwidth-limited transfer).
    fn maybe_start_uplink(&mut self, coord: &Coordinator, now: f64) {
        if self.uplink_busy {
            return;
        }
        let Some(b) = self.uplink_queue.pop_front() else {
            return;
        };
        let members = self.batches[b].clone();
        let tx_s = if members.len() == 1 {
            self.jobs[members[0]].solo_off_s
        } else {
            let payload: f64 = members.iter().map(|&id| self.jobs[id].payload_bytes).sum();
            coord.env.link.tx_time_s(payload)
        };
        let n = members.len();
        for &id in &members {
            if let Some(r) = self.jobs[id].report.as_mut() {
                r.batch_size = n;
            }
        }
        self.uplink_busy = true;
        self.q.push(now + tx_s, EventKind::UplinkDone, b);
    }

    fn dispatch_cloud(&mut self, id: usize, now: f64) {
        if self.cloud_active < self.opts.cloud_slots {
            self.cloud_active += 1;
            self.q.push(now + self.jobs[id].cloud_s, EventKind::CloudDone, id);
        } else {
            self.cloud_queue.push_back(id);
        }
    }

    /// Stamp the queueing-aware fields on the job's report.
    fn finish(&mut self, id: usize, now: f64) {
        let job = &mut self.jobs[id];
        if let Some(r) = job.report.as_mut() {
            r.queue_wait_s = job.queue_wait_s;
            r.e2e_s = (now - job.arrival_s).max(0.0);
            r.stream = job.stream;
        }
    }
}

/// Serve `per_stream` tasks from each of the given streams through the
/// discrete-event core. Reports are accumulated in job-creation
/// (arrival) order, so with one stream the summary is task-ordered
/// exactly like `Coordinator::serve`.
pub fn serve_multistream(
    coord: &mut Coordinator,
    gens: &mut [TaskGen],
    per_stream: usize,
    opts: &DesOpts,
) -> ServeSummary {
    coord.policy.set_training(false);
    if gens.is_empty() || per_stream == 0 {
        return ServeSummary::default();
    }
    let streams = gens.len();
    let mut state = DesState {
        q: EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        },
        jobs: Vec::with_capacity(streams * per_stream),
        edge_queue: VecDeque::new(),
        edge_busy: false,
        residency: Ewma::new(0.2),
        open_batch: Vec::new(),
        batch_open_id: 0,
        batches: Vec::new(),
        uplink_queue: VecDeque::new(),
        uplink_busy: false,
        cloud_active: 0,
        cloud_queue: VecDeque::new(),
        opts: opts.clone(),
    };

    // prime every stream with its first arrival
    let mut next_task: Vec<Option<Task>> = Vec::with_capacity(streams);
    let mut remaining: Vec<usize> = vec![per_stream; streams];
    for (s, gen) in gens.iter_mut().enumerate() {
        if per_stream > 0 {
            let t = gen.next_task();
            remaining[s] -= 1;
            state.q.push(t.arrival_s, EventKind::Arrival, s);
            next_task.push(Some(t));
        } else {
            next_task.push(None);
        }
    }

    while let Some(ev) = state.q.pop() {
        let now = ev.time;
        match ev.kind {
            EventKind::Arrival => {
                let s = ev.payload;
                let task = next_task[s].take().expect("arrival without pending task");
                if remaining[s] > 0 {
                    remaining[s] -= 1;
                    let t = gens[s].next_task();
                    state.q.push(t.arrival_s, EventKind::Arrival, s);
                    next_task[s] = Some(t);
                }
                let id = state.jobs.len();
                state.jobs.push(Job::new(task, s, now));
                state.edge_queue.push_back(id);
                state.maybe_start_edge(coord, now);
            }
            EventKind::EdgeDone => {
                let id = ev.payload;
                state.edge_busy = false;
                let offloads = state.jobs[id]
                    .report
                    .as_ref()
                    .map(|r| r.xi > 0.0)
                    .unwrap_or(false);
                if offloads {
                    if state.opts.batch_window_s > 0.0 {
                        if state.open_batch.is_empty() {
                            state.q.push(
                                now + state.opts.batch_window_s,
                                EventKind::BatchClose,
                                state.batch_open_id,
                            );
                        }
                        state.open_batch.push(id);
                        if state.open_batch.len() >= state.opts.max_batch {
                            state.flush_open_batch(coord, now);
                        }
                    } else {
                        let b = state.freeze_batch(vec![id]);
                        state.uplink_queue.push_back(b);
                        state.maybe_start_uplink(coord, now);
                    }
                } else {
                    state.finish(id, now);
                }
                state.maybe_start_edge(coord, now);
            }
            EventKind::BatchClose => {
                if ev.payload == state.batch_open_id {
                    state.flush_open_batch(coord, now);
                }
            }
            EventKind::UplinkDone => {
                let b = ev.payload;
                state.uplink_busy = false;
                let members = state.batches[b].clone();
                for id in members {
                    state.dispatch_cloud(id, now);
                }
                state.maybe_start_uplink(coord, now);
            }
            EventKind::CloudDone => {
                let id = ev.payload;
                state.cloud_active -= 1;
                state.finish(id, now);
                if let Some(next) = state.cloud_queue.pop_front() {
                    state.cloud_active += 1;
                    state
                        .q
                        .push(now + state.jobs[next].cloud_s, EventKind::CloudDone, next);
                }
            }
        }
    }

    // reset load signals so later synchronous use observes an idle edge
    coord.load = super::LoadSignals::default();

    let mut summary = ServeSummary::default();
    for job in &state.jobs {
        if let Some(r) = &job.report {
            summary.push(r);
        }
    }
    summary
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::Config;
    use crate::workload::Arrivals;

    fn coord(policy: &str) -> (Config, Coordinator) {
        let mut cfg = Config::default();
        cfg.policy = policy.into();
        cfg.seed = 17;
        let c = Coordinator::from_config(&cfg).unwrap();
        (cfg, c)
    }

    #[test]
    fn event_heap_orders_by_time_then_seq() {
        let mut q = EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        };
        q.push(2.0, EventKind::Arrival, 0);
        q.push(1.0, EventKind::Arrival, 1);
        q.push(1.0, EventKind::Arrival, 2);
        q.push(0.5, EventKind::EdgeDone, 3);
        let order: Vec<usize> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec![3, 1, 2, 0]);
    }

    #[test]
    fn event_queue_fifo_tiebreak_is_deterministic() {
        // Property: pops come out in nondecreasing time order, and events
        // with equal timestamps come out in insertion (FIFO) order. Times
        // are quantized to a coarse grid so ties actually occur.
        use crate::proptest_mini::{check, f64_in, vec_of};
        check(
            "event queue time order + FIFO ties",
            0xDE5,
            300,
            vec_of(f64_in(0.0, 4.0), 1, 48),
            |times| {
                let mut q = EventQueue {
                    heap: BinaryHeap::new(),
                    seq: 0,
                };
                let quantized: Vec<f64> =
                    times.iter().map(|t| (t * 4.0).floor() / 4.0).collect();
                for (i, &t) in quantized.iter().enumerate() {
                    q.push(t, EventKind::Arrival, i);
                }
                let mut prev: Option<Event> = None;
                while let Some(ev) = q.pop() {
                    if let Some(p) = prev {
                        if ev.time < p.time {
                            return Err(format!("time went backwards: {} < {}", ev.time, p.time));
                        }
                        if ev.time == p.time && ev.payload < p.payload {
                            return Err(format!(
                                "FIFO tiebreak violated at t={}: {} before {}",
                                ev.time, p.payload, ev.payload
                            ));
                        }
                    }
                    prev = Some(ev);
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nan_event_time_cannot_reorder_real_events() {
        // total_cmp gives NaN a fixed slot (after +inf in ascending order,
        // i.e. popped last from the min-ordered heap) instead of making
        // comparisons against it nondeterministic.
        let mut q = EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        };
        q.push(f64::NAN, EventKind::Arrival, 0);
        q.push(1.0, EventKind::Arrival, 1);
        q.push(2.0, EventKind::Arrival, 2);
        let order: Vec<usize> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn opts_from_config_picks_up_knobs() {
        let mut cfg = Config::default();
        cfg.batch_window_ms = 8.0;
        cfg.max_batch = 5;
        cfg.cloud_slots = 2;
        let o = DesOpts::from_config(&cfg);
        assert_eq!(o.batch_window_s, 0.008);
        assert_eq!(o.max_batch, 5);
        assert_eq!(o.cloud_slots, 2);
    }

    #[test]
    fn streams_tag_reports_and_energy() {
        let (cfg, mut c) = coord("edge_only");
        let mut gens: Vec<TaskGen> = (0..3)
            .map(|s| {
                TaskGen::new(
                    &cfg.model,
                    c.env.dataset,
                    Arrivals::Poisson { rate: 30.0 },
                    200 + s,
                )
                .unwrap()
            })
            .collect();
        let s = serve_multistream(&mut c, &mut gens, 4, &DesOpts::default());
        assert_eq!(s.count(), 12);
        assert_eq!(s.per_stream_j.len(), 3);
        assert!(s.per_stream_j.iter().all(|&e| e > 0.0));
        let mut seen = [0usize; 3];
        for r in &s.reports {
            seen[r.stream] += 1;
            assert!(r.e2e_s >= r.tti_total_s - 1e-12, "e2e includes service");
        }
        assert_eq!(seen, [4, 4, 4]);
    }

    #[test]
    fn queue_wait_grows_under_closed_loop_herd() {
        // 2 streams of back-to-back arrivals all land at t=0: the second
        // half of tasks must observe real queueing delay.
        let (cfg, mut c) = coord("edge_only");
        let mut gens: Vec<TaskGen> = (0..2)
            .map(|s| {
                TaskGen::new(&cfg.model, c.env.dataset, Arrivals::Sequential, 300 + s).unwrap()
            })
            .collect();
        let s = serve_multistream(&mut c, &mut gens, 5, &DesOpts::default());
        assert_eq!(s.count(), 10);
        // first-served task has zero wait, later ones wait behind it
        let waits: Vec<f64> = s.reports.iter().map(|r| r.queue_wait_s).collect();
        assert!(waits.iter().any(|&w| w == 0.0));
        assert!(waits.iter().any(|&w| w > 0.0), "{waits:?}");
    }

    #[test]
    fn max_batch_caps_batch_size() {
        let (cfg, mut c) = coord("cloud_only");
        let mut gens: Vec<TaskGen> = (0..6)
            .map(|s| {
                TaskGen::new(&cfg.model, c.env.dataset, Arrivals::Sequential, 400 + s).unwrap()
            })
            .collect();
        let opts = DesOpts {
            batch_window_s: 10.0, // effectively unbounded window
            max_batch: 3,
            ..DesOpts::default()
        };
        let s = serve_multistream(&mut c, &mut gens, 3, &opts);
        assert!(s.reports.iter().all(|r| (1..=3).contains(&r.batch_size)));
        assert!(s.reports.iter().any(|r| r.batch_size == 3));
    }
}
