//! §Perf — pluggable event scheduler for the unified DES engine.
//!
//! The engine's future-event set was historically a `BinaryHeap`:
//! every push/pop is O(log n) with `(f64::total_cmp, seq)` comparator
//! calls, and the batching-window design (uplink + cloud close timers
//! after arXiv:2504.14611) plus `Rebalance` ticks floods the queue with
//! short-horizon timer events — exactly the workload a **calendar
//! queue** (Brown 1988) turns into amortized O(1). This module makes
//! the scheduler pluggable behind a sealed [`Sched`] front (enum
//! dispatch, no dyn): [`SchedKind::Heap`] is the bit-exact historical
//! scheduler, [`SchedKind::Calendar`] the bucketed one.
//!
//! The non-negotiable contract shared by both backends: **identical
//! pop order for any push sequence** — events pop in ascending
//! `(time, seq)` order where time compares by `f64::total_cmp` (so
//! `-NaN < -inf < finite < +inf < +NaN`) and `seq` is the push stamp
//! that breaks ties FIFO. `rust/tests/sched_parity.rs` drives both
//! backends with identical randomized interleavings and asserts
//! bit-identical pop sequences; every golden/parity/determinism gate
//! therefore passes unchanged under either scheduler.
//!
//! Calendar model: a rotating day-array of `n_buckets` buckets keyed
//! by `floor(time / width) % n_buckets`. A cursor (`cur_day`) walks
//! the days; buckets sort lazily (first access after a push), and
//! events more than one bucket-year (`n_buckets × width`) past the
//! promotion horizon — plus every non-finite timestamp — live in an
//! overflow list that each pop compares against the bucket candidate
//! by the exact `(time, seq)` key, so correctness never depends on
//! promotion timing. Occupancy drift (> 2 events/bucket, or < 1/4)
//! doubles/halves the bucket count and recomputes the width from the
//! observed event span. In steady state nothing resizes and bucket
//! `Vec`s recycle their capacity: pushes and pops are allocation-free.

use anyhow::{bail, Result};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Which backend schedules the engine's future events.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedKind {
    /// Binary heap — O(log n) push/pop, the historical scheduler.
    Heap,
    /// Calendar queue — amortized O(1), the default.
    #[default]
    Calendar,
}

impl SchedKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "heap" => SchedKind::Heap,
            "calendar" => SchedKind::Calendar,
            other => bail!("unknown scheduler `{other}` (expected `heap` or `calendar`)"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SchedKind::Heap => "heap",
            SchedKind::Calendar => "calendar",
        }
    }
}

/// A scheduled event: payload `ev` due at `time`, with the push-order
/// stamp `seq` breaking ties FIFO.
#[derive(Clone, Copy, Debug)]
pub struct Event<T> {
    pub time: f64,
    pub seq: u64,
    pub ev: T,
}

/// The pop order: ascending `(total_cmp(time), seq)`.
fn cmp_pop<T>(a: &Event<T>, b: &Event<T>) -> Ordering {
    a.time.total_cmp(&b.time).then_with(|| a.seq.cmp(&b.seq))
}

impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl<T> Eq for Event<T> {}
impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Event<T> {
    /// Reversed pop order so `BinaryHeap` (a max-heap) yields the
    /// earliest event first — `total_cmp` gives NaN timestamps a fixed
    /// slot instead of poisoning the ordering.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Floor of the calendar bucket count (and the floor a shrink stops at).
const MIN_BUCKETS: usize = 16;
/// Bucket width before the first resize observes a real event span.
const INITIAL_WIDTH: f64 = 1e-3;
/// Width floor — keeps `time / width` finite for any finite time that
/// the engine's second-denominated clocks actually reach.
const MIN_WIDTH: f64 = 1e-9;

/// One calendar day (also the overflow list): events kept sorted
/// **descending** by pop order, lazily, so the back is the pop-min and
/// `Vec::pop` serves it in O(1) without shifting.
struct Slot<T> {
    items: Vec<Event<T>>,
    sorted: bool,
}

impl<T> Default for Slot<T> {
    fn default() -> Self {
        Self { items: Vec::new(), sorted: true }
    }
}

impl<T> Slot<T> {
    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.items.sort_unstable_by(|a, b| cmp_pop(b, a));
            self.sorted = true;
        }
    }

    /// Append, tracking whether the descending invariant survived (it
    /// does iff the new event is the new pop-min).
    fn push(&mut self, e: Event<T>) {
        if let Some(back) = self.items.last() {
            if self.sorted && cmp_pop(&e, back) == Ordering::Greater {
                self.sorted = false;
            }
        }
        self.items.push(e);
    }
}

/// Where the current pop-min lives.
#[derive(Clone, Copy)]
enum MinLoc {
    Bucket(usize),
    Overflow,
}

struct Calendar<T> {
    /// Seconds per day; strictly positive and finite.
    width: f64,
    /// Power of two ≥ [`MIN_BUCKETS`].
    n_buckets: usize,
    buckets: Vec<Slot<T>>,
    /// Non-finite timestamps and events at/past the promotion horizon.
    overflow: Slot<T>,
    /// Events currently in `buckets` (excludes overflow).
    bucketed_len: usize,
    /// The day the cursor is serving; no bucketed event has a smaller
    /// day (pushes into the past rewind the cursor).
    cur_day: i64,
    /// First day outside the current bucket-year: finite pushes at or
    /// past it go to overflow until a promotion pass moves them in.
    next_promote_day: i64,
    /// Scratch for resize rebuilds (kept to recycle its capacity).
    spill: Vec<Event<T>>,
}

impl<T> Calendar<T> {
    fn with_capacity(capacity: usize) -> Self {
        let n_buckets = capacity.max(MIN_BUCKETS).next_power_of_two();
        let mut buckets = Vec::with_capacity(n_buckets);
        buckets.resize_with(n_buckets, Slot::default);
        Self {
            width: INITIAL_WIDTH,
            n_buckets,
            buckets,
            overflow: Slot::default(),
            bucketed_len: 0,
            cur_day: 0,
            next_promote_day: n_buckets as i64,
            spill: Vec::new(),
        }
    }

    fn len(&self) -> usize {
        self.bucketed_len + self.overflow.items.len()
    }

    /// `floor(t / width)` for finite `t`; the `as` cast saturates for
    /// astronomically large quotients, which is safe because saturated
    /// days always classify as past the promotion horizon.
    fn day_of(&self, t: f64) -> i64 {
        (t / self.width).floor() as i64
    }

    /// Point the wheel at `t` (callers do this when the queue is empty
    /// or after the cursor lost track of the population).
    fn anchor(&mut self, t: f64) {
        if t.is_finite() {
            self.cur_day = self.day_of(t);
            self.next_promote_day = self.cur_day.saturating_add(self.n_buckets as i64);
        }
    }

    /// Place an event without seq-stamping or resize checks (shared by
    /// push, promotion, and rebuild).
    fn insert(&mut self, e: Event<T>) {
        if !e.time.is_finite() {
            self.overflow.push(e);
            return;
        }
        let day = self.day_of(e.time);
        if day >= self.next_promote_day {
            self.overflow.push(e);
        } else {
            self.place_bucket(e, day);
        }
    }

    fn place_bucket(&mut self, e: Event<T>, day: i64) {
        if day < self.cur_day {
            self.cur_day = day;
        }
        let idx = day.rem_euclid(self.n_buckets as i64) as usize;
        self.buckets[idx].push(e);
        self.bucketed_len += 1;
    }

    fn push(&mut self, e: Event<T>) {
        if self.len() == 0 {
            self.anchor(e.time);
        }
        self.insert(e);
        if self.len() > 2 * self.n_buckets {
            self.rebuild(self.n_buckets * 2);
        }
    }

    /// Move overflow events whose day now falls inside the bucket-year
    /// starting at the cursor into the wheel.
    fn promote(&mut self) {
        self.next_promote_day = self.cur_day.saturating_add(self.n_buckets as i64);
        let limit = self.next_promote_day;
        let mut i = 0;
        let mut moved = false;
        while i < self.overflow.items.len() {
            let t = self.overflow.items[i].time;
            if t.is_finite() && self.day_of(t) < limit {
                let e = self.overflow.items.swap_remove(i);
                let day = self.day_of(e.time);
                self.place_bucket(e, day);
                moved = true;
            } else {
                i += 1;
            }
        }
        if moved && self.overflow.items.len() > 1 {
            self.overflow.sorted = false;
        }
    }

    /// The bucket holding the bucketed pop-min, advancing the cursor
    /// (and promoting at year boundaries) along the way. Walks at most
    /// one full rotation; past that it direct-searches every bucket
    /// head and jumps the cursor to the winner, so sparse populations
    /// cannot spin the wheel.
    fn bucket_candidate(&mut self) -> Option<usize> {
        if self.bucketed_len == 0 {
            return None;
        }
        let n = self.n_buckets as i64;
        let mut scanned = 0usize;
        loop {
            if self.cur_day >= self.next_promote_day {
                self.promote();
            }
            let idx = self.cur_day.rem_euclid(n) as usize;
            if !self.buckets[idx].items.is_empty() {
                self.buckets[idx].ensure_sorted();
                let head = self.buckets[idx].items.last().expect("non-empty bucket");
                if self.day_of(head.time) == self.cur_day {
                    return Some(idx);
                }
            }
            self.cur_day = self.cur_day.saturating_add(1);
            scanned += 1;
            if scanned >= self.n_buckets {
                let mut best: Option<usize> = None;
                for i in 0..self.n_buckets {
                    if self.buckets[i].items.is_empty() {
                        continue;
                    }
                    self.buckets[i].ensure_sorted();
                    let better = match best {
                        None => true,
                        Some(b) => {
                            let (hi, hb) = (
                                self.buckets[i].items.last().expect("non-empty"),
                                self.buckets[b].items.last().expect("non-empty"),
                            );
                            cmp_pop(hi, hb) == Ordering::Less
                        }
                    };
                    if better {
                        best = Some(i);
                    }
                }
                let b = best.expect("bucketed_len > 0 implies a non-empty bucket");
                let t = self.buckets[b].items.last().expect("non-empty").time;
                self.cur_day = self.day_of(t);
                return Some(b);
            }
        }
    }

    /// Locate the global pop-min: the bucket candidate raced against
    /// the overflow min by the exact `(time, seq)` key. This per-pop
    /// comparison is what makes pop order independent of promotion and
    /// anchoring heuristics.
    fn min_loc(&mut self) -> Option<MinLoc> {
        let bucket = self.bucket_candidate();
        if !self.overflow.items.is_empty() {
            self.overflow.ensure_sorted();
        }
        match (bucket, self.overflow.items.last()) {
            (None, None) => None,
            (Some(i), None) => Some(MinLoc::Bucket(i)),
            (None, Some(_)) => Some(MinLoc::Overflow),
            (Some(i), Some(of_min)) => {
                let b_min = self.buckets[i].items.last().expect("non-empty bucket");
                if cmp_pop(b_min, of_min) == Ordering::Less {
                    Some(MinLoc::Bucket(i))
                } else {
                    Some(MinLoc::Overflow)
                }
            }
        }
    }

    fn time_at(&self, loc: MinLoc) -> f64 {
        match loc {
            MinLoc::Bucket(i) => self.buckets[i].items.last().expect("non-empty").time,
            MinLoc::Overflow => self.overflow.items.last().expect("non-empty").time,
        }
    }

    fn take(&mut self, loc: MinLoc) -> Event<T> {
        let e = match loc {
            MinLoc::Bucket(i) => {
                self.bucketed_len -= 1;
                self.buckets[i].items.pop().expect("non-empty bucket")
            }
            MinLoc::Overflow => {
                let e = self.overflow.items.pop().expect("non-empty overflow");
                if self.bucketed_len == 0 && e.time.is_finite() {
                    // the wheel went dark while overflow served — drag
                    // the cursor to now and pull siblings back in
                    self.anchor(e.time);
                    self.promote();
                }
                e
            }
        };
        if self.n_buckets > MIN_BUCKETS && self.len() < self.n_buckets / 4 {
            self.rebuild(self.n_buckets / 2);
        }
        e
    }

    fn peek_time(&mut self) -> Option<f64> {
        self.min_loc().map(|loc| self.time_at(loc))
    }

    fn pop(&mut self) -> Option<Event<T>> {
        let loc = self.min_loc()?;
        Some(self.take(loc))
    }

    /// Pop the min unless a finite `t_stop` bounds it: events at or
    /// past the boundary stay queued. NaN timestamps pop even under a
    /// finite boundary (`NaN >= t` is false) — exactly the engine's
    /// historical `peek_time`-then-`pop` epoch predicate.
    fn pop_before(&mut self, t_stop: f64) -> Option<Event<T>> {
        let loc = self.min_loc()?;
        if t_stop.is_finite() && self.time_at(loc) >= t_stop {
            return None;
        }
        Some(self.take(loc))
    }

    /// Re-bucket everything into `new_n` buckets, re-deriving the
    /// width from the observed span (targets ~3 events per day) and
    /// re-anchoring at the earliest finite event. `(time, seq)` stamps
    /// ride along untouched, so pop order is unaffected.
    fn rebuild(&mut self, new_n: usize) {
        let new_n = new_n.max(MIN_BUCKETS);
        let mut spill = std::mem::take(&mut self.spill);
        for b in &mut self.buckets {
            spill.append(&mut b.items);
            b.sorted = true;
        }
        spill.append(&mut self.overflow.items);
        self.overflow.sorted = true;
        self.bucketed_len = 0;

        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut finite = 0usize;
        for e in &spill {
            if e.time.is_finite() {
                lo = lo.min(e.time);
                hi = hi.max(e.time);
                finite += 1;
            }
        }
        if finite >= 2 && hi > lo {
            let w = 3.0 * (hi - lo) / finite as f64;
            if w.is_finite() {
                self.width = w.max(MIN_WIDTH);
            }
        }

        self.buckets.resize_with(new_n, Slot::default);
        self.n_buckets = new_n;
        if lo.is_finite() {
            self.cur_day = self.day_of(lo);
            self.next_promote_day = self.cur_day.saturating_add(new_n as i64);
        }
        for e in spill.drain(..) {
            self.insert(e);
        }
        self.spill = spill;
    }
}

enum Backend<T> {
    Heap(BinaryHeap<Event<T>>),
    Calendar(Calendar<T>),
}

/// The engine's future-event set: push events with a due time, pop
/// them in ascending `(total_cmp(time), seq)` order. Sealed — the two
/// backends dispatch through this enum-backed front, and both honor
/// the identical-total-order contract (see the module docs).
pub struct Sched<T> {
    seq: u64,
    q: Backend<T>,
}

impl<T> Sched<T> {
    pub fn new(kind: SchedKind) -> Self {
        Self::with_capacity(kind, 0)
    }

    /// Pre-size for an expected concurrent event population (the
    /// engine seeds this with `streams + devices + cloud_slots`).
    pub fn with_capacity(kind: SchedKind, capacity: usize) -> Self {
        let q = match kind {
            SchedKind::Heap => Backend::Heap(BinaryHeap::with_capacity(capacity)),
            SchedKind::Calendar => Backend::Calendar(Calendar::with_capacity(capacity)),
        };
        Self { seq: 0, q }
    }

    pub fn kind(&self) -> SchedKind {
        match &self.q {
            Backend::Heap(_) => SchedKind::Heap,
            Backend::Calendar(_) => SchedKind::Calendar,
        }
    }

    /// Schedule `ev` at `time`; the monotone seq stamp makes same-time
    /// pops FIFO in push order.
    pub fn push(&mut self, time: f64, ev: T) {
        let e = Event { time, seq: self.seq, ev };
        self.seq += 1;
        match &mut self.q {
            Backend::Heap(h) => h.push(e),
            Backend::Calendar(c) => c.push(e),
        }
    }

    pub fn pop(&mut self) -> Option<Event<T>> {
        match &mut self.q {
            Backend::Heap(h) => h.pop(),
            Backend::Calendar(c) => c.pop(),
        }
    }

    /// Fused peek+pop for epoch loops: one traversal pops the min
    /// unless a finite `t_stop` bounds it, leaving at-or-past-boundary
    /// events queued. NaN times pop even under a finite `t_stop`,
    /// matching the engine's historical boundary predicate.
    pub fn pop_before(&mut self, t_stop: f64) -> Option<Event<T>> {
        match &mut self.q {
            Backend::Heap(h) => {
                let t = h.peek()?.time;
                if t_stop.is_finite() && t >= t_stop {
                    return None;
                }
                h.pop()
            }
            Backend::Calendar(c) => c.pop_before(t_stop),
        }
    }

    /// Due time of the next pop (`&mut` because the calendar sorts its
    /// current bucket lazily and may advance its cursor).
    pub fn peek_time(&mut self) -> Option<f64> {
        match &mut self.q {
            Backend::Heap(h) => h.peek().map(|e| e.time),
            Backend::Calendar(c) => c.peek_time(),
        }
    }

    pub fn len(&self) -> usize {
        match &self.q {
            Backend::Heap(h) => h.len(),
            Backend::Calendar(c) => c.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current calendar bucket count (`None` on the heap) — exposed so
    /// resize tests can watch the grow/shrink paths fire.
    pub fn bucket_count(&self) -> Option<usize> {
        match &self.q {
            Backend::Heap(_) => None,
            Backend::Calendar(c) => Some(c.n_buckets),
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    fn drain(s: &mut Sched<usize>) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        while let Some(e) = s.pop() {
            out.push((e.time.to_bits(), e.seq));
        }
        out
    }

    fn both(times: &[f64]) -> (Vec<(u64, u64)>, Vec<(u64, u64)>) {
        let mut h = Sched::new(SchedKind::Heap);
        let mut c = Sched::new(SchedKind::Calendar);
        for (i, &t) in times.iter().enumerate() {
            h.push(t, i);
            c.push(t, i);
        }
        (drain(&mut h), drain(&mut c))
    }

    #[test]
    fn kind_parse_round_trips() {
        for k in [SchedKind::Heap, SchedKind::Calendar] {
            assert_eq!(SchedKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(SchedKind::parse("fifo").is_err());
        assert_eq!(SchedKind::default(), SchedKind::Calendar);
    }

    #[test]
    fn ties_pop_fifo_and_orders_match_the_heap() {
        let (h, c) = both(&[0.5, 0.1, 0.5, 0.1, 0.3, 0.5]);
        assert_eq!(h, c);
        // ties resolve in push order
        assert_eq!(h[0].1, 1);
        assert_eq!(h[1].1, 3);
    }

    #[test]
    fn non_finite_times_take_their_total_cmp_slots() {
        let times = [
            1.0,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            -f64::NAN,
            0.0,
            -0.0,
        ];
        let (h, c) = both(&times);
        assert_eq!(h, c);
        // total_cmp order: -NaN < -inf < -0.0 < 0.0 < 1.0 < +inf < +NaN
        let seqs: Vec<u64> = h.iter().map(|&(_, s)| s).collect();
        assert_eq!(seqs, vec![4, 3, 6, 5, 0, 2, 1]);
    }

    #[test]
    fn pop_before_leaves_boundary_events_queued() {
        for kind in [SchedKind::Heap, SchedKind::Calendar] {
            let mut s = Sched::new(kind);
            s.push(0.10, 0usize);
            s.push(0.05, 1);
            s.push(0.05, 2);
            assert_eq!(s.pop_before(0.10).map(|e| e.ev), Some(1));
            assert_eq!(s.pop_before(0.10).map(|e| e.ev), Some(2));
            assert!(s.pop_before(0.10).is_none(), "{kind:?}");
            assert_eq!(s.len(), 1);
            // an infinite boundary pops everything; a NaN event time
            // pops even under a finite boundary (NaN >= t is false)
            s.push(f64::NAN, 3);
            assert_eq!(s.pop_before(0.0).map(|e| e.ev), Some(3));
            assert_eq!(s.pop_before(f64::INFINITY).map(|e| e.ev), Some(0));
            assert!(s.pop_before(f64::INFINITY).is_none());
        }
    }

    #[test]
    fn interleaved_push_pop_matches_heap_with_clustered_times() {
        let mut h = Sched::new(SchedKind::Heap);
        let mut c = Sched::new(SchedKind::Calendar);
        let mut x = 0x9E3779B97F4A7C15u64;
        let mut step = || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            x >> 33
        };
        for round in 0..2000u64 {
            let r = step();
            let t = (r % 97) as f64 * 1e-3 + (round as f64) * 1e-4;
            h.push(t, round as usize);
            c.push(t, round as usize);
            if r % 3 == 0 {
                let eh = h.pop().expect("heap non-empty");
                let ec = c.pop().expect("calendar non-empty");
                assert_eq!((eh.time.to_bits(), eh.seq), (ec.time.to_bits(), ec.seq));
            }
        }
        assert_eq!(drain(&mut h), drain(&mut c));
    }

    #[test]
    fn calendar_grows_under_burst_and_shrinks_on_drain() {
        let mut c = Sched::new(SchedKind::Calendar);
        let n0 = c.bucket_count().unwrap();
        for i in 0..4096 {
            c.push(i as f64 * 1e-3, i);
        }
        let grown = c.bucket_count().unwrap();
        assert!(grown > n0, "burst must grow buckets ({n0} -> {grown})");
        let mut prev = f64::NEG_INFINITY;
        while let Some(e) = c.pop() {
            assert!(e.time >= prev);
            prev = e.time;
        }
        let shrunk = c.bucket_count().unwrap();
        assert!(shrunk < grown, "drain must shrink buckets ({grown} -> {shrunk})");
    }

    #[test]
    fn far_future_outliers_ride_the_overflow_list() {
        let mut h = Sched::new(SchedKind::Heap);
        let mut c = Sched::new(SchedKind::Calendar);
        let times = [0.001, 1e12, 0.002, 9e307, 0.0015, 1e12];
        for (i, &t) in times.iter().enumerate() {
            h.push(t, i);
            c.push(t, i);
        }
        for _ in 0..times.len() {
            let eh = h.pop().unwrap();
            let ec = c.pop().unwrap();
            assert_eq!((eh.time.to_bits(), eh.seq), (ec.time.to_bits(), ec.seq));
        }
        assert!(c.pop().is_none());
    }
}
