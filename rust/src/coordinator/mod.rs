//! The DVFO coordinator: builds the environment + policy from a Config,
//! trains the DRL policy offline (paper: "the training process is
//! offline"), and serves task streams, producing the telemetry every
//! experiment consumes.

// Decision-path code must not panic on unwrap: surface errors through
// Result or encode the invariant in types. Tests opt back in locally.
#![warn(clippy::unwrap_used)]

pub mod chaos;
pub mod config;
pub mod des;
pub mod engine;
pub mod env;
pub mod fleet;
pub mod pipeline;
pub mod sched;
pub mod shard;

pub use chaos::{Fault, FaultSchedule, RetryPolicy};
pub use config::EngineConfig;
pub use des::{serve_multistream, DesOpts};
pub use sched::{Sched, SchedKind};
pub use env::{Decision, EdgeCloudEnv, TaskReport, EXTRACTOR_FRAC};
pub use fleet::{
    serve_fleet, serve_fleet_sharded, serve_fleet_streaming, Admission, Fleet, FleetOpts,
    FleetSummary, Router, StreamSummary,
};
pub use shard::{serve_sharded, ShardOutcome, SHARD_EPOCH_S};

use crate::configx::Config;
use crate::device::spec::find_device;
use crate::net::{Bandwidth, Link};
use crate::perfmodel::{find_model, Dataset};
use crate::policy::{
    AppealNetPolicy, CloudOnlyPolicy, DrldoPolicy, DvfoPolicy, EdgeOnlyPolicy, Feedback,
    Obs, OraclePolicy, Policy,
};
use crate::util::Samples;
use crate::workload::{Arrivals, Task, TaskGen};
use anyhow::Result;

/// Build the simulated environment from a config.
pub fn build_env(cfg: &Config) -> Result<EdgeCloudEnv> {
    let edge = find_device(&cfg.device)?.with_levels(cfg.freq_levels);
    let cloud = find_device(&cfg.cloud)?;
    let link = Link::new(Bandwidth::parse(&cfg.bandwidth, cfg.seed)?);
    let profile = find_model(&cfg.model)?;
    let dataset = Dataset::parse(&cfg.dataset)?;
    Ok(EdgeCloudEnv::new(
        edge, cloud, link, profile, dataset, cfg.eta, cfg.lambda,
    ))
}

/// Build a policy by name. The oracle gets a frozen clone of the
/// environment to grid-search against.
pub fn build_policy(cfg: &Config, env: &EdgeCloudEnv) -> Result<Box<dyn Policy>> {
    let l = cfg.freq_levels;
    // gradient-step placement for the training policies; "inline" (the
    // default) leaves the historical blocking behavior untouched
    let lopts = crate::dqn::LearnerOpts {
        mode: crate::dqn::LearnerMode::parse(&cfg.learner)?,
        publish_every: cfg.learner_publish_every,
        ..crate::dqn::LearnerOpts::default()
    };
    Ok(match cfg.policy.as_str() {
        "dvfo" => Box::new(
            DvfoPolicy::new(l, cfg.xi_levels, cfg.concurrent, cfg.queue_aware, cfg.seed)
                .with_learner(lopts),
        ),
        "drldo" => {
            Box::new(DrldoPolicy::new(l, cfg.xi_levels, cfg.seed).with_learner(lopts))
        }
        "appealnet" => Box::new(AppealNetPolicy::new(l, cfg.seed)),
        "cloud_only" => Box::new(CloudOnlyPolicy::new(l)),
        "edge_only" => Box::new(EdgeOnlyPolicy::new(l)),
        "oracle" => {
            let probe_env = env.clone();
            let mut gen = TaskGen::new(
                &cfg.model,
                env.dataset,
                Arrivals::Sequential,
                cfg.seed ^ 0x0CC1,
            )?;
            let probe_task = gen.next_task();
            Box::new(OraclePolicy {
                levels: l,
                xi_levels: cfg.xi_levels,
                stride: 1,
                latency_s: 0.05,
                eval: Box::new(move |d: &Decision| {
                    probe_env.clone().execute(&probe_task, d, 0.0).cost
                }),
            })
        }
        other => anyhow::bail!("unknown policy `{other}`"),
    })
}

/// Live load signals the discrete-event serving core publishes before
/// each decision so queue-aware policies can react to backlog (zeros on
/// the synchronous single-task path).
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadSignals {
    /// tasks waiting in the edge queue
    pub queue_depth: usize,
    /// estimated seconds of edge work queued ahead
    pub backlog_s: f64,
}

/// The serving system: one environment, one policy, shared telemetry.
pub struct Coordinator {
    pub env: EdgeCloudEnv,
    pub policy: Box<dyn Policy>,
    /// cost of the edge-only max-frequency decision — the reward scale
    /// (rewards are r = −C/C_ref so DQN targets are O(1))
    pub ref_cost: f64,
    /// queue state visible to the next observation (set by the DES core)
    pub load: LoadSignals,
    prev_xi: f64,
}

/// Aggregated outcome of one serving run.
#[derive(Default)]
pub struct ServeSummary {
    pub tti_ms: Samples,
    pub eti_mj: Samples,
    pub accuracy_pct: Samples,
    pub cost: Samples,
    pub tti_local_ms: Samples,
    pub tti_comp_ms: Samples,
    pub tti_off_ms: Samples,
    pub tti_cloud_ms: Samples,
    pub tti_decision_ms: Samples,
    pub xi: Samples,
    pub payload_kb: Samples,
    /// queueing delay before edge service (0 on the synchronous path)
    pub queue_wait_ms: Samples,
    /// end-to-end latency including queueing/batching delays
    pub e2e_ms: Samples,
    /// uplink batch size per task (0 = the task did not offload)
    pub batch_size: Samples,
    /// cloud-invocation batch size per task (0 = never reached the
    /// cloud executor)
    pub cloud_batch_size: Samples,
    /// total energy per user stream (index = stream id)
    pub per_stream_j: Vec<f64>,
    pub per_unit_j: [f64; 3],
    pub reports: Vec<TaskReport>,
}

impl ServeSummary {
    /// Fold one completed task in. Takes the report by value — the
    /// telemetry path never clones (or formats) per task; strings only
    /// appear when `main.rs` finally prints.
    fn push(&mut self, r: TaskReport) {
        self.tti_ms.push(r.tti_total_s * 1e3);
        self.eti_mj.push(r.eti_total_j * 1e3);
        self.accuracy_pct.push(r.accuracy_pct);
        self.cost.push(r.cost);
        self.tti_local_ms.push(r.tti_local_s * 1e3);
        self.tti_comp_ms.push(r.tti_comp_s * 1e3);
        self.tti_off_ms.push(r.tti_off_s * 1e3);
        self.tti_cloud_ms.push(r.tti_cloud_s * 1e3);
        self.tti_decision_ms.push(r.tti_decision_s * 1e3);
        self.xi.push(r.xi);
        self.payload_kb.push(r.payload_bytes / 1024.0);
        self.queue_wait_ms.push(r.queue_wait_s * 1e3);
        let e2e_s = if r.e2e_s > 0.0 {
            r.e2e_s
        } else {
            r.queue_wait_s + r.tti_total_s
        };
        self.e2e_ms.push(e2e_s * 1e3);
        self.batch_size.push(r.batch_size as f64);
        self.cloud_batch_size.push(r.cloud_batch_size as f64);
        if r.stream >= self.per_stream_j.len() {
            self.per_stream_j.resize(r.stream + 1, 0.0);
        }
        self.per_stream_j[r.stream] += r.eti_total_j;
        for i in 0..3 {
            self.per_unit_j[i] += r.eti_per_unit_j[i];
        }
        self.reports.push(r);
    }

    pub fn count(&self) -> usize {
        self.reports.len()
    }
}

impl Coordinator {
    pub fn new(env: EdgeCloudEnv, policy: Box<dyn Policy>) -> Self {
        let mut probe = env.clone();
        let mut gen = TaskGen::new(
            probe.profile.name,
            probe.dataset,
            Arrivals::Sequential,
            0xEF_C0DE,
        )
        .expect("profile exists");
        let t = gen.next_task();
        let ref_cost = probe
            .execute(&t, &Decision::edge_only_max(env.levels()), 0.0)
            .cost
            .max(1e-9);
        Self {
            env,
            policy,
            ref_cost,
            load: LoadSignals::default(),
            prev_xi: 0.0,
        }
    }

    pub fn from_config(cfg: &Config) -> Result<Self> {
        let env = build_env(cfg)?;
        let policy = build_policy(cfg, &env)?;
        Ok(Self::new(env, policy))
    }

    /// Observation for the next task.
    pub fn observe(&self, task: &Task) -> Obs {
        let intensity = self.env.profile.intensity(self.env.dataset);
        Obs {
            lambda: self.env.lambda,
            eta: self.env.eta,
            bandwidth_mbps: self.env.link.observed_mbps(),
            top_quarter_mass: task.importance.top_quarter_mass(),
            skewness: task.importance.skewness(),
            entropy_norm: task.importance.entropy_norm(),
            intensity_norm: (intensity.ln() / 6.0).clamp(0.0, 1.0),
            prev_xi: self.prev_xi,
            queue_depth_norm: (self.load.queue_depth as f64 / 8.0).min(2.0),
            backlog_norm: (self.load.backlog_s / 2.0).min(2.0),
        }
    }

    /// Serve one task end-to-end (decide → execute → feedback).
    pub fn step(&mut self, task: &Task, learn: bool) -> TaskReport {
        self.step_constrained(task, learn, false)
    }

    /// `step` with an optional admission-control override: when
    /// `force_edge_only` is set, the policy still picks frequencies but
    /// the offload proportion is clamped to ξ=0 (the fleet dispatcher's
    /// "downgrade" action for tasks whose deadline the uplink/cloud
    /// detour would blow).
    pub fn step_constrained(
        &mut self,
        task: &Task,
        learn: bool,
        force_edge_only: bool,
    ) -> TaskReport {
        let obs = self.observe(task);
        let mut decision = self.policy.decide(&obs);
        if force_edge_only {
            decision.xi = 0.0;
            decision.compression = crate::offload::Compression::None;
            decision.fusion = crate::accuracy::Fusion::Single;
        }
        // thinking-while-moving: policy inference overlaps the ongoing
        // execution, so only a small residual lands on the critical path
        let lat = self.policy.decision_latency_s();
        let overhead = if self.policy.concurrent() { lat * 0.1 } else { lat };
        let report = self.env.execute(task, &decision, overhead);
        self.prev_xi = decision.xi;
        if learn {
            let next_obs = self.observe(task);
            let gamma_pow = if self.policy.concurrent() {
                (lat / report.tti_total_s.max(1e-6)).clamp(0.05, 1.0)
            } else {
                1.0
            };
            self.policy.feedback(
                &obs,
                &decision,
                &next_obs,
                Feedback {
                    reward: -report.cost / self.ref_cost,
                    gamma_pow,
                    done: false,
                },
            );
        }
        report
    }

    /// Offline training phase (paper Algorithm 1). Returns the mean
    /// reward per episode (the Fig. 15 learning curve).
    pub fn train(&mut self, gen: &mut TaskGen, episodes: usize, tasks_per_ep: usize) -> Vec<f64> {
        self.policy.set_training(true);
        let mut curve = Vec::with_capacity(episodes);
        for _ in 0..episodes {
            let mut sum = 0.0;
            for _ in 0..tasks_per_ep {
                let t = gen.next_task();
                let r = self.step(&t, true);
                sum += -r.cost / self.ref_cost;
            }
            curve.push(sum / tasks_per_ep as f64);
        }
        self.policy.set_training(false);
        curve
    }

    /// Deployment: serve a task list (no learning, greedy policy).
    pub fn serve(&mut self, tasks: &[Task]) -> ServeSummary {
        self.policy.set_training(false);
        let mut summary = ServeSummary::default();
        for t in tasks {
            let r = self.step(t, false);
            summary.push(r);
        }
        summary
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    fn cfg(policy: &str) -> Config {
        let mut c = Config::default();
        c.policy = policy.into();
        c.requests = 30;
        c.train_episodes = 8;
        c.seed = 7;
        c
    }

    fn run(policy: &str, train_eps: usize) -> ServeSummary {
        let c = cfg(policy);
        let mut coord = Coordinator::from_config(&c).unwrap();
        let mut gen = TaskGen::new(&c.model, coord.env.dataset, Arrivals::Sequential, 3).unwrap();
        if train_eps > 0 {
            coord.train(&mut gen, train_eps, 16);
        }
        let tasks = gen.take(c.requests);
        coord.serve(&tasks)
    }

    #[test]
    fn all_policies_serve_without_panic() {
        for p in ["dvfo", "drldo", "appealnet", "cloud_only", "edge_only"] {
            let s = run(p, if p.starts_with('d') { 2 } else { 0 });
            assert_eq!(s.count(), 30, "{p}");
            assert!(s.tti_ms.mean() > 0.0, "{p}");
            assert!(s.eti_mj.mean() > 0.0, "{p}");
            assert!(s.accuracy_pct.mean() > 70.0, "{p}");
        }
    }

    #[test]
    fn edge_only_never_offloads_cloud_only_always() {
        let e = run("edge_only", 0);
        assert!(e.xi.values().iter().all(|&x| x == 0.0));
        assert!(e.payload_kb.values().iter().all(|&x| x == 0.0));
        let c = run("cloud_only", 0);
        assert!(c.xi.values().iter().all(|&x| x == 1.0));
        assert!(c.payload_kb.values().iter().all(|&x| x > 0.0));
    }

    #[test]
    fn trained_dvfo_beats_untrained_on_cost() {
        let untrained = run("dvfo", 0);
        let trained = run("dvfo", 25);
        assert!(
            trained.cost.mean() < untrained.cost.mean() * 1.02,
            "trained {} vs untrained {}",
            trained.cost.mean(),
            untrained.cost.mean()
        );
    }

    #[test]
    fn trained_dvfo_beats_edge_only_cost() {
        // the paper's headline: DVFO cuts cost (energy+latency blend)
        // vs static max-frequency edge inference.
        let dvfo = run("dvfo", 30);
        let edge = run("edge_only", 0);
        assert!(
            dvfo.cost.mean() < edge.cost.mean(),
            "dvfo {} vs edge {}",
            dvfo.cost.mean(),
            edge.cost.mean()
        );
    }

    #[test]
    fn bg_learner_trains_and_serves_through_the_coordinator() {
        // --learner bg end-to-end: train() spawns the background
        // learner on the first decide, set_training(false) drains it,
        // and deployment serves greedily off the trained agent
        let mut c = cfg("dvfo");
        c.learner = "bg".into();
        c.learner_publish_every = 8;
        let mut coord = Coordinator::from_config(&c).unwrap();
        let mut gen =
            TaskGen::new(&c.model, coord.env.dataset, Arrivals::Sequential, 3).unwrap();
        let curve = coord.train(&mut gen, 2, 16);
        assert_eq!(curve.len(), 2);
        assert!(curve.iter().all(|r| r.is_finite()));
        let tasks = gen.take(10);
        let s = coord.serve(&tasks);
        assert_eq!(s.count(), 10);
        assert!(s.tti_ms.mean() > 0.0);
    }

    #[test]
    fn reward_reference_is_positive_finite() {
        let c = cfg("dvfo");
        let coord = Coordinator::from_config(&c).unwrap();
        assert!(coord.ref_cost > 0.0 && coord.ref_cost.is_finite());
    }

    #[test]
    fn dvfo_decision_latency_overlapped() {
        // with concurrent=true the decision overhead on the path is 10%
        // of the policy latency
        let c = cfg("dvfo");
        let mut coord = Coordinator::from_config(&c).unwrap();
        let mut gen = TaskGen::new(&c.model, coord.env.dataset, Arrivals::Sequential, 5).unwrap();
        let t = gen.next_task();
        let r = coord.step(&t, false);
        assert!(r.tti_decision_s < coord.policy.decision_latency_s());
    }

    #[test]
    fn oracle_builds_and_beats_edge_only() {
        let mut c = cfg("oracle");
        c.freq_levels = 4; // keep the grid tiny
        c.xi_levels = 4;
        let mut coord = Coordinator::from_config(&c).unwrap();
        // isolate decision *quality*: don't charge the (deliberately
        // huge) exhaustive-search latency to the critical path here —
        // rebuild the oracle with zero charged latency
        {
            let probe_env = coord.env.clone();
            let mut pgen =
                TaskGen::new(&c.model, coord.env.dataset, Arrivals::Sequential, 5).unwrap();
            let probe_task = pgen.next_task();
            coord.policy = Box::new(crate::policy::OraclePolicy {
                levels: c.freq_levels,
                xi_levels: c.xi_levels,
                stride: 1,
                latency_s: 0.0,
                eval: Box::new(move |d| probe_env.clone().execute(&probe_task, d, 0.0).cost),
            });
        }
        let mut gen = TaskGen::new(&c.model, coord.env.dataset, Arrivals::Sequential, 5).unwrap();
        let tasks = gen.take(3);
        let s = coord.serve(&tasks);

        let mut ce = cfg("edge_only");
        ce.freq_levels = 4;
        let mut coord_e = Coordinator::from_config(&ce).unwrap();
        let mut gen_e =
            TaskGen::new(&ce.model, coord_e.env.dataset, Arrivals::Sequential, 5).unwrap();
        let tasks_e = gen_e.take(3);
        let se = coord_e.serve(&tasks_e);
        // the oracle probes a fixed reference task; on the served stream it
        // must still be within a small factor of (and usually below) the
        // static max-frequency baseline
        assert!(s.cost.mean() <= se.cost.mean() * 1.08);
    }
}
