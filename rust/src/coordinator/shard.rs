//! Sharded fleet serving: share-nothing engine shards in bounded time
//! epochs.
//!
//! One `engine.rs` kernel on one core drives today's whole fleet. This
//! module splits the fleet into `N` **shards** — contiguous, disjoint
//! device and stream ranges — and runs a full [`EngineCore`] per shard
//! on its own scoped thread (the same scoped-thread shape as
//! `util::parallel::sweep`, but long-lived workers instead of a work
//! queue, because shards must advance in lockstep). Inside an epoch a
//! shard is completely independent: its own event heap, edge queues,
//! batching windows, and a *local* slice of the cloud executor pool, so
//! no lock is ever taken on the event path.
//!
//! **Epoch semantics.** All shards advance simulated time in lockstep
//! windows of `epoch_s` seconds: shard k processes every event with
//! `t < epoch * epoch_s`, then meets the others at a barrier. At the
//! boundary each shard publishes its cloud-pool occupancy and its
//! cloud-service EWMA; after the barrier every shard adopts the summed
//! *external* occupancy and the blended (mean) service estimate via
//! [`EngineCore::set_cloud_signals`] / [`EngineCore::set_cloud_service`],
//! then runs the next epoch. Admission estimates therefore price the
//! **shared** pool with at most one epoch of staleness, which is the
//! quantified (and tested) deviation of a sharded run from the
//! unsharded trace. The run ends when every shard reports drained.
//!
//! **Cloud-slot partitioning.** The executor pool is divided across
//! shards (`cloud_slots / N` each, remainder to the first shards, floor
//! of one slot so no shard can deadlock on cloud work). When
//! `cloud_slots >= N` the partition is exact; otherwise the effective
//! global pool grows to `N` — the documented cost of share-nothing
//! shards. Admission estimators on every shard price the *global*
//! (post-partition) slot count.
//!
//! With `shards <= 1` the runner degenerates to a single
//! `run_until(∞)` call — the exact unsharded kernel, bit-for-bit.

use super::engine::{EngineCore, EngineResult};
use super::fleet::FleetOpts;
use super::Coordinator;
use crate::telemetry::sink::ReportSink;
use crate::util::sync::EpochExchange;
use crate::workload::TaskGen;

/// Default epoch length (simulated seconds) for sharded runs: long
/// enough to amortize the barrier, short enough that cross-shard cloud
/// signals stay fresh relative to typical task service times.
pub const SHARD_EPOCH_S: f64 = 0.05;

/// What one shard hands back: its kernel counters, its sink (whatever
/// telemetry the caller's sink type retained), and the device/stream
/// ranges it owned (bases into the fleet-global index spaces).
pub struct ShardOutcome<S> {
    pub result: EngineResult,
    pub sink: S,
    /// fleet-global index of this shard's first device
    pub dev_base: usize,
    /// number of devices this shard owned
    pub devices: usize,
    /// fleet-global index of this shard's first stream
    pub stream_base: usize,
}

/// Boundary snapshot one shard publishes for the others.
#[derive(Clone, Copy, Default)]
struct CloudSignal {
    in_flight: usize,
    service: Option<f64>,
    drained: bool,
}

/// Serve the fleet on `shards` share-nothing engine shards advancing in
/// `epoch_s` time epochs. `make_sink(k)` builds shard k's report sink;
/// outcomes return in shard order. The shard count is clamped to the
/// device and stream counts (every shard needs at least one of each);
/// `shards <= 1` runs the plain unsharded kernel.
///
/// Deterministic for a fixed shard count: each shard's trace is a
/// deterministic DES, and the boundary exchange folds the published
/// signals in shard-index order at a barrier, so thread scheduling
/// cannot leak into results.
pub fn serve_sharded<S, F>(
    devices: &mut [Coordinator],
    gens: &mut [TaskGen],
    per_stream: usize,
    opts: &FleetOpts,
    shards: usize,
    epoch_s: f64,
    make_sink: F,
) -> Vec<ShardOutcome<S>>
where
    S: ReportSink + Send,
    F: Fn(usize) -> S + Sync,
{
    let n_dev = devices.len();
    let n_gen = gens.len();
    let shards = shards.clamp(1, n_dev.max(1)).min(n_gen.max(1));
    if shards <= 1 {
        let mut sink = make_sink(0);
        let mut core = EngineCore::new(devices, gens, per_stream, opts);
        core.run_until(f64::INFINITY, &mut sink);
        return vec![ShardOutcome {
            result: core.into_result(),
            sink,
            dev_base: 0,
            devices: n_dev,
            stream_base: 0,
        }];
    }
    assert!(epoch_s > 0.0, "sharded runs need a positive epoch");

    // contiguous partition: shard k owns devices [n_dev*k/N, n_dev*(k+1)/N)
    // and streams [n_gen*k/N, n_gen*(k+1)/N); with N <= min(n_dev, n_gen)
    // every shard gets at least one of each
    let mut parts: Vec<(usize, &mut [Coordinator], usize, &mut [TaskGen])> = Vec::new();
    {
        let mut dev_rest = devices;
        let mut gen_rest = gens;
        let (mut dev_base, mut stream_base) = (0usize, 0usize);
        for k in 0..shards {
            let dev_end = n_dev * (k + 1) / shards;
            let gen_end = n_gen * (k + 1) / shards;
            let (d, dr) = dev_rest.split_at_mut(dev_end - dev_base);
            let (g, gr) = gen_rest.split_at_mut(gen_end - stream_base);
            dev_rest = dr;
            gen_rest = gr;
            parts.push((dev_base, d, stream_base, g));
            dev_base = dev_end;
            stream_base = gen_end;
        }
    }

    // local slice of the executor pool per shard (remainder to the first
    // shards, floor one so cloud work can always run somewhere)
    let slots = opts.des.cloud_slots;
    let local_slots: Vec<usize> = (0..shards)
        .map(|k| (slots / shards + usize::from(k < slots % shards)).max(1))
        .collect();
    let est_slots_global: usize = local_slots.iter().sum();

    // the epoch-boundary protocol (publish → barrier → index-ordered
    // read → barrier) lives in `util::sync::EpochExchange`, where the
    // loom models in tests/loom_models.rs check every interleaving of it
    let exchange = EpochExchange::new(shards, CloudSignal::default());
    let exchange = &exchange;
    let make_sink = &make_sink;
    let local_slots = &local_slots;

    let mut outcomes: Vec<ShardOutcome<S>> = Vec::with_capacity(shards);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(shards);
        for (k, (dev_base, devs, stream_base, gs)) in parts.into_iter().enumerate() {
            let mut shard_opts = opts.clone();
            shard_opts.des.cloud_slots = local_slots[k];
            // device faults move with their shard (indices rebased to the
            // local range); cloud outages replicate to every shard so each
            // local pool drops to zero — summing back to a global outage
            shard_opts.chaos = opts.chaos.partition(dev_base, devs.len());
            handles.push(scope.spawn(move || {
                let mut sink = make_sink(k);
                let n_local_dev = devs.len();
                let mut core = EngineCore::new(devs, gs, per_stream, &shard_opts);
                core.set_cloud_signals(0, est_slots_global);
                let mut epoch: u64 = 1;
                loop {
                    let drained = core.run_until(epoch as f64 * epoch_s, &mut sink);
                    let published = CloudSignal {
                        in_flight: core.cloud_in_flight(),
                        service: core.cloud_service(),
                        drained,
                    };
                    let mut all_drained = true;
                    let mut ext = 0usize;
                    let (mut svc_sum, mut svc_n) = (0.0f64, 0usize);
                    // publish barrier / index-ordered read / read barrier:
                    // every shard's boundary snapshot is visible before
                    // anyone reads, and nobody re-publishes until everyone
                    // has consumed this epoch's snapshots
                    exchange.exchange_with(k, published, |i, sig| {
                        all_drained &= sig.drained;
                        if i != k {
                            ext += sig.in_flight;
                        }
                        if let Some(v) = sig.service {
                            svc_sum += v;
                            svc_n += 1;
                        }
                    });
                    if all_drained {
                        break;
                    }
                    core.set_cloud_signals(ext, est_slots_global);
                    core.set_cloud_service(if svc_n > 0 {
                        Some(svc_sum / svc_n as f64)
                    } else {
                        None
                    });
                    epoch += 1;
                }
                ShardOutcome {
                    result: core.into_result(),
                    sink,
                    dev_base,
                    devices: n_local_dev,
                    stream_base,
                }
            }));
        }
        for h in handles {
            outcomes.push(h.join().expect("shard worker panicked"));
        }
    });
    outcomes
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;
    use crate::configx::Config;
    use crate::coordinator::engine::CollectSink;
    use crate::coordinator::fleet::Fleet;
    use crate::workload::{Arrivals, SloClass};

    fn fleet(spec: &str) -> Fleet {
        let mut c = Config::default();
        c.policy = "cloud_only".into();
        c.fleet = spec.into();
        c.seed = 23;
        Fleet::from_config(&c).unwrap()
    }

    fn gens(fleet: &Fleet, n: usize, seed: u64, slo: SloClass) -> Vec<TaskGen> {
        (0..n)
            .map(|s| {
                TaskGen::new(
                    fleet.devices[0].env.profile.name,
                    fleet.devices[0].env.dataset,
                    Arrivals::Poisson { rate: 20.0 },
                    seed + s as u64,
                )
                .unwrap()
                .with_slo(slo)
            })
            .collect()
    }

    #[test]
    fn shard_count_clamps_to_devices_and_streams() {
        let mut f = fleet("xavier-nx,jetson-nano");
        let mut g = gens(&f, 2, 50, SloClass::default());
        // 8 requested shards, 2 devices -> 2 shards
        let out = serve_sharded(
            &mut f.devices,
            &mut g,
            3,
            &FleetOpts::default(),
            8,
            SHARD_EPOCH_S,
            |_| CollectSink::new(),
        );
        assert_eq!(out.len(), 2);
        assert_eq!((out[0].dev_base, out[0].devices), (0, 1));
        assert_eq!((out[1].dev_base, out[1].devices), (1, 1));
        assert_eq!(out[1].stream_base, 1);
        let completed: usize = out.iter().map(|o| o.result.completed).sum();
        let offered: usize = out.iter().map(|o| o.result.offered).sum();
        assert_eq!(offered, 6);
        assert_eq!(completed, 6);
        // the collected jobs agree with the counter shard by shard
        for o in out {
            let n = o.result.completed;
            assert_eq!(o.sink.into_jobs().len(), n);
        }
    }

    #[test]
    fn single_shard_is_bit_exact_with_serve() {
        let run_serve = || {
            let mut f = fleet("xavier-nx,jetson-tx2");
            let mut g = gens(&f, 4, 70, SloClass::parse("200").unwrap());
            super::super::engine::serve(&mut f.devices, &mut g, 5, &FleetOpts::default())
        };
        let run_sharded = || {
            let mut f = fleet("xavier-nx,jetson-tx2");
            let mut g = gens(&f, 4, 70, SloClass::parse("200").unwrap());
            let mut out = serve_sharded(
                &mut f.devices,
                &mut g,
                5,
                &FleetOpts::default(),
                1,
                SHARD_EPOCH_S,
                |_| CollectSink::new(),
            );
            let mut o = out.pop().unwrap();
            o.result.jobs = o.sink.into_jobs();
            o.result
        };
        let a = run_serve();
        let b = run_sharded();
        assert_eq!(a.events, b.events);
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            let (rx, ry) = (x.report.as_ref().unwrap(), y.report.as_ref().unwrap());
            assert_eq!(rx.e2e_s.to_bits(), ry.e2e_s.to_bits());
            assert_eq!(rx.eti_total_j.to_bits(), ry.eti_total_j.to_bits());
        }
    }

    #[test]
    fn sharded_epochs_are_bit_exact_across_schedulers() {
        // the epoch loop leans on `pop_before` leaving boundary events
        // queued; heap and calendar must agree shard by shard, task by
        // task — same epochs, same event counts, same bit patterns
        use crate::coordinator::des::DesOpts;
        use crate::coordinator::sched::SchedKind;
        let run = |kind: SchedKind| {
            let mut f = fleet("xavier-nx,jetson-tx2,jetson-nano");
            let mut g = gens(&f, 6, 110, SloClass::parse("200").unwrap());
            let opts = FleetOpts {
                des: DesOpts {
                    sched: kind,
                    cloud_batch_window_s: 0.005,
                    ..DesOpts::default()
                },
                ..FleetOpts::default()
            };
            serve_sharded(&mut f.devices, &mut g, 5, &opts, 3, 0.02, |_| {
                CollectSink::new()
            })
        };
        let heap = run(SchedKind::Heap);
        let cal = run(SchedKind::Calendar);
        assert_eq!(heap.len(), cal.len());
        for (h, c) in heap.iter().zip(&cal) {
            assert_eq!(h.result.events, c.result.events);
            assert_eq!(h.result.completed, c.result.completed);
            assert_eq!(h.result.stale_closes, c.result.stale_closes);
        }
        for (h, c) in heap.into_iter().zip(cal) {
            let (hj, cj) = (h.sink.into_jobs(), c.sink.into_jobs());
            assert_eq!(hj.len(), cj.len());
            for (x, y) in hj.iter().zip(&cj) {
                let (rx, ry) = (x.report.as_ref().unwrap(), y.report.as_ref().unwrap());
                assert_eq!(rx.e2e_s.to_bits(), ry.e2e_s.to_bits());
                assert_eq!(rx.eti_total_j.to_bits(), ry.eti_total_j.to_bits());
            }
        }
    }

    /// Loom regression seed (runs on stable, no `--cfg loom` needed):
    /// the minimized interleaving that breaks a *single*-barrier
    /// exchange. Participant A races one epoch ahead and tries to
    /// republish while participant B is still reading; the exchange's
    /// second barrier makes that impossible, so B only ever observes
    /// epoch-consistent slot values. Under the buggy single-barrier
    /// variant, B's read window overlaps A's next publish and the
    /// assertion below trips. The full interleaving space is explored
    /// by `tests/loom_models.rs` under `--cfg loom`.
    #[test]
    fn epoch_exchange_blocks_early_republish_regression_seed() {
        use crate::util::sync::EpochExchange;
        let ex = EpochExchange::new(2, 0u64);
        std::thread::scope(|s| {
            let exr = &ex;
            // A: publish epoch e and move on as fast as possible
            s.spawn(move || {
                for e in 1..=64u64 {
                    exr.exchange_with(0, e, |_, _| {});
                }
            });
            // B: read slowly, yielding mid-read to hand A every chance
            // to race ahead
            for e in 1..=64u64 {
                let mut seen = Vec::new();
                exr.exchange_with(1, e, |i, &v| {
                    std::thread::yield_now();
                    seen.push((i, v));
                });
                assert_eq!(
                    seen,
                    vec![(0, e), (1, e)],
                    "epoch {e}: B must never observe A's next-epoch publish"
                );
            }
        });
    }

    #[test]
    fn sharded_run_is_deterministic_and_conserves_tasks() {
        let run = || {
            let mut f = fleet("xavier-nx,jetson-tx2,jetson-nano,xavier-nx");
            let mut g = gens(&f, 8, 90, SloClass::parse("150").unwrap());
            let opts = FleetOpts {
                admission: super::super::fleet::Admission::Shed,
                ..FleetOpts::default()
            };
            serve_sharded(&mut f.devices, &mut g, 6, &opts, 4, 0.02, |_| {
                CollectSink::new()
            })
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 4);
        let offered: usize = a.iter().map(|o| o.result.offered).sum();
        let shed: usize = a.iter().map(|o| o.result.shed).sum();
        let completed: usize = a.iter().map(|o| o.result.completed).sum();
        let failed: usize = a.iter().map(|o| o.result.failed).sum();
        assert_eq!(offered, 48);
        assert_eq!(
            offered,
            completed + shed + failed,
            "conservation across shards"
        );
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.result.offered, y.result.offered);
            assert_eq!(x.result.shed, y.result.shed);
            assert_eq!(x.result.events, y.result.events);
            assert_eq!(x.result.completed, y.result.completed);
        }
    }
}
