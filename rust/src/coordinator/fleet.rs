//! Fleet-scale serving: the multi-edge dispatch layer over the unified
//! discrete-event kernel (`super::engine`).
//!
//! Where `des.rs` drives one loaded edge node, this module owns a
//! **fleet** of N heterogeneous edge devices. Each device is a full
//! `Coordinator` (its own `EdgeCloudEnv`, DVFS state, FIFO/priority
//! queue, residency estimate, and policy instance built from a
//! per-device `DeviceSpec`), with its own uplink and batching window;
//! all devices share one bounded cloud executor pool, where co-arriving
//! cloud work from different devices can merge into batched invocations
//! within the cloud batch window. Arriving tasks are routed by a
//! pluggable [`Router`] (round-robin, join-shortest-queue, energy-aware
//! least-backlog) and screened by an [`Admission`] policy: when the
//! chosen device's estimated completion time — edge backlog *plus* the
//! expected uplink transfer and shared cloud-pool wait — would blow the
//! task's SLO deadline, the dispatcher can shed the task outright or
//! downgrade it to edge-only execution (skipping the uplink/cloud
//! detour) — or, with re-route-before-shed enabled, first retry the
//! cheapest feasible sibling device. A periodic rebalance tick can also
//! migrate queued-but-not-started tasks from the most-backlogged device
//! to the least-backlogged one mid-run (work stealing, with a
//! configurable in-transit latency penalty). Shed, downgrade,
//! SLO-violation, re-route/migration, and cloud-batch-occupancy counts
//! are first-class telemetry next to the p50/p95/p99 latency
//! percentiles.
//!
//! This module holds the policy surface (specs, parsing, fleet
//! construction, summary folding); the event loop itself lives in the
//! kernel, shared bit-for-bit with `serve_multistream` — a 1-device
//! fleet with round-robin routing, no SLOs, and admission disabled
//! reproduces it task-for-task (the parity gate in
//! `rust/tests/fleet_serving.rs`).

use super::chaos::{FaultSchedule, RetryPolicy};
use super::engine::{self, CollectSink, EngineJob};
use super::shard::{serve_sharded, SHARD_EPOCH_S};
use super::{Coordinator, ServeSummary};
use crate::configx::Config;
use crate::coordinator::des::DesOpts;
use crate::device::spec::find_device;
use crate::telemetry::sink::StreamingSink;
use crate::util::{Running, Samples};
use crate::workload::{Arrivals, TaskGen};
use anyhow::{bail, Context, Result};

/// Dispatch policy: which edge device an arriving task lands on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Router {
    /// cycle through devices in index order
    RoundRobin,
    /// join-shortest-queue: fewest tasks queued or in service
    ShortestQueue,
    /// energy-aware least-backlog: minimize estimated backlog seconds
    /// weighted by the device's power envelope, so work drifts toward
    /// idle *and* efficient devices
    LeastBacklog,
}

impl Router {
    /// Parse a router spec: `round_robin` | `shortest_queue` | `least_backlog`
    /// (aliases: `rr`, `jsq`, `energy`).
    pub fn parse(spec: &str) -> Result<Router> {
        Ok(match spec.trim() {
            "round_robin" | "rr" => Router::RoundRobin,
            "shortest_queue" | "jsq" => Router::ShortestQueue,
            "least_backlog" | "energy" => Router::LeastBacklog,
            other => bail!(
                "unknown router `{other}`; valid routers: round_robin (alias rr), \
                 shortest_queue (alias jsq), least_backlog (alias energy)"
            ),
        })
    }
}

/// What the dispatcher does with a task whose estimated completion time
/// would blow its SLO deadline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// accept everything (no admission control)
    Off,
    /// drop doomed best-effort tasks; priority > 0 tasks are downgraded
    /// to edge-only instead of dropped
    Shed,
    /// keep every task but force doomed ones to edge-only execution
    /// (skips the uplink/cloud detour, freeing the shared pool)
    Downgrade,
}

impl Admission {
    /// Parse an admission spec: `off` | `shed` | `downgrade`.
    pub fn parse(spec: &str) -> Result<Admission> {
        Ok(match spec.trim() {
            "off" | "none" => Admission::Off,
            "shed" => Admission::Shed,
            "downgrade" => Admission::Downgrade,
            other => bail!(
                "unknown admission policy `{other}`; valid policies: off (alias none), \
                 shed, downgrade"
            ),
        })
    }
}

/// Tunables of a fleet serving run.
///
/// Deprecated as a construction surface: prefer
/// [`EngineConfig`](super::EngineConfig), the flat builder that subsumes
/// these knobs plus [`DesOpts`] and the sharding controls, and convert
/// with [`EngineConfig::fleet_opts`](super::EngineConfig::fleet_opts).
/// This type remains the engine-internal parameter block (a parity test
/// in `rust/tests/engine_config_parity.rs` pins the two construction
/// paths to identical values).
#[derive(Clone, Debug)]
pub struct FleetOpts {
    /// per-device DES tunables (uplink batch window + cap), the size of
    /// the *shared* cloud executor pool, and the cross-device cloud
    /// batching window
    pub des: DesOpts,
    pub router: Router,
    pub admission: Admission,
    /// re-route-before-shed: when the routed device's completion
    /// estimate would blow the task's deadline, re-route to the
    /// cheapest feasible sibling and only shed/downgrade when no device
    /// can make it (takes effect with `admission` shed|downgrade)
    pub reroute: bool,
    /// period of the cross-device rebalance tick in seconds; 0 (the
    /// default) schedules no ticks at all and reproduces the
    /// non-rebalancing engine trace bit-for-bit
    pub rebalance_window_s: f64,
    /// backlog divergence (seconds) between the most- and least-
    /// backlogged devices above which queued tasks migrate; ∞ (the
    /// default) makes every tick a no-op
    pub migrate_threshold_s: f64,
    /// latency penalty a migrated task pays in transit (it re-enqueues
    /// on the destination only after the transfer completes)
    pub migrate_penalty_s: f64,
    /// deterministic fault schedule (device dropouts, bandwidth
    /// collapses, cloud outages); empty (the default) schedules no
    /// fault events at all and reproduces the fault-free engine trace
    /// bit-for-bit
    pub chaos: FaultSchedule,
    /// retry budget + deterministic exponential backoff for work a
    /// fault kills mid-flight
    pub retry: RetryPolicy,
}

impl Default for FleetOpts {
    fn default() -> Self {
        Self {
            des: DesOpts::default(),
            router: Router::RoundRobin,
            admission: Admission::Off,
            reroute: false,
            rebalance_window_s: 0.0,
            migrate_threshold_s: f64::INFINITY,
            migrate_penalty_s: 0.005,
            chaos: FaultSchedule::default(),
            retry: RetryPolicy::default(),
        }
    }
}

impl FleetOpts {
    /// Build from a run config (`fleet`/`router`/`slo`/`admission` and
    /// the rebalancing knobs, plus the DES knobs).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        Ok(Self {
            des: DesOpts::from_config(cfg),
            router: Router::parse(&cfg.router)?,
            admission: Admission::parse(&cfg.admission)?,
            reroute: cfg.reroute,
            rebalance_window_s: cfg.rebalance_window_ms / 1e3,
            migrate_threshold_s: cfg.migrate_threshold_ms / 1e3,
            migrate_penalty_s: cfg.migrate_penalty_ms / 1e3,
            chaos: FaultSchedule::parse(&cfg.chaos)?,
            retry: RetryPolicy {
                max_retries: cfg.retry_max as u32,
                backoff_base_s: cfg.retry_backoff_ms / 1e3,
            },
        })
    }
}

/// Expand a fleet spec into a device-name list. Empty spec = one device
/// of `default_device`. Entries are comma-separated device-zoo names,
/// with `name*count` for homogeneous groups.
pub fn parse_fleet_spec(spec: &str, default_device: &str) -> Result<Vec<String>> {
    let spec = spec.trim();
    if spec.is_empty() {
        find_device(default_device)?;
        return Ok(vec![default_device.to_string()]);
    }
    let mut out = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            bail!("empty device entry in fleet spec `{spec}`");
        }
        let (name, count) = match part.split_once('*') {
            Some((n, c)) => (
                n.trim(),
                c.trim()
                    .parse::<usize>()
                    .with_context(|| format!("fleet count `{c}` in `{part}`"))?,
            ),
            None => (part, 1),
        };
        if count == 0 {
            bail!("fleet count must be >= 1 in `{part}`");
        }
        find_device(name)?;
        for _ in 0..count {
            out.push(name.to_string());
        }
    }
    Ok(out)
}

/// The fleet: N per-device serving systems sharing a cloud pool.
pub struct Fleet {
    pub devices: Vec<Coordinator>,
    pub names: Vec<String>,
}

impl Fleet {
    /// Build one `Coordinator` per fleet entry. Device 0 uses the
    /// config's seed unchanged (that is what the N=1 parity gate relies
    /// on); later devices get decorrelated seeds.
    pub fn from_config(cfg: &Config) -> Result<Fleet> {
        let names = parse_fleet_spec(&cfg.fleet, &cfg.device)?;
        let mut devices = Vec::with_capacity(names.len());
        for (i, name) in names.iter().enumerate() {
            let mut dcfg = cfg.clone();
            dcfg.device = name.clone();
            dcfg.seed = cfg.seed ^ ((i as u64) << 17);
            devices.push(Coordinator::from_config(&dcfg)?);
        }
        Ok(Fleet { devices, names })
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// Offline-train every device's policy (no-op feedback for fixed
    /// policies; callers usually gate this on the policy being a
    /// learning one to save the wasted simulation).
    pub fn train_offline(&mut self, episodes: usize, tasks_per_ep: usize, seed: u64) -> Result<()> {
        for (i, coord) in self.devices.iter_mut().enumerate() {
            let mut gen = TaskGen::new(
                coord.env.profile.name,
                coord.env.dataset,
                Arrivals::Sequential,
                seed ^ 0x7341 ^ ((i as u64) << 9),
            )?;
            coord.train(&mut gen, episodes, tasks_per_ep);
        }
        Ok(())
    }
}

/// Per-device telemetry row of a fleet run.
#[derive(Clone, Debug)]
pub struct DeviceTelemetry {
    pub name: String,
    /// tasks that completed on this device
    pub served: usize,
    /// total energy spent by this device's completed tasks (J)
    pub energy_j: f64,
    /// completed tasks that missed their deadline
    pub violations: usize,
    /// tasks re-routed TO this device by re-route-before-shed
    pub rerouted_in: usize,
    /// queued tasks the rebalancer migrated onto this device
    pub migrated_in: usize,
    /// queued tasks the rebalancer migrated away from this device
    pub migrated_out: usize,
    /// fault windows from the chaos schedule that targeted this device
    pub faults: usize,
    /// tasks that terminally failed (retry budget exhausted) while
    /// owned by this device
    pub failed: usize,
}

/// Aggregated outcome of a fleet serving run: the usual latency/energy
/// summary plus SLO/admission accounting and cloud-batching telemetry.
#[derive(Default)]
pub struct FleetSummary {
    pub serve: ServeSummary,
    /// tasks generated by the streams
    pub offered: usize,
    /// tasks that ran to completion
    pub completed: usize,
    /// tasks dropped by admission control, plus accepted tasks shed
    /// while draining a downed device with no feasible sibling
    pub shed: usize,
    /// tasks that exhausted their fault-retry budget (terminal,
    /// distinct from `shed`; `offered == completed + shed + failed`)
    pub failed: usize,
    /// fault windows injected from the chaos schedule (onsets)
    pub faults_injected: usize,
    /// retry re-enqueues scheduled for fault-killed work
    pub retries: usize,
    /// tasks pulled off a downed device's edge queue at dropout
    /// (re-routed to a sibling or shed)
    pub drained_on_dropout: usize,
    /// tasks forced to edge-only by admission control
    pub downgraded: usize,
    /// completed tasks whose end-to-end latency missed their deadline
    pub slo_violations: usize,
    /// completed tasks that met their deadline (== completed when no
    /// task carries a deadline)
    pub goodput: usize,
    pub per_device: Vec<DeviceTelemetry>,
    /// cloud executor invocations (batched and singleton)
    pub cloud_invocations: usize,
    /// jobs per cloud executor invocation (batch occupancy)
    pub cloud_occupancy: Samples,
    /// dispatch/runtime overhead amortized away by cloud batching (s)
    pub cloud_dispatch_saved_s: f64,
    /// tasks re-routed to a sibling device instead of shed/downgraded
    pub rerouted: usize,
    /// queued tasks migrated between devices by the rebalancer
    pub migrated: usize,
    /// total migration latency penalty paid by migrated tasks (s)
    pub migration_latency_s: f64,
    /// discrete events the kernel processed for this run (the
    /// `engine_throughput` bench divides these by wall-clock)
    pub events: usize,
    /// generation-stale batch-close timers popped and discarded
    /// (tombstones left behind by size-cap flushes; always ≤
    /// `window_flushes`)
    pub stale_closes: usize,
    /// uplink + cloud batch windows flushed with at least one job
    pub window_flushes: usize,
}

/// Empty per-device telemetry rows, one per fleet device in order.
fn device_rows(fleet: &Fleet) -> Vec<DeviceTelemetry> {
    fleet
        .names
        .iter()
        .map(|n| DeviceTelemetry {
            name: n.clone(),
            served: 0,
            energy_j: 0.0,
            violations: 0,
            rerouted_in: 0,
            migrated_in: 0,
            migrated_out: 0,
            faults: 0,
            failed: 0,
        })
        .collect()
}

/// Fold completed jobs into the summary: SLO accounting, per-device
/// served/energy/violation rows, and the full `ServeSummary` telemetry.
/// Consumes the jobs so each report MOVES into the summary — the fold
/// stays string- and clone-free per task.
fn fold_jobs(summary: &mut FleetSummary, jobs: Vec<EngineJob>) {
    for job in jobs {
        let Some(r) = job.report else { continue };
        summary.completed += 1;
        let e2e = if r.e2e_s > 0.0 {
            r.e2e_s
        } else {
            r.queue_wait_s + r.tti_total_s
        };
        let violated = job.deadline_s.is_finite() && e2e > job.deadline_s;
        if violated {
            summary.slo_violations += 1;
        } else {
            summary.goodput += 1;
        }
        let d = &mut summary.per_device[job.dev];
        d.served += 1;
        d.energy_j += r.eti_total_j;
        if violated {
            d.violations += 1;
        }
        summary.serve.push(r);
    }
}

/// Serve `per_stream` tasks from each stream through the fleet via the
/// unified kernel. Streams are routed per task by the configured
/// router; reports accumulate in job-creation (arrival) order so a
/// 1-device round-robin fleet is report-ordered exactly like
/// `serve_multistream`.
pub fn serve_fleet(
    fleet: &mut Fleet,
    gens: &mut [TaskGen],
    per_stream: usize,
    opts: &FleetOpts,
) -> FleetSummary {
    let mut summary = FleetSummary {
        per_device: device_rows(fleet),
        ..FleetSummary::default()
    };
    let result = engine::serve(&mut fleet.devices, gens, per_stream, opts);
    summary.offered = result.offered;
    summary.shed = result.shed;
    summary.failed = result.failed;
    summary.faults_injected = result.faults_injected;
    summary.retries = result.retries;
    summary.drained_on_dropout = result.drained_on_dropout;
    summary.downgraded = result.downgraded;
    summary.cloud_invocations = result.cloud_invocations;
    summary.cloud_occupancy = result.cloud_occupancy;
    summary.cloud_dispatch_saved_s = result.cloud_dispatch_saved_s;
    summary.rerouted = result.rerouted;
    summary.migrated = result.migrated;
    summary.migration_latency_s = result.migration_latency_s;
    summary.events = result.events;
    summary.stale_closes = result.stale_closes;
    summary.window_flushes = result.window_flushes;
    for (i, d) in summary.per_device.iter_mut().enumerate() {
        // EngineResult::default() (empty run) carries empty vectors
        d.rerouted_in = result.per_dev_rerouted.get(i).copied().unwrap_or(0);
        d.migrated_in = result.per_dev_migrated_in.get(i).copied().unwrap_or(0);
        d.migrated_out = result.per_dev_migrated_out.get(i).copied().unwrap_or(0);
        d.faults = result.per_dev_faults.get(i).copied().unwrap_or(0);
        d.failed = result.per_dev_failed.get(i).copied().unwrap_or(0);
    }
    fold_jobs(&mut summary, result.jobs);
    summary
}

/// Sharded fleet serving with collected reports: the fleet splits into
/// `shards` share-nothing engine shards (see `coordinator::shard`),
/// every shard's collected jobs are remapped into fleet-global device
/// and stream indices, and the usual [`FleetSummary`] folds over the
/// concatenation in shard order. `shards <= 1` delegates to
/// [`serve_fleet`] — bit-exact with the unsharded path.
pub fn serve_fleet_sharded(
    fleet: &mut Fleet,
    gens: &mut [TaskGen],
    per_stream: usize,
    opts: &FleetOpts,
    shards: usize,
) -> FleetSummary {
    if shards <= 1 {
        return serve_fleet(fleet, gens, per_stream, opts);
    }
    let mut summary = FleetSummary {
        per_device: device_rows(fleet),
        ..FleetSummary::default()
    };
    let outcomes = serve_sharded(
        &mut fleet.devices,
        gens,
        per_stream,
        opts,
        shards,
        SHARD_EPOCH_S,
        |_| CollectSink::new(),
    );
    for o in outcomes {
        let result = o.result;
        summary.offered += result.offered;
        summary.shed += result.shed;
        summary.failed += result.failed;
        summary.faults_injected += result.faults_injected;
        summary.retries += result.retries;
        summary.drained_on_dropout += result.drained_on_dropout;
        summary.downgraded += result.downgraded;
        summary.cloud_invocations += result.cloud_invocations;
        for &occ in result.cloud_occupancy.values() {
            summary.cloud_occupancy.push(occ);
        }
        summary.cloud_dispatch_saved_s += result.cloud_dispatch_saved_s;
        summary.rerouted += result.rerouted;
        summary.migrated += result.migrated;
        summary.migration_latency_s += result.migration_latency_s;
        summary.events += result.events;
        summary.stale_closes += result.stale_closes;
        summary.window_flushes += result.window_flushes;
        for i in 0..o.devices {
            let d = &mut summary.per_device[o.dev_base + i];
            d.rerouted_in += result.per_dev_rerouted.get(i).copied().unwrap_or(0);
            d.migrated_in += result.per_dev_migrated_in.get(i).copied().unwrap_or(0);
            d.migrated_out += result.per_dev_migrated_out.get(i).copied().unwrap_or(0);
            d.faults += result.per_dev_faults.get(i).copied().unwrap_or(0);
            d.failed += result.per_dev_failed.get(i).copied().unwrap_or(0);
        }
        let mut jobs = o.sink.into_jobs();
        for job in jobs.iter_mut() {
            job.dev += o.dev_base;
            if let Some(r) = job.report.as_mut() {
                r.stream += o.stream_base;
            }
        }
        fold_jobs(&mut summary, jobs);
    }
    summary
}

/// Aggregated outcome of a **streaming** fleet run: constant-memory
/// telemetry (quantile sketches + counters, no per-task reports) plus
/// the same SLO/admission/cloud accounting as [`FleetSummary`]. This is
/// what a million-task run returns without holding a million reports.
pub struct StreamSummary {
    /// merged streaming telemetry across all shards (sketches in
    /// fleet-global device indices)
    pub telemetry: StreamingSink,
    /// tasks generated by the streams
    pub offered: usize,
    /// tasks that ran to completion
    pub completed: usize,
    /// tasks dropped by admission control, plus accepted tasks shed
    /// while draining a downed device with no feasible sibling
    pub shed: usize,
    /// tasks that exhausted their fault-retry budget (terminal,
    /// distinct from `shed`; `offered == completed + shed + failed`)
    pub failed: usize,
    /// fault windows injected from the chaos schedule (onsets)
    pub faults_injected: usize,
    /// retry re-enqueues scheduled for fault-killed work
    pub retries: usize,
    /// tasks pulled off a downed device's edge queue at dropout
    pub drained_on_dropout: usize,
    /// tasks forced to edge-only by admission control
    pub downgraded: usize,
    /// completed tasks whose end-to-end latency missed their deadline
    pub slo_violations: usize,
    /// completed tasks that met their deadline
    pub goodput: usize,
    pub per_device: Vec<DeviceTelemetry>,
    /// cloud executor invocations (batched and singleton)
    pub cloud_invocations: usize,
    /// batch-occupancy aggregate (running mean/min/max — the streaming
    /// replacement for the exact per-invocation sample buffer)
    pub cloud_occupancy: Running,
    /// dispatch/runtime overhead amortized away by cloud batching (s)
    pub cloud_dispatch_saved_s: f64,
    /// tasks re-routed to a sibling device instead of shed/downgraded
    pub rerouted: usize,
    /// queued tasks migrated between devices by the rebalancer
    pub migrated: usize,
    /// total migration latency penalty paid by migrated tasks (s)
    pub migration_latency_s: f64,
    /// discrete events processed across all shards
    pub events: usize,
    /// generation-stale batch-close timers popped and discarded across
    /// all shards (always ≤ `window_flushes`)
    pub stale_closes: usize,
    /// uplink + cloud batch windows flushed with at least one job
    pub window_flushes: usize,
    /// engine shards the run actually used (after clamping)
    pub shards: usize,
}

/// Sharded fleet serving with **streaming** telemetry: every shard
/// folds its completions into a [`StreamingSink`] the moment they
/// finish, and the per-shard sinks merge (device-offset) into one.
/// Memory stays bounded by the sketch spans and the device count — a
/// 1M-task, 100-device run never materializes a report vector.
/// `shards <= 1` still streams (one shard, same constant-memory
/// property, identical event trace to the unsharded kernel).
pub fn serve_fleet_streaming(
    fleet: &mut Fleet,
    gens: &mut [TaskGen],
    per_stream: usize,
    opts: &FleetOpts,
    shards: usize,
) -> StreamSummary {
    let outcomes = serve_sharded(
        &mut fleet.devices,
        gens,
        per_stream,
        opts,
        shards,
        SHARD_EPOCH_S,
        |_| StreamingSink::new(),
    );
    let mut telemetry = StreamingSink::new();
    let mut per_device = device_rows(fleet);
    let shards_used = outcomes.len();
    let (mut offered, mut completed, mut shed, mut downgraded) = (0, 0, 0, 0);
    let (mut failed, mut faults_injected, mut retries, mut drained_on_dropout) = (0, 0, 0, 0);
    let mut cloud_invocations = 0;
    let mut cloud_occupancy = Running::new();
    let mut cloud_dispatch_saved_s = 0.0;
    let (mut rerouted, mut migrated) = (0, 0);
    let mut migration_latency_s = 0.0;
    let mut events = 0;
    let (mut stale_closes, mut window_flushes) = (0, 0);
    for o in outcomes {
        telemetry.merge_offset(&o.sink, o.dev_base);
        let result = o.result;
        offered += result.offered;
        completed += result.completed;
        shed += result.shed;
        failed += result.failed;
        faults_injected += result.faults_injected;
        retries += result.retries;
        drained_on_dropout += result.drained_on_dropout;
        downgraded += result.downgraded;
        cloud_invocations += result.cloud_invocations;
        cloud_occupancy.merge(&result.cloud_occupancy_run);
        cloud_dispatch_saved_s += result.cloud_dispatch_saved_s;
        rerouted += result.rerouted;
        migrated += result.migrated;
        migration_latency_s += result.migration_latency_s;
        events += result.events;
        stale_closes += result.stale_closes;
        window_flushes += result.window_flushes;
        for i in 0..o.devices {
            let d = &mut per_device[o.dev_base + i];
            d.rerouted_in += result.per_dev_rerouted.get(i).copied().unwrap_or(0);
            d.migrated_in += result.per_dev_migrated_in.get(i).copied().unwrap_or(0);
            d.migrated_out += result.per_dev_migrated_out.get(i).copied().unwrap_or(0);
            d.faults += result.per_dev_faults.get(i).copied().unwrap_or(0);
            d.failed += result.per_dev_failed.get(i).copied().unwrap_or(0);
        }
    }
    for (i, d) in per_device.iter_mut().enumerate() {
        d.served = telemetry.dev_served.get(i).copied().unwrap_or(0);
        d.energy_j = telemetry.dev_energy_j.get(i).copied().unwrap_or(0.0);
        d.violations = telemetry.dev_violations.get(i).copied().unwrap_or(0);
    }
    let (slo_violations, goodput) = (telemetry.violations, telemetry.goodput);
    StreamSummary {
        telemetry,
        offered,
        completed,
        shed,
        failed,
        faults_injected,
        retries,
        drained_on_dropout,
        downgraded,
        slo_violations,
        goodput,
        per_device,
        cloud_invocations,
        cloud_occupancy,
        cloud_dispatch_saved_s,
        rerouted,
        migrated,
        migration_latency_s,
        events,
        stale_closes,
        window_flushes,
        shards: shards_used,
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::workload::SloClass;

    fn cfg(policy: &str, fleet: &str) -> Config {
        let mut c = Config::default();
        c.policy = policy.into();
        c.fleet = fleet.into();
        c.seed = 19;
        c
    }

    fn gens(
        fleet: &Fleet,
        n: usize,
        arrivals: Arrivals,
        base_seed: u64,
        slo: SloClass,
    ) -> Vec<TaskGen> {
        (0..n)
            .map(|s| {
                TaskGen::new(
                    fleet.devices[0].env.profile.name,
                    fleet.devices[0].env.dataset,
                    arrivals.clone(),
                    base_seed + s as u64,
                )
                .unwrap()
                .with_slo(slo)
            })
            .collect()
    }

    #[test]
    fn fleet_spec_expansion() {
        assert_eq!(
            parse_fleet_spec("", "xavier-nx").unwrap(),
            vec!["xavier-nx"]
        );
        assert_eq!(
            parse_fleet_spec("jetson-nano*2, jetson-tx2", "xavier-nx").unwrap(),
            vec!["jetson-nano", "jetson-nano", "jetson-tx2"]
        );
        assert!(parse_fleet_spec("warp-core", "xavier-nx").is_err());
        assert!(parse_fleet_spec("jetson-nano*0", "xavier-nx").is_err());
        assert!(parse_fleet_spec("jetson-nano*x", "xavier-nx").is_err());
        assert!(parse_fleet_spec(",", "xavier-nx").is_err());
    }

    #[test]
    fn router_and_admission_parse() {
        assert_eq!(Router::parse("rr").unwrap(), Router::RoundRobin);
        assert_eq!(Router::parse("jsq").unwrap(), Router::ShortestQueue);
        assert_eq!(Router::parse("energy").unwrap(), Router::LeastBacklog);
        assert!(Router::parse("psychic").is_err());
        assert_eq!(Admission::parse("off").unwrap(), Admission::Off);
        assert_eq!(Admission::parse("shed").unwrap(), Admission::Shed);
        assert_eq!(Admission::parse("downgrade").unwrap(), Admission::Downgrade);
        assert!(Admission::parse("maybe").is_err());
    }

    #[test]
    fn parse_errors_list_the_valid_variants() {
        // a typo'd spec must name every accepted value (and alias) so
        // the error is actionable without reading the source
        let e = Router::parse("psychic").unwrap_err().to_string();
        for want in ["psychic", "round_robin", "rr", "shortest_queue", "jsq",
            "least_backlog", "energy"]
        {
            assert!(e.contains(want), "router error missing `{want}`: {e}");
        }
        let e = Admission::parse("maybe").unwrap_err().to_string();
        for want in ["maybe", "off", "none", "shed", "downgrade"] {
            assert!(e.contains(want), "admission error missing `{want}`: {e}");
        }
    }

    #[test]
    fn round_robin_spreads_tasks_across_heterogeneous_devices() {
        let c = cfg("edge_only", "xavier-nx,jetson-nano,jetson-tx2");
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(
            &fleet,
            3,
            Arrivals::Poisson { rate: 10.0 },
            700,
            SloClass::default(),
        );
        let s = serve_fleet(&mut fleet, &mut g, 4, &FleetOpts::default());
        assert_eq!(s.offered, 12);
        assert_eq!(s.completed, 12);
        assert_eq!(s.shed, 0);
        assert_eq!(s.per_device.len(), 3);
        assert_eq!(s.per_device.iter().map(|d| d.served).sum::<usize>(), 12);
        assert_eq!(s.per_device.iter().map(|d| d.served).collect::<Vec<_>>(), vec![4, 4, 4]);
        assert!(s.per_device.iter().all(|d| d.energy_j > 0.0));
    }

    #[test]
    fn shortest_queue_uses_every_device_under_load() {
        let c = cfg("edge_only", "xavier-nx,jetson-nano");
        c.validate().unwrap();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&fleet, 8, Arrivals::Sequential, 800, SloClass::default());
        let opts = FleetOpts {
            router: Router::ShortestQueue,
            ..FleetOpts::default()
        };
        let s = serve_fleet(&mut fleet, &mut g, 4, &opts);
        assert_eq!(s.completed, 32);
        assert!(s.per_device.iter().all(|d| d.served > 0), "{:?}", s.per_device);
    }

    #[test]
    fn least_backlog_prefers_the_fast_efficient_device() {
        // xavier-nx is both faster and the backlog metric is
        // power-weighted; it must end up with the lion's share.
        let c = cfg("edge_only", "xavier-nx,jetson-nano");
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&fleet, 6, Arrivals::Sequential, 900, SloClass::default());
        let opts = FleetOpts {
            router: Router::LeastBacklog,
            ..FleetOpts::default()
        };
        let s = serve_fleet(&mut fleet, &mut g, 5, &opts);
        assert_eq!(s.completed, 30);
        assert!(
            s.per_device[0].served >= s.per_device[1].served,
            "{:?}",
            s.per_device
        );
    }

    #[test]
    fn priority_tasks_jump_the_queue() {
        // one stream of priority-2 tasks against seven best-effort
        // streams, all arriving at t=0: the priority stream's mean queue
        // wait must be below the best-effort mean.
        let c = cfg("edge_only", "jetson-nano");
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&fleet, 8, Arrivals::Sequential, 300, SloClass::default());
        g[0] = TaskGen::new(
            fleet.devices[0].env.profile.name,
            fleet.devices[0].env.dataset,
            Arrivals::Sequential,
            300,
        )
        .unwrap()
        .with_slo(SloClass {
            deadline_s: f64::INFINITY,
            priority: 2,
        });
        let s = serve_fleet(&mut fleet, &mut g, 4, &FleetOpts::default());
        assert_eq!(s.completed, 32);
        let mean_wait = |stream: usize| {
            let ws: Vec<f64> = s
                .serve
                .reports
                .iter()
                .filter(|r| r.stream == stream)
                .map(|r| r.queue_wait_s)
                .collect();
            ws.iter().sum::<f64>() / ws.len() as f64
        };
        let prio = mean_wait(0);
        let best_effort =
            (1..8).map(mean_wait).sum::<f64>() / 7.0;
        assert!(
            prio < best_effort,
            "priority wait {prio} vs best-effort {best_effort}"
        );
    }

    #[test]
    fn downgrade_forces_edge_only_under_overload() {
        // cloud_only policy wants xi=1 for every task; a tight deadline
        // plus admission=downgrade must force some tasks to xi=0.
        let c = cfg("cloud_only", "jetson-nano");
        let mut fleet = Fleet::from_config(&c).unwrap();
        let slo = SloClass::parse("60").unwrap();
        let mut g = gens(&fleet, 8, Arrivals::Sequential, 400, slo);
        let opts = FleetOpts {
            admission: Admission::Downgrade,
            ..FleetOpts::default()
        };
        let s = serve_fleet(&mut fleet, &mut g, 5, &opts);
        assert_eq!(s.completed, 40, "downgrade never drops tasks");
        assert_eq!(s.shed, 0);
        assert!(s.downgraded > 0, "overload must trigger downgrades");
        assert!(
            s.serve.reports.iter().any(|r| r.xi == 0.0),
            "downgraded tasks must run edge-only"
        );
        assert!(s.serve.reports.iter().any(|r| r.xi > 0.0));
    }

    #[test]
    fn cloud_aware_admission_sheds_when_the_pool_is_the_bottleneck() {
        // cloud_only overload into a 1-slot shared pool. Poisson (not
        // sequential) arrivals matter here: decisions must keep landing
        // WHILE uplinks complete and cloud work is in flight, so the
        // estimator's pool-wait and cloud-service terms are live (the
        // formula itself is pinned by the unit test
        // `admission_estimate_includes_cloud_detour` in engine.rs).
        let run = |admission| {
            let c = cfg("cloud_only", "xavier-nx,jetson-tx2");
            let mut fleet = Fleet::from_config(&c).unwrap();
            let slo = SloClass::parse("120").unwrap();
            let mut g = gens(&fleet, 10, Arrivals::Poisson { rate: 30.0 }, 1100, slo);
            let opts = FleetOpts {
                des: DesOpts {
                    cloud_slots: 1,
                    ..DesOpts::default()
                },
                admission,
                ..FleetOpts::default()
            };
            serve_fleet(&mut fleet, &mut g, 4, &opts)
        };
        let shed = run(Admission::Shed);
        assert!(
            shed.shed > 0,
            "pool saturation must trigger shedding: {:?} shed",
            shed.shed
        );
        let off = run(Admission::Off);
        assert_eq!(off.shed, 0);
        assert!(
            shed.slo_violations < off.slo_violations,
            "shed violations {} vs off {}",
            shed.slo_violations,
            off.slo_violations
        );
    }

    #[test]
    fn no_deadline_means_no_violations_and_full_goodput() {
        let c = cfg("edge_only", "xavier-nx");
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&fleet, 4, Arrivals::Sequential, 500, SloClass::default());
        let s = serve_fleet(&mut fleet, &mut g, 3, &FleetOpts::default());
        assert_eq!(s.slo_violations, 0);
        assert_eq!(s.goodput, s.completed);
        assert_eq!(s.completed, 12);
    }

    #[test]
    fn cross_device_cloud_batch_merges_two_devices() {
        // one task per device, both offloading, with a wide cloud window:
        // the two devices' cloud jobs must merge into ONE batched
        // invocation — occupancy 2 from two distinct uplinks.
        let c = cfg("cloud_only", "xavier-nx,jetson-tx2");
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&fleet, 2, Arrivals::Sequential, 1200, SloClass::default());
        let opts = FleetOpts {
            des: DesOpts {
                // wide enough to straddle both devices' edge + uplink time
                cloud_batch_window_s: 2.0,
                ..DesOpts::default()
            },
            ..FleetOpts::default()
        };
        let s = serve_fleet(&mut fleet, &mut g, 1, &opts);
        assert_eq!(s.completed, 2);
        assert_eq!(s.per_device.iter().map(|d| d.served).collect::<Vec<_>>(), vec![1, 1]);
        assert_eq!(s.cloud_invocations, 1, "two devices, one invocation");
        assert_eq!(s.cloud_occupancy.values().to_vec(), vec![2.0]);
        assert!(s.cloud_dispatch_saved_s > 0.0);
        assert!(s.serve.reports.iter().all(|r| r.cloud_batch_size == 2));
    }

    #[test]
    fn fleet_run_is_deterministic_per_seed() {
        let run = || {
            let c = cfg("cloud_only", "xavier-nx,jetson-tx2");
            let mut fleet = Fleet::from_config(&c).unwrap();
            let slo = SloClass::parse("150").unwrap();
            let mut g = gens(&fleet, 6, Arrivals::Poisson { rate: 40.0 }, 600, slo);
            let opts = FleetOpts {
                des: DesOpts {
                    batch_window_s: 0.01,
                    cloud_batch_window_s: 0.005,
                    ..DesOpts::default()
                },
                router: Router::LeastBacklog,
                admission: Admission::Shed,
                ..FleetOpts::default()
            };
            let s = serve_fleet(&mut fleet, &mut g, 6, &opts);
            (
                s.completed,
                s.shed,
                s.slo_violations,
                s.serve.e2e_ms.mean(),
                s.serve.cost.mean(),
            )
        };
        assert_eq!(run(), run());
    }
}
