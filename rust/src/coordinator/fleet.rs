//! Fleet-scale serving: a multi-edge dispatcher layered over the
//! discrete-event core.
//!
//! Where `des.rs` simulates one loaded edge node, this module owns a
//! **fleet** of N heterogeneous edge devices. Each device is a full
//! `Coordinator` (its own `EdgeCloudEnv`, DVFS state, FIFO/priority
//! queue, residency estimate, and policy instance built from a
//! per-device `DeviceSpec`), with its own uplink and batching window;
//! all devices share one bounded cloud executor pool. Arriving tasks are
//! routed by a pluggable [`Router`] (round-robin, join-shortest-queue,
//! energy-aware least-backlog) and screened by an [`Admission`] policy:
//! when the chosen device's estimated backlog would blow the task's SLO
//! deadline, the dispatcher can shed the task outright or downgrade it
//! to edge-only execution (skipping the uplink/cloud detour). Shed,
//! downgrade, and SLO-violation counts are first-class telemetry next to
//! the p50/p95/p99 latency percentiles.
//!
//! Per-task physics still come from `EdgeCloudEnv::execute` via
//! `Coordinator::step_constrained`, invoked exactly once per task at
//! edge-service start — so a 1-device fleet with round-robin routing, no
//! SLOs, and admission disabled reproduces `serve_multistream` reports
//! task-for-task (the parity gate in `rust/tests/fleet_serving.rs`).

use super::{Coordinator, LoadSignals, ServeSummary};
use crate::configx::Config;
use crate::coordinator::des::DesOpts;
use crate::coordinator::env::TaskReport;
use crate::device::spec::find_device;
use crate::util::Ewma;
use crate::workload::{Arrivals, Task, TaskGen};
use anyhow::{bail, Context, Result};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Dispatch policy: which edge device an arriving task lands on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Router {
    /// cycle through devices in index order
    RoundRobin,
    /// join-shortest-queue: fewest tasks queued or in service
    ShortestQueue,
    /// energy-aware least-backlog: minimize estimated backlog seconds
    /// weighted by the device's power envelope, so work drifts toward
    /// idle *and* efficient devices
    LeastBacklog,
}

impl Router {
    /// Parse a router spec: `round_robin` | `shortest_queue` | `least_backlog`
    /// (aliases: `rr`, `jsq`, `energy`).
    pub fn parse(spec: &str) -> Result<Router> {
        Ok(match spec.trim() {
            "round_robin" | "rr" => Router::RoundRobin,
            "shortest_queue" | "jsq" => Router::ShortestQueue,
            "least_backlog" | "energy" => Router::LeastBacklog,
            other => bail!(
                "unknown router `{other}` (want round_robin | shortest_queue | least_backlog)"
            ),
        })
    }
}

/// What the dispatcher does with a task whose estimated completion time
/// would blow its SLO deadline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// accept everything (no admission control)
    Off,
    /// drop doomed best-effort tasks; priority > 0 tasks are downgraded
    /// to edge-only instead of dropped
    Shed,
    /// keep every task but force doomed ones to edge-only execution
    /// (skips the uplink/cloud detour, freeing the shared pool)
    Downgrade,
}

impl Admission {
    /// Parse an admission spec: `off` | `shed` | `downgrade`.
    pub fn parse(spec: &str) -> Result<Admission> {
        Ok(match spec.trim() {
            "off" | "none" => Admission::Off,
            "shed" => Admission::Shed,
            "downgrade" => Admission::Downgrade,
            other => bail!("unknown admission policy `{other}` (want off | shed | downgrade)"),
        })
    }
}

/// Tunables of a fleet serving run.
#[derive(Clone, Debug)]
pub struct FleetOpts {
    /// per-device DES tunables (uplink batch window + cap) and the size
    /// of the *shared* cloud executor pool
    pub des: DesOpts,
    pub router: Router,
    pub admission: Admission,
}

impl Default for FleetOpts {
    fn default() -> Self {
        Self {
            des: DesOpts::default(),
            router: Router::RoundRobin,
            admission: Admission::Off,
        }
    }
}

impl FleetOpts {
    /// Build from a run config (`fleet`/`router`/`slo`/`admission` plus
    /// the DES knobs).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        Ok(Self {
            des: DesOpts::from_config(cfg),
            router: Router::parse(&cfg.router)?,
            admission: Admission::parse(&cfg.admission)?,
        })
    }
}

/// Expand a fleet spec into a device-name list. Empty spec = one device
/// of `default_device`. Entries are comma-separated device-zoo names,
/// with `name*count` for homogeneous groups.
pub fn parse_fleet_spec(spec: &str, default_device: &str) -> Result<Vec<String>> {
    let spec = spec.trim();
    if spec.is_empty() {
        find_device(default_device)?;
        return Ok(vec![default_device.to_string()]);
    }
    let mut out = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            bail!("empty device entry in fleet spec `{spec}`");
        }
        let (name, count) = match part.split_once('*') {
            Some((n, c)) => (
                n.trim(),
                c.trim()
                    .parse::<usize>()
                    .with_context(|| format!("fleet count `{c}` in `{part}`"))?,
            ),
            None => (part, 1),
        };
        if count == 0 {
            bail!("fleet count must be >= 1 in `{part}`");
        }
        find_device(name)?;
        for _ in 0..count {
            out.push(name.to_string());
        }
    }
    Ok(out)
}

/// The fleet: N per-device serving systems sharing a cloud pool.
pub struct Fleet {
    pub devices: Vec<Coordinator>,
    pub names: Vec<String>,
}

impl Fleet {
    /// Build one `Coordinator` per fleet entry. Device 0 uses the
    /// config's seed unchanged (that is what the N=1 parity gate relies
    /// on); later devices get decorrelated seeds.
    pub fn from_config(cfg: &Config) -> Result<Fleet> {
        let names = parse_fleet_spec(&cfg.fleet, &cfg.device)?;
        let mut devices = Vec::with_capacity(names.len());
        for (i, name) in names.iter().enumerate() {
            let mut dcfg = cfg.clone();
            dcfg.device = name.clone();
            dcfg.seed = cfg.seed ^ ((i as u64) << 17);
            devices.push(Coordinator::from_config(&dcfg)?);
        }
        Ok(Fleet { devices, names })
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// Offline-train every device's policy (no-op feedback for fixed
    /// policies; callers usually gate this on the policy being a
    /// learning one to save the wasted simulation).
    pub fn train_offline(&mut self, episodes: usize, tasks_per_ep: usize, seed: u64) -> Result<()> {
        for (i, coord) in self.devices.iter_mut().enumerate() {
            let mut gen = TaskGen::new(
                coord.env.profile.name,
                coord.env.dataset,
                Arrivals::Sequential,
                seed ^ 0x7341 ^ ((i as u64) << 9),
            )?;
            coord.train(&mut gen, episodes, tasks_per_ep);
        }
        Ok(())
    }
}

/// Per-device telemetry row of a fleet run.
#[derive(Clone, Debug)]
pub struct DeviceTelemetry {
    pub name: String,
    /// tasks that completed on this device
    pub served: usize,
    /// total energy spent by this device's completed tasks (J)
    pub energy_j: f64,
    /// completed tasks that missed their deadline
    pub violations: usize,
}

/// Aggregated outcome of a fleet serving run: the usual latency/energy
/// summary plus SLO/admission accounting.
#[derive(Default)]
pub struct FleetSummary {
    pub serve: ServeSummary,
    /// tasks generated by the streams
    pub offered: usize,
    /// tasks that ran to completion
    pub completed: usize,
    /// tasks dropped by admission control
    pub shed: usize,
    /// tasks forced to edge-only by admission control
    pub downgraded: usize,
    /// completed tasks whose end-to-end latency missed their deadline
    pub slo_violations: usize,
    /// completed tasks that met their deadline (== completed when no
    /// task carries a deadline)
    pub goodput: usize,
    pub per_device: Vec<DeviceTelemetry>,
}

// ---------------------------------------------------------------------
// event machinery: a device-tagged variant of des.rs (NaN-proof
// ordering). Deliberately a parallel implementation for this PR so the
// battle-tested single-edge path stays byte-identical; once a local
// toolchain can re-gate parity, `serve_multistream` should delegate to
// this engine with N=1 and the des.rs copy be deleted (ROADMAP item).
// ---------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Ev {
    Arrival { stream: usize },
    EdgeDone { dev: usize, job: usize },
    BatchClose { dev: usize, generation: usize },
    UplinkDone { dev: usize, batch: usize },
    CloudDone { job: usize },
}

/// Heap entry; the `seq` tiebreak makes simultaneous events FIFO and the
/// whole simulation deterministic.
#[derive(Clone, Debug)]
struct Event {
    time: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
}

impl EventQueue {
    fn push(&mut self, time: f64, ev: Ev) {
        self.heap.push(Event {
            time,
            seq: self.seq,
            ev,
        });
        self.seq += 1;
    }

    fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }
}

/// One in-flight task.
struct Job {
    task: Task,
    stream: usize,
    dev: usize,
    arrival_s: f64,
    queue_wait_s: f64,
    solo_off_s: f64,
    cloud_s: f64,
    payload_bytes: f64,
    /// admission control forced this task to edge-only execution
    downgraded: bool,
    report: Option<TaskReport>,
}

/// Per-device queueing state (mirrors the single-edge `DesState`).
struct DevState {
    edge_queue: VecDeque<usize>,
    edge_busy: bool,
    /// EWMA of edge residency, drives backlog estimates for routing,
    /// admission, and the policy's LoadSignals
    residency: Ewma,
    open_batch: Vec<usize>,
    /// bumps on every flush so stale BatchClose events are ignored
    batch_open_id: usize,
    uplink_queue: VecDeque<usize>,
    uplink_busy: bool,
}

impl DevState {
    fn new() -> Self {
        Self {
            edge_queue: VecDeque::new(),
            edge_busy: false,
            residency: Ewma::new(0.2),
            open_batch: Vec::new(),
            batch_open_id: 0,
            uplink_queue: VecDeque::new(),
            uplink_busy: false,
        }
    }

    /// Tasks queued or in service on this device.
    fn in_system(&self) -> usize {
        self.edge_queue.len() + self.edge_busy as usize
    }

    /// Estimated seconds until a newly queued task would *finish* edge
    /// service, from the residency EWMA. `None` before the first
    /// completion (cold start — admission stays open).
    fn est_completion_s(&self) -> Option<f64> {
        self.residency
            .get()
            .map(|res| res * (self.in_system() as f64 + 1.0))
    }
}

struct FleetState {
    q: EventQueue,
    jobs: Vec<Job>,
    devs: Vec<DevState>,
    /// flushed batches, addressed by UplinkDone payload (global ids;
    /// the owning device rides in the event)
    batches: Vec<Vec<usize>>,
    cloud_active: usize,
    cloud_queue: VecDeque<usize>,
    opts: FleetOpts,
    rr_next: usize,
    shed: usize,
    downgraded: usize,
}

impl FleetState {
    /// Pick the device for an arriving task.
    fn route(&mut self, fleet: &Fleet) -> usize {
        let n = self.devs.len();
        match self.opts.router {
            Router::RoundRobin => {
                let d = self.rr_next % n;
                self.rr_next += 1;
                d
            }
            Router::ShortestQueue => (0..n)
                .min_by_key(|&d| self.devs[d].in_system())
                .unwrap_or(0),
            Router::LeastBacklog => {
                let score = |d: usize| {
                    let res = self.devs[d].residency.get().unwrap_or(1.0);
                    let power = fleet.devices[d].env.edge.spec().max_power_w;
                    self.devs[d].in_system() as f64 * res * power
                };
                (0..n)
                    .min_by(|&a, &b| score(a).total_cmp(&score(b)))
                    .unwrap_or(0)
            }
        }
    }

    /// Queue a job on its device, honoring priority classes: a task
    /// jumps ahead of queued lower-priority tasks (FIFO within a class,
    /// so all-default-priority traffic keeps the exact legacy order).
    fn enqueue_edge(&mut self, id: usize) {
        let dev = self.jobs[id].dev;
        let prio = self.jobs[id].task.priority;
        if prio == 0 {
            self.devs[dev].edge_queue.push_back(id);
            return;
        }
        let pos = self.devs[dev]
            .edge_queue
            .iter()
            .position(|&j| self.jobs[j].task.priority < prio)
            .unwrap_or(self.devs[dev].edge_queue.len());
        self.devs[dev].edge_queue.insert(pos, id);
    }

    /// Start edge service on the next queued job if the device is idle:
    /// publish per-device load signals, run decide→execute through the
    /// device's coordinator, and schedule the edge-completion event.
    fn maybe_start_edge(&mut self, fleet: &mut Fleet, dev: usize, now: f64) {
        if self.devs[dev].edge_busy {
            return;
        }
        let Some(id) = self.devs[dev].edge_queue.pop_front() else {
            return;
        };
        let coord = &mut fleet.devices[dev];
        coord.load.queue_depth = self.devs[dev].edge_queue.len();
        coord.load.backlog_s = self.devs[dev].residency.get().unwrap_or(0.0)
            * self.devs[dev].edge_queue.len() as f64;
        let force_edge = self.jobs[id].downgraded;
        let r = coord.step_constrained(&self.jobs[id].task, false, force_edge);
        let residency = (r.tti_total_s - r.tti_off_s - r.tti_cloud_s).max(0.0);
        self.devs[dev].residency.push(residency);
        let job = &mut self.jobs[id];
        job.queue_wait_s = (now - job.arrival_s).max(0.0);
        job.solo_off_s = r.tti_off_s;
        job.cloud_s = r.tti_cloud_s;
        job.payload_bytes = r.payload_bytes;
        job.report = Some(r);
        self.devs[dev].edge_busy = true;
        self.q.push(now + residency, Ev::EdgeDone { dev, job: id });
    }

    fn freeze_batch(&mut self, members: Vec<usize>) -> usize {
        self.batches.push(members);
        self.batches.len() - 1
    }

    fn flush_open_batch(&mut self, fleet: &Fleet, dev: usize, now: f64) {
        if self.devs[dev].open_batch.is_empty() {
            return;
        }
        let members = std::mem::take(&mut self.devs[dev].open_batch);
        self.devs[dev].batch_open_id += 1;
        let b = self.freeze_batch(members);
        self.devs[dev].uplink_queue.push_back(b);
        self.maybe_start_uplink(fleet, dev, now);
    }

    /// Start transmitting the next batch on the device's uplink if it is
    /// idle (singleton batches reuse the env-computed solo transmission
    /// time; real batches ship the summed payload in one transfer).
    fn maybe_start_uplink(&mut self, fleet: &Fleet, dev: usize, now: f64) {
        if self.devs[dev].uplink_busy {
            return;
        }
        let Some(b) = self.devs[dev].uplink_queue.pop_front() else {
            return;
        };
        let members = self.batches[b].clone();
        let tx_s = if members.len() == 1 {
            self.jobs[members[0]].solo_off_s
        } else {
            let payload: f64 = members.iter().map(|&id| self.jobs[id].payload_bytes).sum();
            fleet.devices[dev].env.link.tx_time_s(payload)
        };
        let n = members.len();
        for &id in &members {
            if let Some(r) = self.jobs[id].report.as_mut() {
                r.batch_size = n;
            }
        }
        self.devs[dev].uplink_busy = true;
        self.q.push(now + tx_s, Ev::UplinkDone { dev, batch: b });
    }

    /// Hand a job to the shared cloud pool (or its queue).
    fn dispatch_cloud(&mut self, id: usize, now: f64) {
        if self.cloud_active < self.opts.des.cloud_slots {
            self.cloud_active += 1;
            self.q.push(now + self.jobs[id].cloud_s, Ev::CloudDone { job: id });
        } else {
            self.cloud_queue.push_back(id);
        }
    }

    /// Stamp the queueing-aware fields on the job's report.
    fn finish(&mut self, id: usize, now: f64) {
        let job = &mut self.jobs[id];
        if let Some(r) = job.report.as_mut() {
            r.queue_wait_s = job.queue_wait_s;
            r.e2e_s = (now - job.arrival_s).max(0.0);
            r.stream = job.stream;
        }
    }

    /// Admission decision for a routed task. Returns what to do given
    /// the device's backlog estimate and the task's SLO class.
    ///
    /// The estimate is deliberately the *edge* backlog only (residency
    /// EWMA × queue occupancy): at admission time the offload decision
    /// hasn't been made yet, so uplink and cloud-pool time are unknown.
    /// That makes this a lower bound on completion time — admission can
    /// under-shed when the uplink or shared cloud pool is the
    /// bottleneck, never over-shed. Folding a cloud/uplink wait estimate
    /// in is a ROADMAP item.
    fn admit(&self, dev: usize, task: &Task) -> Verdict {
        if self.opts.admission == Admission::Off || !task.deadline_s.is_finite() {
            return Verdict::Accept;
        }
        let Some(est) = self.devs[dev].est_completion_s() else {
            // cold start: no residency estimate yet, accept everything
            return Verdict::Accept;
        };
        if est <= task.deadline_s {
            return Verdict::Accept;
        }
        match self.opts.admission {
            Admission::Shed if task.priority == 0 => Verdict::Shed,
            // high-priority tasks (and every task under `downgrade`)
            // stay in the system but skip the cloud detour
            _ => Verdict::Downgrade,
        }
    }
}

enum Verdict {
    Accept,
    Shed,
    Downgrade,
}

/// Serve `per_stream` tasks from each stream through the fleet. Streams
/// are routed per task by the configured router; reports accumulate in
/// job-creation (arrival) order so a 1-device round-robin fleet is
/// report-ordered exactly like `serve_multistream`.
pub fn serve_fleet(
    fleet: &mut Fleet,
    gens: &mut [TaskGen],
    per_stream: usize,
    opts: &FleetOpts,
) -> FleetSummary {
    for coord in fleet.devices.iter_mut() {
        coord.policy.set_training(false);
    }
    let mut summary = FleetSummary {
        per_device: fleet
            .names
            .iter()
            .map(|n| DeviceTelemetry {
                name: n.clone(),
                served: 0,
                energy_j: 0.0,
                violations: 0,
            })
            .collect(),
        ..FleetSummary::default()
    };
    if gens.is_empty() || per_stream == 0 || fleet.devices.is_empty() {
        return summary;
    }
    let streams = gens.len();
    let mut state = FleetState {
        q: EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        },
        jobs: Vec::with_capacity(streams * per_stream),
        devs: (0..fleet.len()).map(|_| DevState::new()).collect(),
        batches: Vec::new(),
        cloud_active: 0,
        cloud_queue: VecDeque::new(),
        opts: opts.clone(),
        rr_next: 0,
        shed: 0,
        downgraded: 0,
    };

    // prime every stream with its first arrival
    let mut next_task: Vec<Option<Task>> = Vec::with_capacity(streams);
    let mut remaining: Vec<usize> = vec![per_stream; streams];
    for (s, gen) in gens.iter_mut().enumerate() {
        let t = gen.next_task();
        remaining[s] -= 1;
        state.q.push(t.arrival_s, Ev::Arrival { stream: s });
        next_task.push(Some(t));
    }

    while let Some(ev) = state.q.pop() {
        let now = ev.time;
        match ev.ev {
            Ev::Arrival { stream } => {
                let task = next_task[stream]
                    .take()
                    .expect("arrival without pending task");
                if remaining[stream] > 0 {
                    remaining[stream] -= 1;
                    let t = gens[stream].next_task();
                    state.q.push(t.arrival_s, Ev::Arrival { stream });
                    next_task[stream] = Some(t);
                }
                summary.offered += 1;
                let dev = state.route(fleet);
                let verdict = state.admit(dev, &task);
                let downgraded = match verdict {
                    Verdict::Shed => {
                        state.shed += 1;
                        continue;
                    }
                    Verdict::Downgrade => {
                        state.downgraded += 1;
                        true
                    }
                    Verdict::Accept => false,
                };
                let id = state.jobs.len();
                state.jobs.push(Job {
                    task,
                    stream,
                    dev,
                    arrival_s: now,
                    queue_wait_s: 0.0,
                    solo_off_s: 0.0,
                    cloud_s: 0.0,
                    payload_bytes: 0.0,
                    downgraded,
                    report: None,
                });
                state.enqueue_edge(id);
                state.maybe_start_edge(fleet, dev, now);
            }
            Ev::EdgeDone { dev, job: id } => {
                state.devs[dev].edge_busy = false;
                let offloads = state.jobs[id]
                    .report
                    .as_ref()
                    .map(|r| r.xi > 0.0)
                    .unwrap_or(false);
                if offloads {
                    if state.opts.des.batch_window_s > 0.0 {
                        if state.devs[dev].open_batch.is_empty() {
                            state.q.push(
                                now + state.opts.des.batch_window_s,
                                Ev::BatchClose {
                                    dev,
                                    generation: state.devs[dev].batch_open_id,
                                },
                            );
                        }
                        state.devs[dev].open_batch.push(id);
                        if state.devs[dev].open_batch.len() >= state.opts.des.max_batch {
                            state.flush_open_batch(fleet, dev, now);
                        }
                    } else {
                        let b = state.freeze_batch(vec![id]);
                        state.devs[dev].uplink_queue.push_back(b);
                        state.maybe_start_uplink(fleet, dev, now);
                    }
                } else {
                    state.finish(id, now);
                }
                state.maybe_start_edge(fleet, dev, now);
            }
            Ev::BatchClose { dev, generation } => {
                if generation == state.devs[dev].batch_open_id {
                    state.flush_open_batch(fleet, dev, now);
                }
            }
            Ev::UplinkDone { dev, batch } => {
                state.devs[dev].uplink_busy = false;
                let members = state.batches[batch].clone();
                for id in members {
                    state.dispatch_cloud(id, now);
                }
                state.maybe_start_uplink(fleet, dev, now);
            }
            Ev::CloudDone { job: id } => {
                state.cloud_active -= 1;
                state.finish(id, now);
                if let Some(next) = state.cloud_queue.pop_front() {
                    state.cloud_active += 1;
                    state
                        .q
                        .push(now + state.jobs[next].cloud_s, Ev::CloudDone { job: next });
                }
            }
        }
    }

    // reset load signals so later synchronous use observes idle edges
    for coord in fleet.devices.iter_mut() {
        coord.load = LoadSignals::default();
    }

    summary.shed = state.shed;
    summary.downgraded = state.downgraded;
    for job in &state.jobs {
        if let Some(r) = &job.report {
            summary.serve.push(r);
            summary.completed += 1;
            let e2e = if r.e2e_s > 0.0 {
                r.e2e_s
            } else {
                r.queue_wait_s + r.tti_total_s
            };
            let violated = job.task.deadline_s.is_finite() && e2e > job.task.deadline_s;
            if violated {
                summary.slo_violations += 1;
            } else {
                summary.goodput += 1;
            }
            let d = &mut summary.per_device[job.dev];
            d.served += 1;
            d.energy_j += r.eti_total_j;
            if violated {
                d.violations += 1;
            }
        }
    }
    summary
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::SloClass;

    fn cfg(policy: &str, fleet: &str) -> Config {
        let mut c = Config::default();
        c.policy = policy.into();
        c.fleet = fleet.into();
        c.seed = 19;
        c
    }

    fn gens(
        fleet: &Fleet,
        n: usize,
        arrivals: Arrivals,
        base_seed: u64,
        slo: SloClass,
    ) -> Vec<TaskGen> {
        (0..n)
            .map(|s| {
                TaskGen::new(
                    fleet.devices[0].env.profile.name,
                    fleet.devices[0].env.dataset,
                    arrivals,
                    base_seed + s as u64,
                )
                .unwrap()
                .with_slo(slo)
            })
            .collect()
    }

    #[test]
    fn fleet_spec_expansion() {
        assert_eq!(
            parse_fleet_spec("", "xavier-nx").unwrap(),
            vec!["xavier-nx"]
        );
        assert_eq!(
            parse_fleet_spec("jetson-nano*2, jetson-tx2", "xavier-nx").unwrap(),
            vec!["jetson-nano", "jetson-nano", "jetson-tx2"]
        );
        assert!(parse_fleet_spec("warp-core", "xavier-nx").is_err());
        assert!(parse_fleet_spec("jetson-nano*0", "xavier-nx").is_err());
        assert!(parse_fleet_spec("jetson-nano*x", "xavier-nx").is_err());
        assert!(parse_fleet_spec(",", "xavier-nx").is_err());
    }

    #[test]
    fn router_and_admission_parse() {
        assert_eq!(Router::parse("rr").unwrap(), Router::RoundRobin);
        assert_eq!(Router::parse("jsq").unwrap(), Router::ShortestQueue);
        assert_eq!(Router::parse("energy").unwrap(), Router::LeastBacklog);
        assert!(Router::parse("psychic").is_err());
        assert_eq!(Admission::parse("off").unwrap(), Admission::Off);
        assert_eq!(Admission::parse("shed").unwrap(), Admission::Shed);
        assert_eq!(Admission::parse("downgrade").unwrap(), Admission::Downgrade);
        assert!(Admission::parse("maybe").is_err());
    }

    #[test]
    fn round_robin_spreads_tasks_across_heterogeneous_devices() {
        let c = cfg("edge_only", "xavier-nx,jetson-nano,jetson-tx2");
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(
            &fleet,
            3,
            Arrivals::Poisson { rate: 10.0 },
            700,
            SloClass::default(),
        );
        let s = serve_fleet(&mut fleet, &mut g, 4, &FleetOpts::default());
        assert_eq!(s.offered, 12);
        assert_eq!(s.completed, 12);
        assert_eq!(s.shed, 0);
        assert_eq!(s.per_device.len(), 3);
        assert_eq!(s.per_device.iter().map(|d| d.served).sum::<usize>(), 12);
        assert_eq!(s.per_device.iter().map(|d| d.served).collect::<Vec<_>>(), vec![4, 4, 4]);
        assert!(s.per_device.iter().all(|d| d.energy_j > 0.0));
    }

    #[test]
    fn shortest_queue_uses_every_device_under_load() {
        let c = cfg("edge_only", "xavier-nx,jetson-nano");
        c.validate().unwrap();
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&fleet, 8, Arrivals::Sequential, 800, SloClass::default());
        let opts = FleetOpts {
            router: Router::ShortestQueue,
            ..FleetOpts::default()
        };
        let s = serve_fleet(&mut fleet, &mut g, 4, &opts);
        assert_eq!(s.completed, 32);
        assert!(s.per_device.iter().all(|d| d.served > 0), "{:?}", s.per_device);
    }

    #[test]
    fn least_backlog_prefers_the_fast_efficient_device() {
        // xavier-nx is both faster and the backlog metric is
        // power-weighted; it must end up with the lion's share.
        let c = cfg("edge_only", "xavier-nx,jetson-nano");
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&fleet, 6, Arrivals::Sequential, 900, SloClass::default());
        let opts = FleetOpts {
            router: Router::LeastBacklog,
            ..FleetOpts::default()
        };
        let s = serve_fleet(&mut fleet, &mut g, 5, &opts);
        assert_eq!(s.completed, 30);
        assert!(
            s.per_device[0].served >= s.per_device[1].served,
            "{:?}",
            s.per_device
        );
    }

    #[test]
    fn priority_tasks_jump_the_queue() {
        // one stream of priority-2 tasks against seven best-effort
        // streams, all arriving at t=0: the priority stream's mean queue
        // wait must be below the best-effort mean.
        let c = cfg("edge_only", "jetson-nano");
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&fleet, 8, Arrivals::Sequential, 300, SloClass::default());
        g[0] = TaskGen::new(
            fleet.devices[0].env.profile.name,
            fleet.devices[0].env.dataset,
            Arrivals::Sequential,
            300,
        )
        .unwrap()
        .with_slo(SloClass {
            deadline_s: f64::INFINITY,
            priority: 2,
        });
        let s = serve_fleet(&mut fleet, &mut g, 4, &FleetOpts::default());
        assert_eq!(s.completed, 32);
        let mean_wait = |stream: usize| {
            let ws: Vec<f64> = s
                .serve
                .reports
                .iter()
                .filter(|r| r.stream == stream)
                .map(|r| r.queue_wait_s)
                .collect();
            ws.iter().sum::<f64>() / ws.len() as f64
        };
        let prio = mean_wait(0);
        let best_effort =
            (1..8).map(mean_wait).sum::<f64>() / 7.0;
        assert!(
            prio < best_effort,
            "priority wait {prio} vs best-effort {best_effort}"
        );
    }

    #[test]
    fn downgrade_forces_edge_only_under_overload() {
        // cloud_only policy wants xi=1 for every task; a tight deadline
        // plus admission=downgrade must force some tasks to xi=0.
        let c = cfg("cloud_only", "jetson-nano");
        let mut fleet = Fleet::from_config(&c).unwrap();
        let slo = SloClass::parse("60").unwrap();
        let mut g = gens(&fleet, 8, Arrivals::Sequential, 400, slo);
        let opts = FleetOpts {
            admission: Admission::Downgrade,
            ..FleetOpts::default()
        };
        let s = serve_fleet(&mut fleet, &mut g, 5, &opts);
        assert_eq!(s.completed, 40, "downgrade never drops tasks");
        assert_eq!(s.shed, 0);
        assert!(s.downgraded > 0, "overload must trigger downgrades");
        assert!(
            s.serve.reports.iter().any(|r| r.xi == 0.0),
            "downgraded tasks must run edge-only"
        );
        assert!(s.serve.reports.iter().any(|r| r.xi > 0.0));
    }

    #[test]
    fn no_deadline_means_no_violations_and_full_goodput() {
        let c = cfg("edge_only", "xavier-nx");
        let mut fleet = Fleet::from_config(&c).unwrap();
        let mut g = gens(&fleet, 4, Arrivals::Sequential, 500, SloClass::default());
        let s = serve_fleet(&mut fleet, &mut g, 3, &FleetOpts::default());
        assert_eq!(s.slo_violations, 0);
        assert_eq!(s.goodput, s.completed);
        assert_eq!(s.completed, 12);
    }

    #[test]
    fn fleet_run_is_deterministic_per_seed() {
        let run = || {
            let c = cfg("cloud_only", "xavier-nx,jetson-tx2");
            let mut fleet = Fleet::from_config(&c).unwrap();
            let slo = SloClass::parse("150").unwrap();
            let mut g = gens(&fleet, 6, Arrivals::Poisson { rate: 40.0 }, 600, slo);
            let opts = FleetOpts {
                des: DesOpts {
                    batch_window_s: 0.01,
                    ..DesOpts::default()
                },
                router: Router::LeastBacklog,
                admission: Admission::Shed,
            };
            let s = serve_fleet(&mut fleet, &mut g, 6, &opts);
            (
                s.completed,
                s.shed,
                s.slo_violations,
                s.serve.e2e_ms.mean(),
                s.serve.cost.mean(),
            )
        };
        assert_eq!(run(), run());
    }
}
