//! The one event engine: a single discrete-event serving kernel shared
//! by every serving path in the crate.
//!
//! Historically `des.rs` (single edge, N streams) and `fleet.rs`
//! (N edges, shared cloud pool) each carried their own copy of the
//! event machinery — two heaps, two `Job` structs, two state machines
//! that had to evolve in lockstep. This module is the merge: it owns
//! the time-ordered event scheduler (`coordinator::sched` — heap or
//! calendar queue, identical `(time, seq)` pop order either way), the
//! per-device edge queues (priority-aware), the per-device uplink
//! batching windows, and the bounded **shared** cloud executor pool,
//! parameterized over N devices. `serve_multistream` delegates here
//! with N = 1 and `serve_fleet` with N = fleet size; both parity gates
//! (`rust/tests/multistream_queueing.rs`, `rust/tests/fleet_serving.rs`)
//! run against this kernel.
//!
//! On top of the merged machinery the kernel adds **cloud-side
//! cross-device batching** (the server-side analogue of the uplink
//! window, after arXiv:2504.14611): cloud work arriving from *any*
//! device within `cloud_batch_window_s` merges into one batched
//! executor invocation. A batch occupies a single executor slot, pays
//! the service-runtime dispatch overhead once (amortized across its
//! members), is size-capped by `cloud_max_batch` (a full batch flushes
//! before the window closes), and is guarded against stale window
//! closes by a generation id — mirroring the uplink window exactly.
//! With `cloud_batch_window_s == 0` every cloud job runs in its own
//! invocation and the kernel reproduces the pre-batching event
//! sequence bit-for-bit (gated by `rust/tests/engine_golden.rs`).
//!
//! The kernel also owns **cross-device rebalancing**, which turns the
//! router from a one-shot decision into a continuously-correcting
//! system:
//!
//! * **re-route-before-shed** — when admission control finds that the
//!   routed device's completion estimate (`est_completion_s`, the same
//!   residency/ξ/uplink EWMAs) would blow the task's deadline, the
//!   arrival path scans the sibling devices and re-routes to the
//!   cheapest still-feasible one; a task is only shed/downgraded when
//!   *no* device can make the deadline (`FleetOpts::reroute`).
//! * **mid-run migration (work stealing)** — a periodic `Rebalance`
//!   event on the heap (`rebalance_window_s`) moves queued-but-not-
//!   started tasks from the most-backlogged device to the least-
//!   backlogged one when their backlog estimates diverge by more than
//!   `migrate_threshold_s`. A migrated task pays `migrate_penalty_s`
//!   in transit (it re-enqueues at the destination only after the
//!   transfer completes) and **keeps its original arrival time**, so
//!   deadline/violation math never resets on requeue. With the window
//!   at 0 no tick is ever scheduled and with the threshold at ∞ every
//!   tick is a no-op; either way the event trace is bit-identical to
//!   the non-rebalancing kernel (gated by `rust/tests/engine_golden.rs`).
//!
//! Per-task physics still come from `EdgeCloudEnv::execute` via
//! `Coordinator::step_constrained`, invoked exactly once per task at
//! edge-service start (for a migrated task: on the *destination*
//! device, with its own env/DVFS/policy). Before each decision the
//! kernel publishes the owning device's `LoadSignals` so queue-aware
//! policies can react to backlog.
//!
//! **Report sinks and the resumable core.** Completed task reports no
//! longer accumulate inside the kernel: every completion is delivered
//! to a caller-supplied `telemetry::sink::ReportSink` the moment it is
//! stamped, and the job's slot is recycled through a free list — live
//! memory is bounded by the number of *in-flight* tasks, not the run
//! length. [`CollectSink`] reassembles the reports in admission order
//! (the pre-sink `Vec` behavior, bit-exact, still what `serve` uses);
//! `telemetry::sink::StreamingSink` folds them into constant-memory
//! sketches instead. The event loop itself lives in [`EngineCore`],
//! which can run to completion (`run_until(f64::INFINITY, ..)` — the
//! classic `serve`) or advance in bounded time epochs for the sharded
//! fleet runner in `coordinator::shard`, pausing at an epoch boundary
//! with all queues, windows, and EWMAs intact.

use super::chaos::Fault;
use super::fleet::{Admission, FleetOpts, Router};
use super::sched::Sched;
use super::{Coordinator, LoadSignals};
use crate::coordinator::env::TaskReport;
use crate::perfmodel::CLOUD_DISPATCH_OVERHEAD_S;
use crate::telemetry::sink::{JobMeta, ReportSink};
use crate::util::{Ewma, Running, Samples};
use crate::workload::{Task, TaskGen};
use std::collections::VecDeque;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Ev {
    Arrival { stream: usize },
    EdgeDone { dev: usize, job: usize },
    /// per-device uplink batch window expired (generation guards stale
    /// closes after an early size-capped flush)
    BatchClose { dev: usize, generation: usize },
    /// `gen` is the transfer generation of the batch slot at start time:
    /// a device dropout that kills the in-flight transfer bumps the
    /// slot's generation, turning this event into a tombstone
    UplinkDone { dev: usize, batch: usize, gen: u32 },
    /// shared cloud batch window expired (same stale-close guard)
    CloudBatchClose { generation: usize },
    /// one batched executor invocation completed (`gen` tombstones
    /// invocations killed by a cloud outage, like `UplinkDone`)
    CloudDone { batch: usize, gen: u32 },
    /// periodic cross-device rebalance tick (work stealing); scheduled
    /// only when `rebalance_window_s > 0`
    Rebalance,
    /// a migrated task finished its transfer and re-enqueues on the
    /// destination device's edge queue
    Migrate { dev: usize, job: usize },
    /// a scheduled fault window opens (`idx` into the fault schedule);
    /// armed at core construction, so an empty schedule pushes nothing
    Fault { idx: usize },
    /// the matching fault window closes (device recovery, bandwidth
    /// restore, cloud pool back up)
    FaultEnd { idx: usize },
    /// a killed uplink-stage job's retry backoff expired
    RetryUplink { job: usize },
    /// a killed cloud-stage job's retry backoff expired
    RetryCloud { job: usize },
}

/// Which stage a fault killed a job out of — decides where its retry
/// re-enqueues.
#[derive(Clone, Copy)]
enum RetryStage {
    Uplink,
    Cloud,
}

/// One open batching window — the uplink windows (one per device) and
/// the shared cloud window are the same state machine: members
/// accumulate until the size cap flushes early or the close event
/// scheduled at open time fires; `generation` bumps on every flush so
/// a stale close (scheduled for a window that already cap-flushed) is
/// ignored.
#[derive(Default)]
struct BatchWindow {
    members: Vec<usize>,
    generation: usize,
}

impl BatchWindow {
    /// Add a member; true when this member OPENED the window (the
    /// caller schedules the close event, guarded by `generation`).
    fn join(&mut self, id: usize) -> bool {
        let opened = self.members.is_empty();
        self.members.push(id);
        opened
    }

    fn is_full(&self, cap: usize) -> bool {
        self.members.len() >= cap
    }

    fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Freeze the window into `slot` (an empty, recycled batch slot):
    /// the members swap in and the generation bumps so any
    /// still-scheduled close event for this window goes stale. The
    /// window inherits the slot's previous (cleared) allocation, so in
    /// steady state neither side ever reallocates.
    fn freeze_into(&mut self, slot: &mut Vec<usize>) {
        debug_assert!(slot.is_empty(), "freeze target slot must be empty");
        self.generation += 1;
        std::mem::swap(&mut self.members, slot);
    }
}

/// One in-flight task.
struct Job {
    task: Task,
    stream: usize,
    dev: usize,
    arrival_s: f64,
    queue_wait_s: f64,
    /// solo transmission time computed by the env (used for singleton
    /// batches so unbatched timing matches the legacy path exactly)
    solo_off_s: f64,
    cloud_s: f64,
    payload_bytes: f64,
    /// admission control forced this task to edge-only execution
    downgraded: bool,
    /// admission re-routed this task to a sibling before accepting it
    rerouted: bool,
    /// the rebalancer migrated this task across devices while queued
    migrated: bool,
    /// times a fault killed this job's uplink/cloud work and it
    /// re-enqueued; bounded by `RetryPolicy::max_retries`, after which
    /// the job terminates as `failed`
    retries: u32,
    /// admission-order index among accepted tasks. Job *slots* are
    /// recycled once a task completes, so the slot id is not a stable
    /// ordering — this is what sinks key report ordering on.
    arrival_idx: usize,
    report: Option<TaskReport>,
}

/// Per-device queueing state.
struct DevState {
    edge_queue: VecDeque<usize>,
    edge_busy: bool,
    /// EWMA of edge residency, drives backlog estimates for routing,
    /// admission, and the policy's LoadSignals
    residency: Ewma,
    /// EWMA of the offload proportion ξ of tasks started here — the
    /// admission estimator's weight on the uplink/cloud detour
    xi: Ewma,
    /// EWMA of the solo uplink transfer time of offloading tasks
    uplink_s: Ewma,
    /// open uplink batch (stale closes guarded by its generation)
    open_batch: BatchWindow,
    uplink_queue: VecDeque<usize>,
    uplink_busy: bool,
    /// the batch slot currently transmitting on this device's uplink —
    /// what a dropout kills (its `UplinkDone` goes stale via the slot's
    /// generation bump)
    uplink_inflight: Option<usize>,
    /// nesting depth of open `DeviceDown` windows; the device is down
    /// while > 0 (depth, not a flag, so overlapping windows compose)
    down_depth: usize,
    /// composed bandwidth-collapse factor: uplink transfers started now
    /// take `1/link_scale` times longer. Exactly 1.0 outside collapse
    /// windows — and `x / 1.0 == x` bit-for-bit, so the fault-free
    /// timing path is untouched.
    link_scale: f64,
    /// tasks migrating TOWARD this device, still in transit — counted
    /// in backlog/occupancy so successive rebalance ticks (and
    /// admission) don't treat the destination as emptier than it is
    /// about to be when the migration penalty exceeds the tick period
    migrating_in: usize,
    /// cached `residency × (queued + in-transit)` product — the O(1)
    /// edge-backlog estimate the routing/admission/rebalance scans read
    /// on every arrival. Re-derived by `sync_backlog` at every mutation
    /// of the queue, the in-transit count, or the residency EWMA
    /// (enqueue / service start / steal / migration landing), never
    /// recomputed per query; a debug_assert in
    /// `EngineState::edge_backlog_s` compares it bit-for-bit against a
    /// fresh recomputation, so a missed update point fails loudly under
    /// `cargo test`.
    backlog_s: f64,
}

impl DevState {
    fn new() -> Self {
        Self {
            edge_queue: VecDeque::new(),
            edge_busy: false,
            residency: Ewma::new(0.2),
            xi: Ewma::new(0.2),
            uplink_s: Ewma::new(0.2),
            open_batch: BatchWindow::default(),
            uplink_queue: VecDeque::new(),
            uplink_busy: false,
            uplink_inflight: None,
            down_depth: 0,
            link_scale: 1.0,
            migrating_in: 0,
            backlog_s: 0.0,
        }
    }

    /// True while at least one `DeviceDown` window is open.
    fn down(&self) -> bool {
        self.down_depth > 0
    }

    /// Tasks queued, in service, or in transit toward this device.
    fn in_system(&self) -> usize {
        self.edge_queue.len() + self.edge_busy as usize + self.migrating_in
    }

    /// Recompute the cached backlog product after a queue / in-transit /
    /// residency mutation. The recomputation (not an incremental ±)
    /// keeps the cache bit-identical to the from-scratch formula, so
    /// every trace gated by `engine_golden.rs` is unchanged.
    fn sync_backlog(&mut self) {
        self.backlog_s = self.residency.get().unwrap_or(0.0)
            * (self.edge_queue.len() + self.migrating_in) as f64;
    }
}

/// Per-job row of an engine run: the env report plus the dispatch
/// metadata the fleet layer folds into SLO accounting.
pub struct EngineJob {
    pub report: Option<TaskReport>,
    /// device the job was routed to
    pub dev: usize,
    /// the task's SLO deadline (∞ = best-effort)
    pub deadline_s: f64,
}

/// Raw outcome of one engine run, in job-creation (arrival) order.
#[derive(Default)]
pub struct EngineResult {
    /// one entry per accepted job, in admission order. Populated by
    /// [`serve`] from its `CollectSink`; empty when the caller drove
    /// [`EngineCore`] with a streaming sink (the sink holds the
    /// telemetry instead).
    pub jobs: Vec<EngineJob>,
    /// tasks generated by the streams (accepted + shed)
    pub offered: usize,
    /// tasks that ran to completion — accepted minus the fault-era
    /// terminal outcomes (`failed` and accepted-then-shed dropout
    /// drains); without faults this is exactly the accepted count
    pub completed: usize,
    /// tasks dropped by admission control, plus accepted tasks shed
    /// while draining a downed device with no feasible sibling —
    /// `offered == completed + shed + failed` always holds
    pub shed: usize,
    /// tasks that exhausted their fault-retry budget (terminal outcome,
    /// distinct from `shed`)
    pub failed: usize,
    /// fault windows injected from the schedule (onsets only)
    pub faults_injected: usize,
    /// retry re-enqueues scheduled for fault-killed work
    pub retries: usize,
    /// tasks pulled off a downed device's edge queue at dropout
    /// (re-routed to a sibling or shed)
    pub drained_on_dropout: usize,
    /// per-device: fault windows that targeted this device
    pub per_dev_faults: Vec<usize>,
    /// per-device: tasks that terminally failed while owned by this device
    pub per_dev_failed: Vec<usize>,
    /// tasks forced to edge-only by admission control
    pub downgraded: usize,
    /// cloud executor invocations (batched and singleton)
    pub cloud_invocations: usize,
    /// jobs per cloud executor invocation (batch occupancy). Collected
    /// only when the sink keeps traces (`ReportSink::keep_trace`);
    /// empty under a streaming sink
    pub cloud_occupancy: Samples,
    /// running aggregate of batch occupancy (mean/min/max/sum) — always
    /// maintained, so streaming runs keep the headline occupancy
    /// figures without the per-invocation trace buffer
    pub cloud_occupancy_run: Running,
    /// dispatch/runtime overhead amortized away by cloud batching (s)
    pub cloud_dispatch_saved_s: f64,
    /// tasks re-routed to a sibling device instead of shed/downgraded
    pub rerouted: usize,
    /// queued tasks migrated between devices by the rebalancer
    pub migrated: usize,
    /// total migration latency paid by migrated tasks in transit (s)
    pub migration_latency_s: f64,
    /// per-device: tasks re-routed TO this device
    pub per_dev_rerouted: Vec<usize>,
    /// per-device: queued tasks migrated onto this device
    pub per_dev_migrated_in: Vec<usize>,
    /// per-device: queued tasks migrated away from this device
    pub per_dev_migrated_out: Vec<usize>,
    /// discrete events processed by the kernel loop (the denominator of
    /// the `engine_throughput` bench's events/sec figure)
    pub events: usize,
    /// generation-stale `BatchClose`/`CloudBatchClose` events popped and
    /// discarded (their window already cap-flushed) — tombstone traffic
    /// the scheduler carried for nothing
    pub stale_closes: usize,
    /// batching windows actually frozen (uplink + cloud); every stale
    /// close was scheduled by some flushed window, so
    /// `stale_closes <= window_flushes` always
    pub window_flushes: usize,
}

enum Verdict {
    Accept,
    Shed,
    Downgrade,
}

struct EngineState {
    q: Sched<Ev>,
    jobs: Vec<Job>,
    /// job slots retired by `finish` — recycled on the next admission,
    /// so the table size tracks in-flight (not lifetime) task count
    free_jobs: Vec<usize>,
    /// accepted-task counter: the admission-order index stamped on each
    /// job (what `jobs.len()` was before slot recycling)
    accepted: usize,
    devs: Vec<DevState>,
    /// flushed uplink batches, addressed by UplinkDone payload (global
    /// ids; the owning device rides in the event). Slots are recycled
    /// through `free_batches` once their UplinkDone consumes them, so
    /// the table stops growing (and stops re-allocating member lists)
    /// after the first few windows.
    batches: Vec<Vec<usize>>,
    /// slot indices in `batches` whose batch completed — each holds an
    /// empty `Vec` that kept its allocation for the next batch
    free_batches: Vec<usize>,
    /// open cross-device cloud batch (cloud work waiting for the
    /// window; stale closes guarded by its generation)
    cloud_open: BatchWindow,
    /// frozen cloud batches, addressed by CloudDone payload (slots
    /// recycled through `free_cloud_batches`, same scheme as `batches`)
    cloud_batches: Vec<Vec<usize>>,
    free_cloud_batches: Vec<usize>,
    /// transfer generation per `batches` slot: bumped when a dropout
    /// kills the slot's in-flight transfer, so the pending `UplinkDone`
    /// tombstones instead of completing dead work
    batch_gen: Vec<u32>,
    /// invocation generation per `cloud_batches` slot (same tombstone
    /// scheme, for cloud outages killing in-service invocations)
    cloud_batch_gen: Vec<u32>,
    /// frozen batches waiting for a free executor slot
    cloud_ready: VecDeque<usize>,
    /// batch slots currently occupying executor slots, in start order —
    /// what a cloud outage kills
    cloud_running: Vec<usize>,
    /// nesting depth of open cloud-outage windows; effective executor
    /// slots are 0 while > 0
    cloud_outage_depth: usize,
    /// busy executor slots (one per invocation, regardless of occupancy)
    cloud_active: usize,
    /// jobs between uplink completion and cloud completion — the live
    /// pool pressure the admission estimator reads
    cloud_in_flight: usize,
    /// cloud jobs in flight on OTHER shards of the same run, refreshed
    /// at epoch boundaries by the sharded runner (0 unsharded) — added
    /// to the local in-flight count by the admission estimator so every
    /// shard prices the *shared* pool, not just its slice
    ext_cloud_in_flight: usize,
    /// executor-slot denominator for the admission estimate: the global
    /// pool size under sharding, the local `cloud_slots` otherwise
    est_cloud_slots: usize,
    /// EWMA of the solo cloud service time
    cloud_service: Ewma,
    cloud_invocations: usize,
    cloud_occupancy: Samples,
    cloud_occupancy_run: Running,
    cloud_dispatch_saved_s: f64,
    /// whether the active sink keeps unbounded trace buffers (set from
    /// `ReportSink::keep_trace` on every `run_until` entry)
    trace: bool,
    opts: FleetOpts,
    rr_next: usize,
    offered: usize,
    shed: usize,
    /// the subset of `shed` that had already been accepted (dropout
    /// drains with no feasible sibling); subtracted from `accepted`
    /// when deriving `completed`
    shed_after_accept: usize,
    failed: usize,
    faults_injected: usize,
    retries: usize,
    drained_on_dropout: usize,
    per_dev_faults: Vec<usize>,
    per_dev_failed: Vec<usize>,
    downgraded: usize,
    rerouted: usize,
    migrated: usize,
    migration_latency_s: f64,
    per_dev_rerouted: Vec<usize>,
    per_dev_migrated_in: Vec<usize>,
    per_dev_migrated_out: Vec<usize>,
    events: usize,
    stale_closes: usize,
    window_flushes: usize,
}

impl EngineState {
    /// `sched_capacity` seeds the event scheduler for its steady-state
    /// population (one pending arrival per stream plus per-device and
    /// cloud-slot completion timers), mirroring the `jobs` reservation
    /// below — neither structure should realloc through warmup.
    fn new(devices: usize, capacity: usize, sched_capacity: usize, opts: &FleetOpts) -> Self {
        Self {
            q: Sched::with_capacity(opts.des.sched, sched_capacity),
            // slots are recycled at completion, so the table only needs
            // in-flight capacity; cap the reservation so a million-task
            // run does not pre-commit a million slots
            jobs: Vec::with_capacity(capacity.min(4096)),
            free_jobs: Vec::new(),
            accepted: 0,
            devs: (0..devices).map(|_| DevState::new()).collect(),
            batches: Vec::new(),
            free_batches: Vec::new(),
            cloud_open: BatchWindow::default(),
            cloud_batches: Vec::new(),
            free_cloud_batches: Vec::new(),
            batch_gen: Vec::new(),
            cloud_batch_gen: Vec::new(),
            cloud_ready: VecDeque::new(),
            cloud_running: Vec::new(),
            cloud_outage_depth: 0,
            cloud_active: 0,
            cloud_in_flight: 0,
            ext_cloud_in_flight: 0,
            est_cloud_slots: opts.des.cloud_slots,
            cloud_service: Ewma::new(0.2),
            cloud_invocations: 0,
            cloud_occupancy: Samples::new(),
            cloud_occupancy_run: Running::new(),
            cloud_dispatch_saved_s: 0.0,
            trace: true,
            opts: opts.clone(),
            rr_next: 0,
            offered: 0,
            shed: 0,
            shed_after_accept: 0,
            failed: 0,
            faults_injected: 0,
            retries: 0,
            drained_on_dropout: 0,
            per_dev_faults: vec![0; devices],
            per_dev_failed: vec![0; devices],
            downgraded: 0,
            rerouted: 0,
            migrated: 0,
            migration_latency_s: 0.0,
            per_dev_rerouted: vec![0; devices],
            per_dev_migrated_in: vec![0; devices],
            per_dev_migrated_out: vec![0; devices],
            events: 0,
            stale_closes: 0,
            window_flushes: 0,
        }
    }

    /// Pick the device for an arriving task, skipping downed devices;
    /// `None` (shed at arrival) only when every device is down. With no
    /// open dropout window every router behaves exactly as it always
    /// has (round-robin probes once and advances its cursor by one).
    fn route(&mut self, devices: &[Coordinator]) -> Option<usize> {
        let n = self.devs.len();
        match self.opts.router {
            Router::RoundRobin => {
                for _ in 0..n {
                    let d = self.rr_next % n;
                    self.rr_next += 1;
                    if !self.devs[d].down() {
                        return Some(d);
                    }
                }
                None
            }
            Router::ShortestQueue => (0..n)
                .filter(|&d| !self.devs[d].down())
                .min_by_key(|&d| self.devs[d].in_system()),
            Router::LeastBacklog => {
                let score = |d: usize| {
                    let res = self.devs[d].residency.get().unwrap_or(1.0);
                    let power = devices[d].env.edge.spec().max_power_w;
                    self.devs[d].in_system() as f64 * res * power
                };
                (0..n)
                    .filter(|&d| !self.devs[d].down())
                    .min_by(|&a, &b| score(a).total_cmp(&score(b)))
            }
        }
    }

    /// Estimated seconds until a task routed to `dev` right now would
    /// finish: edge backlog (residency EWMA × queue occupancy) plus the
    /// expected uplink/cloud detour, weighted by the device's observed
    /// offload propensity — expected solo transfer time, shared-pool
    /// wait (in-flight cloud jobs over executor slots), and one cloud
    /// service. `None` before the first edge start (cold start —
    /// admission stays open). Devices that never offload (ξ-EWMA 0)
    /// reduce to the pure edge estimate, so shedding also triggers when
    /// the cloud, not the edge, is the bottleneck, without penalizing
    /// edge-only traffic.
    fn est_completion_s(&self, dev: usize) -> Option<f64> {
        let res = self.devs[dev].residency.get()?;
        let edge = res * (self.devs[dev].in_system() as f64 + 1.0);
        let xi = self.devs[dev].xi.get().unwrap_or(0.0);
        if xi <= 0.0 {
            return Some(edge);
        }
        let tx = self.devs[dev].uplink_s.get().unwrap_or(0.0);
        let svc = self.cloud_service.get().unwrap_or(0.0);
        // under sharding the pool pressure is the epoch-synced global
        // view (local + other shards) over the global slot count; in an
        // unsharded run both extensions are identities (ext = 0,
        // est_cloud_slots = cloud_slots), so the estimate is unchanged
        let in_flight = self.cloud_in_flight + self.ext_cloud_in_flight;
        let pool_wait = svc * in_flight as f64 / self.est_cloud_slots.max(1) as f64;
        Some(edge + xi * (tx + svc + pool_wait))
    }

    /// Admission decision for a routed task, given the completion
    /// estimate and the task's SLO class.
    fn admit(&self, dev: usize, task: &Task) -> Verdict {
        if self.opts.admission == Admission::Off || !task.deadline_s.is_finite() {
            return Verdict::Accept;
        }
        let Some(est) = self.est_completion_s(dev) else {
            // cold start: no residency estimate yet, accept everything
            return Verdict::Accept;
        };
        if est <= task.deadline_s {
            return Verdict::Accept;
        }
        match self.opts.admission {
            Admission::Shed if task.priority == 0 => Verdict::Shed,
            // high-priority tasks (and every task under `downgrade`)
            // stay in the system but skip the cloud detour
            _ => Verdict::Downgrade,
        }
    }

    /// Cheapest sibling of `dev` that can still make `deadline_s`, by
    /// the same completion estimate admission uses. A cold-start sibling
    /// (no residency sample yet) counts as feasible with estimate 0,
    /// mirroring admission's cold-start accept. Ties break toward the
    /// lowest device index (deterministic).
    fn cheapest_feasible_sibling(&self, dev: usize, deadline_s: f64) -> Option<usize> {
        (0..self.devs.len())
            .filter(|&d| d != dev && !self.devs[d].down())
            .filter_map(|d| {
                let est = self.est_completion_s(d).unwrap_or(0.0);
                (est <= deadline_s).then_some((d, est))
            })
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(d, _)| d)
    }

    /// Edge backlog on `dev`: residency EWMA × (queued-but-not-started
    /// tasks + tasks in transit toward it). Counting in-transit arrivals
    /// keeps ticks that fire faster than the migration penalty from
    /// repeatedly stealing toward a destination that still looks empty.
    /// A cold device (no residency sample) reports 0 — it is an ideal
    /// steal target and never a steal source. Reads the accumulator
    /// maintained by `DevState::sync_backlog` — an O(1) load per query —
    /// and asserts (debug builds) it agrees bit-for-bit with a fresh
    /// recomputation, so any missed sync point trips under `cargo test`.
    fn edge_backlog_s(&self, dev: usize) -> f64 {
        let d = &self.devs[dev];
        debug_assert_eq!(
            d.backlog_s.to_bits(),
            (d.residency.get().unwrap_or(0.0) * (d.edge_queue.len() + d.migrating_in) as f64)
                .to_bits(),
            "backlog accumulator out of sync on dev {dev}"
        );
        d.backlog_s
    }

    /// One work-stealing pass: while the backlog estimates of the most-
    /// and least-backlogged devices diverge by more than the threshold,
    /// move tasks from the tail of the hot device's edge queue to the
    /// cold one. Each move charges the migration latency penalty: the
    /// task is in transit (in neither queue) until its `Migrate` event
    /// fires, and it keeps its original `arrival_s`, so queue wait and
    /// deadline math keep accumulating across the transfer. At most
    /// half of the source queue moves per tick — the classic work-
    /// stealing cap that keeps one tick from inverting the imbalance.
    fn rebalance(&mut self, now: f64) {
        let n = self.devs.len();
        if n < 2 || !self.opts.migrate_threshold_s.is_finite() {
            return;
        }
        // a device with a queue is necessarily warm (queued ⇒ busy ⇒
        // started ⇒ residency sampled), so the steal source always has
        // a real residency; only a cold DESTINATION needs a fallback
        let src = (0..n)
            .max_by(|&a, &b| self.edge_backlog_s(a).total_cmp(&self.edge_backlog_s(b)))
            .unwrap_or(0);
        let src_res = self.devs[src].residency.get().unwrap_or(0.0);
        // destination view with the same cold fallback (src-like
        // service) the in-loop projection uses, so in-transit arrivals
        // toward a cold device still register as backlog across ticks
        // instead of vanishing under a 0.0 residency multiplier
        let cold_adjusted = |d: usize| {
            self.devs[d].residency.get().unwrap_or(src_res)
                * (self.devs[d].edge_queue.len() + self.devs[d].migrating_in) as f64
        };
        // never steal toward a downed device (its landing would just
        // re-drain); a downed source has an empty queue, so the loop
        // below is naturally inert for it
        let Some(dst) = (0..n)
            .filter(|&d| d != src && !self.devs[d].down())
            .min_by(|&a, &b| cold_adjusted(a).total_cmp(&cold_adjusted(b)))
        else {
            return;
        };
        let dst_res = self.devs[dst].residency.get().unwrap_or(src_res);
        let mut src_backlog = self.edge_backlog_s(src);
        let mut dst_backlog = cold_adjusted(dst);
        let mut moves = self.devs[src].edge_queue.len() / 2;
        while moves > 0 && src_backlog - dst_backlog > self.opts.migrate_threshold_s {
            let Some(id) = self.devs[src].edge_queue.pop_back() else {
                break;
            };
            moves -= 1;
            src_backlog -= src_res;
            dst_backlog += dst_res;
            self.jobs[id].dev = dst;
            self.jobs[id].migrated = true;
            self.devs[dst].migrating_in += 1;
            self.migrated += 1;
            self.migration_latency_s += self.opts.migrate_penalty_s;
            self.per_dev_migrated_out[src] += 1;
            self.per_dev_migrated_in[dst] += 1;
            self.q.push(
                now + self.opts.migrate_penalty_s,
                Ev::Migrate { dev: dst, job: id },
            );
        }
        // the loop tracked projected backlogs locally; re-derive the
        // per-device accumulators from the settled queues
        self.devs[src].sync_backlog();
        self.devs[dst].sync_backlog();
    }

    /// Queue a job on its device, honoring priority classes: a task
    /// jumps ahead of queued lower-priority tasks (FIFO within a class,
    /// so all-default-priority traffic keeps the exact legacy order).
    fn enqueue_edge(&mut self, id: usize) {
        let dev = self.jobs[id].dev;
        let prio = self.jobs[id].task.priority;
        if prio == 0 {
            self.devs[dev].edge_queue.push_back(id);
            self.devs[dev].sync_backlog();
            return;
        }
        let pos = self.devs[dev]
            .edge_queue
            .iter()
            .position(|&j| self.jobs[j].task.priority < prio)
            .unwrap_or(self.devs[dev].edge_queue.len());
        self.devs[dev].edge_queue.insert(pos, id);
        self.devs[dev].sync_backlog();
    }

    /// Start edge service on the next queued job if the device is idle:
    /// publish per-device load signals, run decide→execute through the
    /// device's coordinator, and schedule the edge-completion event
    /// after the edge-side residency (local compute + compression +
    /// decision overhead + DVFS switch).
    fn maybe_start_edge(&mut self, devices: &mut [Coordinator], dev: usize, now: f64) {
        if self.devs[dev].edge_busy || self.devs[dev].down() {
            return;
        }
        let Some(id) = self.devs[dev].edge_queue.pop_front() else {
            return;
        };
        self.devs[dev].sync_backlog();
        let coord = &mut devices[dev];
        coord.load.queue_depth = self.devs[dev].edge_queue.len();
        coord.load.backlog_s = self.edge_backlog_s(dev);
        let force_edge = self.jobs[id].downgraded;
        let mut r = coord.step_constrained(&self.jobs[id].task, false, force_edge);
        let residency = (r.tti_total_s - r.tti_off_s - r.tti_cloud_s).max(0.0);
        self.devs[dev].residency.push(residency);
        self.devs[dev].sync_backlog();
        // track the policy's NATURAL offload propensity: an
        // admission-forced ξ=0 must not decay the EWMA, or sustained
        // downgrades would erase the cloud-detour term from
        // est_completion_s and re-admit traffic into the very backlog
        // that triggered them (oscillating under-protection)
        if !force_edge {
            self.devs[dev].xi.push(r.xi);
            if r.xi > 0.0 {
                self.devs[dev].uplink_s.push(r.tti_off_s);
            }
        }
        let job = &mut self.jobs[id];
        job.queue_wait_s = (now - job.arrival_s).max(0.0);
        job.solo_off_s = r.tti_off_s;
        job.cloud_s = r.tti_cloud_s;
        job.payload_bytes = r.payload_bytes;
        r.rerouted = job.rerouted;
        r.migrated = job.migrated;
        job.report = Some(r);
        self.devs[dev].edge_busy = true;
        self.q.push(now + residency, Ev::EdgeDone { dev, job: id });
    }

    /// Claim an uplink-batch slot: a recycled one (its empty member
    /// `Vec` kept the old allocation) when available, a fresh one
    /// otherwise. Slot indices ride in `UplinkDone` events; a slot is
    /// only recycled once that event has consumed it, so a live event
    /// can never observe a reused slot.
    fn acquire_batch_slot(&mut self) -> usize {
        match self.free_batches.pop() {
            Some(b) => {
                debug_assert!(self.batches[b].is_empty());
                b
            }
            None => {
                self.batches.push(Vec::new());
                self.batch_gen.push(0);
                self.batches.len() - 1
            }
        }
    }

    /// Return a consumed slot's (emptied) member list to the free list.
    fn release_batch_slot(&mut self, b: usize, mut members: Vec<usize>) {
        members.clear();
        self.batches[b] = members;
        self.free_batches.push(b);
    }

    fn flush_open_batch(&mut self, devices: &[Coordinator], dev: usize, now: f64) {
        if self.devs[dev].open_batch.is_empty() {
            return;
        }
        self.window_flushes += 1;
        let b = self.acquire_batch_slot();
        // swap the window's members into the recycled slot; the window
        // inherits the slot's cleared allocation for its next batch
        let mut slot = std::mem::take(&mut self.batches[b]);
        self.devs[dev].open_batch.freeze_into(&mut slot);
        self.batches[b] = slot;
        self.devs[dev].uplink_queue.push_back(b);
        self.maybe_start_uplink(devices, dev, now);
    }

    /// Start transmitting the next batch on the device's uplink if it is
    /// idle (singleton batches reuse the env-computed solo transmission
    /// time; real batches ship the summed payload in one transfer — one
    /// wire header amortized, one bandwidth-limited transfer).
    fn maybe_start_uplink(&mut self, devices: &[Coordinator], dev: usize, now: f64) {
        if self.devs[dev].uplink_busy || self.devs[dev].down() {
            return;
        }
        let Some(b) = self.devs[dev].uplink_queue.pop_front() else {
            return;
        };
        // take the member list instead of cloning it — stamping
        // batch_size needs `jobs` mutable while the members are read —
        // and restore it below: the UplinkDone event still needs it
        let members = std::mem::take(&mut self.batches[b]);
        // a bandwidth-collapse window stretches transfers started inside
        // it by 1/scale; outside any window the scale is exactly 1.0 and
        // IEEE division by 1.0 is the identity, so fault-free timing is
        // bit-for-bit the historical path
        let scale = self.devs[dev].link_scale;
        let tx_s = if members.len() == 1 {
            self.jobs[members[0]].solo_off_s / scale
        } else {
            // detlint: allow(R4, summed in batch-member index order; replay/golden gated)
            let payload: f64 = members.iter().map(|&id| self.jobs[id].payload_bytes).sum();
            devices[dev].env.link.tx_time_s(payload) / scale
        };
        let n = members.len();
        for &id in &members {
            if let Some(r) = self.jobs[id].report.as_mut() {
                r.batch_size = n;
            }
        }
        self.batches[b] = members;
        self.devs[dev].uplink_busy = true;
        self.devs[dev].uplink_inflight = Some(b);
        self.q.push(
            now + tx_s,
            Ev::UplinkDone {
                dev,
                batch: b,
                gen: self.batch_gen[b],
            },
        );
    }

    /// Hand an offloading job to its device's uplink stage. With a
    /// batch window it joins the device's open batch (size-capped,
    /// stale-close guarded); without one it ships as a singleton batch
    /// immediately — built in a recycled slot, not a fresh `vec![id]`.
    /// Mirrors `enqueue_cloud` — the two stages share the `BatchWindow`
    /// state machine.
    fn enqueue_uplink(&mut self, devices: &[Coordinator], dev: usize, id: usize, now: f64) {
        if self.opts.des.batch_window_s > 0.0 {
            if self.devs[dev].open_batch.join(id) {
                self.q.push(
                    now + self.opts.des.batch_window_s,
                    Ev::BatchClose {
                        dev,
                        generation: self.devs[dev].open_batch.generation,
                    },
                );
            }
            if self.devs[dev].open_batch.is_full(self.opts.des.max_batch) {
                self.flush_open_batch(devices, dev, now);
            }
        } else {
            let b = self.acquire_batch_slot();
            self.batches[b].push(id);
            self.devs[dev].uplink_queue.push_back(b);
            self.maybe_start_uplink(devices, dev, now);
        }
    }

    /// Cloud-side twin of `acquire_batch_slot` (slot indices ride in
    /// `CloudDone` events; recycled only after that event consumes them).
    fn acquire_cloud_slot(&mut self) -> usize {
        match self.free_cloud_batches.pop() {
            Some(b) => {
                debug_assert!(self.cloud_batches[b].is_empty());
                b
            }
            None => {
                self.cloud_batches.push(Vec::new());
                self.cloud_batch_gen.push(0);
                self.cloud_batches.len() - 1
            }
        }
    }

    fn release_cloud_slot(&mut self, b: usize, mut members: Vec<usize>) {
        members.clear();
        self.cloud_batches[b] = members;
        self.free_cloud_batches.push(b);
    }

    /// Hand a job to the shared cloud stage. With a cloud batch window
    /// it joins the open cross-device batch (size-capped, stale-close
    /// guarded); without one it becomes a singleton invocation exactly
    /// like the pre-batching pool.
    fn enqueue_cloud(&mut self, id: usize, now: f64) {
        self.cloud_in_flight += 1;
        self.cloud_service.push(self.jobs[id].cloud_s);
        if self.opts.des.cloud_batch_window_s > 0.0 {
            if self.cloud_open.join(id) {
                self.q.push(
                    now + self.opts.des.cloud_batch_window_s,
                    Ev::CloudBatchClose {
                        generation: self.cloud_open.generation,
                    },
                );
            }
            if self.cloud_open.is_full(self.opts.des.cloud_max_batch) {
                self.flush_cloud_batch(now);
            }
        } else {
            let b = self.acquire_cloud_slot();
            self.cloud_batches[b].push(id);
            self.cloud_ready.push_back(b);
            self.maybe_start_cloud(now);
        }
    }

    fn flush_cloud_batch(&mut self, now: f64) {
        if self.cloud_open.is_empty() {
            return;
        }
        self.window_flushes += 1;
        let b = self.acquire_cloud_slot();
        let mut slot = std::mem::take(&mut self.cloud_batches[b]);
        self.cloud_open.freeze_into(&mut slot);
        self.cloud_batches[b] = slot;
        self.cloud_ready.push_back(b);
        self.maybe_start_cloud(now);
    }

    /// Start batched executor invocations while slots are free. A
    /// singleton invocation runs for the env-computed solo cloud time
    /// (bit-identical to the unbatched pool); a real batch pays the
    /// service-runtime dispatch overhead once and runs its members'
    /// compute back-to-back in one slot — the server-side analogue of
    /// the uplink's amortized wire header.
    /// Executor slots currently usable: 0 for the duration of a cloud
    /// outage, the configured pool otherwise.
    fn effective_cloud_slots(&self) -> usize {
        if self.cloud_outage_depth > 0 {
            0
        } else {
            self.opts.des.cloud_slots
        }
    }

    fn maybe_start_cloud(&mut self, now: f64) {
        while self.cloud_active < self.effective_cloud_slots() {
            let Some(b) = self.cloud_ready.pop_front() else {
                return;
            };
            // take, stamp, restore — same clone-free pattern as
            // `maybe_start_uplink`; CloudDone still needs the members
            let members = std::mem::take(&mut self.cloud_batches[b]);
            let n = members.len();
            let svc = if n == 1 {
                self.jobs[members[0]].cloud_s
            } else {
                let compute: f64 = members
                    .iter()
                    .map(|&id| (self.jobs[id].cloud_s - CLOUD_DISPATCH_OVERHEAD_S).max(0.0))
                    // detlint: allow(R4, summed in batch-member index order; replay/golden gated)
                    .sum();
                self.cloud_dispatch_saved_s += (n - 1) as f64 * CLOUD_DISPATCH_OVERHEAD_S;
                CLOUD_DISPATCH_OVERHEAD_S + compute
            };
            for &id in &members {
                if let Some(r) = self.jobs[id].report.as_mut() {
                    r.cloud_batch_size = n;
                }
            }
            self.cloud_batches[b] = members;
            self.cloud_invocations += 1;
            // the per-invocation trace buffer only grows for collecting
            // sinks; the running aggregate is always maintained
            if self.trace {
                self.cloud_occupancy.push(n as f64);
            }
            self.cloud_occupancy_run.push(n as f64);
            self.cloud_active += 1;
            self.cloud_running.push(b);
            self.q.push(
                now + svc,
                Ev::CloudDone {
                    batch: b,
                    gen: self.cloud_batch_gen[b],
                },
            );
        }
    }

    /// Stamp the queueing-aware fields on the job's report, deliver it
    /// to the sink, and retire the job slot to the free list (no event,
    /// queue, or batch references the id past this point).
    fn finish<S: ReportSink>(&mut self, id: usize, now: f64, sink: &mut S) {
        let job = &mut self.jobs[id];
        if let Some(r) = job.report.as_mut() {
            r.queue_wait_s = job.queue_wait_s;
            r.e2e_s = (now - job.arrival_s).max(0.0);
            r.stream = job.stream;
        }
        let meta = JobMeta {
            dev: job.dev,
            deadline_s: job.task.deadline_s,
            priority: job.task.priority,
            arrival_idx: job.arrival_idx,
        };
        if let Some(r) = job.report.take() {
            sink.push(&meta, r);
        }
        self.free_jobs.push(id);
    }

    /// Retire a job without a completion report (terminal `failed` or
    /// accepted-then-shed): the sink still learns the job's identity so
    /// collecting sinks fill the admission-order slot, and the slot is
    /// recycled exactly like a completion.
    fn terminate<S: ReportSink>(&mut self, id: usize, sink: &mut S) {
        let job = &mut self.jobs[id];
        job.report = None;
        let meta = JobMeta {
            dev: job.dev,
            deadline_s: job.task.deadline_s,
            priority: job.task.priority,
            arrival_idx: job.arrival_idx,
        };
        sink.fail(&meta);
        self.free_jobs.push(id);
    }

    /// A fault killed this job's uplink/cloud work: charge one retry
    /// attempt and either schedule the backed-off re-enqueue or, with
    /// the budget exhausted, terminate the job as `failed`. Termination
    /// is guaranteed: fault windows are finite and the budget is
    /// bounded, so every accepted job eventually completes, sheds, or
    /// fails.
    fn retry_or_fail<S: ReportSink>(
        &mut self,
        id: usize,
        stage: RetryStage,
        now: f64,
        sink: &mut S,
    ) {
        self.jobs[id].retries += 1;
        let attempt = self.jobs[id].retries;
        if attempt > self.opts.retry.max_retries {
            self.failed += 1;
            self.per_dev_failed[self.jobs[id].dev] += 1;
            self.terminate(id, sink);
            return;
        }
        self.retries += 1;
        let ev = match stage {
            RetryStage::Uplink => Ev::RetryUplink { job: id },
            RetryStage::Cloud => Ev::RetryCloud { job: id },
        };
        self.q.push(now + self.opts.retry.backoff_s(attempt), ev);
    }

    /// Drain a queued-but-unstarted task off a downed device: re-route
    /// it through the same sibling scan admission uses (when re-routing
    /// is enabled and a sibling is feasible), otherwise shed it
    /// post-acceptance.
    fn reroute_or_shed<S: ReportSink>(
        &mut self,
        devices: &mut [Coordinator],
        id: usize,
        now: f64,
        sink: &mut S,
    ) {
        let deadline_s = self.jobs[id].task.deadline_s;
        let from = self.jobs[id].dev;
        let alt = if self.opts.reroute {
            self.cheapest_feasible_sibling(from, deadline_s)
        } else {
            None
        };
        match alt {
            Some(alt) => {
                self.jobs[id].dev = alt;
                self.jobs[id].rerouted = true;
                self.rerouted += 1;
                self.per_dev_rerouted[alt] += 1;
                self.enqueue_edge(id);
                self.maybe_start_edge(devices, alt, now);
            }
            None => {
                self.shed += 1;
                self.shed_after_accept += 1;
                self.terminate(id, sink);
            }
        }
    }

    /// Apply a `DeviceDown` onset: drain the edge queue through
    /// re-route-or-shed and kill every uplink-stage holding — the open
    /// window, queued frozen batches, and the in-flight transfer — into
    /// the bounded retry path. In-service *edge* compute is left to
    /// finish (the dropout models the device's radio dying, not its
    /// local accelerator); its offload is killed at `EdgeDone` instead.
    fn drain_downed_device<S: ReportSink>(
        &mut self,
        devices: &mut [Coordinator],
        dev: usize,
        now: f64,
        sink: &mut S,
    ) {
        while let Some(id) = self.devs[dev].edge_queue.pop_front() {
            self.drained_on_dropout += 1;
            self.devs[dev].sync_backlog();
            self.reroute_or_shed(devices, id, now, sink);
        }
        // the open uplink window: count the forced freeze as a flush so
        // the pending BatchClose tombstones within the usual
        // `stale_closes <= window_flushes` budget
        if !self.devs[dev].open_batch.is_empty() {
            self.window_flushes += 1;
            let mut members = Vec::new();
            self.devs[dev].open_batch.freeze_into(&mut members);
            for id in members {
                self.retry_or_fail(id, RetryStage::Uplink, now, sink);
            }
        }
        while let Some(b) = self.devs[dev].uplink_queue.pop_front() {
            let members = std::mem::take(&mut self.batches[b]);
            for &id in &members {
                self.retry_or_fail(id, RetryStage::Uplink, now, sink);
            }
            self.release_batch_slot(b, members);
        }
        if let Some(b) = self.devs[dev].uplink_inflight.take() {
            // the pending UplinkDone goes stale via the generation bump
            self.batch_gen[b] += 1;
            self.devs[dev].uplink_busy = false;
            let members = std::mem::take(&mut self.batches[b]);
            for &id in &members {
                self.retry_or_fail(id, RetryStage::Uplink, now, sink);
            }
            self.release_batch_slot(b, members);
        }
    }

    /// Apply a cloud-outage onset: every in-service invocation is
    /// killed (its `CloudDone` tombstones via the generation bump) and
    /// its members enter the retry path; frozen batches already queued
    /// simply wait — `effective_cloud_slots` is 0 until the window
    /// closes.
    fn kill_running_cloud<S: ReportSink>(&mut self, now: f64, sink: &mut S) {
        let running = std::mem::take(&mut self.cloud_running);
        for b in running {
            self.cloud_batch_gen[b] += 1;
            self.cloud_active -= 1;
            let members = std::mem::take(&mut self.cloud_batches[b]);
            for &id in &members {
                self.cloud_in_flight -= 1;
                self.retry_or_fail(id, RetryStage::Cloud, now, sink);
            }
            self.release_cloud_slot(b, members);
        }
    }

    /// A scheduled fault window opens.
    fn apply_fault<S: ReportSink>(
        &mut self,
        devices: &mut [Coordinator],
        idx: usize,
        now: f64,
        sink: &mut S,
    ) {
        self.faults_injected += 1;
        let fault = self.opts.chaos.faults()[idx];
        match fault {
            Fault::DeviceDown { dev, .. } => {
                self.per_dev_faults[dev] += 1;
                self.devs[dev].down_depth += 1;
                if self.devs[dev].down_depth == 1 {
                    self.drain_downed_device(devices, dev, now, sink);
                }
            }
            Fault::BandwidthCollapse { dev, scale, .. } => {
                self.per_dev_faults[dev] += 1;
                self.devs[dev].link_scale *= scale;
            }
            Fault::CloudOutage { .. } => {
                self.cloud_outage_depth += 1;
                if self.cloud_outage_depth == 1 {
                    self.kill_running_cloud(now, sink);
                }
            }
        }
    }

    /// The matching fault window closes. A recovered device has an
    /// empty queue by construction (drained at dropout, skipped by
    /// routing while down), so recovery just reopens it to traffic and
    /// pending retries; a closed cloud outage restarts the pool.
    fn clear_fault(&mut self, idx: usize, now: f64) {
        let fault = self.opts.chaos.faults()[idx];
        match fault {
            Fault::DeviceDown { dev, .. } => {
                self.devs[dev].down_depth -= 1;
            }
            Fault::BandwidthCollapse { dev, scale, .. } => {
                self.devs[dev].link_scale /= scale;
            }
            Fault::CloudOutage { .. } => {
                self.cloud_outage_depth -= 1;
                if self.cloud_outage_depth == 0 {
                    self.maybe_start_cloud(now);
                }
            }
        }
    }
}

/// The collecting sink: every report retained, reassembled in
/// admission order — exactly the `Vec<EngineJob>` the engine built
/// before sinks existed, and still the default behavior of [`serve`].
pub struct CollectSink {
    jobs: Vec<Option<EngineJob>>,
}

impl CollectSink {
    pub fn new() -> Self {
        Self { jobs: Vec::new() }
    }

    /// The accepted jobs in admission order. Every accepted job reaches
    /// a terminal state (completed, failed, or drain-shed) before the
    /// engine drains, so every slot is filled — terminal non-completions
    /// carry `report: None`.
    pub fn into_jobs(self) -> Vec<EngineJob> {
        self.jobs
            .into_iter()
            .map(|j| j.expect("every accepted job terminates before the engine drains"))
            .collect()
    }
}

impl ReportSink for CollectSink {
    fn push(&mut self, meta: &JobMeta, report: TaskReport) {
        if self.jobs.len() <= meta.arrival_idx {
            self.jobs.resize_with(meta.arrival_idx + 1, || None);
        }
        debug_assert!(
            self.jobs[meta.arrival_idx].is_none(),
            "a job completed twice"
        );
        self.jobs[meta.arrival_idx] = Some(EngineJob {
            report: Some(report),
            dev: meta.dev,
            deadline_s: meta.deadline_s,
        });
    }

    fn fail(&mut self, meta: &JobMeta) {
        if self.jobs.len() <= meta.arrival_idx {
            self.jobs.resize_with(meta.arrival_idx + 1, || None);
        }
        debug_assert!(
            self.jobs[meta.arrival_idx].is_none(),
            "a job terminated twice"
        );
        self.jobs[meta.arrival_idx] = Some(EngineJob {
            report: None,
            dev: meta.dev,
            deadline_s: meta.deadline_s,
        });
    }
}

/// The resumable event loop: the kernel's state machine plus its task
/// streams, runnable to completion in one call or in bounded time
/// epochs.
///
/// `serve` drives a core with `run_until(f64::INFINITY, ..)` — one
/// uninterrupted run, event-for-event identical to the historical
/// monolithic loop. The sharded fleet runner (`coordinator::shard`)
/// instead advances every shard's core epoch by epoch, reconciling the
/// shared-cloud signals between epochs through the `cloud_*` accessors
/// below.
pub struct EngineCore<'a> {
    devices: &'a mut [Coordinator],
    gens: &'a mut [TaskGen],
    state: EngineState,
    next_task: Vec<Option<Task>>,
    remaining: Vec<usize>,
    clock: f64,
}

impl<'a> EngineCore<'a> {
    /// Build a core over the devices and streams: primes every stream's
    /// first arrival and arms the rebalance tick chain. Streams may be
    /// empty (the core is then born drained); `devices` must be
    /// non-empty if any stream has tasks to route.
    pub fn new(
        devices: &'a mut [Coordinator],
        gens: &'a mut [TaskGen],
        per_stream: usize,
        opts: &FleetOpts,
    ) -> Self {
        for coord in devices.iter_mut() {
            coord.policy.set_training(false);
        }
        let streams = gens.len();
        // steady-state scheduler population: one pending arrival per
        // stream, a completion/window timer or two per device, one
        // CloudDone per busy executor slot
        let sched_capacity = streams + devices.len() + opts.des.cloud_slots;
        let mut state = EngineState::new(devices.len(), streams * per_stream, sched_capacity, opts);

        // prime every stream with its first arrival
        let mut next_task: Vec<Option<Task>> = Vec::with_capacity(streams);
        let mut remaining: Vec<usize> = vec![per_stream; streams];
        if per_stream > 0 {
            for (s, gen) in gens.iter_mut().enumerate() {
                let t = gen.next_task();
                remaining[s] -= 1;
                state.q.push(t.arrival_s, Ev::Arrival { stream: s });
                next_task.push(Some(t));
            }
        }

        // arm the rebalance tick chain; with the window at 0 no tick is
        // ever scheduled and the event trace is bit-identical to the
        // non-rebalancing kernel
        if opts.rebalance_window_s > 0.0 && !state.q.is_empty() {
            state.q.push(opts.rebalance_window_s, Ev::Rebalance);
        }

        // arm the fault schedule; an empty schedule pushes nothing and
        // keeps the event trace bit-identical to the fault-free kernel.
        // Faults aimed past the fleet (a global schedule partitioned
        // onto a smaller shard) are skipped here, not at parse time.
        if !state.q.is_empty() {
            for (idx, f) in opts.chaos.faults().iter().enumerate() {
                if f.dev().is_some_and(|d| d >= devices.len()) {
                    continue;
                }
                state.q.push(f.at_s(), Ev::Fault { idx });
                state.q.push(f.until_s(), Ev::FaultEnd { idx });
            }
        }

        Self {
            devices,
            gens,
            state,
            next_task,
            remaining,
            clock: f64::NEG_INFINITY,
        }
    }

    /// True once every event has been consumed (all streams exhausted,
    /// all in-flight work completed).
    pub fn drained(&self) -> bool {
        self.state.q.is_empty()
    }

    /// Local cloud jobs currently between uplink completion and cloud
    /// completion — published to sibling shards at epoch boundaries.
    pub fn cloud_in_flight(&self) -> usize {
        self.state.cloud_in_flight
    }

    /// Current value of the local cloud-service EWMA (`None` before the
    /// first cloud job).
    pub fn cloud_service(&self) -> Option<f64> {
        self.state.cloud_service.get()
    }

    /// Adopt the epoch-synced cross-shard view of the shared cloud
    /// pool: jobs in flight on *other* shards and the global executor
    /// slot count the admission estimator should price against.
    pub fn set_cloud_signals(&mut self, ext_in_flight: usize, est_slots: usize) {
        self.state.ext_cloud_in_flight = ext_in_flight;
        self.state.est_cloud_slots = est_slots;
    }

    /// Adopt a blended global cloud-service estimate (every shard sets
    /// the same value, then keeps smoothing locally until the next
    /// epoch).
    pub fn set_cloud_service(&mut self, v: Option<f64>) {
        self.state.cloud_service.set(v);
    }

    /// Process events strictly before `t_stop` (an infinite `t_stop`
    /// runs to drain). Completed reports are delivered to `sink` as
    /// they finish. Returns `true` when the core drained, `false` when
    /// it paused at the epoch boundary with events still queued.
    pub fn run_until<S: ReportSink>(&mut self, t_stop: f64, sink: &mut S) -> bool {
        self.state.trace = sink.keep_trace();
        let devices = &mut *self.devices;
        let gens = &mut *self.gens;
        let state = &mut self.state;
        let next_task = &mut self.next_task;
        let remaining = &mut self.remaining;
        loop {
            // fused peek+pop: one scheduler traversal either yields the
            // next event (strictly before the boundary), pauses at the
            // epoch boundary, or observes the drained queue
            let Some(ev) = state.q.pop_before(t_stop) else {
                if state.q.is_empty() {
                    break;
                }
                return false;
            };
            let now = ev.time;
            // the kernel invariant the heap ordering guarantees: events
            // pop in nondecreasing time order across every device and
            // stage (and across epoch pauses)
            debug_assert!(
                now >= self.clock,
                "event clock went backwards: {now} < {}",
                self.clock
            );
            self.clock = now;
            state.events += 1;
            match ev.ev {
                Ev::Arrival { stream } => {
                    let task = next_task[stream]
                        .take()
                        .expect("arrival without pending task");
                    if remaining[stream] > 0 {
                        remaining[stream] -= 1;
                        let t = gens[stream].next_task();
                        state.q.push(t.arrival_s, Ev::Arrival { stream });
                        next_task[stream] = Some(t);
                    }
                    state.offered += 1;
                    // None only when every device is down: shed at arrival
                    let Some(mut dev) = state.route(devices) else {
                        state.shed += 1;
                        continue;
                    };
                    let mut verdict = state.admit(dev, &task);
                    let mut rerouted = false;
                    // re-route-before-shed: when the routed device would
                    // blow the deadline, try the cheapest feasible
                    // sibling; only give up (shed/downgrade) when no
                    // device can make the deadline
                    if state.opts.reroute && !matches!(verdict, Verdict::Accept) {
                        if let Some(alt) =
                            state.cheapest_feasible_sibling(dev, task.deadline_s)
                        {
                            dev = alt;
                            verdict = Verdict::Accept;
                            rerouted = true;
                            state.rerouted += 1;
                            state.per_dev_rerouted[alt] += 1;
                        }
                    }
                    let downgraded = match verdict {
                        Verdict::Shed => {
                            state.shed += 1;
                            continue;
                        }
                        Verdict::Downgrade => {
                            state.downgraded += 1;
                            true
                        }
                        Verdict::Accept => false,
                    };
                    let arrival_idx = state.accepted;
                    state.accepted += 1;
                    let job = Job {
                        task,
                        stream,
                        dev,
                        arrival_s: now,
                        queue_wait_s: 0.0,
                        solo_off_s: 0.0,
                        cloud_s: 0.0,
                        payload_bytes: 0.0,
                        downgraded,
                        rerouted,
                        migrated: false,
                        retries: 0,
                        arrival_idx,
                        report: None,
                    };
                    // reuse a retired slot when one is free; ids are
                    // opaque handles, so recycling never reorders
                    // anything (ordering keys off `arrival_idx`)
                    let id = match state.free_jobs.pop() {
                        Some(slot) => {
                            state.jobs[slot] = job;
                            slot
                        }
                        None => {
                            state.jobs.push(job);
                            state.jobs.len() - 1
                        }
                    };
                    state.enqueue_edge(id);
                    state.maybe_start_edge(devices, dev, now);
                }
                Ev::EdgeDone { dev, job: id } => {
                    state.devs[dev].edge_busy = false;
                    let offloads = state.jobs[id]
                        .report
                        .as_ref()
                        .map(|r| r.xi > 0.0)
                        .unwrap_or(false);
                    if offloads {
                        if state.devs[dev].down() {
                            // the device dropped while this task was in
                            // edge service: the compute finished but the
                            // radio is dead — kill the offload into the
                            // retry path
                            state.retry_or_fail(id, RetryStage::Uplink, now, sink);
                        } else {
                            state.enqueue_uplink(devices, dev, id, now);
                        }
                    } else {
                        state.finish(id, now, sink);
                    }
                    state.maybe_start_edge(devices, dev, now);
                }
                Ev::BatchClose { dev, generation } => {
                    if generation == state.devs[dev].open_batch.generation {
                        state.flush_open_batch(devices, dev, now);
                    } else {
                        // tombstone: the window this close was armed for
                        // already cap-flushed
                        state.stale_closes += 1;
                    }
                }
                Ev::UplinkDone { dev, batch, gen } => {
                    if gen != state.batch_gen[batch] {
                        // tombstone: a dropout killed this transfer and
                        // already recycled the slot
                        continue;
                    }
                    state.devs[dev].uplink_busy = false;
                    state.devs[dev].uplink_inflight = None;
                    // final use of this batch slot: drain it, then hand
                    // the emptied member list back to the free list
                    let members = std::mem::take(&mut state.batches[batch]);
                    for &id in &members {
                        state.enqueue_cloud(id, now);
                    }
                    state.release_batch_slot(batch, members);
                    state.maybe_start_uplink(devices, dev, now);
                }
                Ev::CloudBatchClose { generation } => {
                    if generation == state.cloud_open.generation {
                        state.flush_cloud_batch(now);
                    } else {
                        state.stale_closes += 1;
                    }
                }
                Ev::CloudDone { batch, gen } => {
                    if gen != state.cloud_batch_gen[batch] {
                        // tombstone: a cloud outage killed this
                        // invocation and already recycled the slot
                        continue;
                    }
                    state.cloud_active -= 1;
                    if let Some(p) = state.cloud_running.iter().position(|&b| b == batch) {
                        state.cloud_running.remove(p);
                    }
                    // final use of this invocation's slot — recycle it
                    let members = std::mem::take(&mut state.cloud_batches[batch]);
                    for &id in &members {
                        state.cloud_in_flight -= 1;
                        state.finish(id, now, sink);
                    }
                    state.release_cloud_slot(batch, members);
                    state.maybe_start_cloud(now);
                }
                Ev::Rebalance => {
                    state.rebalance(now);
                    // keep ticking while any other event is pending;
                    // when this tick was the last event the system is
                    // fully drained (queued work always has a completion
                    // or window-close event in flight) and the chain ends
                    if !state.q.is_empty() {
                        state
                            .q
                            .push(now + state.opts.rebalance_window_s, Ev::Rebalance);
                    }
                }
                Ev::Migrate { dev, job } => {
                    debug_assert_eq!(state.jobs[job].dev, dev);
                    state.devs[dev].migrating_in -= 1;
                    if state.devs[dev].down() {
                        // the destination dropped while the task was in
                        // transit: drain it like any other queued task
                        state.reroute_or_shed(devices, job, now, sink);
                        continue;
                    }
                    // the job kept its original arrival_s across the
                    // transfer: queue wait and deadline math never reset
                    // (enqueue_edge re-syncs the backlog accumulator
                    // after the in-transit decrement above)
                    state.enqueue_edge(job);
                    state.maybe_start_edge(devices, dev, now);
                }
                Ev::Fault { idx } => {
                    state.apply_fault(devices, idx, now, sink);
                }
                Ev::FaultEnd { idx } => {
                    state.clear_fault(idx, now);
                }
                Ev::RetryUplink { job } => {
                    let dev = state.jobs[job].dev;
                    if !state.devs[dev].down() {
                        state.enqueue_uplink(devices, dev, job, now);
                        continue;
                    }
                    let alt = if state.opts.reroute {
                        state.cheapest_feasible_sibling(dev, state.jobs[job].task.deadline_s)
                    } else {
                        None
                    };
                    match alt {
                        Some(alt) => {
                            // the home device is still dark: ship the
                            // transfer through a feasible sibling's
                            // uplink (compute already happened on `dev`,
                            // so the job keeps its device attribution)
                            state.rerouted += 1;
                            state.per_dev_rerouted[alt] += 1;
                            state.jobs[job].rerouted = true;
                            state.enqueue_uplink(devices, alt, job, now);
                        }
                        None => state.retry_or_fail(job, RetryStage::Uplink, now, sink),
                    }
                }
                Ev::RetryCloud { job } => {
                    // re-enters the shared pool queue; during an outage
                    // effective_cloud_slots() is 0 so the batch simply
                    // waits for recovery
                    state.enqueue_cloud(job, now);
                }
            }
        }
        true
    }

    /// Tear the core down into its counters. Reports live in whatever
    /// sink the caller drove `run_until` with (`jobs` stays empty here;
    /// [`serve`] refills it from its `CollectSink`).
    pub fn into_result(self) -> EngineResult {
        // reset load signals so later synchronous use observes idle edges
        for coord in self.devices.iter_mut() {
            coord.load = LoadSignals::default();
        }
        let state = self.state;
        EngineResult {
            jobs: Vec::new(),
            offered: state.offered,
            completed: state.accepted - state.failed - state.shed_after_accept,
            shed: state.shed,
            downgraded: state.downgraded,
            cloud_invocations: state.cloud_invocations,
            cloud_occupancy: state.cloud_occupancy,
            cloud_occupancy_run: state.cloud_occupancy_run,
            cloud_dispatch_saved_s: state.cloud_dispatch_saved_s,
            rerouted: state.rerouted,
            migrated: state.migrated,
            migration_latency_s: state.migration_latency_s,
            per_dev_rerouted: state.per_dev_rerouted,
            per_dev_migrated_in: state.per_dev_migrated_in,
            per_dev_migrated_out: state.per_dev_migrated_out,
            events: state.events,
            stale_closes: state.stale_closes,
            window_flushes: state.window_flushes,
            failed: state.failed,
            faults_injected: state.faults_injected,
            retries: state.retries,
            drained_on_dropout: state.drained_on_dropout,
            per_dev_faults: state.per_dev_faults,
            per_dev_failed: state.per_dev_failed,
        }
    }
}

/// Serve `per_stream` tasks from each stream through the kernel over
/// the given devices. Streams are routed per task by the configured
/// router and screened by the admission policy; jobs accumulate in
/// creation (arrival) order, so a 1-device round-robin run is
/// report-ordered exactly like the legacy single-edge core.
pub fn serve(
    devices: &mut [Coordinator],
    gens: &mut [TaskGen],
    per_stream: usize,
    opts: &FleetOpts,
) -> EngineResult {
    for coord in devices.iter_mut() {
        coord.policy.set_training(false);
    }
    if gens.is_empty() || per_stream == 0 || devices.is_empty() {
        return EngineResult::default();
    }
    let mut core = EngineCore::new(devices, gens, per_stream, opts);
    let mut sink = CollectSink::new();
    core.run_until(f64::INFINITY, &mut sink);
    let mut result = core.into_result();
    result.jobs = sink.into_jobs();
    result
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::configx::Config;
    use crate::coordinator::des::DesOpts;
    use crate::coordinator::fleet::{serve_fleet, Fleet};
    use crate::coordinator::sched::{Event, SchedKind};
    use crate::workload::Arrivals;

    #[test]
    fn event_queue_orders_by_time_then_seq() {
        for kind in [SchedKind::Heap, SchedKind::Calendar] {
            let mut q: Sched<Ev> = Sched::new(kind);
            q.push(2.0, Ev::Arrival { stream: 0 });
            q.push(1.0, Ev::Arrival { stream: 1 });
            q.push(1.0, Ev::Arrival { stream: 2 });
            q.push(0.5, Ev::Arrival { stream: 3 });
            let order: Vec<usize> = std::iter::from_fn(|| {
                q.pop().map(|e| match e.ev {
                    Ev::Arrival { stream } => stream,
                    _ => unreachable!(),
                })
            })
            .collect();
            assert_eq!(order, vec![3, 1, 2, 0], "{kind:?}");
        }
    }

    #[test]
    fn event_queue_never_pops_out_of_time_order_across_devices() {
        // Property: with events scattered across N devices and every
        // event kind, pops come out in nondecreasing time order, and
        // events with equal timestamps come out in insertion (FIFO)
        // order regardless of which device they belong to. Times are
        // quantized to a coarse grid so cross-device ties actually occur.
        use crate::proptest_mini::{check, f64_in, vec_of};
        check(
            "cross-device event time order + FIFO ties",
            0xE6E1,
            300,
            vec_of(f64_in(0.0, 4.0), 1, 64),
            |times| {
                for kind in [SchedKind::Heap, SchedKind::Calendar] {
                    let mut q: Sched<Ev> = Sched::new(kind);
                    let quantized: Vec<f64> =
                        times.iter().map(|t| (t * 4.0).floor() / 4.0).collect();
                    for (i, &t) in quantized.iter().enumerate() {
                        let ev = match i % 4 {
                            0 => Ev::Arrival { stream: i },
                            1 => Ev::EdgeDone { dev: i % 3, job: i },
                            2 => Ev::UplinkDone {
                                dev: i % 3,
                                batch: i,
                                gen: 0,
                            },
                            _ => Ev::CloudDone { batch: i, gen: 0 },
                        };
                        q.push(t, ev);
                    }
                    let mut prev: Option<Event<Ev>> = None;
                    while let Some(ev) = q.pop() {
                        if let Some(p) = prev {
                            if ev.time < p.time {
                                return Err(format!(
                                    "{kind:?}: time went backwards: {} < {}",
                                    ev.time, p.time
                                ));
                            }
                            if ev.time == p.time && ev.seq < p.seq {
                                return Err(format!(
                                    "{kind:?}: FIFO tiebreak violated at t={}: seq {} before {}",
                                    ev.time, p.seq, ev.seq
                                ));
                            }
                        }
                        prev = Some(ev);
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nan_event_time_cannot_reorder_real_events() {
        // total_cmp gives NaN a fixed slot (after +inf in ascending order,
        // i.e. popped last from the min-ordered scheduler) instead of
        // making comparisons against it nondeterministic.
        for kind in [SchedKind::Heap, SchedKind::Calendar] {
            let mut q: Sched<Ev> = Sched::new(kind);
            q.push(f64::NAN, Ev::Arrival { stream: 0 });
            q.push(1.0, Ev::Arrival { stream: 1 });
            q.push(2.0, Ev::Arrival { stream: 2 });
            let order: Vec<usize> = std::iter::from_fn(|| {
                q.pop().map(|e| match e.ev {
                    Ev::Arrival { stream } => stream,
                    _ => unreachable!(),
                })
            })
            .collect();
            assert_eq!(order, vec![1, 2, 0], "{kind:?}");
        }
    }

    #[test]
    fn randomized_fleets_never_violate_engine_invariants() {
        // Property: for random fleet sizes, stream counts, uplink and
        // cloud batch windows, AND random rebalance schedules (tick
        // period / migration threshold / penalty), the unified engine
        // (a) conserves tasks (offered = completed + shed — migration
        // never loses or duplicates a task), (b) keeps every cloud
        // invocation within the size cap, and (c) never pops events out
        // of time order — the in-loop debug_assert on the event clock
        // fires under `cargo test` if it ever regresses.
        use crate::proptest_mini::{check, usize_in, Gen};
        let fleets = ["xavier-nx", "xavier-nx,jetson-nano", "jetson-nano*2,jetson-tx2"];
        check(
            "engine invariants over random fleets",
            0xF1EE7,
            12,
            |r: &mut crate::util::Pcg32| {
                (
                    usize_in(0, 2).sample(r),
                    usize_in(1, 4).sample(r),
                    usize_in(1, 4).sample(r),
                    usize_in(0, 2).sample(r),
                    usize_in(0, 2).sample(r),
                    usize_in(0, 2).sample(r),
                    usize_in(0, 2).sample(r),
                    r.next_u64(),
                )
            },
            |&(fi, streams, per_stream, wi, cwi, ri, ti, seed)| {
                let mut cfg = Config::default();
                cfg.policy = "cloud_only".into();
                cfg.fleet = fleets[fi].into();
                cfg.seed = seed;
                let mut fleet = Fleet::from_config(&cfg).map_err(|e| e.to_string())?;
                let mut gens: Vec<TaskGen> = (0..streams)
                    .map(|s| {
                        TaskGen::new(
                            &cfg.model,
                            fleet.devices[0].env.dataset,
                            Arrivals::Poisson { rate: 40.0 },
                            seed ^ (s as u64),
                        )
                        .map_err(|e| e.to_string())
                    })
                    .collect::<Result<_, _>>()?;
                let windows = [0.0, 0.005, 0.05];
                let rebalance_windows = [0.0, 0.002, 0.02];
                let thresholds = [f64::INFINITY, 0.05, 0.0];
                let opts = FleetOpts {
                    des: DesOpts {
                        batch_window_s: windows[wi],
                        cloud_batch_window_s: windows[cwi],
                        cloud_max_batch: 3,
                        cloud_slots: 2,
                        ..DesOpts::default()
                    },
                    rebalance_window_s: rebalance_windows[ri],
                    migrate_threshold_s: thresholds[ti],
                    migrate_penalty_s: 0.001,
                    ..FleetOpts::default()
                };
                let s = serve_fleet(&mut fleet, &mut gens, per_stream, &opts);
                if s.offered != s.completed + s.shed + s.failed {
                    return Err(format!(
                        "task conservation: offered {} vs completed {} + shed {} + failed {}",
                        s.offered, s.completed, s.shed, s.failed
                    ));
                }
                if s.completed != streams * per_stream {
                    return Err(format!("completed {}", s.completed));
                }
                let occ = s.cloud_occupancy.values();
                if occ.iter().any(|&o| !(1.0..=3.0).contains(&o)) {
                    return Err(format!("occupancy outside [1, cap]: {occ:?}"));
                }
                if occ.iter().map(|&o| o as usize).sum::<usize>() != s.completed {
                    return Err("cloud invocations do not cover all cloud jobs".into());
                }
                let mig_in: usize = s.per_device.iter().map(|d| d.migrated_in).sum();
                let mig_out: usize = s.per_device.iter().map(|d| d.migrated_out).sum();
                if mig_in != s.migrated || mig_out != s.migrated {
                    return Err(format!(
                        "migration ledger: {} in / {} out vs {} migrated",
                        mig_in, mig_out, s.migrated
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn admission_estimate_includes_cloud_detour() {
        // Two states that differ only in cloud-side signals: once the
        // device is known to offload and the shared pool is saturated,
        // the completion estimate must exceed the pure edge backlog.
        let opts = FleetOpts::default();
        let mut st = EngineState::new(1, 4, 8, &opts);
        st.devs[0].residency.push(0.1);
        let edge_only = st.est_completion_s(0).unwrap();
        st.devs[0].xi.push(1.0);
        st.devs[0].uplink_s.push(0.05);
        st.cloud_service.push(0.2);
        st.cloud_in_flight = 8;
        let saturated = st.est_completion_s(0).unwrap();
        assert!((edge_only - 0.1).abs() < 1e-12, "edge backlog {edge_only}");
        // detour = 1.0 * (0.05 + 0.2 + 0.2 * 8 / 4) = 0.65
        assert!(
            (saturated - (0.1 + 0.65)).abs() < 1e-9,
            "estimate {saturated}"
        );
    }

    #[test]
    fn cold_start_estimate_is_none() {
        let st = EngineState::new(2, 4, 8, &FleetOpts::default());
        assert!(st.est_completion_s(0).is_none());
        assert!(st.est_completion_s(1).is_none());
    }

    #[test]
    fn sibling_scan_picks_the_cheapest_feasible_device() {
        // dev0 is the (overloaded) routed device; dev1 and dev2 are
        // feasible with different estimates; dev3 blows the deadline.
        let mut st = EngineState::new(4, 4, 8, &FleetOpts::default());
        st.devs[0].residency.push(1.0);
        st.devs[1].residency.push(0.2);
        st.devs[2].residency.push(0.05);
        st.devs[3].residency.push(0.9);
        // est = residency * (in_system + 1); all queues empty here
        assert_eq!(st.cheapest_feasible_sibling(0, 0.5), Some(2));
        // dev2 out of budget too -> dev1 is next-cheapest
        assert_eq!(st.cheapest_feasible_sibling(0, 0.1), Some(1));
        // nothing feasible -> None (caller sheds/downgrades)
        assert_eq!(st.cheapest_feasible_sibling(0, 0.01), None);
        // the routed device itself is never a candidate: dev2 (est
        // 0.05) is excluded and every sibling blows the 0.06 budget
        assert_eq!(st.cheapest_feasible_sibling(2, 0.06), None);
    }

    #[test]
    fn cold_sibling_counts_as_feasible_with_zero_estimate() {
        let mut st = EngineState::new(3, 4, 8, &FleetOpts::default());
        st.devs[0].residency.push(1.0);
        st.devs[1].residency.push(0.2);
        // dev2 never started a task: est None -> treated as 0, wins
        assert_eq!(st.cheapest_feasible_sibling(0, 0.5), Some(2));
    }

    #[test]
    fn rebalance_moves_tail_of_the_hot_queue_and_charges_the_penalty() {
        let opts = FleetOpts {
            migrate_threshold_s: 0.05,
            migrate_penalty_s: 0.002,
            ..FleetOpts::default()
        };
        let mut st = EngineState::new(2, 8, 8, &opts);
        st.devs[0].residency.push(0.1);
        st.devs[1].residency.push(0.02);
        // six jobs queued on dev0 (jobs carry no reports yet — only the
        // queueing fields matter for the steal), dev1 empty
        for i in 0..6 {
            st.jobs.push(Job {
                task: crate::workload::TaskGen::new(
                    "efficientnet-b0",
                    crate::perfmodel::Dataset::Cifar100,
                    Arrivals::Sequential,
                    i as u64,
                )
                .unwrap()
                .next_task(),
                stream: 0,
                dev: 0,
                arrival_s: 0.0,
                queue_wait_s: 0.0,
                solo_off_s: 0.0,
                cloud_s: 0.0,
                payload_bytes: 0.0,
                downgraded: false,
                rerouted: false,
                migrated: false,
                retries: 0,
                arrival_idx: i,
                report: None,
            });
            st.devs[0].edge_queue.push_back(i);
        }
        st.devs[0].sync_backlog();
        st.devs[0].edge_busy = true;
        st.rebalance(1.0);
        // backlog 0.6 vs 0: each move shifts the projected divergence by
        // 0.1 + 0.02; the half-queue cap (3) binds before the threshold
        assert_eq!(st.migrated, 3);
        assert_eq!(st.per_dev_migrated_out[0], 3);
        assert_eq!(st.per_dev_migrated_in[1], 3);
        assert_eq!(st.devs[0].edge_queue.len(), 3);
        // stolen from the tail, re-targeted, flagged, penalty accounted
        assert!((st.migration_latency_s - 3.0 * 0.002).abs() < 1e-12);
        for id in [5, 4, 3] {
            assert_eq!(st.jobs[id].dev, 1);
            assert!(st.jobs[id].migrated);
            // original arrival untouched: no clock reset on requeue
            assert_eq!(st.jobs[id].arrival_s, 0.0);
        }
        // the in-transit jobs are in neither queue until Migrate fires,
        // but the destination already counts them — a second tick right
        // now would see dev1's backlog at 3 × its residency, not zero
        assert!(st.devs[1].edge_queue.is_empty());
        assert_eq!(st.devs[1].migrating_in, 3);
        assert!((st.edge_backlog_s(1) - 3.0 * 0.02).abs() < 1e-12);
        let expected = 1.0 + opts.migrate_penalty_s;
        let mut times = Vec::new();
        while let Some(e) = st.q.pop() {
            times.push(e.time);
            assert!(matches!(e.ev, Ev::Migrate { dev: 1, .. }));
        }
        assert_eq!(times, vec![expected; 3]);
    }

    #[test]
    fn rebalance_is_inert_with_an_infinite_threshold() {
        let opts = FleetOpts {
            migrate_threshold_s: f64::INFINITY,
            ..FleetOpts::default()
        };
        let mut st = EngineState::new(2, 4, 8, &opts);
        st.devs[0].residency.push(10.0);
        st.rebalance(0.5);
        assert_eq!(st.migrated, 0);
        assert!(st.q.is_empty());
    }

    #[test]
    fn batch_slots_are_recycled_through_the_free_list() {
        let mut st = EngineState::new(1, 4, 8, &FleetOpts::default());
        let a = st.acquire_batch_slot();
        st.batches[a].push(7);
        let members = std::mem::take(&mut st.batches[a]);
        st.release_batch_slot(a, members);
        // the next acquisition reuses the freed slot AND its allocation
        let b = st.acquire_batch_slot();
        assert_eq!(a, b);
        assert!(st.batches[b].is_empty());
        assert!(st.batches[b].capacity() >= 1, "allocation recycled");
        // a second concurrent slot is fresh; the table holds exactly two
        let c = st.acquire_batch_slot();
        assert_ne!(b, c);
        assert_eq!(st.batches.len(), 2);
        // the cloud-side twins behave identically
        let ca = st.acquire_cloud_slot();
        st.cloud_batches[ca].push(1);
        let m = std::mem::take(&mut st.cloud_batches[ca]);
        st.release_cloud_slot(ca, m);
        assert_eq!(st.acquire_cloud_slot(), ca);
    }

    #[test]
    fn job_slots_recycle_across_a_paced_run() {
        // Paced arrivals let earlier tasks retire their slots before
        // later ones are admitted: the job table must stay far smaller
        // than the run while the sink still sees every report.
        let mut cfg = Config::default();
        cfg.policy = "edge_only".into();
        cfg.seed = 11;
        let mut fleet = Fleet::from_config(&cfg).unwrap();
        let mut gens = vec![TaskGen::new(
            &cfg.model,
            fleet.devices[0].env.dataset,
            Arrivals::Poisson { rate: 2.0 },
            77,
        )
        .unwrap()];
        let opts = FleetOpts::default();
        let mut core = EngineCore::new(&mut fleet.devices, &mut gens, 20, &opts);
        let mut sink = CollectSink::new();
        assert!(core.run_until(f64::INFINITY, &mut sink));
        assert_eq!(core.state.accepted, 20);
        assert!(
            core.state.jobs.len() < 20,
            "job table never recycled: {} slots",
            core.state.jobs.len()
        );
        // every slot is back on the free list once the run drains
        assert_eq!(core.state.free_jobs.len(), core.state.jobs.len());
        let jobs = sink.into_jobs();
        assert_eq!(jobs.len(), 20);
        assert!(jobs.iter().all(|j| j.report.is_some()));
    }

    #[test]
    fn epoch_stepped_core_is_bit_exact_with_one_shot_serve() {
        // The sharded runner drives the core in bounded time epochs;
        // stepping run_until through finite horizons must replay the
        // exact event sequence of a single infinite-horizon call.
        let mk = || {
            let mut cfg = Config::default();
            cfg.policy = "cloud_only".into();
            cfg.fleet = "xavier-nx,jetson-nano".into();
            cfg.seed = 23;
            let fleet = Fleet::from_config(&cfg).unwrap();
            let gens: Vec<TaskGen> = (0..3)
                .map(|s| {
                    TaskGen::new(
                        &cfg.model,
                        fleet.devices[0].env.dataset,
                        Arrivals::Poisson { rate: 25.0 },
                        900 + s,
                    )
                    .unwrap()
                })
                .collect();
            (fleet, gens)
        };
        let opts = FleetOpts {
            des: DesOpts {
                batch_window_s: 0.004,
                cloud_batch_window_s: 0.004,
                cloud_slots: 2,
                ..DesOpts::default()
            },
            ..FleetOpts::default()
        };
        let (mut f1, mut g1) = mk();
        let oneshot = serve(&mut f1.devices, &mut g1, 6, &opts);
        let (mut f2, mut g2) = mk();
        let mut core = EngineCore::new(&mut f2.devices, &mut g2, 6, &opts);
        let mut sink = CollectSink::new();
        let mut t = 0.01;
        let mut epochs = 0usize;
        while !core.run_until(t, &mut sink) {
            t += 0.01;
            epochs += 1;
        }
        assert!(epochs > 1, "run never actually spanned multiple epochs");
        let stepped = core.into_result();
        let jobs = sink.into_jobs();
        assert_eq!(oneshot.offered, stepped.offered);
        assert_eq!(oneshot.completed, stepped.completed);
        assert_eq!(oneshot.events, stepped.events);
        assert_eq!(oneshot.jobs.len(), jobs.len());
        for (a, b) in oneshot.jobs.iter().zip(&jobs) {
            let (ra, rb) = (a.report.as_ref().unwrap(), b.report.as_ref().unwrap());
            assert_eq!(ra.e2e_s.to_bits(), rb.e2e_s.to_bits());
            assert_eq!(ra.queue_wait_s.to_bits(), rb.queue_wait_s.to_bits());
            assert_eq!(ra.eti_total_j.to_bits(), rb.eti_total_j.to_bits());
        }
    }

    #[test]
    fn stale_closes_are_counted_and_bounded_by_flushes() {
        // Long windows with tiny size caps make nearly every window
        // cap-flush before its close timer fires, stranding the timer
        // as a tombstone. Every stale close was armed by some window
        // that eventually flushed, so the count is bounded by the flush
        // count — and both counters must agree across schedulers.
        let run = |kind: SchedKind| {
            let mut cfg = Config::default();
            cfg.policy = "cloud_only".into();
            cfg.seed = 99;
            let mut fleet = Fleet::from_config(&cfg).unwrap();
            let mut gens: Vec<TaskGen> = (0..4)
                .map(|s| {
                    TaskGen::new(
                        &cfg.model,
                        fleet.devices[0].env.dataset,
                        Arrivals::Poisson { rate: 60.0 },
                        300 + s as u64,
                    )
                    .unwrap()
                })
                .collect();
            let opts = FleetOpts {
                des: DesOpts {
                    batch_window_s: 0.5,
                    max_batch: 2,
                    cloud_batch_window_s: 0.5,
                    cloud_max_batch: 2,
                    cloud_slots: 2,
                    sched: kind,
                    ..DesOpts::default()
                },
                ..FleetOpts::default()
            };
            serve(&mut fleet.devices, &mut gens, 10, &opts)
        };
        let heap = run(SchedKind::Heap);
        let calendar = run(SchedKind::Calendar);
        for r in [&heap, &calendar] {
            assert!(r.window_flushes > 0, "batched run must flush windows");
            assert!(r.stale_closes > 0, "cap flushes must strand close timers");
            assert!(
                r.stale_closes <= r.window_flushes,
                "stale {} > flushes {}",
                r.stale_closes,
                r.window_flushes
            );
        }
        assert_eq!(heap.stale_closes, calendar.stale_closes);
        assert_eq!(heap.window_flushes, calendar.window_flushes);
        assert_eq!(heap.events, calendar.events);
    }

    #[test]
    fn window_freeze_swaps_allocations_and_bumps_generation() {
        let mut w = BatchWindow::default();
        assert!(w.join(1));
        assert!(!w.join(2));
        let g = w.generation;
        let mut slot = Vec::with_capacity(8);
        w.freeze_into(&mut slot);
        assert_eq!(slot, vec![1, 2]);
        assert_eq!(w.generation, g + 1);
        assert!(w.is_empty());
        assert!(
            w.members.capacity() >= 8,
            "window inherited the slot's allocation"
        );
    }

    #[test]
    fn backlog_accumulator_matches_scan_under_random_mutation() {
        // Property for the O(1) backlog estimate: drive the per-device
        // queues through random enqueue / work-steal / migration-landing
        // sequences and assert after every op that each device's cached
        // accumulator equals the from-scratch product bit-for-bit. The
        // service-start path is covered end-to-end by
        // `randomized_fleets_never_violate_engine_invariants`, which
        // runs the full kernel with the same debug_assert armed.
        use crate::proptest_mini::{check, usize_in, vec_of, Gen};
        let mk_task = |seed: u64| {
            crate::workload::TaskGen::new(
                "efficientnet-b0",
                crate::perfmodel::Dataset::Cifar100,
                Arrivals::Sequential,
                seed,
            )
            .unwrap()
            .next_task()
        };
        check(
            "backlog accumulator == scan",
            0xACC0,
            40,
            |r: &mut crate::util::Pcg32| {
                let devs = usize_in(2, 4).sample(r);
                let ops = vec_of(usize_in(0, 99), 4, 40).sample(r);
                (devs, ops)
            },
            |&(devs, ref ops)| {
                let opts = FleetOpts {
                    migrate_threshold_s: 0.01,
                    migrate_penalty_s: 0.001,
                    ..FleetOpts::default()
                };
                let mut st = EngineState::new(devs, 64, 8, &opts);
                let scan = |st: &EngineState, d: usize| {
                    st.devs[d].residency.get().unwrap_or(0.0)
                        * (st.devs[d].edge_queue.len() + st.devs[d].migrating_in) as f64
                };
                for (step, &op) in ops.iter().enumerate() {
                    let dev = op % devs;
                    match op % 4 {
                        // enqueue a fresh job on `dev` after a residency
                        // sample lands (the test stands in for the
                        // service-start path, so it syncs like it does)
                        0 | 1 => {
                            let id = st.jobs.len();
                            st.jobs.push(Job {
                                task: mk_task(step as u64),
                                stream: 0,
                                dev,
                                arrival_s: 0.0,
                                queue_wait_s: 0.0,
                                solo_off_s: 0.0,
                                cloud_s: 0.0,
                                payload_bytes: 0.0,
                                downgraded: false,
                                rerouted: false,
                                migrated: false,
                                retries: 0,
                                arrival_idx: id,
                                report: None,
                            });
                            st.devs[dev].residency.push(0.01 + op as f64 * 1e-3);
                            st.devs[dev].sync_backlog();
                            st.enqueue_edge(id);
                        }
                        // work-stealing pass across the whole fleet
                        2 => st.rebalance(step as f64),
                        // land one in-transit migration, if any
                        _ => {
                            if let Some(ev) = st.q.pop() {
                                if let Ev::Migrate { dev, job } = ev.ev {
                                    st.devs[dev].migrating_in -= 1;
                                    st.enqueue_edge(job);
                                }
                            }
                        }
                    }
                    for d in 0..devs {
                        let got = st.devs[d].backlog_s;
                        let want = scan(&st, d);
                        if got.to_bits() != want.to_bits() {
                            return Err(format!("dev {d} op {step}: cache {got} vs scan {want}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Chaos test helper: a small cloud-only run (every task rides
    /// edge-extract → uplink → shared pool, so all three fault classes
    /// have work to bite) under the given options. The offered load
    /// saturates a single device, so at any mid-run fault onset the
    /// pipeline is guaranteed (by work conservation, not timing luck)
    /// to hold queued and in-flight work for the fault to bite.
    fn chaos_run(fleet_spec: &str, seed: u64, opts: &FleetOpts) -> EngineResult {
        let mut cfg = Config::default();
        cfg.policy = "cloud_only".into();
        cfg.fleet = fleet_spec.into();
        cfg.seed = seed;
        let mut fleet = Fleet::from_config(&cfg).unwrap();
        let mut gens: Vec<TaskGen> = (0..4)
            .map(|s| {
                TaskGen::new(
                    &cfg.model,
                    fleet.devices[0].env.dataset,
                    Arrivals::Poisson { rate: 60.0 },
                    seed ^ (600 + s),
                )
                .unwrap()
            })
            .collect();
        serve(&mut fleet.devices, &mut gens, 8, opts)
    }

    #[test]
    fn empty_fault_schedule_and_retry_knobs_are_bit_inert() {
        // The compatibility gate at engine level: an empty schedule arms
        // nothing, so the event trace — and every report — is
        // bit-identical to the fault-free kernel, no matter how the
        // retry knobs are tuned (they only matter once a fault kills
        // something).
        use crate::coordinator::{FaultSchedule, RetryPolicy};
        let plain = chaos_run("xavier-nx,jetson-nano", 31, &FleetOpts::default());
        let armed = chaos_run(
            "xavier-nx,jetson-nano",
            31,
            &FleetOpts {
                chaos: FaultSchedule::parse(" ; ").unwrap(),
                retry: RetryPolicy {
                    max_retries: 9,
                    backoff_base_s: 0.5,
                },
                ..FleetOpts::default()
            },
        );
        assert_eq!(plain.events, armed.events, "empty schedule must add no events");
        assert_eq!(armed.faults_injected, 0);
        assert_eq!(armed.retries, 0);
        assert_eq!(armed.failed, 0);
        assert_eq!(plain.completed, armed.completed);
        assert_eq!(plain.jobs.len(), armed.jobs.len());
        for (a, b) in plain.jobs.iter().zip(&armed.jobs) {
            let (ra, rb) = (a.report.as_ref().unwrap(), b.report.as_ref().unwrap());
            assert_eq!(ra.e2e_s.to_bits(), rb.e2e_s.to_bits());
            assert_eq!(ra.eti_total_j.to_bits(), rb.eti_total_j.to_bits());
        }
    }

    #[test]
    fn permanent_dropout_of_the_lone_device_fails_or_sheds_everything_mid_pipeline() {
        // One device, no siblings, radio dead from 100 ms to the end of
        // time: work caught mid-pipeline burns its 1-retry budget into
        // the terminal `failed` state (re-route has nowhere to go),
        // queued work drains into shed, and arrivals while everything is
        // down shed at the door. The engine still drains, conservation
        // still balances, and only completed jobs carry reports.
        use crate::coordinator::{FaultSchedule, RetryPolicy};
        let r = chaos_run(
            "xavier-nx",
            47,
            &FleetOpts {
                reroute: true, // inert: no sibling exists
                chaos: FaultSchedule::parse("down:0@100+60000000").unwrap(),
                retry: RetryPolicy {
                    max_retries: 1,
                    backoff_base_s: 0.005,
                },
                ..FleetOpts::default()
            },
        );
        assert_eq!(r.faults_injected, 1);
        assert_eq!(r.per_dev_faults[0], 1);
        assert!(r.completed > 0, "pre-fault work must finish");
        assert!(
            r.failed > 0,
            "work caught mid-pipeline must exhaust its retry budget"
        );
        assert_eq!(r.per_dev_failed[0], r.failed);
        assert!(
            r.retries >= r.failed,
            "every failure burned at least one retry: {} vs {}",
            r.retries,
            r.failed
        );
        assert!(r.shed > 0, "post-dropout arrivals must shed at the door");
        assert_eq!(
            r.offered,
            r.completed + r.shed + r.failed,
            "conservation: {} vs {} + {} + {}",
            r.offered,
            r.completed,
            r.shed,
            r.failed
        );
        // CollectSink invariant: every ACCEPTED job reached a terminal
        // state (the drain would hang otherwise), and exactly the
        // completed ones carry a report
        assert_eq!(
            r.jobs.iter().filter(|j| j.report.is_some()).count(),
            r.completed
        );
    }

    #[test]
    fn cloud_outage_kills_the_running_invocation_and_retry_budget_is_terminal() {
        // State-level walk through the cloud fault machinery: the onset
        // kills the running invocation (its pending `CloudDone`
        // tombstones via the generation bump), the member enters the
        // backed-off retry path with exponential spacing, the pool
        // reports zero slots while the outage holds, and burning the
        // whole budget lands in the terminal `failed` ledger.
        use crate::coordinator::{FaultSchedule, RetryPolicy};
        let opts = FleetOpts {
            chaos: FaultSchedule::parse("cloud@100+50").unwrap(),
            retry: RetryPolicy {
                max_retries: 2,
                backoff_base_s: 0.01,
            },
            ..FleetOpts::default()
        };
        let mut st = EngineState::new(1, 4, 8, &opts);
        st.jobs.push(Job {
            task: crate::workload::TaskGen::new(
                "efficientnet-b0",
                crate::perfmodel::Dataset::Cifar100,
                Arrivals::Sequential,
                5,
            )
            .unwrap()
            .next_task(),
            stream: 0,
            dev: 0,
            arrival_s: 0.0,
            queue_wait_s: 0.0,
            solo_off_s: 0.0,
            cloud_s: 0.0,
            payload_bytes: 0.0,
            downgraded: false,
            rerouted: false,
            migrated: false,
            retries: 0,
            arrival_idx: 0,
            report: None,
        });
        // one singleton invocation mid-service on the shared pool
        let b = st.acquire_cloud_slot();
        st.cloud_batches[b].push(0);
        st.cloud_running.push(b);
        st.cloud_active = 1;
        st.cloud_in_flight = 1;
        let gen = st.cloud_batch_gen[b];
        let mut sink = CollectSink::new();
        st.apply_fault(&mut [], 0, 0.1, &mut sink);
        assert_eq!(st.faults_injected, 1);
        assert_eq!(st.cloud_batch_gen[b], gen + 1, "pending CloudDone tombstoned");
        assert_eq!(st.cloud_active, 0);
        assert_eq!(st.cloud_in_flight, 0);
        assert_eq!(st.retries, 1);
        assert_eq!(st.jobs[0].retries, 1);
        assert_eq!(
            st.effective_cloud_slots(),
            0,
            "the pool is dark while the outage holds"
        );
        let ev = st.q.pop().unwrap();
        assert!(matches!(ev.ev, Ev::RetryCloud { job: 0 }));
        assert!(
            (ev.time - 0.11).abs() < 1e-12,
            "first retry at now + base backoff, got {}",
            ev.time
        );
        assert!(st.q.is_empty());
        // recovery reopens the pool
        st.clear_fault(0, 0.15);
        assert_eq!(st.cloud_outage_depth, 0);
        assert!(st.effective_cloud_slots() > 0);
        // second kill: attempt 2 still fits the budget, with doubled
        // backoff; the third is terminal
        st.retry_or_fail(0, RetryStage::Cloud, 0.2, &mut sink);
        let ev = st.q.pop().unwrap();
        assert!(matches!(ev.ev, Ev::RetryCloud { job: 0 }));
        assert!(
            (ev.time - 0.22).abs() < 1e-12,
            "second retry doubles the backoff, got {}",
            ev.time
        );
        st.retry_or_fail(0, RetryStage::Cloud, 0.3, &mut sink);
        assert_eq!(st.failed, 1);
        assert_eq!(st.per_dev_failed[0], 1);
        assert_eq!(st.retries, 2, "the terminal attempt schedules nothing");
        assert!(st.q.is_empty());
        assert_eq!(st.free_jobs, vec![0], "the failed job's slot recycles");
        let jobs = sink.into_jobs();
        assert_eq!(jobs.len(), 1);
        assert!(jobs[0].report.is_none(), "failed jobs carry no report");
    }

    #[test]
    fn dropout_with_recovery_completes_everything_via_sibling_reroute() {
        // A bounded dropout on one device of a pair, with re-route on:
        // drained queue work and killed transfers ship through the
        // sibling (or retry after recovery), so the run conserves tasks
        // with zero terminal failures, and the reroute/drain ledgers
        // record the detour.
        use crate::coordinator::{FaultSchedule, RetryPolicy};
        let r = chaos_run(
            "xavier-nx,jetson-nano",
            59,
            &FleetOpts {
                reroute: true,
                chaos: FaultSchedule::parse("down:1@120+300").unwrap(),
                retry: RetryPolicy {
                    max_retries: 3,
                    backoff_base_s: 0.005,
                },
                ..FleetOpts::default()
            },
        );
        assert_eq!(r.faults_injected, 1);
        assert_eq!(r.per_dev_faults[1], 1);
        assert_eq!(
            r.offered,
            r.completed + r.shed + r.failed,
            "conservation: {} vs {} + {} + {}",
            r.offered,
            r.completed,
            r.shed,
            r.failed
        );
        assert_eq!(r.failed, 0, "a sibling always exists for killed work");
        assert!(
            r.retries + r.rerouted + r.drained_on_dropout > 0,
            "the dropout must actually touch in-flight or queued work"
        );
        assert_eq!(
            r.jobs.iter().filter(|j| j.report.is_some()).count(),
            r.completed
        );
    }
}
