//! Deterministic fault injection for the unified DES kernel.
//!
//! A [`FaultSchedule`] is a sorted list of fault windows, parsed from a
//! compact CLI grammar or from a JSON fault-trace file, and injected into
//! the engine as first-class events at exactly the scheduled times. No
//! RNG is involved anywhere in this module: the schedule *is* the fault
//! process, so a fixed schedule replays bit-for-bit (detlint R3 holds
//! trivially), and an empty schedule pushes zero events, leaving every
//! golden trace untouched.
//!
//! Three fault classes:
//!
//! - `down:<dev>@<at_ms>+<dur_ms>` — device dropout. The device stops
//!   accepting work; its queued-but-unstarted tasks drain through the
//!   re-route path (or shed when no sibling is feasible / re-routing is
//!   off) and its uplink-stage work is killed into the retry path. The
//!   device recovers at `at + dur`.
//! - `bw:<dev>@<at_ms>+<dur_ms>*<scale>` — bandwidth collapse. Uplink
//!   transfers started during the window take `1/scale` times longer
//!   (`scale` in `(0, 1]`; `1.0` is a no-op window).
//! - `cloud@<at_ms>+<dur_ms>` — shared cloud-pool outage. Cloud slots
//!   are forced to zero and in-service cloud batches are killed into the
//!   retry path; queued batches wait out the window.
//!
//! Entries are separated by `;`, and `file:<path>` splices in a JSON
//! array of `{"kind", "dev", "at_ms", "dur_ms", "scale"}` objects.
//!
//! Killed work retries under a [`RetryPolicy`]: a bounded attempt budget
//! with deterministic exponential backoff (`base * 2^(attempt-1)`), no
//! jitter. A task that exhausts its budget becomes the terminal outcome
//! `failed` — distinct from `shed` — so the fleet-level conservation
//! invariant stays checkable as `offered == completed + shed + failed`.

use crate::configx::Json;
use anyhow::{anyhow, bail, Context, Result};

/// One fault window. Times are absolute sim seconds; every window is
/// finite (`until_s > at_s`), which is what guarantees a chaos run still
/// drains: retries back off geometrically and devices always recover.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Device `dev` drops out at `at_s` and recovers at `until_s`.
    DeviceDown { dev: usize, at_s: f64, until_s: f64 },
    /// Device `dev`'s uplink rate is multiplied by `scale` in `(0, 1]`
    /// for transfers started inside the window.
    BandwidthCollapse {
        dev: usize,
        at_s: f64,
        until_s: f64,
        scale: f64,
    },
    /// The shared cloud pool is down: slots forced to 0, in-service
    /// batches killed into the retry path.
    CloudOutage { at_s: f64, until_s: f64 },
}

impl Fault {
    pub fn at_s(&self) -> f64 {
        match *self {
            Fault::DeviceDown { at_s, .. }
            | Fault::BandwidthCollapse { at_s, .. }
            | Fault::CloudOutage { at_s, .. } => at_s,
        }
    }

    pub fn until_s(&self) -> f64 {
        match *self {
            Fault::DeviceDown { until_s, .. }
            | Fault::BandwidthCollapse { until_s, .. }
            | Fault::CloudOutage { until_s, .. } => until_s,
        }
    }

    /// The device a fault targets; `None` for pool-wide faults.
    pub fn dev(&self) -> Option<usize> {
        match *self {
            Fault::DeviceDown { dev, .. } | Fault::BandwidthCollapse { dev, .. } => Some(dev),
            Fault::CloudOutage { .. } => None,
        }
    }
}

/// Bounded-retry contract for fault-killed work. Purely deterministic:
/// attempt `k` (1-based) backs off `backoff_base_s * 2^(k-1)` seconds,
/// and attempt `max_retries + 1` does not happen — the task is `failed`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// How many re-enqueues a killed task gets before it is `failed`.
    pub max_retries: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub backoff_base_s: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            backoff_base_s: 0.01,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (1-based): `base * 2^(attempt-1)`.
    /// The shift saturates so absurd budgets cannot overflow.
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        let pow = attempt.saturating_sub(1).min(30);
        self.backoff_base_s * f64::from(1u32 << pow)
    }
}

/// A validated, time-sorted set of fault windows. `Default` is empty,
/// and an empty schedule injects nothing — the engine's chaos arm never
/// arms, so pre-chaos traces replay bit-for-bit.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSchedule {
    faults: Vec<Fault>,
}

impl FaultSchedule {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// Build a schedule directly from validated windows (used by
    /// experiments and tests); sorts by onset like `parse` does.
    pub fn from_faults(mut faults: Vec<Fault>) -> Result<Self> {
        for f in &faults {
            validate_window(f.at_s(), f.until_s())?;
            if let Fault::BandwidthCollapse { scale, .. } = *f {
                validate_scale(scale)?;
            }
        }
        faults.sort_by(|a, b| a.at_s().total_cmp(&b.at_s()));
        Ok(FaultSchedule { faults })
    }

    /// Parse the `;`-separated CLI grammar (see module docs). An empty
    /// or whitespace-only spec is the empty schedule.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut faults = Vec::new();
        for entry in spec.split(';') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            if let Some(path) = entry.strip_prefix("file:") {
                let text = std::fs::read_to_string(path.trim())
                    .map_err(|e| anyhow!("fault trace '{}': {e}", path.trim()))?;
                parse_trace_json(&text, &mut faults)
                    .with_context(|| format!("fault trace '{}'", path.trim()))?;
            } else {
                faults.push(parse_entry(entry)?);
            }
        }
        Self::from_faults(faults)
    }

    /// Reject device indices outside a fleet of `n_dev` devices.
    pub fn validate_for(&self, n_dev: usize) -> Result<()> {
        for f in &self.faults {
            if let Some(dev) = f.dev() {
                if dev >= n_dev {
                    bail!("fault targets device {dev} but the fleet has {n_dev} devices");
                }
            }
        }
        Ok(())
    }

    /// Restrict the schedule to one shard's contiguous device slice
    /// `[dev_base, dev_base + n_dev)`, translating device indices to
    /// shard-local ones. Cloud outages hit the *shared* pool, so they
    /// are replicated into every shard: each shard forces its local
    /// slot allotment to zero, which sums to a global outage, and the
    /// killed in-flight work shows up in the shard's published cloud
    /// signals at the next epoch boundary.
    pub fn partition(&self, dev_base: usize, n_dev: usize) -> Self {
        let faults = self
            .faults
            .iter()
            .filter_map(|f| match *f {
                Fault::DeviceDown { dev, at_s, until_s } => {
                    (dev >= dev_base && dev < dev_base + n_dev).then_some(Fault::DeviceDown {
                        dev: dev - dev_base,
                        at_s,
                        until_s,
                    })
                }
                Fault::BandwidthCollapse {
                    dev,
                    at_s,
                    until_s,
                    scale,
                } => (dev >= dev_base && dev < dev_base + n_dev).then_some(
                    Fault::BandwidthCollapse {
                        dev: dev - dev_base,
                        at_s,
                        until_s,
                        scale,
                    },
                ),
                Fault::CloudOutage { .. } => Some(*f),
            })
            .collect();
        FaultSchedule { faults }
    }
}

fn validate_window(at_s: f64, until_s: f64) -> Result<()> {
    if !at_s.is_finite() || at_s < 0.0 {
        bail!("fault onset must be finite and >= 0, got {at_s}");
    }
    if !until_s.is_finite() || until_s <= at_s {
        bail!("fault window must have finite positive duration (onset {at_s}, end {until_s})");
    }
    Ok(())
}

fn validate_scale(scale: f64) -> Result<()> {
    if !scale.is_finite() || scale <= 0.0 || scale > 1.0 {
        bail!("bandwidth collapse scale must be in (0, 1], got {scale}");
    }
    Ok(())
}

/// `<at_ms>+<dur_ms>` → `(at_s, until_s)`.
fn parse_window(s: &str) -> Result<(f64, f64)> {
    let (at, dur) = s
        .split_once('+')
        .ok_or_else(|| anyhow!("expected <at_ms>+<dur_ms>, got '{s}'"))?;
    let at_ms: f64 = at
        .trim()
        .parse()
        .map_err(|_| anyhow!("bad fault onset '{at}'"))?;
    let dur_ms: f64 = dur
        .trim()
        .parse()
        .map_err(|_| anyhow!("bad fault duration '{dur}'"))?;
    if !dur_ms.is_finite() || dur_ms <= 0.0 {
        bail!("fault duration must be finite and > 0 ms, got '{dur}'");
    }
    let at_s = at_ms / 1e3;
    Ok((at_s, at_s + dur_ms / 1e3))
}

fn parse_dev(s: &str) -> Result<usize> {
    s.trim()
        .parse()
        .map_err(|_| anyhow!("bad fault device index '{s}'"))
}

fn parse_entry(entry: &str) -> Result<Fault> {
    if let Some(rest) = entry.strip_prefix("cloud@") {
        let (at_s, until_s) = parse_window(rest).with_context(|| format!("in '{entry}'"))?;
        return Ok(Fault::CloudOutage { at_s, until_s });
    }
    let (kind, rest) = entry.split_once(':').ok_or_else(|| {
        anyhow!(
            "bad fault '{entry}': expected down:<dev>@<at_ms>+<dur_ms>, \
             bw:<dev>@<at_ms>+<dur_ms>*<scale>, cloud@<at_ms>+<dur_ms>, or file:<path>"
        )
    })?;
    let (dev, window) = rest
        .split_once('@')
        .ok_or_else(|| anyhow!("bad fault '{entry}': missing '@<at_ms>+<dur_ms>'"))?;
    let dev = parse_dev(dev).with_context(|| format!("in '{entry}'"))?;
    match kind.trim() {
        "down" => {
            let (at_s, until_s) = parse_window(window).with_context(|| format!("in '{entry}'"))?;
            Ok(Fault::DeviceDown { dev, at_s, until_s })
        }
        "bw" => {
            let (window, scale) = window
                .split_once('*')
                .ok_or_else(|| anyhow!("bad fault '{entry}': missing '*<scale>' on bw"))?;
            let (at_s, until_s) = parse_window(window).with_context(|| format!("in '{entry}'"))?;
            let scale: f64 = scale
                .trim()
                .parse()
                .map_err(|_| anyhow!("bad bandwidth scale '{scale}'"))?;
            validate_scale(scale).with_context(|| format!("in '{entry}'"))?;
            Ok(Fault::BandwidthCollapse {
                dev,
                at_s,
                until_s,
                scale,
            })
        }
        other => bail!("unknown fault kind '{other}' (valid: down, bw, cloud, file)"),
    }
}

/// JSON fault-trace file: an array of objects, each
/// `{"kind": "down"|"bw"|"cloud", "dev": n, "at_ms": x, "dur_ms": y, "scale": s}`.
fn parse_trace_json(text: &str, out: &mut Vec<Fault>) -> Result<()> {
    let doc = Json::parse(text).map_err(|e| anyhow!("bad JSON: {e}"))?;
    let arr = doc
        .as_arr()
        .ok_or_else(|| anyhow!("fault trace must be a JSON array"))?;
    for (i, obj) in arr.iter().enumerate() {
        let field = |key: &str| -> Result<f64> {
            obj.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("entry {i}: missing numeric '{key}'"))
        };
        let kind = obj
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("entry {i}: missing string 'kind'"))?;
        let at_ms = field("at_ms")?;
        let dur_ms = field("dur_ms")?;
        if !dur_ms.is_finite() || dur_ms <= 0.0 {
            bail!("entry {i}: dur_ms must be finite and > 0, got {dur_ms}");
        }
        let at_s = at_ms / 1e3;
        let until_s = at_s + dur_ms / 1e3;
        let dev = || -> Result<usize> {
            obj.get("dev")
                .and_then(Json::as_f64)
                .filter(|d| d.is_finite() && *d >= 0.0)
                .map(|d| d as usize)
                .ok_or_else(|| anyhow!("entry {i}: missing device index 'dev'"))
        };
        out.push(match kind {
            "down" => Fault::DeviceDown {
                dev: dev()?,
                at_s,
                until_s,
            },
            "bw" => Fault::BandwidthCollapse {
                dev: dev()?,
                at_s,
                until_s,
                scale: field("scale")?,
            },
            "cloud" => Fault::CloudOutage { at_s, until_s },
            other => bail!("entry {i}: unknown kind '{other}' (valid: down, bw, cloud)"),
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;

    #[test]
    fn empty_and_whitespace_specs_are_the_empty_schedule() {
        assert!(FaultSchedule::parse("").unwrap().is_empty());
        assert!(FaultSchedule::parse("  ; ;  ").unwrap().is_empty());
        assert_eq!(FaultSchedule::default(), FaultSchedule::parse("").unwrap());
    }

    #[test]
    fn grammar_parses_all_three_fault_kinds_and_sorts_by_onset() {
        let s = FaultSchedule::parse("cloud@900+100; down:1@200+400; bw:0@50+100*0.25").unwrap();
        assert_eq!(
            s.faults(),
            &[
                Fault::BandwidthCollapse {
                    dev: 0,
                    at_s: 0.05,
                    until_s: 0.05 + 0.1,
                    scale: 0.25
                },
                Fault::DeviceDown {
                    dev: 1,
                    at_s: 0.2,
                    until_s: 0.2 + 0.4
                },
                Fault::CloudOutage {
                    at_s: 0.9,
                    until_s: 0.9 + 0.1
                },
            ]
        );
    }

    #[test]
    fn garbage_specs_are_rejected_with_context() {
        for bad in [
            "down:0",            // no window
            "down:x@1+2",        // bad device
            "down:0@1",          // no duration
            "down:0@1+0",        // zero-length window
            "down:0@1+-5",       // negative duration
            "down:0@NaN+5",      // NaN onset
            "bw:0@1+2",          // missing scale
            "bw:0@1+2*0",        // scale out of range
            "bw:0@1+2*1.5",      // scale out of range
            "bw:0@1+2*NaN",      // NaN scale
            "flood:0@1+2",       // unknown kind
            "cloud@1",           // no duration
            "file:/no/such/f.x", // unreadable file
        ] {
            assert!(FaultSchedule::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn json_trace_files_splice_into_the_schedule() {
        let dir = std::env::temp_dir().join("dvfo_chaos_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        std::fs::write(
            &path,
            r#"[
                {"kind": "down", "dev": 2, "at_ms": 300, "dur_ms": 200},
                {"kind": "bw", "dev": 0, "at_ms": 10, "dur_ms": 20, "scale": 0.5},
                {"kind": "cloud", "at_ms": 100, "dur_ms": 50}
            ]"#,
        )
        .unwrap();
        let s = FaultSchedule::parse(&format!("file:{}; down:0@700+100", path.display())).unwrap();
        assert_eq!(s.faults().len(), 4);
        assert_eq!(
            s.faults()[0],
            Fault::BandwidthCollapse {
                dev: 0,
                at_s: 0.01,
                until_s: 0.01 + 0.02,
                scale: 0.5
            }
        );
        assert_eq!(
            s.faults()[3],
            Fault::DeviceDown {
                dev: 0,
                at_s: 0.7,
                until_s: 0.7 + 0.1
            }
        );

        let garbage = dir.join("garbage.json");
        std::fs::write(&garbage, "not json at all").unwrap();
        assert!(FaultSchedule::parse(&format!("file:{}", garbage.display())).is_err());
        let not_arr = dir.join("not_arr.json");
        std::fs::write(&not_arr, r#"{"kind": "down"}"#).unwrap();
        assert!(FaultSchedule::parse(&format!("file:{}", not_arr.display())).is_err());
    }

    #[test]
    fn validate_for_rejects_out_of_range_devices() {
        let s = FaultSchedule::parse("down:2@100+100").unwrap();
        assert!(s.validate_for(3).is_ok());
        assert!(s.validate_for(2).is_err());
        // Cloud outages are device-free and always in range.
        assert!(FaultSchedule::parse("cloud@0+1")
            .unwrap()
            .validate_for(0)
            .is_ok());
    }

    #[test]
    fn partition_translates_device_faults_and_replicates_cloud_outages() {
        let s =
            FaultSchedule::parse("down:0@100+100; down:2@200+100; bw:3@300+100*0.5; cloud@50+25")
                .unwrap();
        let shard = s.partition(2, 2);
        assert_eq!(
            shard.faults(),
            &[
                Fault::CloudOutage {
                    at_s: 0.05,
                    until_s: 0.05 + 0.025
                },
                Fault::DeviceDown {
                    dev: 0,
                    at_s: 0.2,
                    until_s: 0.2 + 0.1
                },
                Fault::BandwidthCollapse {
                    dev: 1,
                    at_s: 0.3,
                    until_s: 0.3 + 0.1,
                    scale: 0.5
                },
            ]
        );
        // Partitions of the empty schedule stay empty.
        assert!(FaultSchedule::default().partition(0, 4).is_empty());
    }

    #[test]
    fn backoff_doubles_deterministically_and_saturates() {
        let p = RetryPolicy {
            max_retries: 5,
            backoff_base_s: 0.01,
        };
        assert_eq!(p.backoff_s(1), 0.01);
        assert_eq!(p.backoff_s(2), 0.02);
        assert_eq!(p.backoff_s(3), 0.04);
        assert_eq!(p.backoff_s(4), 0.08);
        // Saturation: huge attempt counts stay finite.
        assert!(p.backoff_s(u32::MAX).is_finite());
        let d = RetryPolicy::default();
        assert_eq!(d.max_retries, 3);
        assert_eq!(d.backoff_base_s, 0.01);
    }
}
