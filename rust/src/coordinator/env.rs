//! The edge-cloud execution environment: applies a serving decision to a
//! task and produces the full latency/energy/accuracy/cost report —
//! Eqs. (3)-(13) of the paper over the device/net/perfmodel substrates.

use crate::accuracy::{accuracy_loss_pts, AccuracyInputs, Fusion};
use crate::device::{idle_power_w, DeviceSpec, EnergyMeter, FrequencyController, FreqVector};
use crate::net::Link;
use crate::offload::{payload_bytes, Compression};
use crate::perfmodel::{cloud_compute, compress_time_s, edge_compute, Dataset, ModelProfile};
use crate::workload::Task;

/// Fraction of the DNN body that always runs on the edge (the feature
/// extractor ahead of the split point — paper Fig. 4 ①).
pub const EXTRACTOR_FRAC: f64 = 0.18;

/// A concrete serving decision for one task.
#[derive(Clone, Copy, Debug)]
pub struct Decision {
    pub cpu_lvl: usize,
    pub gpu_lvl: usize,
    pub mem_lvl: usize,
    /// offload proportion ξ ∈ [0,1]
    pub xi: f64,
    pub compression: Compression,
    pub fusion: Fusion,
    /// split guided by SCAM importance (vs arbitrary)
    pub importance_guided: bool,
    /// DVFO drops frequencies during the offload/compression and
    /// cloud-wait phases (paper Fig. 10: phases ② and ③ run at very low
    /// frequency); baselines without per-phase DVFS keep one setting.
    pub phase_scaling: bool,
}

impl Decision {
    pub fn edge_only_max(levels: usize) -> Self {
        Self {
            cpu_lvl: levels - 1,
            gpu_lvl: levels - 1,
            mem_lvl: levels - 1,
            xi: 0.0,
            compression: Compression::None,
            fusion: Fusion::Single,
            importance_guided: true,
            phase_scaling: false,
        }
    }
}

/// Full per-task outcome (Eq. 9 latency breakdown + Eq. 10 energy split).
#[derive(Clone, Debug, Default)]
pub struct TaskReport {
    pub tti_local_s: f64,
    pub tti_comp_s: f64,
    pub tti_off_s: f64,
    pub tti_cloud_s: f64,
    /// policy-inference latency on the critical path (0 when concurrent)
    pub tti_decision_s: f64,
    pub tti_total_s: f64,
    pub eti_compute_j: f64,
    pub eti_offload_j: f64,
    pub eti_total_j: f64,
    /// per-unit dynamic energy [cpu, gpu, mem] of the edge compute phases
    pub eti_per_unit_j: [f64; 3],
    pub cost: f64,
    pub accuracy_pct: f64,
    pub accuracy_loss_pts: f64,
    pub payload_bytes: f64,
    pub freqs: [f64; 3],
    /// per-phase frequency vectors [cpu,gpu,mem] MHz for ① edge compute,
    /// ② compression+offload, ③ cloud wait (Fig. 10)
    pub phase_freqs: [[f64; 3]; 3],
    pub xi: f64,
    pub local_mass: f64,
    pub bandwidth_mbps: f64,
    /// queueing delay before edge service started (set by the
    /// discrete-event serving core; 0 on the synchronous path)
    pub queue_wait_s: f64,
    /// end-to-end latency including queueing/batching delays (set by the
    /// discrete-event serving core; 0 ⇒ interpret as queue_wait+tti_total)
    pub e2e_s: f64,
    /// originating user stream (discrete-event serving core)
    pub stream: usize,
    /// uplink batch size this task's offload shipped in (0 = no offload)
    pub batch_size: usize,
    /// cloud-invocation batch size this task's cloud work ran in
    /// (0 = the task never reached the cloud executor)
    pub cloud_batch_size: usize,
    /// admission re-routed this task to a sibling device before
    /// accepting it (fleet re-route-before-shed)
    pub rerouted: bool,
    /// the rebalancer migrated this task to another device while it was
    /// still queued (its e2e keeps the original arrival time)
    pub migrated: bool,
}

/// The simulated serving environment for one (device, cloud, model,
/// dataset) configuration. Clone-able so the Oracle policy can evaluate
/// candidate decisions without disturbing the live state.
#[derive(Clone)]
pub struct EdgeCloudEnv {
    pub edge: FrequencyController,
    pub cloud: DeviceSpec,
    pub link: Link,
    pub profile: ModelProfile,
    pub dataset: Dataset,
    /// cost weight η (Eq. 4)
    pub eta: f64,
    /// fusion weight λ (paper §5.3)
    pub lambda: f64,
}

impl EdgeCloudEnv {
    pub fn new(
        edge: DeviceSpec,
        cloud: DeviceSpec,
        link: Link,
        profile: ModelProfile,
        dataset: Dataset,
        eta: f64,
        lambda: f64,
    ) -> Self {
        Self {
            edge: FrequencyController::new(edge),
            cloud,
            link,
            profile,
            dataset,
            eta,
            lambda,
        }
    }

    pub fn levels(&self) -> usize {
        self.edge.spec().cpu.levels
    }

    /// Execute one task under `decision`; `decision_overhead_s` is the
    /// policy-inference latency that lands on the critical path (the
    /// thinking-while-moving mechanism drives it to ~0; blocking policies
    /// pay it in full — §5.1).
    pub fn execute(
        &mut self,
        task: &Task,
        decision: &Decision,
        decision_overhead_s: f64,
    ) -> TaskReport {
        let mut rep = TaskReport {
            xi: decision.xi,
            bandwidth_mbps: self.link.mbps(),
            ..Default::default()
        };

        // -- DVFS actuation (transition latency counts on the path)
        let trans_s = self
            .edge
            .set_levels(decision.cpu_lvl, decision.gpu_lvl, decision.mem_lvl)
            .expect("ladder levels are always in range");
        let f = self.edge.current();
        rep.freqs = [f.cpu_mhz, f.gpu_mhz, f.mem_mhz];

        // per-phase frequency plan (Fig. 10): DVFO throttles phases ②/③
        let spec0 = self.edge.spec();
        let fmin = FreqVector {
            cpu_mhz: spec0.cpu.min_mhz,
            gpu_mhz: spec0.gpu.min_mhz,
            mem_mhz: spec0.mem.min_mhz,
        };
        let f2 = if decision.phase_scaling {
            FreqVector {
                cpu_mhz: fmin.cpu_mhz + 0.25 * (f.cpu_mhz - fmin.cpu_mhz),
                gpu_mhz: fmin.gpu_mhz + 0.10 * (f.gpu_mhz - fmin.gpu_mhz),
                mem_mhz: fmin.mem_mhz + 0.40 * (f.mem_mhz - fmin.mem_mhz),
            }
        } else {
            f
        };
        let f3 = if decision.phase_scaling { fmin } else { f };
        rep.phase_freqs = [
            [f.cpu_mhz, f.gpu_mhz, f.mem_mhz],
            [f2.cpu_mhz, f2.gpu_mhz, f2.mem_mhz],
            [f3.cpu_mhz, f3.gpu_mhz, f3.mem_mhz],
        ];

        // -- channel split
        let plan = task.importance.split(decision.xi);
        rep.local_mass = if decision.importance_guided {
            plan.local_mass
        } else {
            // arbitrary split keeps mass ≈ (1-ξ) in expectation
            1.0 - decision.xi
        };

        let spec = self.edge.spec().clone();
        let mut meter = EnergyMeter::new();

        // -- phase ①: edge compute (extractor + local head)
        let local_frac = EXTRACTOR_FRAC + (1.0 - decision.xi) * (1.0 - EXTRACTOR_FRAC);
        let local = edge_compute(&self.profile, self.dataset, &spec, &f, local_frac);
        rep.tti_local_s = local.total_s;
        meter.accumulate(&spec, &f, &local.util, local.total_s);

        // -- phase ②: compression + offload
        if decision.xi > 0.0 {
            rep.payload_bytes =
                payload_bytes(&self.profile, self.dataset, decision.xi, decision.compression);
            if decision.compression.has_compress_phase() {
                rep.tti_comp_s = compress_time_s(rep.payload_bytes * 4.0, &spec, &f2);
                // quantization is a memory-bound pass at phase-② freqs
                meter.accumulate(&spec, &f2, &[0.35, 0.05, 0.85], rep.tti_comp_s);
            }
            rep.tti_off_s = self.link.tx_time_s(rep.payload_bytes);
            rep.eti_offload_j = self.link.tx_energy_j(rep.payload_bytes, spec.radio_w)
                + idle_power_w(&spec) * rep.tti_off_s;

            // -- phase ③: cloud compute (+ fusion, negligible — §5.3)
            let cloud_frac = decision.xi * (1.0 - EXTRACTOR_FRAC) * 1.05;
            let cloud = cloud_compute(&self.profile, self.dataset, &self.cloud, cloud_frac);
            rep.tti_cloud_s = cloud.total_s;
            // edge idles while the cloud computes (paper §4.2 assumption)
            rep.eti_offload_j += idle_power_w(&spec) * rep.tti_cloud_s;
        }

        rep.tti_decision_s = decision_overhead_s;
        rep.tti_total_s = rep.tti_local_s
            + rep.tti_comp_s
            + rep.tti_off_s
            + rep.tti_cloud_s
            + rep.tti_decision_s
            + trans_s;

        rep.eti_compute_j = meter.total_j();
        rep.eti_per_unit_j = meter.per_unit_j();
        rep.eti_total_j = rep.eti_compute_j + rep.eti_offload_j;

        // -- accuracy model
        let acc_in = AccuracyInputs {
            base_acc: self.profile.base_acc(self.dataset),
            local_mass: rep.local_mass,
            xi: decision.xi,
            importance_guided: decision.importance_guided,
            compression: decision.compression,
            fusion: decision.fusion,
            lambda: self.lambda,
        };
        rep.accuracy_loss_pts = accuracy_loss_pts(&acc_in);
        rep.accuracy_pct = (acc_in.base_acc - rep.accuracy_loss_pts).max(0.0);

        // -- cost metric Eq. (4)
        rep.cost = self.eta * rep.eti_total_j
            + (1.0 - self.eta) * spec.max_power_w * rep.tti_total_s;

        // advance the world clock
        self.link.advance(rep.tti_total_s);
        rep
    }

    /// The frequency vector at a set of ladder levels (helper for
    /// benches/oracles).
    pub fn freqs_at(&self, cpu: usize, gpu: usize, mem: usize) -> FreqVector {
        let s = self.edge.spec();
        FreqVector {
            cpu_mhz: s.cpu.freq_at(cpu),
            gpu_mhz: s.gpu.freq_at(gpu),
            mem_mhz: s.mem.freq_at(mem),
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::device::spec::find_device;
    use crate::net::Bandwidth;
    use crate::perfmodel::find_model;
    use crate::workload::{Arrivals, TaskGen};

    fn env(eta: f64) -> EdgeCloudEnv {
        EdgeCloudEnv::new(
            find_device("xavier-nx").unwrap(),
            find_device("rtx3080").unwrap(),
            Link::new(Bandwidth::Static { mbps: 5.0 }),
            find_model("efficientnet-b0").unwrap(),
            Dataset::Cifar100,
            eta,
            0.5,
        )
    }

    fn task(seed: u64) -> Task {
        TaskGen::new(
            "efficientnet-b0",
            Dataset::Cifar100,
            Arrivals::Sequential,
            seed,
        )
        .unwrap()
        .next_task()
    }

    fn dvfo_decision(xi: f64, lvl: usize) -> Decision {
        Decision {
            cpu_lvl: lvl,
            gpu_lvl: lvl,
            mem_lvl: lvl,
            xi,
            compression: Compression::Int8,
            fusion: if xi > 0.0 { Fusion::WeightedSum } else { Fusion::Single },
            importance_guided: true,
            phase_scaling: true,
        }
    }

    #[test]
    fn report_is_internally_consistent() {
        let mut e = env(0.5);
        let r = e.execute(&task(1), &dvfo_decision(0.5, 9), 0.0);
        let sum = r.tti_local_s + r.tti_comp_s + r.tti_off_s + r.tti_cloud_s;
        assert!((r.tti_total_s - sum).abs() < 1e-3, "{r:?}");
        assert!((r.eti_total_j - r.eti_compute_j - r.eti_offload_j).abs() < 1e-12);
        assert!(r.cost > 0.0 && r.accuracy_pct > 80.0);
        assert!(r.payload_bytes > 0.0);
    }

    #[test]
    fn edge_only_has_no_network_phases() {
        let mut e = env(0.5);
        let r = e.execute(&task(2), &dvfo_decision(0.0, 9), 0.0);
        assert_eq!(r.tti_off_s, 0.0);
        assert_eq!(r.tti_cloud_s, 0.0);
        assert_eq!(r.payload_bytes, 0.0);
        assert_eq!(r.eti_offload_j, 0.0);
    }

    #[test]
    fn offloading_reduces_edge_latency_at_good_bandwidth() {
        // collaborative inference beats edge-only when the link is decent
        // (paper Fig. 8; the win grows with bandwidth, Fig. 11).
        let mut e = env(0.5);
        e.link = Link::new(Bandwidth::Static { mbps: 8.0 });
        let edge_only = e.execute(&task(3), &dvfo_decision(0.0, 9), 0.0);
        let mut e2 = env(0.5);
        e2.link = Link::new(Bandwidth::Static { mbps: 8.0 });
        let collab = e2.execute(&task(3), &dvfo_decision(1.0, 9), 0.0);
        assert!(
            collab.tti_total_s < edge_only.tti_total_s,
            "collab {} vs edge {}",
            collab.tti_total_s,
            edge_only.tti_total_s
        );
    }

    #[test]
    fn mid_frequency_saves_energy_costs_latency() {
        // the paper's core DVFS observation: max frequency wastes energy
        // (V² superlinearity) while backing off moderately barely hurts
        // latency — but *too low* frequency also wastes energy because
        // static power integrates over the stretched runtime. The
        // optimum is interior, which is exactly what the DQN searches.
        let mut hi = env(0.5);
        let r_hi = hi.execute(&task(4), &dvfo_decision(0.0, 9), 0.0);
        let mut mid = env(0.5);
        let r_mid = mid.execute(&task(4), &dvfo_decision(0.0, 6), 0.0);
        assert!(r_mid.tti_total_s > r_hi.tti_total_s);
        assert!(
            r_mid.eti_total_j < r_hi.eti_total_j,
            "mid {} hi {}",
            r_mid.eti_total_j,
            r_hi.eti_total_j
        );
        // and the floor is NOT optimal: energy turns back up
        let mut lo = env(0.5);
        let r_lo = lo.execute(&task(4), &dvfo_decision(0.0, 0), 0.0);
        assert!(
            r_lo.eti_total_j > r_mid.eti_total_j,
            "lo {} mid {}",
            r_lo.eti_total_j,
            r_mid.eti_total_j
        );
    }

    #[test]
    fn eta_moves_cost_weighting() {
        // η=0: cost is pure latency-power product; η=1: pure energy.
        let mut e0 = env(0.0);
        let mut e1 = env(1.0);
        let t = task(5);
        let d = dvfo_decision(0.4, 8);
        let r0 = e0.execute(&t, &d, 0.0);
        let r1 = e1.execute(&t, &d, 0.0);
        let spec = find_device("xavier-nx").unwrap();
        assert!((r0.cost - spec.max_power_w * r0.tti_total_s).abs() < 1e-9);
        assert!((r1.cost - r1.eti_total_j).abs() < 1e-9);
    }

    #[test]
    fn decision_overhead_lands_on_critical_path() {
        let mut a = env(0.5);
        let mut b = env(0.5);
        let t = task(6);
        let d = dvfo_decision(0.5, 9);
        let ra = a.execute(&t, &d, 0.0);
        let rb = b.execute(&t, &d, 0.010);
        assert!((rb.tti_total_s - ra.tti_total_s - 0.010).abs() < 1e-9);
    }

    #[test]
    fn uncompressed_offload_pays_more_transmission() {
        let mut a = env(0.5);
        let mut b = env(0.5);
        let t = task(7);
        let mut d_raw = dvfo_decision(0.6, 9);
        d_raw.compression = Compression::None;
        let r_int8 = a.execute(&t, &dvfo_decision(0.6, 9), 0.0);
        let r_raw = b.execute(&t, &d_raw, 0.0);
        assert!(r_raw.tti_off_s > 2.8 * r_int8.tti_off_s);
        // but int8 pays a (small) compression phase
        assert!(r_int8.tti_comp_s > 0.0 && r_raw.tti_comp_s == 0.0);
    }

    #[test]
    fn guided_split_retains_more_mass() {
        let mut a = env(0.5);
        let mut b = env(0.5);
        let t = task(8);
        let mut blind = dvfo_decision(0.6, 9);
        blind.importance_guided = false;
        let rg = a.execute(&t, &dvfo_decision(0.6, 9), 0.0);
        let rb = b.execute(&t, &blind, 0.0);
        assert!(rg.local_mass > rb.local_mass);
        assert!(rg.accuracy_pct > rb.accuracy_pct);
    }
}
