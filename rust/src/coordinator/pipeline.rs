//! The real-artifact serving pipeline: leader (edge) and cloud worker
//! threads executing the AOT PJRT artifacts, connected by channels that
//! model the offload wire. This is the path the end-to-end example runs —
//! real numerics, real wall-clock, Python nowhere in sight.
//!
//! Each worker owns its *own* PJRT client and compiled artifacts (the xla
//! handles are not Send — and the edge and cloud are separate machines in
//! the real deployment, so separate clients is the honest topology).
//!
//! Edge thread:  extractor → SCAM importance → split → local_head ─┐
//!                                 │ quantized payload              ├→ fusion
//! Cloud thread:                   └→ offload_prep → remote_head ───┘

// detlint: allow-file(R3, times real PJRT artifact execution on the wall clock, not sim time)

use crate::runtime::Engine;
use crate::scam::ImportanceDist;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::Instant;

/// One request to the real pipeline.
#[derive(Clone, Debug)]
pub struct PipelineRequest {
    pub id: u64,
    /// flattened image (manifest img_shape)
    pub image: Vec<f32>,
    pub label: Option<u32>,
    /// offload proportion ξ
    pub xi: f64,
    pub lambda: f32,
}

/// Outcome of one real request.
#[derive(Clone, Debug)]
pub struct PipelineResponse {
    pub id: u64,
    pub fused_logits: Vec<f32>,
    pub predicted: usize,
    pub correct: Option<bool>,
    pub importance: Vec<f64>,
    pub local_channels: usize,
    /// wall-clock per phase (seconds)
    pub t_extract_s: f64,
    pub t_local_s: f64,
    pub t_offload_prep_s: f64,
    pub t_remote_s: f64,
    pub t_fusion_s: f64,
    pub t_total_s: f64,
    /// offloaded payload size in bytes (int8 wire format)
    pub payload_bytes: usize,
}

/// What travels edge → cloud: channel mask + feature maps. The artifacts
/// quantize inside `offload_prep`, so the accounted payload is the int8
/// wire size even though the in-process channel carries f32.
struct OffloadMsg {
    id: u64,
    features: Vec<f32>,
    inv_mask: Vec<f32>,
}

struct RemoteResult {
    id: u64,
    remote_logits: Vec<f32>,
    t_offload_prep_s: f64,
    t_remote_s: f64,
}

const EDGE_ARTIFACTS: &[&str] = &["extractor", "local_head", "fusion", "dqn_q"];
const CLOUD_ARTIFACTS: &[&str] = &["offload_prep", "remote_head"];

/// The two-worker pipeline. The cloud worker (own PJRT client, own
/// compiled artifacts) is spawned ONCE at load and reused across serve()
/// calls — re-compiling it per batch cost ~140 ms of cold latency
/// (EXPERIMENTS.md §Perf).
pub struct Pipeline {
    edge: Engine,
    to_cloud: mpsc::Sender<OffloadMsg>,
    from_cloud: mpsc::Receiver<RemoteResult>,
    _cloud: std::thread::JoinHandle<Result<()>>,
}

impl Pipeline {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let dir: PathBuf = artifacts_dir.to_path_buf();
        let (to_cloud, cloud_rx) = mpsc::channel::<OffloadMsg>();
        let (to_edge, from_cloud) = mpsc::channel::<RemoteResult>();
        // ---- persistent cloud worker thread (own PJRT client)
        let cloud = std::thread::Builder::new()
            .name("cloud-worker".into())
            .spawn(move || -> Result<()> {
                let engine = Engine::load_filtered(&dir, Some(CLOUD_ARTIFACTS))
                    .context("loading cloud artifacts")?;
                for msg in cloud_rx {
                    let t0 = Instant::now();
                    let dq = engine
                        .execute_f32("offload_prep", &[&msg.features, &msg.inv_mask])?
                        .remove(0);
                    let t1 = Instant::now();
                    let remote_logits = engine
                        .execute_f32("remote_head", &[&dq, &msg.inv_mask])?
                        .remove(0);
                    let t2 = Instant::now();
                    to_edge
                        .send(RemoteResult {
                            id: msg.id,
                            remote_logits,
                            t_offload_prep_s: (t1 - t0).as_secs_f64(),
                            t_remote_s: (t2 - t1).as_secs_f64(),
                        })
                        .ok();
                }
                Ok(())
            })
            .context("spawning cloud worker")?;
        Ok(Self {
            edge: Engine::load_filtered(artifacts_dir, Some(EDGE_ARTIFACTS))
                .context("loading edge artifacts")?,
            to_cloud,
            from_cloud,
            _cloud: cloud,
        })
    }

    /// The edge-side engine (for probes and the DQN artifact).
    pub fn engine(&self) -> &Engine {
        &self.edge
    }

    /// Warm the PJRT executables on both sides (first execution per
    /// executable pays one-time initialization).
    pub fn warmup(&self) -> Result<()> {
        let m = &self.edge.manifest;
        let img = vec![0.1f32; m.img_shape.iter().product()];
        let reqs = vec![PipelineRequest {
            id: u64::MAX,
            image: img,
            label: None,
            xi: 0.5,
            lambda: 0.5,
        }];
        self.serve(reqs)?;
        Ok(())
    }

    /// Serve a batch of requests through the edge+cloud worker pair.
    pub fn serve(&self, requests: Vec<PipelineRequest>) -> Result<Vec<PipelineResponse>> {
        let to_cloud = &self.to_cloud;
        let edge_rx = &self.from_cloud;

        // ---- edge (leader) loop
        let m = &self.edge.manifest;
        let channels = m.feat_channels;
        let mut responses = Vec::with_capacity(requests.len());
        for req in requests {
            let t_start = Instant::now();
            // ① extractor + SCAM
            let outs = self.edge.execute_f32("extractor", &[&req.image])?;
            let t_extract = Instant::now();
            let features = outs[0].clone();
            let importance: Vec<f64> = outs[3].iter().map(|&x| x as f64).collect();
            let dist = ImportanceDist::from_weights(&importance);
            let plan = dist.split(req.xi);
            let mask = plan.local_mask(channels);
            let inv_mask: Vec<f32> = mask.iter().map(|&x| 1.0 - x).collect();

            // ship the secondary-importance features to the cloud worker
            // (concurrent with the local head — execution-level overlap)
            let offload_values = (features.len() / channels) * plan.offload.len();
            let payload_bytes = if plan.offload.is_empty() {
                0
            } else {
                offload_values + 64 // int8 values + scale/shape header
            };
            if !plan.offload.is_empty() {
                to_cloud
                    .send(OffloadMsg {
                        id: req.id,
                        features: features.clone(),
                        inv_mask: inv_mask.clone(),
                    })
                    .ok();
            }

            // ② local head on primary-importance channels
            let local_logits = self
                .edge
                .execute_f32("local_head", &[&features, &mask])?
                .remove(0);
            let t_local = Instant::now();

            // ③ fuse with the remote result (or go local-only)
            let (remote_logits, t_prep, t_remote) = if plan.offload.is_empty() {
                (vec![0.0; local_logits.len()], 0.0, 0.0)
            } else {
                let r = edge_rx.recv().context("cloud worker hung up")?;
                debug_assert_eq!(r.id, req.id);
                (r.remote_logits, r.t_offload_prep_s, r.t_remote_s)
            };
            let lam = if plan.offload.is_empty() { 1.0 } else { req.lambda };
            let lam_arr = [lam];
            let fused = self
                .edge
                .execute_f32("fusion", &[&local_logits, &remote_logits, &lam_arr])?
                .remove(0);
            let t_end = Instant::now();

            // total_cmp never panics on NaN, but a NaN logit would win
            // the argmax — keep the fault loud where it's cheap
            debug_assert!(
                fused.iter().all(|x| !x.is_nan()),
                "NaN in fused logits"
            );
            let predicted = fused
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            responses.push(PipelineResponse {
                id: req.id,
                predicted,
                correct: req.label.map(|l| l as usize == predicted),
                importance,
                local_channels: plan.local.len(),
                t_extract_s: (t_extract - t_start).as_secs_f64(),
                t_local_s: (t_local - t_extract).as_secs_f64(),
                t_offload_prep_s: t_prep,
                t_remote_s: t_remote,
                t_fusion_s: ((t_end - t_local).as_secs_f64() - t_prep - t_remote).max(0.0),
                t_total_s: (t_end - t_start).as_secs_f64(),
                payload_bytes,
                fused_logits: fused,
            });
        }
        Ok(responses)
    }
}

// Integration tests for the real pipeline live in
// rust/tests/runtime_parity.rs (they need built artifacts).

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = PipelineRequest {
            id: 1,
            image: vec![0.0; 3 * 32 * 32],
            label: Some(3),
            xi: 0.5,
            lambda: 0.5,
        };
        assert_eq!(r.image.len(), 3072);
    }
}
