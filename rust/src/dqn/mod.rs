//! From-scratch DQN stack (paper §5.1): tensor ops, MLP with Adam,
//! prioritized replay, and the agent with the thinking-while-moving
//! concurrent backup (Eq. 15). PyTorch substitute per DESIGN.md
//! §Substitutions — training is offline in the paper too, so the rust
//! trainer runs inside the simulator before deployment.
pub mod agent;
pub mod mlp;
pub mod replay;
pub mod tensor;

pub use agent::{ActionSpace, DqnAgent, DqnConfig};
pub use mlp::{Adam, InferScratch, Mlp};
pub use replay::{ReplayBuffer, SumTree, Transition};
pub use tensor::Tensor2;
