//! From-scratch DQN stack (paper §5.1): tensor ops, packed GEMM
//! kernels, MLP with Adam, prioritized replay, the agent with the
//! thinking-while-moving concurrent backup (Eq. 15), and a background
//! learner that takes gradient steps off the decide path. PyTorch
//! substitute per DESIGN.md §Substitutions — training is offline in the
//! paper too, so the rust trainer runs inside the simulator before
//! deployment.
pub mod agent;
pub mod gemm;
pub mod learner;
pub mod mlp;
pub mod replay;
pub mod tensor;

pub use agent::{ActionSpace, DqnAgent, DqnConfig};
pub use learner::{BgLearner, LearnerMode, LearnerOpts};
pub use mlp::{Adam, BatchScratch, InferScratch, Mlp};
pub use replay::{ReplayBuffer, SumTree, Transition};
pub use tensor::Tensor2;
