//! Prioritized experience replay (paper §6.1 trains DQN "with the
//! prioritized experience replay"): a sum-tree over TD-error priorities
//! with proportional sampling and importance-sampling weights.

use crate::util::Pcg32;

/// Binary-indexed sum tree over leaf priorities.
#[derive(Clone, Debug)]
pub struct SumTree {
    cap: usize,
    tree: Vec<f64>,
}

impl SumTree {
    /// Capacity is rounded up to the next power of two: `find`'s
    /// `while i < cap` descent assumes a perfect binary tree (every
    /// internal node has two children at `2i`/`2i+1`), which only holds
    /// for power-of-two leaf counts — a raw cap like 50_000 would
    /// mis-index leaves. The extra tail leaves stay at priority 0 and
    /// are never returned for in-range targets (a descent only enters a
    /// subtree with positive mass).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        let cap = cap.next_power_of_two();
        Self {
            cap,
            tree: vec![0.0; 2 * cap],
        }
    }

    pub fn total(&self) -> f64 {
        self.tree[1]
    }

    pub fn set(&mut self, idx: usize, p: f64) {
        assert!(idx < self.cap);
        let mut i = idx + self.cap;
        let delta = p - self.tree[i];
        while i >= 1 {
            self.tree[i] += delta;
            i /= 2;
        }
    }

    pub fn get(&self, idx: usize) -> f64 {
        self.tree[idx + self.cap]
    }

    /// Find the leaf whose prefix-sum interval contains `target` ∈
    /// [0, total).
    pub fn find(&self, target: f64) -> usize {
        let mut t = target.clamp(0.0, self.total().max(0.0));
        let mut i = 1usize;
        while i < self.cap {
            let left = 2 * i;
            if t < self.tree[left] {
                i = left;
            } else {
                t -= self.tree[left];
                i = left + 1;
            }
        }
        (i - self.cap).min(self.cap - 1)
    }
}

/// One stored transition. `action` is the per-factor index vector (one
/// index per action group, see agent.rs), `gamma_pow` is the fractional
/// discount exponent t_AS/H of the thinking-while-moving backup (1.0 in
/// the blocking formulation).
#[derive(Clone, Debug)]
pub struct Transition {
    pub state: Vec<f32>,
    pub action: Vec<usize>,
    pub reward: f64,
    pub next_state: Vec<f32>,
    pub done: bool,
    pub gamma_pow: f64,
}

/// Ring-structured PER buffer.
pub struct ReplayBuffer {
    cap: usize,
    data: Vec<Transition>,
    next: usize,
    tree: SumTree,
    max_priority: f64,
    alpha: f64,
    pub beta: f64,
}

impl ReplayBuffer {
    pub fn new(cap: usize) -> Self {
        Self {
            cap,
            data: Vec::with_capacity(cap.min(4096)),
            next: 0,
            tree: SumTree::new(cap),
            max_priority: 1.0,
            alpha: 0.6,
            beta: 0.4,
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Insert with max priority (new transitions get sampled soon).
    pub fn push(&mut self, t: Transition) {
        let p = self.max_priority.powf(self.alpha);
        if self.data.len() < self.cap {
            self.data.push(t);
            self.tree.set(self.data.len() - 1, p);
        } else {
            self.data[self.next] = t;
            self.tree.set(self.next, p);
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Sample a batch: returns (indices, importance weights).
    pub fn sample(
        &self,
        batch: usize,
        rng: &mut Pcg32,
    ) -> (Vec<usize>, Vec<f64>) {
        assert!(!self.is_empty());
        let total = self.tree.total();
        let n = self.data.len();
        let mut idxs = Vec::with_capacity(batch);
        let mut weights = Vec::with_capacity(batch);
        let seg = total / batch as f64;
        let mut max_w = 0.0f64;
        for b in 0..batch {
            let target = seg * b as f64 + rng.next_f64() * seg;
            let idx = self.tree.find(target).min(n - 1);
            let p = (self.tree.get(idx) / total).max(1e-12);
            let w = (n as f64 * p).powf(-self.beta);
            max_w = max_w.max(w);
            idxs.push(idx);
            weights.push(w);
        }
        for w in &mut weights {
            *w /= max_w;
        }
        (idxs, weights)
    }

    pub fn get(&self, idx: usize) -> &Transition {
        &self.data[idx]
    }

    /// Update priorities after a learning step.
    pub fn update_priorities(&mut self, idxs: &[usize], td_errors: &[f64]) {
        for (&i, &td) in idxs.iter().zip(td_errors.iter()) {
            let p = td.abs() + 1e-3;
            self.max_priority = self.max_priority.max(p);
            self.tree.set(i, p.powf(self.alpha));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_mini as pt;

    fn t(reward: f64) -> Transition {
        Transition {
            state: vec![0.0; 4],
            action: vec![0],
            reward,
            next_state: vec![0.0; 4],
            done: false,
            gamma_pow: 1.0,
        }
    }

    #[test]
    fn sumtree_total_tracks_sets() {
        let mut st = SumTree::new(8);
        st.set(0, 1.0);
        st.set(3, 2.0);
        st.set(7, 0.5);
        assert!((st.total() - 3.5).abs() < 1e-12);
        st.set(3, 0.0);
        assert!((st.total() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn sumtree_find_respects_intervals() {
        let mut st = SumTree::new(4);
        st.set(0, 1.0);
        st.set(1, 2.0);
        st.set(2, 3.0);
        st.set(3, 4.0);
        assert_eq!(st.find(0.5), 0);
        assert_eq!(st.find(1.5), 1);
        assert_eq!(st.find(3.5), 2);
        assert_eq!(st.find(9.9), 3);
    }

    #[test]
    fn sumtree_find_property() {
        // prefix-sum inversion: find(x) == the index whose cumulative
        // interval contains x, for random priority vectors.
        pt::check(
            "sumtree find",
            11,
            200,
            pt::vec_of(pt::f64_in(0.0, 5.0), 1, 32),
            |ps| {
                // constructor rounds to the next power of two itself
                let mut st = SumTree::new(ps.len());
                for (i, &p) in ps.iter().enumerate() {
                    st.set(i, p);
                }
                let total: f64 = ps.iter().sum();
                if total <= 0.0 {
                    return Ok(());
                }
                let mut rng = Pcg32::seeded(99);
                for _ in 0..16 {
                    let x = rng.next_f64() * total * 0.999;
                    let idx = st.find(x);
                    let mut acc = 0.0;
                    let mut want = ps.len() - 1;
                    for (i, &p) in ps.iter().enumerate() {
                        if x < acc + p {
                            want = i;
                            break;
                        }
                        acc += p;
                    }
                    if idx != want {
                        return Err(format!("find({x})={idx}, want {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn sumtree_non_power_of_two_capacity_rounds_up() {
        // regression: before the constructor rounded up, a cap like 50
        // broke the perfect-binary-tree assumption in `find` and leaves
        // were silently mis-indexed
        let mut st = SumTree::new(50);
        for i in 0..50 {
            st.set(i, 1.0);
        }
        assert!((st.total() - 50.0).abs() < 1e-12);
        for i in 0..50 {
            assert_eq!(st.find(i as f64 + 0.5), i, "unit-priority leaf {i}");
        }
    }

    #[test]
    fn buffer_with_non_power_of_two_cap_samples_correctly() {
        // regression companion: a 50-cap buffer (rounded to 64 leaves
        // internally) must still concentrate samples on the high-
        // priority index, and never return an out-of-range index
        let mut rb = ReplayBuffer::new(50);
        for i in 0..50 {
            rb.push(t(i as f64));
        }
        let idxs: Vec<usize> = (0..50).collect();
        let mut tds = vec![0.001; 50];
        tds[37] = 100.0;
        rb.update_priorities(&idxs, &tds);
        let mut rng = Pcg32::seeded(21);
        let mut hits = 0;
        for _ in 0..100 {
            let (is, _) = rb.sample(4, &mut rng);
            assert!(is.iter().all(|&i| i < 50), "index out of range: {is:?}");
            hits += is.iter().filter(|&&i| i == 37).count();
        }
        // p37 holds ~95% of the total mass after the α=0.6 power law
        assert!(hits > 330, "index 37 sampled {hits}/400 times");
    }

    #[test]
    fn buffer_wraps_at_capacity() {
        let mut rb = ReplayBuffer::new(4);
        for i in 0..10 {
            rb.push(t(i as f64));
        }
        assert_eq!(rb.len(), 4);
        let rewards: Vec<f64> = (0..4).map(|i| rb.get(i).reward).collect();
        // slots hold the last 4 pushes (6..10) in ring order
        let mut sorted = rewards.clone();
        sorted.sort_by(f64::total_cmp);
        assert_eq!(sorted, vec![6.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    fn high_priority_sampled_more() {
        let mut rb = ReplayBuffer::new(16);
        for i in 0..16 {
            rb.push(t(i as f64));
        }
        // all start at max priority; depress all but index 5
        let idxs: Vec<usize> = (0..16).collect();
        let mut tds = vec![0.001; 16];
        tds[5] = 10.0;
        rb.update_priorities(&idxs, &tds);
        let mut rng = Pcg32::seeded(7);
        let mut hits = 0;
        for _ in 0..200 {
            let (is, _) = rb.sample(4, &mut rng);
            hits += is.iter().filter(|&&i| i == 5).count();
        }
        assert!(hits > 300, "index 5 sampled {hits}/800 times");
    }

    #[test]
    fn importance_weights_normalized() {
        let mut rb = ReplayBuffer::new(32);
        for i in 0..32 {
            rb.push(t(i as f64));
        }
        let mut rng = Pcg32::seeded(3);
        let (_, ws) = rb.sample(8, &mut rng);
        assert!(ws.iter().all(|&w| w > 0.0 && w <= 1.0 + 1e-12));
        assert!(ws.iter().any(|&w| (w - 1.0).abs() < 1e-9));
    }
}
