//! §Perf — background learner: gradient steps off the decide path.
//!
//! The paper's "thinking-while-moving" mechanism (§5.1) is concurrent
//! action selection — the fractional `gamma_pow` discount models the
//! staleness, but until now every `Policy::feedback()` still *blocked*
//! on a full inline gradient step. `BgLearner` moves the
//! remember+learn work onto a dedicated thread and leaves the decide
//! path with one queue push and an occasional snapshot adoption.
//!
//! Determinism contract (mirrors the shard-engine publish→barrier→adopt
//! idiom):
//!
//! * The actor sends every transition over a **bounded** channel
//!   (backpressure, never loss) and, every `publish_every`-th push,
//!   sends a `Publish` marker and **blocks** until the snapshot comes
//!   back. The worker drains messages FIFO, so the adopted weights are
//!   exactly `f(all transitions pushed so far)` — a fixed cadence is
//!   bit-reproducible run-to-run regardless of thread scheduling.
//! * Snapshots are double-buffered: two `Mlp`s cycle between actor and
//!   worker over dedicated channels, so steady-state publication
//!   allocates nothing (`Mlp::copy_from` reuses the buffers).
//! * `finish()` hangs up the queue, which makes the worker drain every
//!   queued transition before returning the agent — the final weights
//!   are a deterministic function of the full transition sequence.
//!
//! The actor's exploration RNG is its own `Pcg32` stream, decoupled
//! from the agent's replay-sampling stream, so bg mode is *internally*
//! deterministic but not bit-identical to inline mode (inline keeps the
//! historical single-stream behavior exactly — `--learner inline`
//! changes nothing).

use super::agent::{ActionSpace, DqnAgent};
use super::mlp::{InferScratch, Mlp};
use super::replay::Transition;
use crate::util::sync::{adopt_snapshot, take_publish_buf, BoundedQueue};
use crate::util::Pcg32;
use anyhow::{bail, Result};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Where gradient steps run relative to the decide path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LearnerMode {
    /// Historical behavior: `feedback()` blocks on the gradient step.
    Inline,
    /// Gradient steps on a background thread; decide path pushes to a
    /// bounded queue and adopts weight snapshots at a fixed cadence.
    Background,
}

impl LearnerMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "inline" => Ok(Self::Inline),
            "bg" | "background" => Ok(Self::Background),
            other => bail!("unknown learner mode '{other}' (expected inline | bg)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Inline => "inline",
            Self::Background => "bg",
        }
    }
}

/// Learner placement + cadence knobs, threaded from configx/CLI into
/// the training policies.
#[derive(Clone, Debug)]
pub struct LearnerOpts {
    pub mode: LearnerMode,
    /// Adopt a fresh weight snapshot every this-many pushed transitions
    /// (background mode only).
    pub publish_every: usize,
    /// Bound of the transition queue: a slow learner back-pressures the
    /// actor instead of dropping experience.
    pub queue_cap: usize,
}

impl Default for LearnerOpts {
    fn default() -> Self {
        Self {
            mode: LearnerMode::Inline,
            publish_every: 32,
            queue_cap: 256,
        }
    }
}

enum Msg {
    Step(Transition),
    Publish,
}

/// Worker-side drop guard: closing all three queues on the way out
/// (normal exit *or* panic) guarantees the actor can never block
/// forever on a push or a snapshot pop against a dead worker. `close`
/// is idempotent, so the later `finish()` closes are harmless.
struct HangUp {
    msgs: Arc<BoundedQueue<Msg>>,
    snaps: Arc<BoundedQueue<Mlp>>,
    rets: Arc<BoundedQueue<Mlp>>,
}

impl Drop for HangUp {
    fn drop(&mut self) {
        self.msgs.close();
        self.snaps.close();
        self.rets.close();
    }
}

/// Actor-side handle: owns a read-only weight snapshot for greedy
/// decisions and the queues to the learner thread. `finish()` joins
/// and returns the (fully trained) agent for deployment.
///
/// The queues are `util::sync` primitives (loom-checkable; see
/// `tests/loom_models.rs`): `msgs` carries transitions and `Publish`
/// markers FIFO under backpressure, `snaps`/`rets` cycle the two
/// snapshot buffers between worker and actor.
pub struct BgLearner {
    msgs: Arc<BoundedQueue<Msg>>,
    snaps: Arc<BoundedQueue<Mlp>>,
    rets: Arc<BoundedQueue<Mlp>>,
    handle: JoinHandle<DqnAgent>,
    space: ActionSpace,
    net: Mlp,
    scratch: InferScratch,
    rng: Pcg32,
    steps: usize,
    eps_start: f64,
    eps_end: f64,
    eps_decay_steps: usize,
    publish_every: usize,
    since_publish: usize,
}

impl BgLearner {
    /// Move `agent` onto a learner thread. The actor keeps a clone of
    /// the online net as its decision snapshot and mirrors the agent's
    /// ε schedule (continuing from its current step count); exploration
    /// uses a dedicated RNG stream derived from `seed`.
    pub fn spawn(agent: DqnAgent, opts: &LearnerOpts, seed: u64) -> Self {
        let cfg = agent.config();
        let (eps_start, eps_end, eps_decay_steps) =
            (cfg.eps_start, cfg.eps_end, cfg.eps_decay_steps);
        let steps = agent.steps();
        let space = agent.space.clone();
        let net = agent.online.clone();
        let spare = agent.online.clone();

        let msgs = Arc::new(BoundedQueue::new(opts.queue_cap.max(1)));
        let snaps = Arc::new(BoundedQueue::new(1));
        let rets = Arc::new(BoundedQueue::new(2));

        let hangup = HangUp {
            msgs: Arc::clone(&msgs),
            snaps: Arc::clone(&snaps),
            rets: Arc::clone(&rets),
        };
        let handle = std::thread::Builder::new()
            .name("dqn-learner".into())
            .spawn(move || {
                // `guard` both carries the worker's queue handles and
                // hangs them all up when this closure exits or panics
                let guard = hangup;
                let mut agent = agent;
                let mut spare = Some(spare);
                while let Some(msg) = guard.msgs.pop() {
                    match msg {
                        Msg::Step(t) => {
                            agent.remember(t);
                            agent.learn();
                        }
                        Msg::Publish => {
                            let Some(mut buf) = take_publish_buf(&mut spare, &guard.rets) else {
                                break; // actor gone
                            };
                            buf.copy_from(&agent.online);
                            if guard.snaps.push(buf).is_err() {
                                break; // actor gone
                            }
                        }
                    }
                }
                agent
            })
            .expect("spawn dqn-learner thread");

        Self {
            msgs,
            snaps,
            rets,
            handle,
            space,
            net,
            scratch: InferScratch::default(),
            rng: Pcg32::new(seed, 0xAC7),
            steps,
            eps_start,
            eps_end,
            eps_decay_steps,
            publish_every: opts.publish_every.max(1),
            since_publish: 0,
        }
    }

    fn epsilon(&self) -> f64 {
        let t = (self.steps as f64 / self.eps_decay_steps as f64).min(1.0);
        self.eps_start + (self.eps_end - self.eps_start) * t
    }

    /// ε-greedy action off the current snapshot — never blocks on the
    /// learner (the "thinking" happens on the other thread).
    pub fn act(&mut self, state: &[f32]) -> Vec<usize> {
        self.steps += 1;
        if self.rng.chance(self.epsilon()) {
            return self.space.random(&mut self.rng);
        }
        let q = self.net.infer(state, &mut self.scratch);
        self.space.argmax(q)
    }

    /// Greedy action off the current snapshot (no exploration).
    pub fn greedy_into(&mut self, state: &[f32], out: &mut Vec<usize>) {
        let q = self.net.infer(state, &mut self.scratch);
        self.space.argmax_into(q, out);
    }

    /// Hand a transition to the learner. Every `publish_every`-th push
    /// also requests a snapshot and blocks until it arrives, so the
    /// adopted weights are a deterministic function of the pushed
    /// transition prefix.
    pub fn push(&mut self, t: Transition) {
        if self.msgs.push(Msg::Step(t)).is_err() {
            return; // learner thread died; finish() will surface it
        }
        self.since_publish += 1;
        if self.since_publish >= self.publish_every {
            self.since_publish = 0;
            if self.msgs.push(Msg::Publish).is_err() {
                return;
            }
            adopt_snapshot(&mut self.net, &self.snaps, &self.rets);
        }
    }

    /// Hang up, drain, join: the worker processes every queued
    /// transition before returning the agent, so the result is exactly
    /// what an inline learner fed the same sequence would hold (modulo
    /// the actor-side exploration stream, which lives here, not there).
    pub fn finish(self) -> DqnAgent {
        let BgLearner {
            msgs,
            snaps,
            rets,
            handle,
            ..
        } = self;
        msgs.close();
        snaps.close();
        rets.close();
        handle.join().expect("dqn-learner thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dqn::agent::DqnConfig;

    fn mk_agent(seed: u64) -> DqnAgent {
        DqnAgent::new(
            DqnConfig {
                state_dim: 2,
                hidden: vec![8],
                batch: 8,
                ..Default::default()
            },
            ActionSpace::new(vec![2, 3]),
            seed,
        )
    }

    fn weights_bits(mlp: &Mlp) -> Vec<u32> {
        let mut out = Vec::new();
        for w in &mlp.ws {
            out.extend(w.data.iter().map(|x| x.to_bits()));
        }
        for b in &mlp.bs {
            out.extend(b.iter().map(|x| x.to_bits()));
        }
        out
    }

    fn tr(i: usize) -> Transition {
        Transition {
            state: vec![(i % 5) as f32 * 0.2, 1.0],
            action: vec![i % 2, i % 3],
            reward: (i % 3) as f64 * 0.1,
            next_state: vec![1.0, (i % 7) as f32 * 0.1],
            done: i % 11 == 0,
            gamma_pow: 1.0,
        }
    }

    #[test]
    fn mode_parse_roundtrip_and_errors() {
        assert_eq!(LearnerMode::parse("inline").unwrap(), LearnerMode::Inline);
        assert_eq!(LearnerMode::parse("bg").unwrap(), LearnerMode::Background);
        assert_eq!(
            LearnerMode::parse("background").unwrap(),
            LearnerMode::Background
        );
        assert!(LearnerMode::parse("turbo").is_err());
        assert_eq!(LearnerMode::Inline.as_str(), "inline");
        assert_eq!(LearnerMode::Background.as_str(), "bg");
    }

    /// Loom regression seed (runs on stable, no `--cfg loom` needed):
    /// the minimized interleaving where a snapshot could reflect the
    /// wrong transition prefix. The queue serializes `S1 S2 Publish S3`
    /// FIFO, so the published weights must be `f(S1, S2)` exactly —
    /// never including `S3` — and close-then-drain must still process
    /// `S3`. Driven single-threaded through the same `util::sync`
    /// protocol ops `BgLearner` uses; `tests/loom_models.rs` explores
    /// the full two-thread interleaving space under `--cfg loom`.
    #[test]
    fn handshake_snapshot_is_exact_prefix_regression_seed() {
        use crate::util::sync::{adopt_snapshot, take_publish_buf, BoundedQueue};
        #[derive(Debug, PartialEq)]
        enum M {
            Step,
            Publish,
        }
        let msgs = BoundedQueue::new(8);
        let snaps = BoundedQueue::new(1);
        let rets = BoundedQueue::new(2);
        msgs.try_push(M::Step).unwrap();
        msgs.try_push(M::Step).unwrap();
        msgs.try_push(M::Publish).unwrap();
        msgs.try_push(M::Step).unwrap();
        msgs.close();

        // worker loop, exactly as BgLearner's thread runs it: weights
        // are modeled as "number of steps applied", buffers as boxes
        let mut applied = 0u64;
        let mut spare = Some(Box::new(0u64));
        let mut published = Vec::new();
        while let Some(msg) = msgs.pop() {
            match msg {
                M::Step => applied += 1,
                M::Publish => {
                    let mut buf = take_publish_buf(&mut spare, &rets).unwrap();
                    *buf = applied;
                    published.push(applied);
                    snaps.push(buf).unwrap();
                }
            }
        }
        assert_eq!(applied, 3, "finish-drain must process the trailing step");
        assert_eq!(published, vec![2], "snapshot is f(S1, S2), not f(S1, S2, S3)");

        // actor adoption sees exactly the prefix snapshot and cycles
        // its old buffer back for reuse
        let mut net = Box::new(u64::MAX);
        assert!(adopt_snapshot(&mut net, &snaps, &rets));
        assert_eq!(*net, 2);
        assert_eq!(*rets.try_pop().unwrap(), u64::MAX);
    }

    #[test]
    fn bg_run_is_bit_reproducible() {
        // identical seeds + fixed cadence ⇒ identical action sequences
        // and identical final weights, run-to-run
        let run = || {
            let opts = LearnerOpts {
                mode: LearnerMode::Background,
                publish_every: 4,
                queue_cap: 16,
            };
            let mut learner = BgLearner::spawn(mk_agent(77), &opts, 77);
            let mut actions = Vec::new();
            let mut state = vec![0.1f32, 0.9];
            for i in 0..48 {
                let a = learner.act(&state);
                let next = vec![a[0] as f32 * 0.5, a[1] as f32 * 0.25];
                learner.push(Transition {
                    state: state.clone(),
                    action: a.clone(),
                    reward: (a[0] + a[1]) as f64 * 0.1,
                    next_state: next.clone(),
                    done: i % 10 == 9,
                    gamma_pow: 1.0,
                });
                actions.push(a);
                state = next;
            }
            let agent = learner.finish();
            (actions, weights_bits(&agent.online))
        };
        let (a1, w1) = run();
        let (a2, w2) = run();
        assert_eq!(a1, a2, "action sequences must match run-to-run");
        assert_eq!(w1, w2, "final weights must match run-to-run");
    }

    #[test]
    fn publish_cadence_one_matches_synchronous_twin() {
        // at K=1 every adopted snapshot must equal a synchronous agent
        // fed the identical transition sequence, step for step
        let opts = LearnerOpts {
            mode: LearnerMode::Background,
            publish_every: 1,
            queue_cap: 4,
        };
        let mut learner = BgLearner::spawn(mk_agent(5), &opts, 5);
        let mut twin = mk_agent(5);
        for i in 0..24 {
            let t = tr(i);
            twin.remember(t.clone());
            twin.learn();
            learner.push(t);
            assert_eq!(
                weights_bits(&learner.net),
                weights_bits(&twin.online),
                "snapshot after push {i} must equal the synchronous twin"
            );
        }
        let agent = learner.finish();
        assert_eq!(weights_bits(&agent.online), weights_bits(&twin.online));
    }

    #[test]
    fn finish_drains_queued_transitions() {
        // no publish ever happens (cadence > pushes); finish() must
        // still process every queued transition before returning
        let opts = LearnerOpts {
            mode: LearnerMode::Background,
            publish_every: 1000,
            queue_cap: 64,
        };
        let mut learner = BgLearner::spawn(mk_agent(3), &opts, 3);
        let mut twin = mk_agent(3);
        for i in 0..20 {
            let t = tr(i);
            twin.remember(t.clone());
            twin.learn();
            learner.push(t);
        }
        let agent = learner.finish();
        assert_eq!(agent.replay.len(), 20, "all transitions drained");
        assert_eq!(weights_bits(&agent.online), weights_bits(&twin.online));
    }
}
