//! The DQN agent (paper §5.1, Algorithm 1).
//!
//! * Factored discrete action space: the Q-head has one block per action
//!   factor (f_C level, f_G level, f_M level, ξ level); the joint Q-value
//!   is the sum of the selected per-factor Q's, so argmax decomposes per
//!   factor and the output width stays 3·L+Ξ instead of L³·Ξ (DESIGN.md
//!   §7 — the exact-joint variant exists for small L in `joint_argmax`).
//! * Thinking-while-moving (Eq. 15): the backup discounts by
//!   γ^(t_AS/H) where t_AS is the action-selection latency and H the
//!   action duration, and transitions carry that exponent. In the
//!   blocking formulation gamma_pow = 1.
//! * ε-greedy exploration with linear decay, target network, Adam, Huber
//!   TD gradients, prioritized replay.

use super::mlp::{huber_grad, Adam, BatchScratch, InferScratch, Mlp};
use super::replay::{ReplayBuffer, Transition};
use super::tensor::Tensor2;
use crate::util::Pcg32;

/// Factored action-space description: size of each factor block.
#[derive(Clone, Debug)]
pub struct ActionSpace {
    pub factors: Vec<usize>,
}

impl ActionSpace {
    pub fn new(factors: Vec<usize>) -> Self {
        assert!(!factors.is_empty());
        Self { factors }
    }

    pub fn total_dim(&self) -> usize {
        self.factors.iter().sum()
    }

    /// Offset of factor `g` in the flat Q output.
    pub fn offset(&self, g: usize) -> usize {
        self.factors[..g].iter().sum()
    }

    /// Per-factor argmax over a flat Q row, written into a caller
    /// buffer (the allocation-free deployment path).
    pub fn argmax_into(&self, q: &[f32], out: &mut Vec<usize>) {
        out.clear();
        let mut off = 0;
        for &f in &self.factors {
            let blk = &q[off..off + f];
            let mut best = 0;
            for (i, &x) in blk.iter().enumerate() {
                if x > blk[best] {
                    best = i;
                }
            }
            out.push(best);
            off += f;
        }
    }

    /// Per-factor argmax over a flat Q row.
    pub fn argmax(&self, q: &[f32]) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.factors.len());
        self.argmax_into(q, &mut out);
        out
    }

    /// Sum of per-factor maxima (the factored max_a Q(s', a)).
    pub fn max_sum(&self, q: &[f32]) -> f64 {
        let mut off = 0;
        let mut s = 0.0f64;
        for &f in &self.factors {
            let blk = &q[off..off + f];
            // detlint: allow(R4, max-reduction is order-insensitive up to NaN; q is NaN-free)
            s += blk.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            off += f;
        }
        s
    }

    /// Q-value of a concrete factored action.
    pub fn q_of(&self, q: &[f32], action: &[usize]) -> f64 {
        debug_assert_eq!(action.len(), self.factors.len());
        let mut off = 0;
        let mut s = 0.0f64;
        for (&f, &a) in self.factors.iter().zip(action.iter()) {
            s += q[off + a] as f64;
            off += f;
        }
        s
    }

    /// Uniform random action.
    pub fn random(&self, rng: &mut Pcg32) -> Vec<usize> {
        self.factors
            .iter()
            .map(|&f| rng.below(f as u32) as usize)
            .collect()
    }
}

/// Agent hyperparameters (defaults follow paper §6.1: lr 1e-4, buffer
/// 1e6 — bounded here to keep memory sane — minibatch 256).
#[derive(Clone, Debug)]
pub struct DqnConfig {
    pub state_dim: usize,
    pub hidden: Vec<usize>,
    pub lr: f32,
    pub gamma: f64,
    pub buffer_cap: usize,
    pub batch: usize,
    pub eps_start: f64,
    pub eps_end: f64,
    pub eps_decay_steps: usize,
    pub target_sync_every: usize,
}

impl Default for DqnConfig {
    fn default() -> Self {
        Self {
            state_dim: 8,
            hidden: vec![128, 64, 32],
            lr: 3e-4,
            gamma: 0.95,
            buffer_cap: 65_536,
            batch: 128,
            eps_start: 1.0,
            eps_end: 0.05,
            eps_decay_steps: 500,
            target_sync_every: 100,
        }
    }
}

pub struct DqnAgent {
    pub space: ActionSpace,
    pub online: Mlp,
    pub target: Mlp,
    pub replay: ReplayBuffer,
    cfg: DqnConfig,
    adam: Adam,
    rng: Pcg32,
    steps: usize,
    grad_steps: usize,
    scratch: InferScratch,
    arena: LearnArena,
}

/// Persistent minibatch buffers for `learn`: the flattened state
/// matrices, the TD scratch, and the output-gradient tensor are rebuilt
/// in place each gradient step instead of freshly allocated, so a
/// training loop's steady-state learn() cost is the matmuls, not the
/// allocator.
#[derive(Default)]
struct LearnArena {
    xs: Vec<f32>,
    nxs: Vec<f32>,
    tds: Vec<f64>,
    dout: Option<Tensor2>,
    /// ping-pong tensors for the target net's batched forward
    batch: BatchScratch,
}

impl DqnAgent {
    pub fn new(cfg: DqnConfig, space: ActionSpace, seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let mut dims = vec![cfg.state_dim];
        dims.extend_from_slice(&cfg.hidden);
        dims.push(space.total_dim());
        let online = Mlp::new(&dims, &mut rng);
        let target = online.clone();
        let adam = Adam::new(&online, cfg.lr);
        Self {
            space,
            online,
            target,
            replay: ReplayBuffer::new(cfg.buffer_cap),
            cfg,
            adam,
            rng,
            steps: 0,
            grad_steps: 0,
            scratch: InferScratch::default(),
            arena: LearnArena::default(),
        }
    }

    /// The agent's hyperparameters (read-only — the background learner
    /// mirrors the ε schedule from these).
    pub fn config(&self) -> &DqnConfig {
        &self.cfg
    }

    /// Environment steps taken so far (drives the ε schedule).
    pub fn steps(&self) -> usize {
        self.steps
    }

    pub fn epsilon(&self) -> f64 {
        let t = (self.steps as f64 / self.cfg.eps_decay_steps as f64).min(1.0);
        self.cfg.eps_start + (self.cfg.eps_end - self.cfg.eps_start) * t
    }

    /// ε-greedy action selection (counts as an environment step for the
    /// ε schedule).
    pub fn act(&mut self, state: &[f32]) -> Vec<usize> {
        self.steps += 1;
        if self.rng.chance(self.epsilon()) {
            return self.space.random(&mut self.rng);
        }
        self.greedy(state)
    }

    /// Greedy action (deployment path — no exploration, no counters).
    pub fn greedy(&mut self, state: &[f32]) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.space.factors.len());
        self.greedy_into(state, &mut out);
        out
    }

    /// Greedy action written into a caller buffer: with a warm scratch
    /// and a reused buffer the whole state→Q→argmax path is
    /// allocation-free (the serving engine's per-decision hot path).
    pub fn greedy_into(&mut self, state: &[f32], out: &mut Vec<usize>) {
        let q = self.online.infer(state, &mut self.scratch);
        self.space.argmax_into(q, out);
    }

    /// Raw Q-values for external consumers (e.g. the PJRT parity test).
    pub fn q_values(&mut self, state: &[f32]) -> Vec<f32> {
        self.online.infer(state, &mut self.scratch).to_vec()
    }

    pub fn remember(&mut self, t: Transition) {
        self.replay.push(t);
    }

    /// One gradient step over a prioritized minibatch. Returns the mean
    /// |TD| (None when the buffer is still too small).
    pub fn learn(&mut self) -> Option<f64> {
        let batch = self.cfg.batch.min(self.replay.len());
        if batch < 8 {
            return None;
        }
        let (idxs, weights) = self.replay.sample(batch, &mut self.rng);
        let sd = self.cfg.state_dim;

        // batched forward over states and next states; the flattened
        // matrices reuse the arena's allocations from the previous step
        let mut xs = std::mem::take(&mut self.arena.xs);
        let mut nxs = std::mem::take(&mut self.arena.nxs);
        xs.clear();
        nxs.clear();
        xs.reserve(batch * sd);
        nxs.reserve(batch * sd);
        for &i in &idxs {
            let t = self.replay.get(i);
            xs.extend_from_slice(&t.state);
            nxs.extend_from_slice(&t.next_state);
        }
        let xs = Tensor2::from_vec(batch, sd, xs);
        let nxs = Tensor2::from_vec(batch, sd, nxs);
        let cache = self.online.forward(&xs);
        // target side needs only Q-values, not backprop caches: batched
        // inference through the arena's ping-pong scratch, bit-identical
        // to the historical `forward(&nxs).output`
        let q_next = self.target.infer_batch(&nxs, &mut self.arena.batch);

        // TD targets with the thinking-while-moving fractional discount;
        // dout is the arena tensor zeroed in place when the shape holds
        let dim = self.space.total_dim();
        let mut dout = match self.arena.dout.take() {
            Some(mut t) if t.shape() == (batch, dim) => {
                t.data.fill(0.0);
                t
            }
            _ => Tensor2::zeros(batch, dim),
        };
        let mut tds = std::mem::take(&mut self.arena.tds);
        tds.clear();
        tds.reserve(batch);
        let nf = self.space.factors.len() as f32;
        for (b, &i) in idxs.iter().enumerate() {
            let t = self.replay.get(i);
            let q_row = cache.output.row(b);
            let q_sa = self.space.q_of(q_row, &t.action);
            let bootstrap = if t.done {
                0.0
            } else {
                self.cfg.gamma.powf(t.gamma_pow) * self.space.max_sum(q_next.row(b))
            };
            let target = t.reward + bootstrap;
            let td = q_sa - target;
            tds.push(td);
            // distribute the Huber gradient over the selected factor heads
            let g = huber_grad(q_sa as f32, target as f32) * weights[b] as f32 / nf;
            for (gidx, &a) in t.action.iter().enumerate() {
                let off = self.space.offset(gidx);
                *dout.at_mut(b, off + a) += g;
            }
        }
        dout.scale(1.0 / batch as f32);

        let (dws, dbs) = self.online.backward(&cache, &dout);
        self.adam.step(&mut self.online, &dws, &dbs);
        self.replay.update_priorities(&idxs, &tds);

        self.grad_steps += 1;
        if self.grad_steps % self.cfg.target_sync_every == 0 {
            self.target.copy_from(&self.online);
        }
        // detlint: allow(R4, diagnostics only; summed in fixed minibatch order regardless)
        let mean_td = tds.iter().map(|t| t.abs()).sum::<f64>() / batch as f64;

        // hand the minibatch buffers back to the arena for the next step
        self.arena.xs = xs.data;
        self.arena.nxs = nxs.data;
        self.arena.tds = tds;
        self.arena.dout = Some(dout);

        Some(mean_td)
    }

    /// Exact joint argmax (enumerates the product space) — validation
    /// helper for small ladders; the factored head makes this equal to
    /// the per-factor argmax by construction.
    pub fn joint_argmax(&mut self, state: &[f32]) -> Vec<usize> {
        let q = self.online.infer(state, &mut self.scratch);
        let mut best: Option<(f64, Vec<usize>)> = None;
        let mut idx = vec![0usize; self.space.factors.len()];
        loop {
            let v = self.space.q_of(q, &idx);
            if best.as_ref().map(|(b, _)| v > *b).unwrap_or(true) {
                best = Some((v, idx.clone()));
            }
            // odometer increment
            let mut g = 0;
            loop {
                if g == idx.len() {
                    return best.unwrap().1;
                }
                idx[g] += 1;
                if idx[g] < self.space.factors[g] {
                    break;
                }
                idx[g] = 0;
                g += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> ActionSpace {
        ActionSpace::new(vec![4, 4, 4, 5])
    }

    #[test]
    fn action_space_algebra() {
        let s = space();
        assert_eq!(s.total_dim(), 17);
        assert_eq!(s.offset(0), 0);
        assert_eq!(s.offset(3), 12);
        let q: Vec<f32> = (0..17).map(|i| i as f32).collect();
        assert_eq!(s.argmax(&q), vec![3, 3, 3, 4]);
        assert_eq!(s.max_sum(&q), 3.0 + 7.0 + 11.0 + 16.0);
        assert_eq!(s.q_of(&q, &[0, 1, 2, 3]), 0.0 + 5.0 + 10.0 + 15.0);
    }

    #[test]
    fn argmax_into_matches_argmax_and_reuses_the_buffer() {
        let s = space();
        let q: Vec<f32> = (0..17).map(|i| ((i * 13) % 7) as f32).collect();
        let mut out = Vec::with_capacity(4);
        s.argmax_into(&q, &mut out);
        assert_eq!(out, s.argmax(&q));
        let cap = out.capacity();
        s.argmax_into(&q, &mut out);
        assert_eq!(out, s.argmax(&q));
        assert_eq!(out.capacity(), cap, "warm argmax_into must not grow");
    }

    #[test]
    fn greedy_into_matches_greedy() {
        let mut agent = DqnAgent::new(
            DqnConfig {
                state_dim: 4,
                hidden: vec![16, 8],
                ..Default::default()
            },
            ActionSpace::new(vec![3, 3, 2]),
            11,
        );
        let mut out = Vec::new();
        for i in 0..10 {
            let s: Vec<f32> = (0..4).map(|j| ((i * 3 + j) % 5) as f32 * 0.25).collect();
            agent.greedy_into(&s, &mut out);
            assert_eq!(out, agent.greedy(&s), "state {i}");
        }
    }

    #[test]
    fn learn_arena_is_reused_across_steps() {
        let mut agent = DqnAgent::new(
            DqnConfig {
                state_dim: 2,
                hidden: vec![8],
                batch: 8,
                ..Default::default()
            },
            ActionSpace::new(vec![2]),
            13,
        );
        for i in 0..16 {
            agent.remember(Transition {
                state: vec![i as f32, 1.0],
                action: vec![i % 2],
                reward: 0.1,
                next_state: vec![1.0, i as f32],
                done: false,
                gamma_pow: 1.0,
            });
        }
        assert!(agent.learn().is_some());
        let caps = (
            agent.arena.xs.capacity(),
            agent.arena.nxs.capacity(),
            agent.arena.tds.capacity(),
        );
        assert!(caps.0 > 0 && caps.1 > 0 && caps.2 > 0, "arena warmed");
        assert!(agent.arena.dout.is_some());
        assert!(agent.learn().is_some());
        // a same-sized second step reuses every buffer
        assert_eq!(
            (
                agent.arena.xs.capacity(),
                agent.arena.nxs.capacity(),
                agent.arena.tds.capacity(),
            ),
            caps,
            "warm learn must not reallocate the arena"
        );
    }

    #[test]
    fn joint_argmax_matches_factored() {
        let mut agent = DqnAgent::new(
            DqnConfig {
                state_dim: 4,
                hidden: vec![16, 8],
                ..Default::default()
            },
            ActionSpace::new(vec![3, 3, 2]),
            5,
        );
        for i in 0..20 {
            let s: Vec<f32> = (0..4).map(|j| ((i * 7 + j) % 5) as f32 * 0.2).collect();
            assert_eq!(agent.greedy(&s), agent.joint_argmax(&s));
        }
    }

    #[test]
    fn epsilon_decays() {
        let mut agent = DqnAgent::new(
            DqnConfig {
                state_dim: 2,
                hidden: vec![8],
                eps_decay_steps: 100,
                ..Default::default()
            },
            ActionSpace::new(vec![2]),
            1,
        );
        let e0 = agent.epsilon();
        for _ in 0..100 {
            agent.act(&[0.0, 0.0]);
        }
        let e1 = agent.epsilon();
        assert!(e0 > 0.99 && e1 < 0.06, "{e0} -> {e1}");
    }

    /// A 2-state contextual bandit the agent must solve: state s ∈ {0,1};
    /// action factor matching s gives reward 1, else 0.
    #[test]
    fn learns_contextual_bandit() {
        let cfg = DqnConfig {
            state_dim: 2,
            hidden: vec![32, 16],
            lr: 3e-3,
            gamma: 0.0, // pure bandit
            batch: 64,
            eps_decay_steps: 400,
            target_sync_every: 50,
            ..Default::default()
        };
        let mut agent = DqnAgent::new(cfg, ActionSpace::new(vec![2, 2]), 42);
        let mut rng = Pcg32::seeded(9);
        for _ in 0..1200 {
            let s_id = rng.below(2) as usize;
            let state = vec![(s_id == 0) as u8 as f32, (s_id == 1) as u8 as f32];
            let a = agent.act(&state);
            // reward: both factors must match the context
            let r = ((a[0] == s_id) as u8 + (a[1] == s_id) as u8) as f64 / 2.0;
            agent.remember(Transition {
                state: state.clone(),
                action: a,
                reward: r,
                next_state: state,
                done: true,
                gamma_pow: 1.0,
            });
            agent.learn();
        }
        // deployment: greedy must match context in both factors
        for s_id in 0..2usize {
            let state = vec![(s_id == 0) as u8 as f32, (s_id == 1) as u8 as f32];
            let a = agent.greedy(&state);
            assert_eq!(a, vec![s_id, s_id], "context {s_id}");
        }
    }

    #[test]
    fn twm_discount_shrinks_bootstrap() {
        // A transition with gamma_pow = 0.5 must produce a larger
        // bootstrap than gamma_pow = 1 (γ<1 ⇒ γ^0.5 > γ): verify via the
        // learn() TD magnitudes on a buffer with a single transition and
        // a frozen network.
        let mk = |gp: f64, seed: u64| {
            let cfg = DqnConfig {
                state_dim: 2,
                hidden: vec![8],
                lr: 0.0, // freeze: we only read TDs
                gamma: 0.5,
                batch: 8,
                ..Default::default()
            };
            let mut agent = DqnAgent::new(cfg, ActionSpace::new(vec![2]), seed);
            for _ in 0..8 {
                agent.remember(Transition {
                    state: vec![1.0, 0.0],
                    action: vec![0],
                    reward: 0.0,
                    next_state: vec![0.0, 1.0],
                    done: false,
                    gamma_pow: gp,
                });
            }
            agent.learn().unwrap()
        };
        // same seed → identical nets → TD difference comes from γ^pow only
        let td_full = mk(1.0, 7);
        let td_half = mk(0.5, 7);
        assert!(
            (td_full - td_half).abs() > 1e-9,
            "fractional discount must change the target"
        );
    }
}
