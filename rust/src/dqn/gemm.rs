//! §Perf — packed, register-blocked GEMM kernels for the DQN hot path.
//!
//! The three tensor contractions the trainer lives on (`matmul`,
//! `matmul_tn`, `matmul_nt` in `tensor.rs`) all route through one
//! BLIS-style driver here: pack a block of A into MR-wide row panels
//! and a block of B into NR-wide column panels, then run an MR×NR
//! register-tile microkernel over the packed panels. Cache tiling runs
//! over **M and N only — never K**: each output element is still one
//! sequential accumulation over the full K extent, products added in
//! ascending-k order from a +0.0 accumulator, exactly like the naive
//! triple loop. That keeps every result **bit-identical** to the
//! straight-line reference (`rust/tests/gemm_parity.rs` gates this with
//! `to_bits()` equality), so the golden/parity suites — including the
//! PJRT-artifact comparison in `runtime_parity.rs` — run unchanged.
//!
//! The old per-element `a == 0.0` skip is gone from these kernels: in
//! packed panels the branch defeats vectorization, and skipping is
//! bit-neutral anyway whenever the B operand is finite (`±0.0 · b`
//! rounds to `±0.0`, and adding `±0.0` to a +0.0-seeded accumulator
//! never changes its bits under round-to-nearest — see the README
//! "Learner performance" section for the full argument). The skip
//! survives only in `Mlp::infer`'s matrix-vector path, where a zero
//! ReLU activation provably saves an entire weight-row load.
//!
//! Packing buffers are thread-local (the background learner and the
//! sweep workers each get their own), so the public entry points keep
//! the existing allocation-free `matmul_into` contract after warmup.

use std::cell::RefCell;

/// Microkernel register-tile height (rows of A per panel).
pub const MR: usize = 4;
/// Microkernel register-tile width (columns of B per panel).
pub const NR: usize = 8;
/// Cache-block height over M.
const MC: usize = 64;
/// Cache-block width over N.
const NC: usize = 64;
/// Below this many multiply-adds the plain triple loop beats the cost
/// of packing (the DQN's per-decision 1×K vectors land here).
const SMALL_FLOPS: usize = 8 * 1024;

thread_local! {
    static PACK: RefCell<PackBufs> = RefCell::new(PackBufs::default());
}

/// Reusable packing buffers: grown once to the largest block seen on
/// this thread, then reused for every subsequent call.
#[derive(Default)]
struct PackBufs {
    a: Vec<f32>,
    b: Vec<f32>,
}

/// out = A (m,k) @ B (k,n), all row-major; `out` is fully overwritten.
pub fn gemm_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    gemm_driver(m, k, n, |i, p| a[i * k + p], |p, j| b[p * n + j], out);
}

/// out = Aᵀ @ B with A stored (k,m): the backward-pass `input.T @ grad`
/// contraction. A's column i is read as `a[p*m + i]`.
pub fn gemm_tn(k: usize, m: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    gemm_driver(m, k, n, |i, p| a[p * m + i], |p, j| b[p * n + j], out);
}

/// out = A @ Bᵀ with B stored (n,k): the backward-pass `grad @ W.T`
/// contraction. B's row j is read as `b[j*k + p]`.
pub fn gemm_nt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    gemm_driver(m, k, n, |i, p| a[i * k + p], |p, j| b[j * k + p], out);
}

/// One driver for all three layouts: the indexers abstract A/B element
/// access and monomorphize per call site, packing normalizes the layout
/// so the microkernel only ever sees contiguous panels.
fn gemm_driver<FA, FB>(m: usize, k: usize, n: usize, a_at: FA, b_at: FB, out: &mut [f32])
where
    FA: Fn(usize, usize) -> f32,
    FB: Fn(usize, usize) -> f32,
{
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if m * n * k.max(1) <= SMALL_FLOPS {
        small_gemm(m, k, n, &a_at, &b_at, out);
        return;
    }
    PACK.with(|bufs| {
        let bufs = &mut *bufs.borrow_mut();
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            let jpanels = nc.div_ceil(NR);
            pack_b(k, jc, nc, jpanels, &b_at, &mut bufs.b);
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                let ipanels = mc.div_ceil(MR);
                pack_a(k, ic, mc, ipanels, &a_at, &mut bufs.a);
                for ip in 0..ipanels {
                    let i0 = ic + ip * MR;
                    let mr = MR.min(mc - ip * MR);
                    let apan = &bufs.a[ip * k * MR..(ip + 1) * k * MR];
                    for jp in 0..jpanels {
                        let j0 = jc + jp * NR;
                        let nr = NR.min(nc - jp * NR);
                        let bpan = &bufs.b[jp * k * NR..(jp + 1) * k * NR];
                        // MR×NR register tile: each acc element is one
                        // independent full-K accumulation in ascending-k
                        // order from +0.0 — the bit-exactness invariant.
                        // Padded lanes (r >= mr, c >= nr) compute garbage
                        // against the zero-padded panels and are never
                        // written back.
                        let mut acc = [[0.0f32; NR]; MR];
                        for (arow, brow) in
                            apan.chunks_exact(MR).zip(bpan.chunks_exact(NR))
                        {
                            for r in 0..MR {
                                let av = arow[r];
                                for c in 0..NR {
                                    acc[r][c] += av * brow[c];
                                }
                            }
                        }
                        for r in 0..mr {
                            let o0 = (i0 + r) * n + j0;
                            out[o0..o0 + nr].copy_from_slice(&acc[r][..nr]);
                        }
                    }
                }
            }
        }
    });
}

/// Pack a (mc,k) block of A into `ipanels` MR-row panels, column-major
/// within each panel (panel p-step is MR floats). Short tail panels are
/// zero-padded — the pad rows feed the microkernel but never reach
/// `out`.
fn pack_a<FA: Fn(usize, usize) -> f32>(
    k: usize,
    ic: usize,
    mc: usize,
    ipanels: usize,
    a_at: &FA,
    buf: &mut Vec<f32>,
) {
    buf.clear();
    buf.resize(ipanels * k * MR, 0.0);
    for ip in 0..ipanels {
        let base = ip * k * MR;
        let mr = MR.min(mc - ip * MR);
        for p in 0..k {
            let dst = &mut buf[base + p * MR..base + (p + 1) * MR];
            for (r, d) in dst.iter_mut().enumerate().take(mr) {
                *d = a_at(ic + ip * MR + r, p);
            }
        }
    }
}

/// Pack a (k,nc) block of B into `jpanels` NR-column panels, row-major
/// within each panel (panel p-step is NR floats); zero-padded tails.
fn pack_b<FB: Fn(usize, usize) -> f32>(
    k: usize,
    jc: usize,
    nc: usize,
    jpanels: usize,
    b_at: &FB,
    buf: &mut Vec<f32>,
) {
    buf.clear();
    buf.resize(jpanels * k * NR, 0.0);
    for jp in 0..jpanels {
        let base = jp * k * NR;
        let nr = NR.min(nc - jp * NR);
        for p in 0..k {
            let dst = &mut buf[base + p * NR..base + (p + 1) * NR];
            for (c, d) in dst.iter_mut().enumerate().take(nr) {
                *d = b_at(p, jc + jp * NR + c);
            }
        }
    }
}

/// Plain triple loop for shapes too small to amortize packing — same
/// per-element accumulation order as the tiled path (ascending k from a
/// +0.0 local accumulator), so the two paths are bit-interchangeable.
fn small_gemm<FA, FB>(m: usize, k: usize, n: usize, a_at: &FA, b_at: &FB, out: &mut [f32])
where
    FA: Fn(usize, usize) -> f32,
    FB: Fn(usize, usize) -> f32,
{
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a_at(i, p) * b_at(p, j);
            }
            out[i * n + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Straight-line reference with the same accumulation order.
    fn reference(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    fn seq(len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|i| ((i * 7 + 3) % 11) as f32 * scale - 1.5).collect()
    }

    #[test]
    fn nn_matches_reference_across_tile_boundaries() {
        // shapes straddling MR/NR/MC/NC boundaries, incl. degenerate dims
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (4, 9, 8),
            (5, 16, 9),
            (63, 10, 65),
            (64, 33, 64),
            (65, 12, 63),
            (70, 40, 70),
            (0, 4, 4),
            (4, 0, 4),
            (4, 4, 0),
            (1, 70, 1),
        ] {
            let a = seq(m * k, 0.25);
            let b = seq(k * n, 0.5);
            let mut out = vec![f32::NAN; m * n];
            gemm_nn(m, k, n, &a, &b, &mut out);
            let want = reference(m, k, n, &a, &b);
            for (i, (&x, &y)) in out.iter().zip(want.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{k},{n}) elem {i}");
            }
        }
    }

    #[test]
    fn tn_and_nt_match_explicit_transposes() {
        let (m, k, n) = (66, 21, 67);
        let a = seq(m * k, 0.2); // logical A (m,k)
        let b = seq(k * n, 0.3);
        let want = reference(m, k, n, &a, &b);
        // tn: store A as (k,m)
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let mut out = vec![0.0f32; m * n];
        gemm_tn(k, m, n, &at, &b, &mut out);
        for (x, y) in out.iter().zip(want.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // nt: store B as (n,k)
        let mut bt = vec![0.0f32; n * k];
        for p in 0..k {
            for j in 0..n {
                bt[j * k + p] = b[p * n + j];
            }
        }
        gemm_nt(m, k, n, &a, &bt, &mut out);
        for (x, y) in out.iter().zip(want.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn output_fully_overwritten_even_for_empty_k() {
        // k = 0 ⇒ every element is the empty sum = +0.0; stale sentinel
        // values must not survive
        let (m, n) = (65, 65);
        let mut out = vec![7.5f32; m * n];
        gemm_nn(m, 0, n, &[], &[], &mut out);
        assert!(out.iter().all(|&x| x.to_bits() == 0.0f32.to_bits()));
    }
}
