//! The Q-network MLP: 3 hidden relu layers (128/64/32, paper §6.1) with
//! manual forward/backward and Adam. Architecture mirrors
//! `python/compile/model.py::dqn_q_fwd` exactly — the runtime test
//! `tests/runtime_parity.rs` asserts the rust forward and the PJRT
//! artifact agree bit-tightly on the same weights.

use super::tensor::Tensor2;
use crate::configx::json::{self, Json};
use crate::util::Pcg32;
use anyhow::{Context, Result};

#[derive(Clone, Debug)]
pub struct Mlp {
    /// weight matrices (in, out) and biases per layer
    pub ws: Vec<Tensor2>,
    pub bs: Vec<Vec<f32>>,
}

/// Per-layer cache of one forward pass (inputs and post-relu activations).
pub struct ForwardCache {
    /// layer inputs: x0 (the state), a1, a2, a3
    pub inputs: Vec<Tensor2>,
    /// final linear output (Q-values)
    pub output: Tensor2,
}

impl Mlp {
    /// dims: [in, h1, h2, h3, out]
    pub fn new(dims: &[usize], rng: &mut Pcg32) -> Self {
        let ws = dims
            .windows(2)
            .map(|w| Tensor2::he_init(w[0], w[1], rng))
            .collect();
        let bs = dims[1..].iter().map(|&d| vec![0.0; d]).collect();
        Self { ws, bs }
    }

    pub fn dims(&self) -> Vec<usize> {
        let mut d: Vec<usize> = self.ws.iter().map(|w| w.rows).collect();
        d.push(self.ws.last().unwrap().cols);
        d
    }

    /// Forward with activations cached for backprop (the TRAIN path —
    /// inference goes through `infer`). Each hidden activation MOVES
    /// into the cache instead of being cloned, and the cache vector is
    /// sized once up front, so one minibatch forward costs exactly one
    /// allocation per layer output plus the cached input copy.
    pub fn forward(&self, x: &Tensor2) -> ForwardCache {
        let n = self.ws.len();
        let mut inputs = Vec::with_capacity(n);
        inputs.push(x.clone());
        for i in 0..n {
            let h = inputs.last().expect("seeded with the input tensor");
            let mut z = h.matmul(&self.ws[i]);
            z.add_row_bias(&self.bs[i]);
            if i + 1 == n {
                return ForwardCache { inputs, output: z };
            }
            z.relu_inplace();
            inputs.push(z);
        }
        unreachable!("mlp must have at least one layer");
    }

    /// Inference-only forward: ping-pong scratch buffers, no activation
    /// caches, and the Q-row is returned as a borrow of the scratch —
    /// the per-decision hot path performs no allocation at all (after
    /// the scratch warms to the widest layer). Callers that need an
    /// owned copy (checkpoint probes, parity tests) call `.to_vec()`.
    pub fn infer<'s>(&self, x: &[f32], scratch: &'s mut InferScratch) -> &'s [f32] {
        debug_assert_eq!(x.len(), self.ws[0].rows);
        scratch.ensure(self);
        let n = self.ws.len();
        scratch.a.clear();
        scratch.a.extend_from_slice(x);
        for i in 0..n {
            let w = &self.ws[i];
            scratch.b.clear();
            scratch.b.extend_from_slice(&self.bs[i]);
            for (p, &a) in scratch.a.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &w.data[p * w.cols..(p + 1) * w.cols];
                for (o, &bv) in scratch.b.iter_mut().zip(brow.iter()) {
                    *o += a * bv;
                }
            }
            if i + 1 < n {
                for v in scratch.b.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            std::mem::swap(&mut scratch.a, &mut scratch.b);
        }
        &scratch.a
    }

    /// Batched inference without activation caching — the target-net
    /// side of `learn()` needs only the Q-values, not the per-layer
    /// caches `forward` keeps for backprop. Ping-pongs between the two
    /// scratch tensors, so a warm scratch makes the whole pass
    /// allocation-free.
    ///
    /// Accumulation order is pinned to `forward`'s (matmul from a +0.0
    /// accumulator, then `add_row_bias`), NOT to `infer`'s bias-first
    /// order — `learn()` historically used `forward` for the target
    /// pass, and this keeps the result bit-identical to
    /// `forward(x).output` (gated in `rust/tests/gemm_parity.rs`).
    pub fn infer_batch<'s>(&self, x: &Tensor2, scratch: &'s mut BatchScratch) -> &'s Tensor2 {
        debug_assert_eq!(x.cols, self.ws[0].rows);
        let n = self.ws.len();
        let BatchScratch { a, b } = scratch;
        self.layer_into(x, 0, a);
        let (mut src, mut dst) = (a, b);
        for i in 1..n {
            self.layer_into(src, i, dst);
            std::mem::swap(&mut src, &mut dst);
        }
        src
    }

    /// One linear layer into a reusable output tensor: z = x@W + b,
    /// relu unless it is the final layer.
    fn layer_into(&self, x: &Tensor2, i: usize, out: &mut Tensor2) {
        out.resize(x.rows, self.ws[i].cols);
        x.matmul_into(&self.ws[i], out);
        out.add_row_bias(&self.bs[i]);
        if i + 1 < self.ws.len() {
            out.relu_inplace();
        }
    }

    /// Backprop from dL/d(output); returns gradients aligned with (ws, bs).
    pub fn backward(
        &self,
        cache: &ForwardCache,
        dout: &Tensor2,
    ) -> (Vec<Tensor2>, Vec<Vec<f32>>) {
        let n = self.ws.len();
        let mut dws = vec![Tensor2::zeros(0, 0); n];
        let mut dbs = vec![Vec::new(); n];
        let mut grad = dout.clone();
        for i in (0..n).rev() {
            let input = &cache.inputs[i];
            dws[i] = input.matmul_tn(&grad);
            dbs[i] = grad.col_sums();
            if i > 0 {
                let mut dx = grad.matmul_nt(&self.ws[i]);
                dx.relu_backward_inplace(&cache.inputs[i]);
                grad = dx;
            }
        }
        (dws, dbs)
    }

    /// Hard copy (target-network sync). When the architectures match —
    /// the every-`target_sync_every`-steps case — this copies element-
    /// wise into the existing buffers and performs no allocation; it
    /// falls back to a clone only on a shape mismatch.
    pub fn copy_from(&mut self, other: &Mlp) {
        let same_shape = self.ws.len() == other.ws.len()
            && self.bs.len() == other.bs.len()
            && self.ws.iter().zip(&other.ws).all(|(a, b)| a.shape() == b.shape())
            && self.bs.iter().zip(&other.bs).all(|(a, b)| a.len() == b.len());
        if same_shape {
            for (dst, src) in self.ws.iter_mut().zip(&other.ws) {
                dst.data.copy_from_slice(&src.data);
            }
            for (dst, src) in self.bs.iter_mut().zip(&other.bs) {
                dst.copy_from_slice(src);
            }
        } else {
            self.ws = other.ws.clone();
            self.bs = other.bs.clone();
        }
    }

    /// Flattened weights in the artifact's argument order
    /// (w1, b1, w2, b2, ...) — fed to the PJRT dqn_q artifact.
    pub fn flat_args(&self) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(self.ws.len() * 2);
        for (w, b) in self.ws.iter().zip(self.bs.iter()) {
            out.push(w.data.clone());
            out.push(b.clone());
        }
        out
    }

    // ------------------------------------------------------- checkpoints --
    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .ws
            .iter()
            .zip(self.bs.iter())
            .map(|(w, b)| {
                json::obj(vec![
                    ("rows", json::num(w.rows as f64)),
                    ("cols", json::num(w.cols as f64)),
                    (
                        "w",
                        Json::Arr(w.data.iter().map(|&x| Json::Num(x as f64)).collect()),
                    ),
                    (
                        "b",
                        Json::Arr(b.iter().map(|&x| Json::Num(x as f64)).collect()),
                    ),
                ])
            })
            .collect();
        json::obj(vec![("layers", Json::Arr(layers))])
    }

    pub fn from_json(j: &Json) -> Result<Mlp> {
        let layers = j.req("layers")?.as_arr().context("layers must be array")?;
        let mut ws = Vec::new();
        let mut bs = Vec::new();
        for l in layers {
            let rows = l.req("rows")?.as_usize().context("rows")?;
            let cols = l.req("cols")?.as_usize().context("cols")?;
            let w: Vec<f32> = l
                .req("w")?
                .f64_list()
                .context("w")?
                .into_iter()
                .map(|x| x as f32)
                .collect();
            let b: Vec<f32> = l
                .req("b")?
                .f64_list()
                .context("b")?
                .into_iter()
                .map(|x| x as f32)
                .collect();
            anyhow::ensure!(w.len() == rows * cols && b.len() == cols, "shape");
            ws.push(Tensor2::from_vec(rows, cols, w));
            bs.push(b);
        }
        anyhow::ensure!(!ws.is_empty(), "empty checkpoint");
        Ok(Mlp { ws, bs })
    }
}

/// Reusable activation buffers for `Mlp::infer` — keeps the per-decision
/// hot path allocation-free.
#[derive(Default)]
pub struct InferScratch {
    a: Vec<f32>,
    b: Vec<f32>,
}

impl InferScratch {
    fn ensure(&mut self, mlp: &Mlp) {
        // widest layer boundary, computed without the Vec `dims()` builds
        let cap = mlp.ws.iter().map(|w| w.rows.max(w.cols)).max().unwrap_or(0);
        if self.a.capacity() < cap {
            self.a.reserve(cap - self.a.capacity());
        }
        if self.b.capacity() < cap {
            self.b.reserve(cap - self.b.capacity());
        }
    }
}

/// Reusable ping-pong tensors for `Mlp::infer_batch` — after warming to
/// the batch's widest layer the batched target forward is
/// allocation-free.
pub struct BatchScratch {
    a: Tensor2,
    b: Tensor2,
}

impl Default for BatchScratch {
    fn default() -> Self {
        Self {
            a: Tensor2::zeros(0, 0),
            b: Tensor2::zeros(0, 0),
        }
    }
}

/// Adam optimizer over an Mlp's parameters.
pub struct Adam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: i32,
    mw: Vec<Tensor2>,
    vw: Vec<Tensor2>,
    mb: Vec<Vec<f32>>,
    vb: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(mlp: &Mlp, lr: f32) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            mw: mlp.ws.iter().map(|w| Tensor2::zeros(w.rows, w.cols)).collect(),
            vw: mlp.ws.iter().map(|w| Tensor2::zeros(w.rows, w.cols)).collect(),
            mb: mlp.bs.iter().map(|b| vec![0.0; b.len()]).collect(),
            vb: mlp.bs.iter().map(|b| vec![0.0; b.len()]).collect(),
        }
    }

    pub fn step(&mut self, mlp: &mut Mlp, dws: &[Tensor2], dbs: &[Vec<f32>]) {
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t);
        let bc2 = 1.0 - self.beta2.powi(self.t);
        for i in 0..mlp.ws.len() {
            for j in 0..mlp.ws[i].data.len() {
                let g = dws[i].data[j];
                let m = &mut self.mw[i].data[j];
                let v = &mut self.vw[i].data[j];
                *m = self.beta1 * *m + (1.0 - self.beta1) * g;
                *v = self.beta2 * *v + (1.0 - self.beta2) * g * g;
                mlp.ws[i].data[j] -=
                    self.lr * (*m / bc1) / ((*v / bc2).sqrt() + self.eps);
            }
            for j in 0..mlp.bs[i].len() {
                let g = dbs[i][j];
                let m = &mut self.mb[i][j];
                let v = &mut self.vb[i][j];
                *m = self.beta1 * *m + (1.0 - self.beta1) * g;
                *v = self.beta2 * *v + (1.0 - self.beta2) * g * g;
                mlp.bs[i][j] -= self.lr * (*m / bc1) / ((*v / bc2).sqrt() + self.eps);
            }
        }
    }
}

/// Huber (smooth-L1) loss gradient for TD errors: clips the gradient at
/// ±1 as in the DQN paper.
pub fn huber_grad(pred: f32, target: f32) -> f32 {
    let d = pred - target;
    d.clamp(-1.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(rng: &mut Pcg32) -> Mlp {
        Mlp::new(&[3, 8, 6, 4, 2], rng)
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Pcg32::seeded(0);
        let mlp = tiny(&mut rng);
        let x = Tensor2::from_vec(2, 3, vec![0.1; 6]);
        let c = mlp.forward(&x);
        assert_eq!(c.output.shape(), (2, 2));
        assert_eq!(c.inputs.len(), 4); // x + 3 hidden activations
    }

    #[test]
    fn infer_matches_forward() {
        let mut rng = Pcg32::seeded(1);
        let mlp = tiny(&mut rng);
        let xs = vec![0.3f32, -0.7, 1.1];
        let x = Tensor2::from_vec(1, 3, xs.clone());
        let c = mlp.forward(&x);
        let mut scratch = InferScratch::default();
        let got = mlp.infer(&xs, &mut scratch).to_vec();
        for (a, b) in got.iter().zip(c.output.data.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        // second call reuses the (now-warm) buffers and still agrees —
        // and performs no allocation: the scratch capacity is unchanged
        let cap_before = (scratch.a.capacity(), scratch.b.capacity());
        let got2 = mlp.infer(&xs, &mut scratch).to_vec();
        assert_eq!(got, got2);
        assert_eq!(
            (scratch.a.capacity(), scratch.b.capacity()),
            cap_before,
            "warm infer must not grow the scratch"
        );
    }

    #[test]
    fn infer_batch_matches_forward_bitwise() {
        let mut rng = Pcg32::seeded(7);
        let mlp = tiny(&mut rng);
        let x = Tensor2::from_vec(
            5,
            3,
            (0..15).map(|_| rng.next_f32() * 2.0 - 1.0).collect(),
        );
        let want = mlp.forward(&x).output;
        let mut scratch = BatchScratch::default();
        {
            let got = mlp.infer_batch(&x, &mut scratch);
            assert_eq!(got.shape(), want.shape());
            for (a, b) in got.data.iter().zip(want.data.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
            }
        }
        // warm second pass: same bits, no scratch growth
        let cap_before = (scratch.a.data.capacity(), scratch.b.data.capacity());
        let got2 = mlp.infer_batch(&x, &mut scratch);
        for (a, b) in got2.data.iter().zip(want.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(
            (scratch.a.data.capacity(), scratch.b.data.capacity()),
            cap_before,
            "warm infer_batch must not grow the scratch"
        );
    }

    #[test]
    fn copy_from_is_allocation_free_and_exact() {
        let mut rng = Pcg32::seeded(8);
        let mut src = tiny(&mut rng);
        let mut dst = tiny(&mut rng);
        // make biases nonzero so the bs copy is actually exercised
        for b in src.bs.iter_mut() {
            for (j, x) in b.iter_mut().enumerate() {
                *x = 0.125 * (j as f32 + 1.0);
            }
        }
        let caps: Vec<(usize, usize, *const f32, *const f32)> = dst
            .ws
            .iter()
            .zip(dst.bs.iter())
            .map(|(w, b)| (w.data.capacity(), b.capacity(), w.data.as_ptr(), b.as_ptr()))
            .collect();
        dst.copy_from(&src);
        for ((w, b), (sw, sb)) in dst
            .ws
            .iter()
            .zip(dst.bs.iter())
            .zip(src.ws.iter().zip(src.bs.iter()))
        {
            for (x, y) in w.data.iter().zip(sw.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            for (x, y) in b.iter().zip(sb.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // same-architecture sync reuses every buffer: capacity AND base
        // pointer are untouched
        for ((w, b), &(wc, bc, wp, bp)) in dst.ws.iter().zip(dst.bs.iter()).zip(caps.iter()) {
            assert_eq!(w.data.capacity(), wc);
            assert_eq!(b.capacity(), bc);
            assert_eq!(w.data.as_ptr(), wp);
            assert_eq!(b.as_ptr(), bp);
        }
        // shape mismatch still works via the clone fallback
        let mut rng2 = Pcg32::seeded(9);
        let other = Mlp::new(&[5, 4, 2], &mut rng2);
        dst.copy_from(&other);
        assert_eq!(dst.dims(), other.dims());
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Pcg32::seeded(2);
        let mut mlp = Mlp::new(&[2, 5, 4, 3, 1], &mut rng);
        let x = Tensor2::from_vec(1, 2, vec![0.4, -0.9]);
        // loss = 0.5 * out^2 → dL/dout = out
        let cache = mlp.forward(&x);
        let dout = cache.output.clone();
        let (dws, dbs) = mlp.backward(&cache, &dout);

        let eps = 1e-3f32;
        // probe a handful of weights in every layer
        for layer in 0..mlp.ws.len() {
            for &idx in &[0usize, 1, mlp.ws[layer].data.len() - 1] {
                let orig = mlp.ws[layer].data[idx];
                mlp.ws[layer].data[idx] = orig + eps;
                let lp = 0.5 * mlp.forward(&x).output.data[0].powi(2);
                mlp.ws[layer].data[idx] = orig - eps;
                let lm = 0.5 * mlp.forward(&x).output.data[0].powi(2);
                mlp.ws[layer].data[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = dws[layer].data[idx];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
                    "layer {layer} idx {idx}: fd={fd} analytic={an}"
                );
            }
            let orig = mlp.bs[layer][0];
            mlp.bs[layer][0] = orig + eps;
            let lp = 0.5 * mlp.forward(&x).output.data[0].powi(2);
            mlp.bs[layer][0] = orig - eps;
            let lm = 0.5 * mlp.forward(&x).output.data[0].powi(2);
            mlp.bs[layer][0] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - dbs[layer][0]).abs() < 2e-2 * (1.0 + fd.abs()));
        }
    }

    #[test]
    fn adam_reduces_regression_loss() {
        let mut rng = Pcg32::seeded(3);
        let mut mlp = Mlp::new(&[4, 16, 12, 8, 1], &mut rng);
        let mut adam = Adam::new(&mlp, 3e-3);
        // target function: y = sum(x)
        let data: Vec<(Vec<f32>, f32)> = (0..64)
            .map(|_| {
                let x: Vec<f32> = (0..4).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
                let y = x.iter().sum::<f32>();
                (x, y)
            })
            .collect();
        let loss = |mlp: &Mlp| -> f32 {
            data.iter()
                .map(|(x, y)| {
                    let t = Tensor2::from_vec(1, 4, x.clone());
                    (mlp.forward(&t).output.data[0] - y).powi(2)
                })
                .sum::<f32>()
                / data.len() as f32
        };
        let l0 = loss(&mlp);
        for _ in 0..300 {
            let mut dout_all = Vec::new();
            // full-batch gradient
            let xs = Tensor2::from_vec(
                data.len(),
                4,
                data.iter().flat_map(|(x, _)| x.clone()).collect(),
            );
            let cache = mlp.forward(&xs);
            for (i, (_, y)) in data.iter().enumerate() {
                dout_all.push(2.0 * (cache.output.data[i] - y) / data.len() as f32);
            }
            let dout = Tensor2::from_vec(data.len(), 1, dout_all);
            let (dws, dbs) = mlp.backward(&cache, &dout);
            adam.step(&mut mlp, &dws, &dbs);
        }
        let l1 = loss(&mlp);
        assert!(l1 < l0 * 0.05, "loss {l0} -> {l1}");
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mut rng = Pcg32::seeded(4);
        let mlp = tiny(&mut rng);
        let j = mlp.to_json();
        let back = Mlp::from_json(&j).unwrap();
        assert_eq!(mlp.dims(), back.dims());
        for (a, b) in mlp.ws.iter().zip(back.ws.iter()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn huber_clips() {
        assert_eq!(huber_grad(5.0, 0.0), 1.0);
        assert_eq!(huber_grad(-5.0, 0.0), -1.0);
        assert!((huber_grad(0.3, 0.0) - 0.3).abs() < 1e-7);
    }
}
