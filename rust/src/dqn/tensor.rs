//! Minimal dense 2-D tensor for the from-scratch DQN (row-major f32).
//!
//! The hot path is `matmul` / `matmul_tn` / `matmul_nt` — these delegate
//! to the packed register-blocked kernels in `gemm.rs`, which keep the
//! historical per-element accumulation order (full-K sequential,
//! ascending k from +0.0) so results stay bit-identical to the old
//! naive triple loops; `rust/tests/gemm_parity.rs` gates this.

use super::gemm;
use crate::util::Pcg32;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor2 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Self { rows, cols, data }
    }

    /// He-initialized weights (relu-friendly).
    pub fn he_init(rows: usize, cols: usize, rng: &mut Pcg32) -> Self {
        let std = (2.0 / rows as f64).sqrt();
        let data = (0..rows * cols)
            .map(|_| (rng.normal() * std) as f32)
            .collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// out = self (m,k) @ other (k,n); writes into a caller-provided
    /// buffer (fully overwritten) to keep the agent's act() and the
    /// batched target forward allocation-free.
    pub fn matmul_into(&self, other: &Tensor2, out: &mut Tensor2) {
        assert_eq!(self.cols, other.rows);
        assert_eq!((out.rows, out.cols), (self.rows, other.cols));
        let (m, k, n) = (self.rows, self.cols, other.cols);
        gemm::gemm_nn(m, k, n, &self.data, &other.data, &mut out.data);
    }

    pub fn matmul(&self, other: &Tensor2) -> Tensor2 {
        let mut out = Tensor2::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// self^T (k,m)^T=(m,k) … out = self^T @ other: (cols_a, cols_b).
    pub fn matmul_tn(&self, other: &Tensor2) -> Tensor2 {
        assert_eq!(self.rows, other.rows);
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Tensor2::zeros(m, n);
        gemm::gemm_tn(k, m, n, &self.data, &other.data, &mut out.data);
        out
    }

    /// out = self @ other^T: (rows_a, rows_b).
    pub fn matmul_nt(&self, other: &Tensor2) -> Tensor2 {
        assert_eq!(self.cols, other.cols);
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Tensor2::zeros(m, n);
        gemm::gemm_nt(m, k, n, &self.data, &other.data, &mut out.data);
        out
    }

    /// Reshape in place, reusing the existing allocation where possible
    /// (new elements, if any, are zero; existing data is NOT preserved
    /// in any meaningful layout). Scratch-buffer helper for the batched
    /// inference path.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    pub fn add_row_bias(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (x, &b) in row.iter_mut().zip(bias.iter()) {
                *x += b;
            }
        }
    }

    pub fn relu_inplace(&mut self) {
        for x in &mut self.data {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
    }

    /// Gradient mask: zero where the forward activation was <= 0.
    pub fn relu_backward_inplace(&mut self, forward: &Tensor2) {
        assert_eq!(self.shape(), forward.shape());
        for (g, &f) in self.data.iter_mut().zip(forward.data.iter()) {
            if f <= 0.0 {
                *g = 0.0;
            }
        }
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// column-wise sum (for bias gradients): (1, cols).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (o, &x) in out.iter_mut().zip(self.row(r)) {
                *o += x;
            }
        }
        out
    }

    pub fn argmax_row(&self, r: usize) -> usize {
        let row = self.row(r);
        let mut best = 0;
        for (i, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(rows: usize, cols: usize, xs: &[f32]) -> Tensor2 {
        Tensor2::from_vec(rows, cols, xs.to_vec())
    }

    #[test]
    fn matmul_known() {
        let a = t(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = t(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_tn_equals_explicit_transpose() {
        let a = t(3, 2, &[1., 2., 3., 4., 5., 6.]); // (3,2)
        let b = t(3, 2, &[1., 0., 0., 1., 1., 1.]); // (3,2)
        // a^T @ b = (2,2)
        let c = a.matmul_tn(&b);
        assert_eq!(c.data, vec![1. + 0. + 5., 0. + 3. + 5., 2. + 0. + 6., 0. + 4. + 6.]);
    }

    #[test]
    fn matmul_nt_equals_manual() {
        let a = t(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = t(2, 3, &[1., 1., 1., 2., 0., 1.]);
        let c = a.matmul_nt(&b); // (2,2)
        assert_eq!(c.data, vec![6., 5., 15., 14.]);
    }

    #[test]
    fn relu_and_backward() {
        let mut x = t(1, 4, &[-1., 0., 2., -3.]);
        let fwd = {
            let mut f = x.clone();
            f.relu_inplace();
            f
        };
        assert_eq!(fwd.data, vec![0., 0., 2., 0.]);
        x = t(1, 4, &[10., 10., 10., 10.]);
        x.relu_backward_inplace(&fwd);
        assert_eq!(x.data, vec![0., 0., 10., 0.]);
    }

    #[test]
    fn bias_and_colsums() {
        let mut x = t(2, 2, &[1., 2., 3., 4.]);
        x.add_row_bias(&[10., 20.]);
        assert_eq!(x.data, vec![11., 22., 13., 24.]);
        assert_eq!(x.col_sums(), vec![24., 46.]);
    }

    #[test]
    fn argmax() {
        let x = t(2, 3, &[1., 5., 2., 9., 0., 3.]);
        assert_eq!(x.argmax_row(0), 1);
        assert_eq!(x.argmax_row(1), 0);
    }

    #[test]
    fn he_init_statistics() {
        let mut rng = Pcg32::seeded(1);
        let w = Tensor2::he_init(256, 128, &mut rng);
        let mean: f32 = w.data.iter().sum::<f32>() / w.data.len() as f32;
        let var: f32 =
            w.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / w.data.len() as f32;
        assert!(mean.abs() < 0.01);
        assert!((var - 2.0 / 256.0).abs() < 0.002, "var={var}");
    }
}
