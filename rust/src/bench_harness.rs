//! Tiny benchmark harness for `benches/*.rs` (criterion is unavailable
//! offline): warmup + timed iterations with mean/p50/p99 and throughput,
//! plus the table-printing entry the per-figure benches use.

use crate::telemetry::Table;
use crate::util::Samples;
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} iters={:<5} mean={:>10.3?} p50={:>10.3?} p99={:>10.3?}",
            self.name,
            self.iters,
            std::time::Duration::from_secs_f64(self.mean_s),
            std::time::Duration::from_secs_f64(self.p50_s),
            std::time::Duration::from_secs_f64(self.p99_s),
        )
    }
}

/// Time `f` for up to `iters` iterations (after `warmup` runs).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: s.mean(),
        p50_s: s.p50(),
        p99_s: s.p99(),
    }
}

/// Standard main body for a per-figure bench target: run the experiment,
/// print the paper-style table and the wall-clock, honoring
/// `DVFO_BENCH_FULL=1` for the non-quick variant and
/// `DVFO_BENCH_THREADS=N` for the parallel sweep runner (the table
/// bytes are thread-count-invariant; only the wall-clock moves).
pub fn run_experiment_bench(id: &str) {
    let quick = std::env::var("DVFO_BENCH_FULL").map(|v| v != "1").unwrap_or(true);
    let threads = std::env::var("DVFO_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let t0 = Instant::now();
    match crate::experiments::run_by_name(id, quick, threads) {
        Ok(table) => {
            println!("== {id} ({}) ==", if quick { "quick" } else { "full" });
            println!("{}", table.render());
            println!("[{id}] regenerated in {:?}", t0.elapsed());
        }
        Err(e) => {
            eprintln!("[{id}] FAILED: {e:#}");
            std::process::exit(1);
        }
    }
}

/// Render helper used by benches that also dump CSV artifacts.
pub fn save_csv(table: &Table, path: &str) {
    if let Some(parent) = std::path::Path::new(path).parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    let _ = std::fs::write(path, table.to_csv());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 20);
        assert!(r.mean_s >= 0.0 && r.p99_s >= r.p50_s);
        assert!(r.report().contains("noop-ish"));
    }
}
