//! Streaming report sinks: constant-memory telemetry for million-task
//! serving runs.
//!
//! The engine kernel historically collected every completed
//! [`TaskReport`] into a `Vec`, so run size was capped by RAM (a report
//! plus its job bookkeeping is on the order of a kilobyte). This module
//! splits report *consumption* out of the kernel behind the
//! [`ReportSink`] trait:
//!
//! * `CollectSink` (in `coordinator::engine`) keeps today's behavior —
//!   every report retained, in arrival order, bit-exact with the
//!   pre-sink engine — and stays the default.
//! * [`StreamingSink`] (here) folds each report into mergeable
//!   quantile sketches and per-device / per-SLO-class counters the
//!   moment it completes, then drops it. Memory is bounded by the
//!   sketch bucket span and the device count, never by task count.
//!
//! The sketch is a DDSketch-style log-bucketed quantile estimator with
//! a guaranteed *relative* error bound: every estimate is within
//! `relative_error()` of some true sample at the queried rank. The
//! property gate in `rust/tests/streaming_sink.rs` checks that bound
//! against the exact `util::stats::Samples` percentiles on randomized
//! workloads.

use crate::coordinator::TaskReport;
use crate::util::stats::Running;
use std::collections::BTreeMap;

/// Default relative-error target for [`QuantileSketch`]: estimates are
/// within 1% of a true sample at the queried rank.
pub const SKETCH_RELATIVE_ERROR: f64 = 0.01;

/// Values with magnitude at or below this land in the exact zero
/// bucket (log-bucketing cannot represent 0).
const ZERO_EPS: f64 = 1e-12;

/// Log-bucketed (DDSketch-style) streaming quantile estimator.
///
/// A value `x > 0` lands in bucket `ceil(ln(x) / ln(gamma))` with
/// `gamma = (1 + a) / (1 - a)`; the bucket midpoint estimate
/// `2 * gamma^k / (gamma + 1)` is then within relative error `a` of
/// every value the bucket can hold. Negative values mirror into their
/// own bucket map, near-zero values into an exact zero bucket, and NaN
/// samples count into a trailing slot (mirroring how
/// `Samples::percentile` sorts NaN after `+inf` via `total_cmp`).
///
/// Memory is proportional to the number of *occupied* buckets — for
/// `a = 0.01` the entire positive f64 range spans ~36k buckets and a
/// realistic latency/energy range (say 1e-6 .. 1e6) about 1400, no
/// matter how many samples stream through.
#[derive(Clone, Debug)]
pub struct QuantileSketch {
    alpha: f64,
    ln_gamma: f64,
    /// estimate multiplier: 2 / (gamma + 1), so `value(k) = mult * gamma^k`
    mult: f64,
    /// buckets for positive values, key = ceil(ln(x)/ln(gamma))
    pos: BTreeMap<i32, u64>,
    /// buckets for negative values, key from ln(-x)
    neg: BTreeMap<i32, u64>,
    zero: u64,
    nan: u64,
    count: u64,
    run: Running,
}

impl QuantileSketch {
    /// Sketch with relative-error target `alpha` in (0, 1).
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha < 1.0, "alpha must be in (0, 1)");
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        Self {
            alpha,
            ln_gamma: gamma.ln(),
            mult: 2.0 / (gamma + 1.0),
            pos: BTreeMap::new(),
            neg: BTreeMap::new(),
            zero: 0,
            nan: 0,
            count: 0,
            run: Running::new(),
        }
    }

    /// The guaranteed relative error bound `alpha`.
    pub fn relative_error(&self) -> f64 {
        self.alpha
    }

    fn key(&self, magnitude: f64) -> i32 {
        // clamp into i32: |ln(x)/ln(gamma)| for finite f64 stays far
        // below i32::MAX for any practical alpha
        (magnitude.ln() / self.ln_gamma).ceil() as i32
    }

    fn value(&self, key: i32) -> f64 {
        self.mult * (key as f64 * self.ln_gamma).exp()
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        self.run.push(x);
        if x.is_nan() {
            self.nan += 1;
        } else if x.abs() <= ZERO_EPS {
            self.zero += 1;
        } else if x > 0.0 {
            *self.pos.entry(self.key(x)).or_insert(0) += 1;
        } else {
            *self.neg.entry(self.key(-x)).or_insert(0) += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact running mean over everything pushed (not sketched).
    pub fn mean(&self) -> f64 {
        self.run.mean()
    }

    pub fn min(&self) -> f64 {
        self.run.min()
    }

    pub fn max(&self) -> f64 {
        self.run.max()
    }

    /// Number of occupied buckets — the memory footprint driver.
    pub fn buckets(&self) -> usize {
        self.neg.len() + self.pos.len()
    }

    /// Percentile estimate in `[0, 100]`.
    ///
    /// The estimate is within `relative_error()` of the true sample at
    /// rank `round(p/100 * (n-1))` — i.e. within the rounding slack of
    /// the linearly-interpolated `Samples::percentile(p)`.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut cum: u64 = 0;
        // ascending value order: most-negative first (largest |x| key),
        // then zero, then positives, then NaN (total_cmp order)
        for (&k, &c) in self.neg.iter().rev() {
            cum += c;
            if cum > rank {
                return -self.value(k);
            }
        }
        cum += self.zero;
        if cum > rank {
            return 0.0;
        }
        for (&k, &c) in self.pos.iter() {
            cum += c;
            if cum > rank {
                return self.value(k);
            }
        }
        f64::NAN
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Fold another sketch of the same `alpha` into this one. Bucket
    /// counts add, so a merged sketch answers queries exactly as if it
    /// had seen both streams.
    pub fn merge(&mut self, other: &QuantileSketch) {
        assert!(
            (self.alpha - other.alpha).abs() < 1e-15,
            "cannot merge sketches with different error targets"
        );
        for (&k, &c) in &other.pos {
            *self.pos.entry(k).or_insert(0) += c;
        }
        for (&k, &c) in &other.neg {
            *self.neg.entry(k).or_insert(0) += c;
        }
        self.zero += other.zero;
        self.nan += other.nan;
        self.count += other.count;
        self.run.merge(&other.run);
    }
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new(SKETCH_RELATIVE_ERROR)
    }
}

/// Completion-time context the engine hands a sink alongside the
/// report: which device served the task, the SLO deadline and priority
/// class it carried, and its global arrival index (admission order).
#[derive(Clone, Copy, Debug)]
pub struct JobMeta {
    /// index of the device that served the task
    pub dev: usize,
    /// absolute SLO deadline (`f64::INFINITY` = no deadline)
    pub deadline_s: f64,
    /// SLO priority class (0 = best-effort)
    pub priority: usize,
    /// admission-order index among accepted tasks
    pub arrival_idx: usize,
}

/// Where the engine delivers each completed task report.
///
/// Implementations decide what to retain: `CollectSink` keeps every
/// report (the pre-sink behavior, still the default), `StreamingSink`
/// folds each into constant-memory sketches and counters.
pub trait ReportSink {
    /// Consume one completed task's report.
    fn push(&mut self, meta: &JobMeta, report: TaskReport);

    /// A job reached a terminal non-completion (retry budget exhausted
    /// or shed while draining a downed device): the sink learns its
    /// identity but there is no report. Default no-op — the engine's
    /// own `failed`/`shed` counters carry the aggregate; `CollectSink`
    /// overrides this to keep its admission-order table dense.
    fn fail(&mut self, _meta: &JobMeta) {}

    /// Whether the engine should also retain unbounded per-event traces
    /// (e.g. the exact cloud-occupancy sample buffer). Collecting sinks
    /// keep them for bit-exact replay; streaming sinks drop them and
    /// rely on the running aggregates instead.
    fn keep_trace(&self) -> bool {
        true
    }
}

/// Per-SLO-class streaming counters.
#[derive(Clone, Debug, Default)]
pub struct ClassCounters {
    pub completed: usize,
    pub violations: usize,
}

/// Constant-memory telemetry sink: online quantile sketches for the
/// headline latency/energy distributions plus per-device and
/// per-SLO-class counters. Mergeable across engine shards.
#[derive(Clone, Debug, Default)]
pub struct StreamingSink {
    /// end-to-end latency sketch (ms)
    pub e2e_ms: QuantileSketch,
    /// total inference latency sketch (ms)
    pub tti_ms: QuantileSketch,
    /// queue-wait sketch (ms)
    pub queue_wait_ms: QuantileSketch,
    /// per-task energy sketch (mJ)
    pub eti_mj: QuantileSketch,
    /// completed-task count
    pub completed: usize,
    /// completed tasks that missed their deadline
    pub violations: usize,
    /// completed tasks inside their deadline
    pub goodput: usize,
    /// tasks served per device (index = device)
    pub dev_served: Vec<usize>,
    /// energy per device in joules (index = device)
    pub dev_energy_j: Vec<f64>,
    /// deadline misses per device (index = device)
    pub dev_violations: Vec<usize>,
    /// counters keyed by SLO priority class
    pub per_class: BTreeMap<usize, ClassCounters>,
}

impl StreamingSink {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure_dev(&mut self, dev: usize) {
        if self.dev_served.len() <= dev {
            self.dev_served.resize(dev + 1, 0);
            self.dev_energy_j.resize(dev + 1, 0.0);
            self.dev_violations.resize(dev + 1, 0);
        }
    }

    /// Fold another sink into this one, offsetting its device indices
    /// by `dev_base` (shard k owns a contiguous device range starting
    /// at its base).
    pub fn merge_offset(&mut self, other: &StreamingSink, dev_base: usize) {
        self.e2e_ms.merge(&other.e2e_ms);
        self.tti_ms.merge(&other.tti_ms);
        self.queue_wait_ms.merge(&other.queue_wait_ms);
        self.eti_mj.merge(&other.eti_mj);
        self.completed += other.completed;
        self.violations += other.violations;
        self.goodput += other.goodput;
        if !other.dev_served.is_empty() {
            self.ensure_dev(dev_base + other.dev_served.len() - 1);
            for (i, &n) in other.dev_served.iter().enumerate() {
                self.dev_served[dev_base + i] += n;
            }
            for (i, &e) in other.dev_energy_j.iter().enumerate() {
                self.dev_energy_j[dev_base + i] += e;
            }
            for (i, &v) in other.dev_violations.iter().enumerate() {
                self.dev_violations[dev_base + i] += v;
            }
        }
        for (&class, c) in &other.per_class {
            let e = self.per_class.entry(class).or_default();
            e.completed += c.completed;
            e.violations += c.violations;
        }
    }
}

impl ReportSink for StreamingSink {
    fn push(&mut self, meta: &JobMeta, r: TaskReport) {
        // identical end-to-end fallback and violation test to the
        // collecting fleet fold, so counters agree *exactly* between
        // sinks on the same trace (gated by tests/streaming_sink.rs)
        let e2e_s = if r.e2e_s > 0.0 {
            r.e2e_s
        } else {
            r.queue_wait_s + r.tti_total_s
        };
        let violated = meta.deadline_s.is_finite() && e2e_s > meta.deadline_s;
        self.completed += 1;
        if violated {
            self.violations += 1;
        } else {
            self.goodput += 1;
        }
        self.e2e_ms.push(e2e_s * 1e3);
        self.tti_ms.push(r.tti_total_s * 1e3);
        self.queue_wait_ms.push(r.queue_wait_s * 1e3);
        self.eti_mj.push(r.eti_total_j * 1e3);
        self.ensure_dev(meta.dev);
        self.dev_served[meta.dev] += 1;
        self.dev_energy_j[meta.dev] += r.eti_total_j;
        if violated {
            self.dev_violations[meta.dev] += 1;
        }
        let c = self.per_class.entry(meta.priority).or_default();
        c.completed += 1;
        if violated {
            c.violations += 1;
        }
    }

    fn keep_trace(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Samples;

    fn bound_holds(xs: &[f64], sk: &QuantileSketch, p: f64) -> Result<(), String> {
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = sorted[rank.floor() as usize];
        let hi = sorted[rank.ceil() as usize];
        let a = sk.relative_error();
        let est = sk.percentile(p);
        // est is within `a` (relative) of the sample at the rounded
        // rank, which is one of the two interpolation endpoints
        let lo_b = lo.min(hi) * (1.0 - a) - 1e-9;
        let hi_b = lo.max(hi) * (1.0 + a) + 1e-9;
        if est >= lo_b && est <= hi_b {
            Ok(())
        } else {
            Err(format!("p{p}: est {est} outside [{lo_b}, {hi_b}]"))
        }
    }

    #[test]
    fn sketch_tracks_exact_percentiles() {
        let mut sk = QuantileSketch::default();
        let mut s = Samples::new();
        let mut xs = Vec::new();
        // deterministic scramble spanning five orders of magnitude
        for i in 0u64..4096 {
            let x = (((i * 2654435761) % 100_000) as f64) / 10.0 + 0.05;
            sk.push(x);
            s.push(x);
            xs.push(x);
        }
        for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            bound_holds(&xs, &sk, p).unwrap();
            // and the sketch stays close to the interpolated exact value
            let exact = s.percentile(p);
            assert!(
                (sk.percentile(p) - exact).abs() <= 0.02 * exact.abs() + 1e-6,
                "p{p}: {} vs exact {exact}",
                sk.percentile(p)
            );
        }
        assert!((sk.mean() - s.mean()).abs() < 1e-9, "mean is exact");
        assert!(sk.buckets() < 2500, "bucket count bounded by value span");
    }

    #[test]
    fn sketch_handles_zero_negative_and_nan() {
        let mut sk = QuantileSketch::default();
        for x in [-4.0, -2.0, 0.0, 0.0, 1.0, 8.0, f64::NAN] {
            sk.push(x);
        }
        assert_eq!(sk.count(), 7);
        assert!((sk.percentile(0.0) + 4.0).abs() <= 4.0 * 0.01 + 1e-9);
        // rank 3 of 7 is the second zero
        assert_eq!(sk.p50(), 0.0);
        assert!(sk.percentile(100.0).is_nan(), "NaN sorts last");
    }

    #[test]
    fn empty_sketch_is_nan() {
        let sk = QuantileSketch::default();
        assert!(sk.percentile(50.0).is_nan());
        assert!(sk.is_empty());
    }

    #[test]
    fn merge_equals_concat() {
        let xs: Vec<f64> = (0..500).map(|i| ((i * 37) % 211) as f64 + 0.5).collect();
        let (a, b) = xs.split_at(180);
        let mut sa = QuantileSketch::default();
        let mut sb = QuantileSketch::default();
        let mut sc = QuantileSketch::default();
        a.iter().for_each(|&x| sa.push(x));
        b.iter().for_each(|&x| sb.push(x));
        xs.iter().for_each(|&x| sc.push(x));
        sa.merge(&sb);
        assert_eq!(sa.count(), sc.count());
        for p in [1.0, 50.0, 95.0, 99.9] {
            assert_eq!(
                sa.percentile(p).to_bits(),
                sc.percentile(p).to_bits(),
                "merged sketch answers exactly like the concatenated one"
            );
        }
    }

    #[test]
    fn streaming_sink_counts_violations_per_device_and_class() {
        let mut sink = StreamingSink::new();
        let mut r = TaskReport::default();
        r.e2e_s = 0.1;
        r.eti_total_j = 0.2;
        sink.push(
            &JobMeta { dev: 1, deadline_s: 0.05, priority: 1, arrival_idx: 0 },
            r.clone(),
        );
        sink.push(
            &JobMeta { dev: 0, deadline_s: f64::INFINITY, priority: 0, arrival_idx: 1 },
            r.clone(),
        );
        // e2e_s == 0 falls back to queue + tti (both 0 here): no violation
        r.e2e_s = 0.0;
        sink.push(
            &JobMeta { dev: 1, deadline_s: 0.05, priority: 1, arrival_idx: 2 },
            r,
        );
        assert_eq!((sink.completed, sink.violations, sink.goodput), (3, 1, 2));
        assert_eq!(sink.dev_served, vec![1, 2]);
        assert_eq!(sink.dev_violations, vec![0, 1]);
        assert!((sink.dev_energy_j[1] - 0.4).abs() < 1e-12);
        assert_eq!(sink.per_class[&1].completed, 2);
        assert_eq!(sink.per_class[&1].violations, 1);
        assert_eq!(sink.per_class[&0].violations, 0);
        assert!(!sink.keep_trace());
    }

    #[test]
    fn sink_merge_offsets_devices() {
        let mut a = StreamingSink::new();
        let mut b = StreamingSink::new();
        let r = TaskReport::default();
        let meta = |dev: usize, priority: usize| JobMeta {
            dev,
            deadline_s: f64::INFINITY,
            priority,
            arrival_idx: 0,
        };
        a.push(&meta(0, 0), r.clone());
        b.push(&meta(1, 2), r);
        a.merge_offset(&b, 3);
        assert_eq!(a.dev_served, vec![1, 0, 0, 0, 1]);
        assert_eq!(a.completed, 2);
        assert_eq!(a.per_class[&2].completed, 1);
    }
}
