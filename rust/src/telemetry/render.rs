//! Shared summary renderers.
//!
//! `main.rs` historically carried its own copies of the summary-table
//! and counter-line formatting for the single-edge and fleet serve
//! paths, and the experiment sweeps re-derived the same percentile
//! cells inline — three slowly-drifting copies of one format. This
//! module is the single source: both serve paths, the streaming
//! (sharded) path, and the experiment tables all render through the
//! helpers here. `rust/tests/render_golden.rs` pins the output
//! byte-for-byte against the historical `main.rs` formatting.

use crate::coordinator::ServeSummary;
use crate::telemetry::sink::StreamingSink;
use crate::telemetry::Table;
use crate::util::Samples;

/// The headline metric table of a serving run: mean/p50/p95/p99 for
/// latency, queueing, energy, accuracy, offload proportion, and
/// payload. Exactly the table `dvfo serve` prints.
pub fn summary_table(s: &ServeSummary) -> Table {
    let mut t = Table::new(vec!["metric", "mean", "p50", "p95", "p99"]);
    for (name, s) in [
        ("tti ms", &s.tti_ms),
        ("queue ms", &s.queue_wait_ms),
        ("e2e ms", &s.e2e_ms),
        ("eti mJ", &s.eti_mj),
        ("accuracy %", &s.accuracy_pct),
        ("xi", &s.xi),
        ("payload KB", &s.payload_kb),
    ] {
        t.row(vec![
            name.to_string(),
            format!("{:.2}", s.mean()),
            format!("{:.2}", s.p50()),
            format!("{:.2}", s.p95()),
            format!("{:.2}", s.p99()),
        ]);
    }
    t
}

/// The same headline table from a constant-memory [`StreamingSink`]:
/// identical shape, sketch-estimated percentiles, and only the metrics
/// the sink tracks (the per-report-field trace buffers behind
/// accuracy/ξ/payload are exactly what streaming telemetry drops).
pub fn streaming_table(s: &StreamingSink) -> Table {
    let mut t = Table::new(vec!["metric", "mean", "p50", "p95", "p99"]);
    for (name, q) in [
        ("tti ms", &s.tti_ms),
        ("queue ms", &s.queue_wait_ms),
        ("e2e ms", &s.e2e_ms),
        ("eti mJ", &s.eti_mj),
    ] {
        t.row(vec![
            name.to_string(),
            format!("{:.2}", q.mean()),
            format!("{:.2}", q.p50()),
            format!("{:.2}", q.p95()),
            format!("{:.2}", q.p99()),
        ]);
    }
    t
}

/// The fleet accounting line: `offered=.. completed=.. shed=..
/// downgraded=.. violations=.. goodput=..`.
pub fn counters_line(
    offered: usize,
    completed: usize,
    shed: usize,
    downgraded: usize,
    violations: usize,
    goodput: usize,
) -> String {
    format!(
        "offered={offered} completed={completed} shed={shed} downgraded={downgraded} \
         violations={violations} goodput={goodput}"
    )
}

/// The rebalancing accounting line (callers gate it on the rebalance
/// knobs being enabled, like the cloud line).
pub fn rebalance_line(rerouted: usize, migrated: usize, migration_latency_s: f64) -> String {
    format!(
        "rebalance: rerouted={} migrated={} migration-latency={:.1}ms",
        rerouted,
        migrated,
        migration_latency_s * 1e3
    )
}

/// The fault-injection accounting line (callers gate it on a non-empty
/// fault schedule, like the rebalance line).
pub fn chaos_line(
    faults_injected: usize,
    retries: usize,
    failed: usize,
    drained_on_dropout: usize,
) -> String {
    format!(
        "chaos: faults={faults_injected} retries={retries} failed={failed} \
         drained={drained_on_dropout}"
    )
}

/// Per-device fault columns, appended to [`device_line`] output by
/// callers when a fault schedule is active. A separate suffix (rather
/// than another `Option` column on `device_line`) keeps the pinned
/// no-chaos device format byte-identical.
pub fn device_chaos_suffix(faults: usize, failed: usize) -> String {
    format!(" faults={faults} failed={failed}")
}

/// The cloud-batching accounting line (callers gate it on the window
/// being open and at least one invocation happening).
pub fn cloud_line(
    invocations: usize,
    mean_occupancy: f64,
    max_occupancy: f64,
    dispatch_saved_s: f64,
) -> String {
    format!(
        "cloud: invocations={} mean-occupancy={:.2} max-occupancy={:.0} \
         dispatch-saved={:.1}ms",
        invocations,
        mean_occupancy,
        max_occupancy,
        dispatch_saved_s * 1e3
    )
}

/// The batching-window accounting line: windows flushed with at least
/// one job, and the generation-stale close timers (tombstones left by
/// size-cap flushes) the kernel popped and discarded. Callers gate it
/// on at least one window having flushed.
pub fn stale_line(window_flushes: usize, stale_closes: usize) -> String {
    format!("batching: window-flushes={window_flushes} stale-closes={stale_closes}")
}

/// One per-device telemetry line. `rebalance` carries the
/// (rerouted-in, migrated-in, migrated-out) triple when the rebalance
/// columns are enabled, `None` otherwise.
pub fn device_line(
    name: &str,
    served: usize,
    energy_j: f64,
    violations: usize,
    rebalance: Option<(usize, usize, usize)>,
) -> String {
    let rebalance_cols = match rebalance {
        Some((rerouted_in, migrated_in, migrated_out)) => format!(
            " rerouted-in={rerouted_in} migrated-in={migrated_in} migrated-out={migrated_out}"
        ),
        None => String::new(),
    };
    format!(
        "  device {name:<12} served={served:<5} energy={energy_j:.1} J \
         violations={violations}{rebalance_cols}"
    )
}

/// Per-SLO-class accounting lines of a streaming run, one per class in
/// ascending priority order.
pub fn class_lines(s: &StreamingSink) -> Vec<String> {
    s.per_class
        .iter()
        .map(|(class, c)| {
            format!(
                "  class {class}: completed={} violations={}",
                c.completed, c.violations
            )
        })
        .collect()
}

/// `{:.1}`-formatted percentile cells — the convention every experiment
/// sweep table uses for its latency columns.
pub fn quantile_cells(s: &Samples, percentiles: &[f64]) -> Vec<String> {
    percentiles
        .iter()
        .map(|&p| format!("{:.1}", s.percentile(p)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_lines_match_the_historical_format() {
        assert_eq!(
            counters_line(10, 8, 2, 1, 3, 5),
            "offered=10 completed=8 shed=2 downgraded=1 violations=3 goodput=5"
        );
        assert_eq!(
            rebalance_line(4, 2, 0.0123),
            "rebalance: rerouted=4 migrated=2 migration-latency=12.3ms"
        );
        assert_eq!(
            cloud_line(7, 1.5, 3.0, 0.004),
            "cloud: invocations=7 mean-occupancy=1.50 max-occupancy=3 dispatch-saved=4.0ms"
        );
        assert_eq!(
            stale_line(9, 4),
            "batching: window-flushes=9 stale-closes=4"
        );
        assert_eq!(
            chaos_line(3, 7, 2, 5),
            "chaos: faults=3 retries=7 failed=2 drained=5"
        );
        assert_eq!(device_chaos_suffix(2, 1), " faults=2 failed=1");
        assert_eq!(
            device_line("xavier-nx", 12, 3.14159, 2, None),
            "  device xavier-nx    served=12    energy=3.1 J violations=2"
        );
        assert_eq!(
            device_line("jetson-nano", 5, 0.5, 0, Some((1, 2, 3))),
            "  device jetson-nano  served=5     energy=0.5 J violations=0 \
             rerouted-in=1 migrated-in=2 migrated-out=3"
        );
    }

    #[test]
    fn quantile_cells_format_like_the_sweeps() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(
            quantile_cells(&s, &[50.0, 90.0, 99.0]),
            vec!["50.5", "90.1", "99.0"]
        );
    }

    #[test]
    fn streaming_table_mirrors_the_summary_shape() {
        use crate::coordinator::TaskReport;
        use crate::telemetry::sink::{JobMeta, ReportSink};
        let mut sink = StreamingSink::new();
        let mut r = TaskReport::default();
        r.e2e_s = 0.25;
        r.tti_total_s = 0.2;
        r.queue_wait_s = 0.05;
        r.eti_total_j = 0.003;
        sink.push(
            &JobMeta {
                dev: 0,
                deadline_s: f64::INFINITY,
                priority: 0,
                arrival_idx: 0,
            },
            r,
        );
        let rendered = streaming_table(&sink).render();
        let lines: Vec<&str> = rendered.lines().collect();
        // header + rule + 4 metric rows
        assert_eq!(lines.len(), 6);
        assert!(lines[0].contains("p95"));
        assert!(rendered.contains("tti ms"));
        assert!(rendered.contains("eti mJ"));
    }

    #[test]
    fn class_lines_order_by_priority() {
        use crate::coordinator::TaskReport;
        use crate::telemetry::sink::{JobMeta, ReportSink};
        let mut sink = StreamingSink::new();
        for (prio, ddl) in [(2usize, f64::INFINITY), (0, -1.0), (2, f64::INFINITY)] {
            sink.push(
                &JobMeta {
                    dev: 0,
                    deadline_s: ddl,
                    priority: prio,
                    arrival_idx: 0,
                },
                TaskReport::default(),
            );
        }
        assert_eq!(
            class_lines(&sink),
            vec![
                "  class 0: completed=1 violations=1",
                "  class 2: completed=2 violations=0",
            ]
        );
    }
}
