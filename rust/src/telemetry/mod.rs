//! Telemetry: counters, histograms, per-phase timelines, and text-table
//! rendering for experiment reports (the benches print paper-style rows).
//!
//! Submodules: [`sink`] holds the streaming report sinks (the
//! `ReportSink` trait, the quantile sketch, and `StreamingSink`);
//! [`render`] holds the shared summary renderers used by every serve
//! path in `main.rs`.

pub mod render;
pub mod sink;

use crate::util::{Running, Samples};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Lock-free counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Registry of named metrics shared across coordinator threads.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    samples: Mutex<BTreeMap<String, Samples>>,
    running: Mutex<BTreeMap<String, Running>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn count(&self, name: &str, n: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += n;
    }

    pub fn observe(&self, name: &str, x: f64) {
        self.samples
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(x);
        self.running
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(Running::new)
            .push(x);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn mean(&self, name: &str) -> f64 {
        self.running
            .lock()
            .unwrap()
            .get(name)
            .map(|r| r.mean())
            .unwrap_or(f64::NAN)
    }

    pub fn sum(&self, name: &str) -> f64 {
        self.running
            .lock()
            .unwrap()
            .get(name)
            .map(|r| r.sum())
            .unwrap_or(0.0)
    }

    pub fn percentile(&self, name: &str, p: f64) -> f64 {
        self.samples
            .lock()
            .unwrap()
            .get(name)
            .map(|s| s.percentile(p))
            .unwrap_or(f64::NAN)
    }

    pub fn observation_count(&self, name: &str) -> usize {
        self.samples
            .lock()
            .unwrap()
            .get(name)
            .map(|s| s.len())
            .unwrap_or(0)
    }

    /// Render all metrics as an aligned text report.
    pub fn report(&self) -> String {
        let mut t = Table::new(vec!["metric", "count/mean", "p50", "p99"]);
        for (k, v) in self.counters.lock().unwrap().iter() {
            t.row(vec![k.clone(), v.to_string(), String::new(), String::new()]);
        }
        let samples = self.samples.lock().unwrap();
        for (k, r) in self.running.lock().unwrap().iter() {
            let s = &samples[k];
            t.row(vec![
                k.clone(),
                format!("{:.4}", r.mean()),
                format!("{:.4}", s.p50()),
                format!("{:.4}", s.p99()),
            ]);
        }
        t.render()
    }
}

/// Simple aligned text table (markdown-ish) for experiment outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let mut r: Vec<String> = cells.into_iter().map(Into::into).collect();
        r.resize(self.headers.len(), String::new());
        self.rows.push(r);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let pad = w - c.chars().count();
                line.push(' ');
                line.push_str(c);
                line.push_str(&" ".repeat(pad + 1));
                line.push('|');
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering for machine consumption.
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(esc)
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.count("req", 3);
        m.count("req", 2);
        assert_eq!(m.counter("req"), 5);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn observations_summarize() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("lat", i as f64);
        }
        assert!((m.mean("lat") - 50.5).abs() < 1e-9);
        assert!((m.percentile("lat", 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(m.observation_count("lat"), 100);
        assert!((m.sum("lat") - 5050.0).abs() < 1e-6);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["a", "bb"]);
        t.row(vec!["xxx", "1"]);
        t.row(vec!["y"]);
        let s = t.render();
        let lines: Vec<_> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("xxx"));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(vec!["k", "v"]);
        t.row(vec!["a,b", "say \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn atomic_counter() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }
}
