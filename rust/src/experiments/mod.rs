//! Experiment harness: one function per paper table/figure, each
//! regenerating the corresponding rows/series (DESIGN.md §4 experiment
//! index). Shared by the `dvfo` CLI (`dvfo experiment <id>`) and the
//! `benches/` targets.
//!
//! The grid sweeps (fig08/fig11/fig12/fig13 and the serving sweeps
//! load/fleet/cloudbatch/rebalance) run their cells through
//! [`crate::util::parallel::sweep`] behind a `threads` knob
//! (`dvfo experiment --threads N`, config key `threads`,
//! `DVFO_BENCH_THREADS` for the bench targets). Cells share nothing —
//! each builds its own config, coordinator, and per-cell-seeded task
//! generators — and rows are reassembled in cell-index order, so the
//! threaded tables are byte-identical to the serial ones (gated by
//! `rust/tests/sweep_determinism.rs`).

use crate::configx::Config;
use crate::coordinator::Coordinator;
use crate::device::spec::find_device;
use crate::device::{EnergyMeter, FreqVector};
use crate::perfmodel::{edge_compute, find_model, latency_per_mj, Dataset};
use crate::scam::ImportanceDist;
use crate::telemetry::{render, Table};
use crate::util::Pcg32;
use crate::workload::{Arrivals, TaskGen};
use anyhow::Result;

/// Fan a cell list out over the sweep runner and flatten each cell's
/// rows back in cell order. The first failing cell (in cell order, not
/// completion order) reports its error.
fn sweep_rows<C, F>(threads: usize, cells: &[C], f: F) -> Result<Vec<Vec<String>>>
where
    C: Sync,
    F: Fn(&C) -> Result<Vec<Vec<String>>> + Sync,
{
    let results = crate::util::parallel::sweep(threads, cells.len(), |i| f(&cells[i]));
    let mut rows = Vec::new();
    for r in results {
        rows.extend(r?);
    }
    Ok(rows)
}

/// Train-then-serve one (policy, model, dataset, device, bandwidth) cell.
pub fn run_cell(
    policy: &str,
    model: &str,
    dataset: &str,
    device: &str,
    bandwidth: &str,
    eta: f64,
    lambda: f64,
    requests: usize,
    train_episodes: usize,
    seed: u64,
) -> Result<crate::coordinator::ServeSummary> {
    let mut cfg = Config::default();
    cfg.policy = policy.into();
    cfg.model = model.into();
    cfg.dataset = dataset.into();
    cfg.device = device.into();
    cfg.bandwidth = bandwidth.into();
    cfg.eta = eta;
    cfg.lambda = lambda;
    cfg.requests = requests;
    cfg.seed = seed;
    let mut coord = Coordinator::from_config(&cfg)?;
    let mut gen = TaskGen::new(model, coord.env.dataset, Arrivals::Sequential, seed ^ 0x51)?;
    if policy == "dvfo" || policy == "drldo" {
        coord.train(&mut gen, train_episodes, 24);
    }
    let tasks = gen.take(requests);
    Ok(coord.serve(&tasks))
}

// ======================================================================
// Fig. 1 — normalized CPU/GPU/MEM energy for four models on Xavier NX
// ======================================================================
pub fn fig01_energy_breakdown() -> Result<Table> {
    let mut t = Table::new(vec![
        "model", "cpu (norm)", "gpu (norm)", "mem (norm)", "gpu/cpu", "paper gpu/cpu",
    ]);
    let spec = find_device("xavier-nx")?;
    let f = FreqVector {
        cpu_mhz: spec.cpu.max_mhz,
        gpu_mhz: spec.gpu.max_mhz,
        mem_mhz: spec.mem.max_mhz,
    };
    for model in ["resnet-18", "mobilenet-v2", "efficientnet-b0", "vit-b16"] {
        let m = find_model(model)?;
        let phase = edge_compute(&m, Dataset::Cifar100, &spec, &f, 1.0);
        let mut meter = EnergyMeter::new();
        meter.accumulate(&spec, &f, &phase.util, phase.total_s);
        let [cpu, gpu, mem] = meter.per_unit_j();
        let peak = gpu.max(cpu).max(mem);
        t.row(vec![
            model.to_string(),
            format!("{:.2}", cpu / peak),
            format!("{:.2}", gpu / peak),
            format!("{:.2}", mem / peak),
            format!("{:.2}x", gpu / cpu),
            "3.1-3.5x".into(),
        ]);
    }
    Ok(t)
}

// ======================================================================
// Fig. 2 — latency-per-mJ vs per-unit frequency sweeps
// ======================================================================
pub fn fig02_freq_sweep() -> Result<Table> {
    let mut t = Table::new(vec![
        "device", "model", "unit", "level", "freq MHz", "tti ms", "eti mJ", "perf (1/(s*mJ))",
    ]);
    for (device, model) in [
        ("jetson-nano", "efficientnet-b0"),
        ("jetson-nano", "vit-b16"),
        ("xavier-nx", "efficientnet-b0"),
        ("xavier-nx", "vit-b16"),
    ] {
        let spec = find_device(device)?;
        let m = find_model(model)?;
        for unit in ["cpu", "gpu", "mem"] {
            for lvl in (0..10).step_by(3) {
                let mut f = FreqVector {
                    cpu_mhz: spec.cpu.max_mhz,
                    gpu_mhz: spec.gpu.max_mhz,
                    mem_mhz: spec.mem.max_mhz,
                };
                match unit {
                    "cpu" => f.cpu_mhz = spec.cpu.freq_at(lvl),
                    "gpu" => f.gpu_mhz = spec.gpu.freq_at(lvl),
                    _ => f.mem_mhz = spec.mem.freq_at(lvl),
                }
                let phase = edge_compute(&m, Dataset::Cifar100, &spec, &f, 1.0);
                let mut meter = EnergyMeter::new();
                meter.accumulate(&spec, &f, &phase.util, phase.total_s);
                let eti = meter.total_j();
                let freq = match unit {
                    "cpu" => f.cpu_mhz,
                    "gpu" => f.gpu_mhz,
                    _ => f.mem_mhz,
                };
                t.row(vec![
                    device.to_string(),
                    model.to_string(),
                    unit.to_string(),
                    lvl.to_string(),
                    format!("{freq:.0}"),
                    format!("{:.2}", phase.total_s * 1e3),
                    format!("{:.1}", eti * 1e3),
                    format!("{:.3}", latency_per_mj(phase.total_s, eti)),
                ]);
            }
        }
    }
    Ok(t)
}

// ======================================================================
// Fig. 7 — descending importance contribution (SCAM skew)
// ======================================================================
pub fn fig07_importance() -> Result<Table> {
    let mut t = Table::new(vec!["rank", "synthetic (resnet-18)", "cumulative", "real artifact"]);
    let mut rng = Pcg32::seeded(7);
    let m = find_model("resnet-18")?;
    let mut acc: Vec<f64> = vec![0.0; 16];
    let n = 200;
    for _ in 0..n {
        let d = ImportanceDist::synthetic(16, m.importance_skew, &mut rng);
        let mut ps = d.probs().to_vec();
        ps.sort_by(|a, b| b.total_cmp(a));
        for (a, p) in acc.iter_mut().zip(ps.iter()) {
            *a += p / n as f64;
        }
    }
    // real-artifact column if built
    let real = crate::runtime::Manifest::load(std::path::Path::new("artifacts/manifest.json"))
        .ok()
        .map(|m| {
            let mut ps = m.mean_importance.clone();
            ps.sort_by(|a, b| b.total_cmp(a));
            ps
        });
    let mut cum = 0.0;
    for (i, &p) in acc.iter().enumerate() {
        cum += p;
        let r = real
            .as_ref()
            .and_then(|v| v.get(i))
            .map(|x| format!("{x:.3}"))
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            (i + 1).to_string(),
            format!("{p:.3}"),
            format!("{cum:.3}"),
            r,
        ]);
    }
    Ok(t)
}

// ======================================================================
// Fig. 8 — main comparison: E2E latency + energy, DVFO vs 4 baselines
// ======================================================================
pub fn fig08_main_comparison(requests: usize, train_eps: usize, threads: usize) -> Result<Table> {
    let mut t = Table::new(vec![
        "model", "dataset", "policy", "tti ms", "eti mJ", "Δtti vs edge", "Δeti vs edge",
    ]);
    // cell = (model, dataset): each cell needs its own edge baseline, so
    // that is the smallest self-contained unit of work
    let mut cells = Vec::new();
    for model in ["efficientnet-b0", "vit-b16"] {
        for dataset in ["cifar100", "imagenet"] {
            cells.push((model, dataset));
        }
    }
    let rows = sweep_rows(threads, &cells, |&(model, dataset)| {
        let edge = run_cell(
            "edge_only", model, dataset, "xavier-nx", "static:5", 0.5, 0.5, requests, 0, 11,
        )?;
        let mut rows = Vec::new();
        for policy in ["dvfo", "drldo", "appealnet", "cloud_only", "edge_only"] {
            let s = run_cell(
                policy, model, dataset, "xavier-nx", "static:5", 0.5, 0.5, requests,
                train_eps, 11,
            )?;
            rows.push(vec![
                model.to_string(),
                dataset.to_string(),
                policy.to_string(),
                format!("{:.1}", s.tti_ms.mean()),
                format!("{:.0}", s.eti_mj.mean()),
                format!("{:+.1}%", 100.0 * (s.tti_ms.mean() / edge.tti_ms.mean() - 1.0)),
                format!("{:+.1}%", 100.0 * (s.eti_mj.mean() / edge.eti_mj.mean() - 1.0)),
            ]);
        }
        Ok(rows)
    })?;
    for r in rows {
        t.row(r);
    }
    Ok(t)
}

// ======================================================================
// Fig. 9 — accuracy comparison across schemes
// ======================================================================
pub fn fig09_accuracy(requests: usize, train_eps: usize) -> Result<Table> {
    let mut t = Table::new(vec!["model", "dataset", "policy", "accuracy %", "loss pts"]);
    for model in ["efficientnet-b0", "vit-b16"] {
        for dataset in ["cifar100", "imagenet"] {
            for policy in ["edge_only", "dvfo", "drldo", "appealnet", "cloud_only"] {
                let s = run_cell(
                    policy, model, dataset, "xavier-nx", "static:5", 0.5, 0.5, requests,
                    train_eps, 13,
                )?;
                let base = find_model(model)?.base_acc(Dataset::parse(dataset)?);
                t.row(vec![
                    model.to_string(),
                    dataset.to_string(),
                    policy.to_string(),
                    format!("{:.2}", s.accuracy_pct.mean()),
                    format!("{:.2}", base - s.accuracy_pct.mean()),
                ]);
            }
        }
    }
    Ok(t)
}

// ======================================================================
// Fig. 10 — frequency trend across execution phases ① ② ③
// ======================================================================
pub fn fig10_freq_trend(train_eps: usize) -> Result<Table> {
    let mut t = Table::new(vec![
        "model", "dataset", "phase", "cpu MHz", "gpu MHz", "mem MHz",
    ]);
    for model in ["efficientnet-b0", "vit-b16"] {
        for dataset in ["cifar100", "imagenet"] {
            let s = run_cell(
                "dvfo", model, dataset, "xavier-nx", "static:5", 0.5, 0.5, 40, train_eps, 17,
            )?;
            // mean per-phase frequencies over served tasks
            let mut sums = [[0.0f64; 3]; 3];
            for r in &s.reports {
                for p in 0..3 {
                    for u in 0..3 {
                        sums[p][u] += r.phase_freqs[p][u] / s.reports.len() as f64;
                    }
                }
            }
            for (p, name) in ["(1) edge infer", "(2) offload+comp", "(3) cloud wait"]
                .iter()
                .enumerate()
            {
                t.row(vec![
                    model.to_string(),
                    dataset.to_string(),
                    name.to_string(),
                    format!("{:.0}", sums[p][0]),
                    format!("{:.0}", sums[p][1]),
                    format!("{:.0}", sums[p][2]),
                ]);
            }
        }
    }
    Ok(t)
}

// ======================================================================
// Fig. 11 — latency vs bandwidth (0.5–8 Mbps)
// ======================================================================
pub fn fig11_bandwidth(requests: usize, train_eps: usize, threads: usize) -> Result<Table> {
    let mut t = Table::new(vec!["dataset", "bandwidth Mbps", "policy", "tti ms"]);
    let mut cells = Vec::new();
    for dataset in ["cifar100", "imagenet"] {
        for bw in [0.5, 1.0, 2.0, 4.0, 5.0, 8.0] {
            for policy in ["dvfo", "drldo", "appealnet", "cloud_only"] {
                cells.push((dataset, bw, policy));
            }
        }
    }
    let rows = sweep_rows(threads, &cells, |&(dataset, bw, policy)| {
        let spec = format!("static:{bw}");
        let s = run_cell(
            policy, "efficientnet-b0", dataset, "xavier-nx", &spec, 0.5, 0.5, requests,
            train_eps, 19,
        )?;
        Ok(vec![vec![
            dataset.to_string(),
            format!("{bw}"),
            policy.to_string(),
            format!("{:.1}", s.tti_ms.mean()),
        ]])
    })?;
    for r in rows {
        t.row(r);
    }
    Ok(t)
}

// ======================================================================
// Fig. 12 — sensitivity to the summation weight λ
// ======================================================================
pub fn fig12_lambda(requests: usize, train_eps: usize, threads: usize) -> Result<Table> {
    let mut t = Table::new(vec!["dataset", "lambda", "accuracy %", "eti mJ"]);
    let mut cells = Vec::new();
    for dataset in ["cifar100", "imagenet"] {
        for lam in [0.0, 0.1, 0.2, 0.4, 0.5, 0.6, 0.8, 0.9, 1.0] {
            cells.push((dataset, lam));
        }
    }
    let rows = sweep_rows(threads, &cells, |&(dataset, lam)| {
        let s = run_cell(
            "dvfo", "efficientnet-b0", dataset, "xavier-nx", "static:5", 0.5, lam, requests,
            train_eps, 23,
        )?;
        Ok(vec![vec![
            dataset.to_string(),
            format!("{lam}"),
            format!("{:.2}", s.accuracy_pct.mean()),
            format!("{:.0}", s.eti_mj.mean()),
        ]])
    })?;
    for r in rows {
        t.row(r);
    }
    Ok(t)
}

// ======================================================================
// Fig. 13 — sensitivity to the cost weight η
// ======================================================================
pub fn fig13_eta(requests: usize, train_eps: usize, threads: usize) -> Result<Table> {
    let mut t = Table::new(vec!["dataset", "eta", "tti ms", "eti mJ"]);
    let mut cells = Vec::new();
    for dataset in ["cifar100", "imagenet"] {
        for eta in [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
            cells.push((dataset, eta));
        }
    }
    let rows = sweep_rows(threads, &cells, |&(dataset, eta)| {
        let s = run_cell(
            "dvfo", "efficientnet-b0", dataset, "xavier-nx", "static:5", eta, 0.5, requests,
            train_eps, 29,
        )?;
        Ok(vec![vec![
            dataset.to_string(),
            format!("{eta}"),
            format!("{:.1}", s.tti_ms.mean()),
            format!("{:.0}", s.eti_mj.mean()),
        ]])
    })?;
    for r in rows {
        t.row(r);
    }
    Ok(t)
}

// ======================================================================
// Table 4 — fusion methods: accuracy loss
// ======================================================================
pub fn tab04_fusion_accuracy() -> Result<Table> {
    use crate::accuracy::{accuracy_loss_pts, AccuracyInputs, Fusion};
    use crate::offload::Compression;
    let mut t = Table::new(vec![
        "fusion method", "cifar100 acc %", "(loss)", "imagenet acc %", "(loss)", "paper loss",
    ]);
    // single-device bases from Table 4
    let bases = [("cifar100", 91.84), ("imagenet", 74.52)];
    let rows: [(&str, Option<Fusion>, &str); 4] = [
        ("single-device (no fusion)", None, "0 / 0"),
        ("fully-connected NN layer", Some(Fusion::FcLayer), "4.45 / 3.89"),
        ("convolutional NN layer", Some(Fusion::ConvLayer), "8.91 / 6.28"),
        ("DVFO weighted summation", Some(Fusion::WeightedSum), "0.68 / 0.56"),
    ];
    for (name, fusion, paper) in rows {
        let mut cells = vec![name.to_string()];
        for (ds, base) in bases {
            let lam = if ds == "cifar100" { 0.5 } else { 0.6 }; // paper §6.6
            let loss = match fusion {
                None => 0.0,
                Some(f) => accuracy_loss_pts(&AccuracyInputs {
                    base_acc: base,
                    local_mass: 0.85,
                    xi: 0.6,
                    importance_guided: true,
                    compression: Compression::Int8,
                    fusion: f,
                    lambda: lam,
                }),
            };
            cells.push(format!("{:.2}", base - loss));
            cells.push(format!("({loss:.2})"));
        }
        cells.push(paper.to_string());
        t.row(cells);
    }
    Ok(t)
}

// ======================================================================
// Fig. 14 — fusion methods: runtime overhead (energy + latency)
// ======================================================================
pub fn fig14_fusion_overhead() -> Result<Table> {
    let mut t = Table::new(vec![
        "fusion method", "latency us", "energy uJ", "vs weighted-sum",
    ]);
    // fusion op cost model on the edge device: weighted sum is one fused
    // multiply-add over the logit vector; NN fusion layers run a matmul /
    // conv over concatenated logits.
    let spec = find_device("xavier-nx")?;
    let f = FreqVector {
        cpu_mhz: spec.cpu.max_mhz,
        gpu_mhz: spec.gpu.max_mhz,
        mem_mhz: spec.mem.max_mhz,
    };
    let classes = 1000.0_f64; // ImageNet-width logit vector
    let cases = [
        ("weighted summation (DVFO)", 2.0 * classes, 1.0),
        ("fully-connected layer", 2.0 * classes * classes, 2.2),
        ("convolutional layer", 2.0 * classes * 9.0 * 64.0, 3.1),
    ];
    let mut base_t = 0.0;
    let mut rows = Vec::new();
    for (i, (name, flops, dispatch_mult)) in cases.iter().enumerate() {
        // effective CPU-side fusion throughput + dispatch
        let thru = 8.0e9; // 8 GFLOP/s scalar+NEON path
        let time_s = flops / thru + 8e-6 * dispatch_mult;
        let power = crate::device::power_w(&spec, &f, &[0.6, 0.2, 0.3]);
        let energy = time_s * power;
        if i == 0 {
            base_t = time_s;
        }
        rows.push((name.to_string(), time_s, energy, time_s / base_t));
    }
    for (name, time_s, energy, rel) in rows {
        t.row(vec![
            name,
            format!("{:.1}", time_s * 1e6),
            format!("{:.1}", energy * 1e6),
            format!("{rel:.1}x"),
        ]);
    }
    Ok(t)
}

// ======================================================================
// Fig. 15 — DQN convergence with vs without thinking-while-moving
// ======================================================================
pub fn fig15_twm_convergence(episodes: usize) -> Result<Table> {
    let mut t = Table::new(vec![
        "dataset", "episode", "reward (TwM)", "reward (blocking)",
    ]);
    for dataset in ["cifar100", "imagenet"] {
        let curve = |concurrent: bool| -> Result<Vec<f64>> {
            let mut cfg = Config::default();
            cfg.model = "efficientnet-b0".into();
            cfg.dataset = dataset.into();
            cfg.concurrent = concurrent;
            cfg.seed = 31;
            let mut coord = Coordinator::from_config(&cfg)?;
            let mut gen =
                TaskGen::new(&cfg.model, coord.env.dataset, Arrivals::Sequential, 33)?;
            Ok(coord.train(&mut gen, episodes, 24))
        };
        let twm = curve(true)?;
        let blocking = curve(false)?;
        for (i, (a, b)) in twm.iter().zip(blocking.iter()).enumerate() {
            t.row(vec![
                dataset.to_string(),
                i.to_string(),
                format!("{a:.3}"),
                format!("{b:.3}"),
            ]);
        }
    }
    Ok(t)
}

// ======================================================================
// Fig. 16 — attention-module (SCAM) runtime energy vs baselines' aux
// modules
// ======================================================================
pub fn fig16_scam_overhead() -> Result<Table> {
    let mut t = Table::new(vec![
        "scheme", "aux module", "dataset", "energy mJ", "vs DVFO",
    ]);
    let spec = find_device("xavier-nx")?;
    let f = FreqVector {
        cpu_mhz: spec.cpu.max_mhz,
        gpu_mhz: spec.gpu.max_mhz,
        mem_mhz: spec.mem.max_mhz,
    };
    let power = crate::device::power_w(&spec, &f, &[0.5, 0.6, 0.5]);
    for dataset in [Dataset::Cifar100, Dataset::Imagenet] {
        // aux-module compute scaled by input size
        let scale = if dataset == Dataset::Cifar100 { 1.0 } else { 1.85 };
        // SCAM: two pooled reductions + tiny MLP + 3x3 conv ≈ 3 MFLOP
        let scam_t = 3.0e6 * scale / 2.0e9 + 2.0e-4;
        // AppealNet discriminator: a small CNN over the input ≈ 6 MFLOP
        // plus its own dispatch chain
        let appeal_t = 6.0e6 * scale / 2.0e9 + 1.5e-3;
        // DRLDO: conventional blocking DRL pipeline over raw input data
        let drldo_t = 6.5e-3 * scale;
        let rows = [
            ("dvfo", "SCAM", scam_t),
            ("appealnet", "hard-case discriminator", appeal_t),
            ("drldo", "blocking DRL inference", drldo_t),
        ];
        let base = scam_t * power;
        for (scheme, module, time_s) in rows {
            let e = time_s * power;
            t.row(vec![
                scheme.to_string(),
                module.to_string(),
                dataset.name().to_string(),
                format!("{:.2}", e * 1e3),
                format!("{:.1}x", e / base),
            ]);
        }
    }
    Ok(t)
}

// ======================================================================
// Tables 5 & 6 — scalability: 6 models × {Nano, TX2} × 3 schemes
// ======================================================================
pub fn tab_scalability(dataset: &str, requests: usize, train_eps: usize) -> Result<Table> {
    let mut t = Table::new(vec![
        "device", "model", "policy", "tti ms", "eti mJ", "acc loss pts",
    ]);
    let models = [
        "resnet-18",
        "inception-v4",
        "mobilenet-v2",
        "yolov3-tiny",
        "retinanet",
        "deepspeech",
    ];
    for device in ["jetson-nano", "jetson-tx2"] {
        let mut avgs: Vec<(String, f64, f64, f64)> = Vec::new();
        for policy in ["appealnet", "drldo", "dvfo"] {
            let mut tti = 0.0;
            let mut eti = 0.0;
            let mut loss = 0.0;
            for model in models {
                let s = run_cell(
                    policy, model, dataset, device, "static:5", 0.5, 0.5, requests, train_eps,
                    37,
                )?;
                let base = find_model(model)?.base_acc(Dataset::parse(dataset)?);
                t.row(vec![
                    device.to_string(),
                    model.to_string(),
                    policy.to_string(),
                    format!("{:.1}", s.tti_ms.mean()),
                    format!("{:.0}", s.eti_mj.mean()),
                    format!("{:.2}", base - s.accuracy_pct.mean()),
                ]);
                tti += s.tti_ms.mean() / models.len() as f64;
                eti += s.eti_mj.mean() / models.len() as f64;
                loss += (base - s.accuracy_pct.mean()) / models.len() as f64;
            }
            avgs.push((policy.to_string(), tti, eti, loss));
        }
        for (policy, tti, eti, loss) in avgs {
            t.row(vec![
                device.to_string(),
                "AVERAGE".to_string(),
                policy,
                format!("{tti:.1}"),
                format!("{eti:.0}"),
                format!("{loss:.2}"),
            ]);
        }
    }
    Ok(t)
}

// ======================================================================
// Load sweep — latency vs offered load through the discrete-event
// multi-stream serving core (p50/p95/p99 end-to-end latency, queue wait,
// uplink batch size, per-stream energy).
// ======================================================================
pub fn load_sweep(quick: bool, threads: usize) -> Result<Table> {
    use crate::coordinator::des::serve_multistream;
    use crate::coordinator::EngineConfig;
    let mut t = Table::new(vec![
        "streams",
        "offered req/s",
        "policy",
        "e2e p50 ms",
        "e2e p95 ms",
        "e2e p99 ms",
        "queue p95 ms",
        "mean batch",
        "per-stream mJ",
    ]);
    let streams_list: &[usize] = if quick { &[1, 8, 64] } else { &[1, 4, 16, 64, 128] };
    let per_stream = if quick { 10 } else { 40 };
    let rate = 2.0; // req/s offered per stream
    let mut cells = Vec::new();
    for &n in streams_list {
        for policy in ["edge_only", "dvfo"] {
            cells.push((n, policy));
        }
    }
    let rows = sweep_rows(threads, &cells, |&(n, policy)| {
        let mut cfg = Config::default();
        cfg.policy = policy.into();
        cfg.queue_aware = policy == "dvfo";
        cfg.seed = 61;
        let mut coord = Coordinator::from_config(&cfg)?;
        if policy == "dvfo" {
            let mut tgen = TaskGen::new(&cfg.model, coord.env.dataset, Arrivals::Sequential, 71)?;
            coord.train(&mut tgen, if quick { 4 } else { 20 }, 16);
        }
        let mut gens = (0..n)
            .map(|s| {
                TaskGen::new(
                    &cfg.model,
                    coord.env.dataset,
                    Arrivals::Poisson { rate },
                    100 + s as u64,
                )
            })
            .collect::<Result<Vec<_>>>()?;
        let opts = EngineConfig::new().batch_window_s(0.004).des_opts();
        let s = serve_multistream(&mut coord, &mut gens, per_stream, &opts);
        let offloaded: Vec<f64> = s
            .batch_size
            .values()
            .iter()
            .copied()
            .filter(|&b| b > 0.0)
            .collect();
        let mean_batch = if offloaded.is_empty() {
            0.0
        } else {
            offloaded.iter().sum::<f64>() / offloaded.len() as f64
        };
        let stream_mj =
            1e3 * s.per_stream_j.iter().sum::<f64>() / s.per_stream_j.len().max(1) as f64;
        let mut row = vec![
            n.to_string(),
            format!("{:.0}", rate * n as f64),
            policy.to_string(),
        ];
        row.extend(render::quantile_cells(&s.e2e_ms, &[50.0, 95.0, 99.0]));
        row.extend(render::quantile_cells(&s.queue_wait_ms, &[95.0]));
        row.push(format!("{mean_batch:.2}"));
        row.push(format!("{stream_mj:.0}"));
        Ok(vec![row])
    })?;
    for r in rows {
        t.row(r);
    }
    Ok(t)
}

// ======================================================================
// Fleet sweep — goodput/energy/violation curves vs offered load through
// the multi-edge dispatcher: a heterogeneous 3-device fleet (the paper's
// Table 3 edge boards) under energy-aware routing and a per-stream SLO,
// with admission control off / shed / downgrade at each load point.
// Runs with a non-zero cloud batch window so the cross-device batching
// path is exercised on every regeneration (and in the CI smoke run).
// ======================================================================
pub fn fleet_sweep(quick: bool, threads: usize) -> Result<Table> {
    use crate::coordinator::fleet::{serve_fleet, Admission, Fleet, Router};
    use crate::coordinator::EngineConfig;
    use crate::workload::SloClass;
    let mut t = Table::new(vec![
        "streams",
        "offered req/s",
        "admission",
        "offered",
        "completed",
        "shed",
        "goodput",
        "violations",
        "e2e p50 ms",
        "e2e p99 ms",
        "mJ/task",
    ]);
    let streams_list: &[usize] = if quick { &[6, 24] } else { &[6, 24, 96] };
    let per_stream = if quick { 8 } else { 30 };
    let rate = 4.0; // req/s offered per stream
    let mut cells = Vec::new();
    for &n in streams_list {
        for admission in ["off", "shed", "downgrade"] {
            cells.push((n, admission));
        }
    }
    let rows = sweep_rows(threads, &cells, |&(n, admission)| {
        let mut cfg = Config::default();
        cfg.policy = "edge_only".into();
        cfg.fleet = "xavier-nx,jetson-tx2,jetson-nano".into();
        cfg.router = "least_backlog".into();
        cfg.slo = "300".into();
        cfg.admission = admission.into();
        cfg.seed = 83;
        let mut fleet = Fleet::from_config(&cfg)?;
        let slo = SloClass::parse(&cfg.slo)?;
        let mut gens = (0..n)
            .map(|s| {
                Ok(TaskGen::new(
                    &cfg.model,
                    fleet.devices[0].env.dataset,
                    Arrivals::Poisson { rate },
                    7000 + s as u64,
                )?
                .with_slo(slo))
            })
            .collect::<Result<Vec<_>>>()?;
        let opts = EngineConfig::new()
            .batch_window_s(0.004)
            .cloud_batch_window_s(0.004)
            .router(Router::parse(&cfg.router)?)
            .admission(Admission::parse(admission)?)
            .fleet_opts();
        let s = serve_fleet(&mut fleet, &mut gens, per_stream, &opts);
        let mj_per_task = if s.completed > 0 {
            1e3 * s.per_device.iter().map(|d| d.energy_j).sum::<f64>() / s.completed as f64
        } else {
            0.0
        };
        let mut row = vec![
            n.to_string(),
            format!("{:.0}", rate * n as f64),
            admission.to_string(),
            s.offered.to_string(),
            s.completed.to_string(),
            s.shed.to_string(),
            s.goodput.to_string(),
            s.slo_violations.to_string(),
        ];
        row.extend(render::quantile_cells(&s.serve.e2e_ms, &[50.0, 99.0]));
        row.push(format!("{mj_per_task:.0}"));
        Ok(vec![row])
    })?;
    for r in rows {
        t.row(r);
    }
    Ok(t)
}

// ======================================================================
// Cloud-batch sweep — goodput and executor occupancy vs the cloud-side
// cross-device batching window: cloud-heavy traffic from a 2-device
// fleet into a tight shared executor pool, sweeping
// `cloud_batch_window_ms` from 0 (pre-batching behavior) upward. Emits
// invocation counts, batch occupancy, amortized dispatch time, total
// executor busy time (the server-side cost batching actually reduces),
// goodput/violations, and latency percentiles. Edge energy per task is
// included for context but is *window-invariant by design*: per-task
// physics are stamped at edge-service start, so cloud batching moves
// completion timing and executor occupancy, not edge energy.
// ======================================================================
pub fn cloudbatch_sweep(quick: bool, threads: usize) -> Result<Table> {
    use crate::coordinator::fleet::{serve_fleet, Fleet};
    use crate::coordinator::EngineConfig;
    use crate::workload::SloClass;
    let mut t = Table::new(vec![
        "cloud window ms",
        "invocations",
        "mean occupancy",
        "dispatch saved ms",
        "cloud busy ms",
        "completed",
        "goodput",
        "violations",
        "e2e p50 ms",
        "e2e p99 ms",
        "edge mJ/task",
    ]);
    let windows_ms: &[f64] = if quick {
        &[0.0, 5.0, 20.0]
    } else {
        &[0.0, 2.0, 5.0, 10.0, 20.0, 50.0]
    };
    let streams = if quick { 8 } else { 24 };
    let per_stream = if quick { 6 } else { 20 };
    let rows = sweep_rows(threads, windows_ms, |&window_ms| {
        let mut cfg = Config::default();
        cfg.policy = "cloud_only".into();
        cfg.fleet = "xavier-nx,jetson-nano".into();
        cfg.slo = "400".into();
        cfg.seed = 97;
        let mut fleet = Fleet::from_config(&cfg)?;
        let slo = SloClass::parse(&cfg.slo)?;
        let mut gens = (0..streams)
            .map(|s| {
                Ok(TaskGen::new(
                    &cfg.model,
                    fleet.devices[0].env.dataset,
                    Arrivals::Poisson { rate: 6.0 },
                    9000 + s as u64,
                )?
                .with_slo(slo))
            })
            .collect::<Result<Vec<_>>>()?;
        let opts = EngineConfig::new()
            .batch_window_s(0.004)
            .cloud_batch_window_s(window_ms / 1e3)
            .cloud_slots(2)
            .fleet_opts();
        let s = serve_fleet(&mut fleet, &mut gens, per_stream, &opts);
        let mj_per_task = if s.completed > 0 {
            1e3 * s.per_device.iter().map(|d| d.energy_j).sum::<f64>() / s.completed as f64
        } else {
            0.0
        };
        // total executor busy time = Σ solo cloud service − amortized
        // dispatch: the exact server-side work batching eliminates
        let cloud_busy_ms =
            s.serve.tti_cloud_ms.values().iter().sum::<f64>() - s.cloud_dispatch_saved_s * 1e3;
        let mut row = vec![
            format!("{window_ms}"),
            s.cloud_invocations.to_string(),
            format!("{:.2}", s.cloud_occupancy.mean()),
            format!("{:.1}", s.cloud_dispatch_saved_s * 1e3),
            format!("{cloud_busy_ms:.1}"),
            s.completed.to_string(),
            s.goodput.to_string(),
            s.slo_violations.to_string(),
        ];
        row.extend(render::quantile_cells(&s.serve.e2e_ms, &[50.0, 99.0]));
        row.push(format!("{mj_per_task:.0}"));
        Ok(vec![row])
    })?;
    for r in rows {
        t.row(r);
    }
    Ok(t)
}

// ======================================================================
// Rebalance sweep — goodput/shed/violation vs backlog skew under an
// imbalanced router: round-robin over increasingly heterogeneous fleets
// (the skew axis) sends one third of the traffic to each device
// regardless of speed, overloading the slow boards while the fast one
// has headroom. At each skew point the same offered load runs three
// ways: plain round-robin + shed admission, + re-route-before-shed,
// and + mid-run migration (work stealing) on top.
// ======================================================================
pub fn rebalance_sweep(quick: bool, threads: usize) -> Result<Table> {
    use crate::coordinator::fleet::{serve_fleet, Admission, Fleet};
    use crate::coordinator::EngineConfig;
    use crate::workload::SloClass;
    let mut t = Table::new(vec![
        "fleet",
        "mode",
        "offered",
        "completed",
        "shed",
        "goodput",
        "violations",
        "rerouted",
        "migrated",
        "e2e p50 ms",
        "e2e p99 ms",
    ]);
    let fleets: &[&str] = if quick {
        &["xavier-nx*3", "xavier-nx,jetson-nano*2"]
    } else {
        &["xavier-nx*3", "xavier-nx*2,jetson-nano", "xavier-nx,jetson-nano*2"]
    };
    let streams = if quick { 9 } else { 24 };
    let per_stream = if quick { 8 } else { 24 };
    let mut cells = Vec::new();
    for fleet_spec in fleets {
        for mode in ["rr", "rr+reroute", "rr+reroute+migrate"] {
            cells.push((*fleet_spec, mode));
        }
    }
    let rows = sweep_rows(threads, &cells, |&(fleet_spec, mode)| {
        let mut cfg = Config::default();
        cfg.policy = "edge_only".into();
        cfg.fleet = fleet_spec.into();
        cfg.slo = "250".into();
        cfg.seed = 131;
        let mut fleet = Fleet::from_config(&cfg)?;
        let slo = SloClass::parse(&cfg.slo)?;
        let mut gens = (0..streams)
            .map(|s| {
                Ok(TaskGen::new(
                    &cfg.model,
                    fleet.devices[0].env.dataset,
                    Arrivals::Poisson { rate: 10.0 },
                    11_000 + s as u64,
                )?
                .with_slo(slo))
            })
            .collect::<Result<Vec<_>>>()?;
        let opts = EngineConfig::new()
            .admission(Admission::Shed)
            .reroute(mode != "rr")
            .rebalance_window_s(if mode == "rr+reroute+migrate" { 0.01 } else { 0.0 })
            .migrate_threshold_s(0.05)
            .migrate_penalty_s(0.002)
            .fleet_opts();
        let s = serve_fleet(&mut fleet, &mut gens, per_stream, &opts);
        let mut row = vec![
            fleet_spec.to_string(),
            mode.to_string(),
            s.offered.to_string(),
            s.completed.to_string(),
            s.shed.to_string(),
            s.goodput.to_string(),
            s.slo_violations.to_string(),
            s.rerouted.to_string(),
            s.migrated.to_string(),
        ];
        row.extend(render::quantile_cells(&s.serve.e2e_ms, &[50.0, 99.0]));
        Ok(vec![row])
    })?;
    for r in rows {
        t.row(r);
    }
    Ok(t)
}

// ======================================================================
// Chaos sweep — goodput/violations/failed vs fault intensity on a
// skewed fleet, with and without re-route + migration. Every task
// offloads (cloud_only), so a device dropout kills uplink-stage work
// mid-flight: the rr-alone column can only retry into the same downed
// device until the budget runs out, while the reroute+migrate column
// drains queues and ships retries through siblings. The fault schedule
// is deterministic, so the two modes see the *identical* outage.
// ======================================================================
pub fn chaos_sweep(quick: bool, threads: usize) -> Result<Table> {
    use crate::coordinator::chaos::FaultSchedule;
    use crate::coordinator::fleet::{serve_fleet, Admission, Fleet};
    use crate::coordinator::EngineConfig;
    use crate::workload::SloClass;
    let mut t = Table::new(vec![
        "chaos",
        "mode",
        "offered",
        "completed",
        "shed",
        "failed",
        "goodput",
        "violations",
        "rerouted",
        "retries",
        "faults",
        "e2e p50 ms",
        "e2e p99 ms",
    ]);
    let schedules: &[(&str, &str)] = if quick {
        &[
            ("none", ""),
            ("dropout", "down:1@200+900"),
            (
                "storm",
                "down:1@150+900; down:2@500+900; cloud@400+120; bw:0@250+500*0.25",
            ),
        ]
    } else {
        &[
            ("none", ""),
            ("bw-collapse", "bw:1@200+800*0.1; bw:2@400+800*0.1"),
            ("dropout", "down:1@200+900"),
            ("double-dropout", "down:1@150+900; down:2@500+900"),
            (
                "storm",
                "down:1@150+900; down:2@500+900; cloud@400+120; bw:0@250+500*0.25",
            ),
        ]
    };
    let streams = if quick { 9 } else { 24 };
    let per_stream = if quick { 8 } else { 24 };
    let mut cells = Vec::new();
    for (label, spec) in schedules {
        for mode in ["rr", "rr+reroute+migrate"] {
            cells.push((*label, *spec, mode));
        }
    }
    let rows = sweep_rows(threads, &cells, |&(label, spec, mode)| {
        let mut cfg = Config::default();
        cfg.policy = "cloud_only".into();
        cfg.fleet = "xavier-nx,jetson-nano*2".into();
        cfg.slo = "400".into();
        cfg.seed = 173;
        let mut fleet = Fleet::from_config(&cfg)?;
        let slo = SloClass::parse(&cfg.slo)?;
        let mut gens = (0..streams)
            .map(|s| {
                Ok(TaskGen::new(
                    &cfg.model,
                    fleet.devices[0].env.dataset,
                    Arrivals::Poisson { rate: 10.0 },
                    17_000 + s as u64,
                )?
                .with_slo(slo))
            })
            .collect::<Result<Vec<_>>>()?;
        let opts = EngineConfig::new()
            .admission(Admission::Shed)
            .reroute(mode != "rr")
            .rebalance_window_s(if mode == "rr" { 0.0 } else { 0.01 })
            .migrate_threshold_s(0.05)
            .migrate_penalty_s(0.002)
            .chaos(FaultSchedule::parse(spec)?)
            .fleet_opts();
        let s = serve_fleet(&mut fleet, &mut gens, per_stream, &opts);
        let mut row = vec![
            label.to_string(),
            mode.to_string(),
            s.offered.to_string(),
            s.completed.to_string(),
            s.shed.to_string(),
            s.failed.to_string(),
            s.goodput.to_string(),
            s.slo_violations.to_string(),
            s.rerouted.to_string(),
            s.retries.to_string(),
            s.faults_injected.to_string(),
        ];
        row.extend(render::quantile_cells(&s.serve.e2e_ms, &[50.0, 99.0]));
        Ok(vec![row])
    })?;
    for r in rows {
        t.row(r);
    }
    Ok(t)
}

/// Ablation (DESIGN.md §7): factored vs exact-joint argmax and oracle gap.
pub fn ablation_action_space(requests: usize) -> Result<Table> {
    let mut t = Table::new(vec!["policy", "cost mean", "tti ms", "eti mJ"]);
    for policy in ["dvfo", "oracle", "edge_only"] {
        let mut cfg = Config::default();
        cfg.policy = policy.into();
        cfg.freq_levels = 5;
        cfg.xi_levels = 5;
        cfg.requests = requests;
        let mut coord = Coordinator::from_config(&cfg)?;
        let mut gen = TaskGen::new(&cfg.model, coord.env.dataset, Arrivals::Sequential, 41)?;
        if policy == "dvfo" {
            coord.train(&mut gen, 40, 24);
        }
        let tasks = gen.take(requests);
        let s = coord.serve(&tasks);
        t.row(vec![
            policy.to_string(),
            format!("{:.4}", s.cost.mean()),
            format!("{:.1}", s.tti_ms.mean()),
            format!("{:.0}", s.eti_mj.mean()),
        ]);
    }
    Ok(t)
}

/// Registry for the CLI and benches. `threads` fans the grid sweeps
/// (fig08/11/12/13, load/fleet/cloudbatch/rebalance) out over the
/// scoped-thread runner; 1 is the serial harness, and any N renders the
/// same bytes (gated by `rust/tests/sweep_determinism.rs`).
pub fn run_by_name(name: &str, quick: bool, threads: usize) -> Result<Table> {
    let (req, eps) = if quick { (40, 30) } else { (150, 60) };
    match name {
        "fig01" => fig01_energy_breakdown(),
        "fig02" => fig02_freq_sweep(),
        "fig07" => fig07_importance(),
        "fig08" => fig08_main_comparison(req, eps, threads),
        "fig09" => fig09_accuracy(req, eps),
        "fig10" => fig10_freq_trend(eps),
        "fig11" => fig11_bandwidth(req.min(80), eps, threads),
        "fig12" => fig12_lambda(req.min(60), eps, threads),
        "fig13" => fig13_eta(req.min(60), eps, threads),
        "tab04" => tab04_fusion_accuracy(),
        "fig14" => fig14_fusion_overhead(),
        "fig15" => fig15_twm_convergence(if quick { 15 } else { 40 }),
        "fig16" => fig16_scam_overhead(),
        "tab05" => tab_scalability("cifar100", req.min(60), eps),
        "tab06" => tab_scalability("imagenet", req.min(60), eps),
        "ablation" => ablation_action_space(req.min(40)),
        "load" => load_sweep(quick, threads),
        "fleet" => fleet_sweep(quick, threads),
        "cloudbatch" => cloudbatch_sweep(quick, threads),
        "rebalance" => rebalance_sweep(quick, threads),
        "chaos" => chaos_sweep(quick, threads),
        other => anyhow::bail!("unknown experiment `{other}`"),
    }
}

pub const ALL: &[&str] = &[
    "fig01", "fig02", "fig07", "fig08", "fig09", "fig10", "fig11", "fig12", "fig13",
    "tab04", "fig14", "fig15", "fig16", "tab05", "tab06", "ablation", "load", "fleet",
    "cloudbatch", "rebalance", "chaos",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig01_rows_and_band() {
        let t = fig01_energy_breakdown().unwrap();
        let s = t.render();
        assert!(s.contains("vit-b16") && s.contains("efficientnet-b0"));
    }

    #[test]
    fn tab04_orders_fusion_methods() {
        let t = tab04_fusion_accuracy().unwrap();
        let csv = t.to_csv();
        // weighted summation row must show sub-1pt loss on both datasets
        let row = csv
            .lines()
            .find(|l| l.contains("weighted summation"))
            .unwrap();
        assert!(row.contains("(0."), "row: {row}");
    }

    #[test]
    fn fig16_dvfo_cheapest() {
        let t = fig16_scam_overhead().unwrap();
        let csv = t.to_csv();
        let dvfo_line = csv.lines().find(|l| l.starts_with("dvfo")).unwrap();
        assert!(dvfo_line.contains("1.0x"));
    }

    #[test]
    fn load_sweep_emits_latency_percentiles() {
        let t = load_sweep(true, 1).unwrap();
        let csv = t.to_csv();
        assert!(csv.lines().next().unwrap().contains("e2e p95 ms"));
        // one row per (streams, policy) cell
        assert_eq!(csv.lines().count(), 1 + 3 * 2);
        assert!(csv.contains("\n64,"), "64-stream cell present:\n{csv}");
    }

    #[test]
    fn fleet_sweep_emits_goodput_columns() {
        let t = fleet_sweep(true, 1).unwrap();
        let csv = t.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.contains("goodput") && header.contains("violations"));
        // one row per (streams, admission) cell
        assert_eq!(csv.lines().count(), 1 + 2 * 3);
        assert!(csv.contains(",shed,"), "admission=shed cell present:\n{csv}");
    }

    #[test]
    fn cloudbatch_sweep_emits_occupancy_columns() {
        let t = cloudbatch_sweep(true, 1).unwrap();
        let csv = t.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.contains("mean occupancy") && header.contains("dispatch saved ms"));
        assert!(header.contains("cloud busy ms"));
        // one row per window point
        assert_eq!(csv.lines().count(), 1 + 3);
        // the window-0 row is the pre-batching baseline: all singleton
        // invocations, nothing amortized
        let zero = csv.lines().nth(1).unwrap();
        let cells: Vec<&str> = zero.split(',').collect();
        assert_eq!(cells[0], "0");
        assert_eq!(cells[2], "1.00", "window 0 must be all singletons: {zero}");
        assert_eq!(cells[3], "0.0", "window 0 amortizes nothing: {zero}");
    }

    #[test]
    fn rebalance_sweep_emits_rebalancing_columns() {
        let t = rebalance_sweep(true, 1).unwrap();
        let csv = t.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.contains("rerouted") && header.contains("migrated"));
        // one row per (fleet, mode) cell
        assert_eq!(csv.lines().count(), 1 + 2 * 3);
        assert!(
            csv.contains(",rr+reroute+migrate,"),
            "migration cell present:\n{csv}"
        );
    }

    #[test]
    fn chaos_sweep_emits_fault_columns_and_conserves() {
        let t = chaos_sweep(true, 1).unwrap();
        let csv = t.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.contains("failed") && header.contains("faults"));
        // one row per (schedule, mode) cell
        assert_eq!(csv.lines().count(), 1 + 3 * 2);
        for line in csv.lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            let (offered, completed, shed, failed): (usize, usize, usize, usize) = (
                cells[2].parse().unwrap(),
                cells[3].parse().unwrap(),
                cells[4].parse().unwrap(),
                cells[5].parse().unwrap(),
            );
            assert_eq!(offered, completed + shed + failed, "conservation: {line}");
            // the fault-free row injects nothing and fails nothing
            if cells[0] == "none" {
                assert_eq!(cells[10], "0", "no faults without a schedule: {line}");
                assert_eq!(failed, 0, "no failures without faults: {line}");
            }
        }
    }

    #[test]
    fn quick_cells_run() {
        let s = run_cell(
            "dvfo",
            "efficientnet-b0",
            "cifar100",
            "xavier-nx",
            "static:5",
            0.5,
            0.5,
            10,
            2,
            1,
        )
        .unwrap();
        assert_eq!(s.count(), 10);
    }
}
